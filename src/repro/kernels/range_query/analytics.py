"""Pallas TPU kernels: analytics leaf-scan variants (count / collect /
polygon) over the hierarchically-pruned candidate tiles.

The boolean ``descent_scan`` kernel answers "any entry in the rect?".
The geosocial analytics classes (:mod:`repro.queries`) need richer leaf
scans over the *same* compacted candidate lists phase 1 produces:

* **count** (``count_scan_pallas``) — per-query exact hit count.  The
  boolean scan tolerates duplicate candidate tiles (idempotent OR); a
  sum does not, so padding slots are masked structurally: active
  candidates are strictly ascending and padding repeats the last active
  tile, hence a non-increasing step (``cand[i,k] <= cand[i,k-1]``) is
  padding and contributes zero.

* **collect** (``collect_scan_pallas``) — per-(query, candidate-lane)
  payload id or ``ID_SENTINEL``.  The scan writes the id plane masked
  by the exact hit test (and the same duplicate-tile mask), producing a
  ``(B, K*TP)`` matrix whose non-sentinel entries are exactly the hit
  ids; a fused XLA sort then yields the K smallest ids per query (the
  canonical collect order) with the sentinel sorting last.

* **polygon** (``polygon_scan_pallas``) — boolean RangeReach with a
  convex-polygon region: the query rect is the polygon's bbox and each
  query carries ``NE`` half-planes ``A*x + B*y <= C`` (float32, inert
  padding ``A=B=0, C=+inf``) evaluated against the entry point inside
  the leaf test — the postfilter pushed into the scan.  Entries must be
  points (2DReach's degenerate boxes); the float32 mul/add/compare
  sequence mirrors ``core.polygon.points_in_polygon_region`` op for op,
  which is what makes host and device bit-identical.

Every kernel has a dense jnp reference (``*_ref``) scanning the whole
arena — the exactness oracle for unit tests and a fused XLA fallback.
All run under ``interpret=True`` on CPU; on TPU the same calls compile
to real kernels.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .kernel import TB, TP

# payload-id sentinel for collect padding/misses: sorts after every real
# vertex id and survives the int32 round trip
ID_SENTINEL = np.int32(np.iinfo(np.int32).max)


def _hit_mask(e, q, qs, qe, tile, *, dim: int, tp: int):
    """(TB, TP) exact per-entry test shared by the scan variants:
    arena-slice membership AND box intersection."""
    gidx = tile * tp + jax.lax.broadcasted_iota(jnp.int32, (1, tp), 1)
    ok = (gidx >= qs) & (gidx < qe)
    for a in range(dim):
        ok = ok & (e[a][None, :] <= q[dim + a][:, None])
        ok = ok & (e[dim + a][None, :] >= q[a][:, None])
    return ok


def _dup_slot(cand_ref, i, k):
    """True iff candidate slot k of query tile i is padding: actives are
    strictly ascending, padding repeats the last active tile."""
    prev = cand_ref[i, jnp.maximum(k - 1, 0)]
    return (k > 0) & (cand_ref[i, k] <= prev)


# --------------------------------------------------------------------------
# Count
# --------------------------------------------------------------------------

def _count_kernel(cand_ref, e_ref, q_ref, qs_ref, qe_ref, o_ref, *,
                  dim: int, tp: int):
    i, k = pl.program_id(0), pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ok = _hit_mask(e_ref[...], q_ref[...], qs_ref[...][:, None],
                   qe_ref[...][:, None], cand_ref[i, k], dim=dim, tp=tp)
    cnt = jnp.sum(ok, axis=1).astype(jnp.int32)
    o_ref[...] = o_ref[...] + jnp.where(_dup_slot(cand_ref, i, k), 0, cnt)


@functools.partial(jax.jit, static_argnames=("dim", "interpret", "tb", "tp"))
def count_scan_pallas(
    cand: jax.Array,          # (B // tb, K) int32 candidate leaf tiles
    entries_soa: jax.Array,   # (2*dim, P) float32, P % tp == 0
    rects_soa: jax.Array,     # (2*dim, B) float32, B % tb == 0
    qstart: jax.Array,        # (B,) int32
    qend: jax.Array,          # (B,) int32
    *,
    dim: int = 2,
    interpret: bool = False,
    tb: int = TB,
    tp: int = TP,
) -> jax.Array:
    """(B,) int32 exact hit counts over the K candidate tiles.

    ``cand`` must be a ``compact_candidates`` list (actives strictly
    ascending, then the last active repeated) covering every tile with a
    possible hit — the prune phase guarantees the superset, the exact
    leaf test makes the count independent of superfluous tiles.
    """
    two_dim, P = entries_soa.shape
    _, B = rects_soa.shape
    assert two_dim == 2 * dim
    assert P % tp == 0 and B % tb == 0, (P, B)
    nb = B // tb
    K = cand.shape[1]
    assert cand.shape == (nb, K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, K),
        in_specs=[
            pl.BlockSpec((two_dim, tp), lambda i, k, cand: (0, cand[i, k])),
            pl.BlockSpec((two_dim, tb), lambda i, k, cand: (0, i)),
            pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
            pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
    )
    return pl.pallas_call(
        functools.partial(_count_kernel, dim=dim, tp=tp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(cand, entries_soa, rects_soa, qstart, qend)


def count_scan_ref(entries_soa, rects_soa, qstart, qend, *, dim: int = 2,
                   tp: int = TP):
    """Dense jnp oracle: exact counts scanning the whole arena."""
    P = entries_soa.shape[1]
    gidx = jnp.arange(P, dtype=jnp.int32)[None, :]
    ok = (gidx >= qstart[:, None]) & (gidx < qend[:, None])
    for a in range(dim):
        ok = ok & (entries_soa[a][None, :] <= rects_soa[dim + a][:, None])
        ok = ok & (entries_soa[dim + a][None, :] >= rects_soa[a][:, None])
    return jnp.sum(ok, axis=1).astype(jnp.int32)


# --------------------------------------------------------------------------
# Collect
# --------------------------------------------------------------------------

def _collect_kernel(cand_ref, e_ref, ids_ref, q_ref, qs_ref, qe_ref, o_ref,
                    *, dim: int, tp: int):
    i, k = pl.program_id(0), pl.program_id(1)
    ok = _hit_mask(e_ref[...], q_ref[...], qs_ref[...][:, None],
                   qe_ref[...][:, None], cand_ref[i, k], dim=dim, tp=tp)
    ok = ok & ~_dup_slot(cand_ref, i, k)
    ids = ids_ref[...]                       # (1, tp) payload ids
    o_ref[...] = jnp.where(ok, ids, ID_SENTINEL)


@functools.partial(jax.jit, static_argnames=("dim", "interpret", "tb", "tp"))
def collect_scan_pallas(
    cand: jax.Array,          # (B // tb, K) int32 candidate leaf tiles
    entries_soa: jax.Array,   # (2*dim, P) float32, P % tp == 0
    ids_soa: jax.Array,       # (1, P) int32 payload ids (sentinel padding)
    rects_soa: jax.Array,     # (2*dim, B) float32, B % tb == 0
    qstart: jax.Array,        # (B,) int32
    qend: jax.Array,          # (B,) int32
    *,
    dim: int = 2,
    interpret: bool = False,
    tb: int = TB,
    tp: int = TP,
) -> jax.Array:
    """(B, K*tp) int32 — the hit payload ids of each query (every other
    slot ``ID_SENTINEL``).  Sort rows and keep the prefix for the K
    smallest ids; count non-sentinels for the exact total."""
    two_dim, P = entries_soa.shape
    _, B = rects_soa.shape
    assert two_dim == 2 * dim
    assert P % tp == 0 and B % tb == 0, (P, B)
    assert ids_soa.shape == (1, P)
    nb = B // tb
    K = cand.shape[1]
    assert cand.shape == (nb, K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, K),
        in_specs=[
            pl.BlockSpec((two_dim, tp), lambda i, k, cand: (0, cand[i, k])),
            pl.BlockSpec((1, tp), lambda i, k, cand: (0, cand[i, k])),
            pl.BlockSpec((two_dim, tb), lambda i, k, cand: (0, i)),
            pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
            pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
        ],
        out_specs=pl.BlockSpec((tb, tp), lambda i, k, cand: (i, k)),
    )
    return pl.pallas_call(
        functools.partial(_collect_kernel, dim=dim, tp=tp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K * tp), jnp.int32),
        interpret=interpret,
    )(cand, entries_soa, ids_soa, rects_soa, qstart, qend)


def collect_scan_ref(entries_soa, ids_soa, rects_soa, qstart, qend, *,
                     dim: int = 2, tp: int = TP):
    """Dense jnp oracle: (B, P) ids-or-sentinel over the whole arena."""
    P = entries_soa.shape[1]
    gidx = jnp.arange(P, dtype=jnp.int32)[None, :]
    ok = (gidx >= qstart[:, None]) & (gidx < qend[:, None])
    for a in range(dim):
        ok = ok & (entries_soa[a][None, :] <= rects_soa[dim + a][:, None])
        ok = ok & (entries_soa[dim + a][None, :] >= rects_soa[a][:, None])
    return jnp.where(ok, ids_soa[0][None, :], ID_SENTINEL)


# --------------------------------------------------------------------------
# Polygon (half-plane postfilter in the leaf scan)
# --------------------------------------------------------------------------

def _polygon_kernel(cand_ref, e_ref, q_ref, l_ref, qs_ref, qe_ref, o_ref, *,
                    dim: int, tp: int, ne: int):
    i, k = pl.program_id(0), pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    e = e_ref[...]
    ok = _hit_mask(e, q_ref[...], qs_ref[...][:, None],
                   qe_ref[...][:, None], cand_ref[i, k], dim=dim, tp=tp)
    # half-plane postfilter on the entry point (entries are degenerate
    # point boxes, so the min plane is the coordinate); same f32
    # mul/add/compare sequence as points_in_polygon_region
    x = e[0][None, :]
    y = e[1][None, :]
    lines = l_ref[...]                       # (3*ne, TB)
    for hp in range(ne):
        A = lines[hp][:, None]
        Bc = lines[ne + hp][:, None]
        C = lines[2 * ne + hp][:, None]
        ok = ok & ((A * x + Bc * y) <= C)
    o_ref[...] = o_ref[...] | jnp.any(ok, axis=1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("dim", "interpret", "tb", "tp", "ne")
)
def polygon_scan_pallas(
    cand: jax.Array,          # (B // tb, K) int32 candidate leaf tiles
    entries_soa: jax.Array,   # (2*dim, P) float32, P % tp == 0
    rects_soa: jax.Array,     # (2*dim, B) float32 polygon bboxes
    lines_soa: jax.Array,     # (3*ne, B) float32 half-planes [A.., B.., C..]
    qstart: jax.Array,        # (B,) int32
    qend: jax.Array,          # (B,) int32
    *,
    ne: int,
    dim: int = 2,
    interpret: bool = False,
    tb: int = TB,
    tp: int = TP,
) -> jax.Array:
    """(B,) int32 0/1 — any entry point inside bbox AND all ``ne``
    half-planes (the convex-polygon region).  OR over candidate tiles is
    idempotent, so duplicate padding tiles need no masking."""
    two_dim, P = entries_soa.shape
    _, B = rects_soa.shape
    assert two_dim == 2 * dim == 4, "polygon regions are 2-D point queries"
    assert P % tp == 0 and B % tb == 0, (P, B)
    assert lines_soa.shape == (3 * ne, B)
    nb = B // tb
    K = cand.shape[1]
    assert cand.shape == (nb, K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, K),
        in_specs=[
            pl.BlockSpec((two_dim, tp), lambda i, k, cand: (0, cand[i, k])),
            pl.BlockSpec((two_dim, tb), lambda i, k, cand: (0, i)),
            pl.BlockSpec((3 * ne, tb), lambda i, k, cand: (0, i)),
            pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
            pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
    )
    return pl.pallas_call(
        functools.partial(_polygon_kernel, dim=dim, tp=tp, ne=ne),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(cand, entries_soa, rects_soa, lines_soa, qstart, qend)


def polygon_scan_ref(entries_soa, rects_soa, lines_soa, qstart, qend, *,
                     ne: int, dim: int = 2):
    """Dense jnp oracle for ``polygon_scan_pallas`` (same contract)."""
    P = entries_soa.shape[1]
    gidx = jnp.arange(P, dtype=jnp.int32)[None, :]
    ok = (gidx >= qstart[:, None]) & (gidx < qend[:, None])
    for a in range(dim):
        ok = ok & (entries_soa[a][None, :] <= rects_soa[dim + a][:, None])
        ok = ok & (entries_soa[dim + a][None, :] >= rects_soa[a][:, None])
    x = entries_soa[0][None, :]
    y = entries_soa[1][None, :]
    for hp in range(ne):
        A = lines_soa[hp][:, None]
        Bc = lines_soa[ne + hp][:, None]
        C = lines_soa[2 * ne + hp][:, None]
        ok = ok & ((A * x + Bc * y) <= C)
    return jnp.any(ok, axis=1).astype(jnp.int32)
