"""schnet [gnn]: 3 interactions d_hidden=64 rbf=300 cutoff=10 —
continuous-filter convolutions. [arXiv:1706.08566]"""
from ..models.gnn import schnet as module
from ..models.gnn.schnet import SchNetConfig
from .base import ArchSpec, gnn_cells

NAME = "schnet"


def make_config(reduced: bool = False, d_feat=None, shape=None
                ) -> SchNetConfig:
    if reduced:
        return SchNetConfig(n_interactions=2, d_hidden=32, n_rbf=30)
    return SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300,
                        cutoff=10.0, d_feat=d_feat)


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="gnn", make_config=make_config,
        cells=gnn_cells(NAME, module, make_config),
    )
