"""DIN — Deep Interest Network (Zhou et al., 2017).

Assigned config: embed_dim=18, seq_len=100, attention MLP 80-40, output
MLP 200-80, target attention interaction.  The hot path is the sparse
embedding lookup over large item/category tables — JAX has no
EmbeddingBag, so the history pooling runs on the ``segment_bag``
substrate (kernels/segment_bag; jnp ref path for the sharded tables).

Serving shapes: ``serve_p99`` / ``serve_bulk`` batch scoring, and
``retrieval_cand`` which scores ONE user's history against 10^6 candidate
items as a single batched einsum (no per-candidate loop) — see
``score_candidates``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..nn import ACT, Params, dense, dense_init, embed_init, mlp, mlp_init


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 1_000_000
    n_cates: int = 1_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_hidden: Tuple[int, ...] = (80, 40)
    mlp_hidden: Tuple[int, ...] = (200, 80)


def init_params(key, cfg: DINConfig) -> Params:
    ks = jax.random.split(key, 5)
    d = cfg.embed_dim
    de = 2 * d  # item + cate concatenated
    return {
        "item_emb": embed_init(ks[0], cfg.n_items, d),
        "cate_emb": embed_init(ks[1], cfg.n_cates, d),
        # attention MLP input: [h, t, h - t, h * t]
        "attn": mlp_init(ks[2], (4 * de,) + cfg.attn_hidden + (1,)),
        # final MLP input: [pooled, target, pooled * target]
        "mlp": mlp_init(ks[3], (3 * de,) + cfg.mlp_hidden + (1,)),
    }


def _embed_items(params: Params, items: jnp.ndarray, cfg: DINConfig):
    """(..., ) item ids -> (..., 2*embed_dim) item||category embedding."""
    cates = items % cfg.n_cates
    ie = jnp.take(params["item_emb"]["emb"], items, axis=0)
    ce = jnp.take(params["cate_emb"]["emb"], cates, axis=0)
    return jnp.concatenate([ie, ce], axis=-1)


def target_attention(params, hist_e, target_e, hist_mask):
    """DIN's local activation unit.

    hist_e (B, S, de), target_e (B, de) -> pooled (B, de)."""
    B, S, de = hist_e.shape
    t = jnp.broadcast_to(target_e[:, None, :], (B, S, de))
    feats = jnp.concatenate([hist_e, t, hist_e - t, hist_e * t], axis=-1)
    logits = mlp(params["attn"], feats, act="sigmoid")[..., 0]  # (B, S)
    logits = jnp.where(hist_mask, logits, -1e30)
    # DIN uses un-normalised activation weights (no softmax) per the paper;
    # we keep softmax off but zero masked entries
    w = jnp.where(hist_mask, jax.nn.sigmoid(logits), 0.0)
    return jnp.einsum("bs,bsd->bd", w, hist_e)


def apply(params: Params, batch: Dict, cfg: DINConfig) -> jnp.ndarray:
    """Returns click logits (B,)."""
    hist_e = _embed_items(params, batch["hist_items"], cfg)     # (B, S, de)
    target_e = _embed_items(params, batch["target_item"], cfg)  # (B, de)
    pooled = target_attention(params, hist_e, target_e, batch["hist_mask"])
    feats = jnp.concatenate([pooled, target_e, pooled * target_e], -1)
    return mlp(params["mlp"], feats, act="sigmoid")[..., 0]


def loss_fn(params: Params, batch: Dict, cfg: DINConfig) -> jnp.ndarray:
    logits = apply(params, batch, cfg)
    y = batch["label"]
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def score_candidates(
    params: Params, batch: Dict, cfg: DINConfig, chunk: int = 8192
) -> jnp.ndarray:
    """retrieval_cand: one user, (C,) candidate items -> (C,) scores.

    The target-attention features depend on the candidate, so the exact
    DIN score is O(C*S); candidates are processed as (C/chunk) batched
    einsums via lax.map — no per-candidate loop, and the (chunk, S, 4*de)
    feature tensor (not the (C, S, 4*de) one) bounds memory."""
    cand = batch["candidates"]                                   # (C,)
    hist = batch["hist_items"]                                   # (S,)
    mask = batch["hist_mask"]                                    # (S,)
    hist_e = _embed_items(params, hist, cfg)                     # (S, de)
    S, de = hist_e.shape
    C = cand.shape[0]
    chunk = min(chunk, C)
    assert C % chunk == 0, (C, chunk)

    def score_chunk(cand_c):
        cand_e = _embed_items(params, cand_c, cfg)               # (c, de)
        c = cand_e.shape[0]
        h = jnp.broadcast_to(hist_e[None], (c, S, de))
        t = jnp.broadcast_to(cand_e[:, None], (c, S, de))
        feats = jnp.concatenate([h, t, h - t, h * t], axis=-1)
        logits = mlp(params["attn"], feats, act="sigmoid")[..., 0]
        w = jnp.where(mask[None], jax.nn.sigmoid(logits), 0.0)
        pooled = jnp.einsum("cs,sd->cd", w, hist_e)
        f2 = jnp.concatenate([pooled, cand_e, pooled * cand_e], -1)
        return mlp(params["mlp"], f2, act="sigmoid")[..., 0]

    out = jax.lax.map(score_chunk, cand.reshape(C // chunk, chunk))
    return out.reshape(C)
