"""Host (NumPy) implementations of the analytics query classes.

Each function routes exactly like the boolean host path — the Alg. 2
spatial-sink special case answers from the query vertex's own point,
everything else resolves a tree id and runs the matching
:mod:`repro.core.rtree` descent — and returns the *canonical* answer
the device engine reproduces bit for bit:

* counts are exact int64 totals;
* collects are the K smallest venue ids ascending (+ exact totals and
  overflow flags);
* polygon regions use the canonical float32 bbox + half-plane predicate
  of :mod:`repro.core.polygon`.

kNN lives in :mod:`repro.queries.knn` (host best-first descent + the
device radius-doubling driver).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from ..core.polygon import (
    convex_halfplanes,
    points_in_polygon_region,
    polygon_bbox,
)
from ..core.rtree import query_host_collect_batch, query_host_count
from ..core.two_d_reach import TwoDReachIndex
from .program import CollectResult


def _point_in_rect(pts: np.ndarray, rects: np.ndarray) -> np.ndarray:
    """(B, 2) points vs (B, 4) rects, the Alg. 2 float32 compares."""
    return (
        (pts[:, 0] >= rects[:, 0]) & (pts[:, 0] <= rects[:, 2])
        & (pts[:, 1] >= rects[:, 1]) & (pts[:, 1] <= rects[:, 3])
    )


def _route(index: TwoDReachIndex, us: np.ndarray
           ) -> Tuple[np.ndarray, np.ndarray]:
    """(excluded mask, tree ids) — tree id is -1 for excluded vertices
    and for components with no reachable venues."""
    exc = index.excluded[us]
    tid = np.full(len(us), -1, dtype=np.int64)
    if (~exc).any():
        tid[~exc] = index.lookup_tree(us[~exc])
    return exc, tid


def range_count_host(index: TwoDReachIndex, us: np.ndarray,
                     rects: np.ndarray) -> np.ndarray:
    """(B,) int64 — exact number of venues reachable from each query
    vertex intersecting its rect."""
    us = np.asarray(us, dtype=np.int64)
    B = len(us)
    rects = np.asarray(rects, dtype=np.float32).reshape(B, 4)
    exc, tid = _route(index, us)
    counts = np.zeros(B, dtype=np.int64)
    if exc.any():
        counts[exc] = _point_in_rect(index.coords[us[exc]], rects[exc])
    rest = ~exc
    if rest.any():
        counts[rest] = query_host_count(index.forest, tid[rest], rects[rest])
    return counts


def collect_csr_host(index: TwoDReachIndex, us: np.ndarray,
                     rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Uncapped collect: CSR ``(indptr (B+1,), ids int32)`` of *all*
    reachable venue ids per query, sorted ascending per row — the
    substrate for capped collects and the dynamic overlay's exact
    union merges."""
    us = np.asarray(us, dtype=np.int64)
    B = len(us)
    rects = np.asarray(rects, dtype=np.float32).reshape(B, 4)
    exc, tid = _route(index, us)
    indptr, ids = query_host_collect_batch(index.forest, tid, rects)
    if not exc.any():
        return indptr, ids
    # splice the excluded rows' own point back in ({u} when inside)
    hit = np.zeros(B, dtype=bool)
    hit[exc] = _point_in_rect(index.coords[us[exc]], rects[exc])
    counts = np.diff(indptr)
    counts[hit] = 1
    out_indptr = np.zeros(B + 1, dtype=np.int64)
    np.cumsum(counts, out=out_indptr[1:])
    out_ids = np.empty(int(out_indptr[-1]), dtype=np.int32)
    for b in range(B):
        if hit[b]:
            out_ids[out_indptr[b]] = us[b]
        else:
            out_ids[out_indptr[b]:out_indptr[b + 1]] = \
                ids[indptr[b]:indptr[b + 1]]
    return out_indptr, out_ids


def range_collect_host(index: TwoDReachIndex, us: np.ndarray,
                       rects: np.ndarray, k: int) -> CollectResult:
    """RangeCollect: the K smallest reachable venue ids per rect,
    ascending, with exact totals and overflow flags."""
    k = int(k)
    if k < 1:
        raise ValueError(f"collect needs k >= 1, got {k}")
    indptr, all_ids = collect_csr_host(index, us, rects)
    B = len(indptr) - 1
    counts = np.diff(indptr).astype(np.int64)
    ids = np.full((B, k), -1, dtype=np.int32)
    for b in range(B):
        row = all_ids[indptr[b]:indptr[b + 1]][:k]
        ids[b, : len(row)] = row
    return CollectResult(ids=ids, counts=counts, overflow=counts > k)


def polygon_reach_host(index: TwoDReachIndex, us: np.ndarray,
                       polygons) -> np.ndarray:
    """Batched convex-polygon RangeReach: bbox prefilter through the
    R-tree descent, canonical float32 half-plane postfilter."""
    us = np.asarray(us, dtype=np.int64)
    B = len(us)
    if len(polygons) != B:
        raise ValueError(f"{len(polygons)} polygons for {B} queries")
    bboxes = np.stack([polygon_bbox(p) for p in polygons]) if B else \
        np.zeros((0, 4), np.float32)
    exc, tid = _route(index, us)
    out = np.zeros(B, dtype=bool)
    indptr, cand = query_host_collect_batch(index.forest, tid, bboxes)
    for b in range(B):
        hp = convex_halfplanes(polygons[b])
        if exc[b]:
            out[b] = bool(points_in_polygon_region(
                index.coords[us[b]][None], bboxes[b], hp)[0])
            continue
        row = cand[indptr[b]:indptr[b + 1]]
        if row.size:
            out[b] = bool(points_in_polygon_region(
                index.coords[row], bboxes[b], hp).any())
    return out
