"""Per-architecture configs (+ the paper's own RangeReach workload)."""
from .registry import ARCHS, all_cells, arch_names, get_arch
