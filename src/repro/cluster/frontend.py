"""Micro-batching frontend: request queue -> engine-sized batches.

A serving node receives single RangeReach requests; the engines want
batches (the jit cache is keyed on power-of-two buckets, and per-query
overhead amortises across a tile).  :class:`Frontend` sits between:

* ``submit(u, rect)`` enqueues a request onto a **bounded** queue
  (backpressure: submit blocks while ``max_queue`` requests are
  pending) and returns a future;
* a scheduler thread flushes the queue into the engine on
  **deadline-or-full**: as soon as ``max_batch`` requests are pending,
  or when the oldest pending request has waited ``max_delay`` seconds —
  whichever comes first.  Flushed batches are at most ``max_batch``
  (keep it a power of two so steady state re-uses the engine's compiled
  buckets), and the engine's own bucket padding absorbs ragged tails.

The frontend is engine-agnostic: anything with a
``query_batch(us, rects) -> bool array`` works — the single-device
``QueryEngine``, the cluster ``ShardedEngine``, or a host index.
``warmup`` pre-traces every batch bucket the flush policy can produce,
so a steady-state stream recompiles nothing (asserted in tests via the
engine's ``n_compiles`` introspection).
"""

from __future__ import annotations

import contextlib
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..kernels.range_query.kernel import TB
from ..obs import metrics as obs_metrics
from ..obs import querylog as obs_querylog
from ..obs import span
from ..obs import trace_context
from ..obs.flight import FLIGHT
from ..obs.tracer import TRACER as _TRACER
from ..resilience.errors import (
    DeadlineExceeded,
    FrontendClosed,
    Overloaded,
    QueueFull,
)
from ..resilience.faults import fault_point


class Frontend:
    """Deadline-or-full micro-batch scheduler in front of a query engine.

    Parameters
    ----------
    engine:    anything with ``query_batch(us, rects)``.
    max_batch: flush as soon as this many requests are pending (keep it
               a power of two to reuse the engine's compiled buckets).
    max_delay: flush when the oldest pending request is this old (s).
    max_queue: bounded-queue capacity; ``submit`` blocks above it.
    metrics:   a :class:`repro.obs.Registry` for the frontend's gauges
               (queue depth, batch occupancy), counters (flushes by
               reason, deadline misses, backpressure blocks) and wait /
               lateness histograms; defaults to the global registry.
    query_log: a :class:`repro.obs.QueryLog` receiving one structured
               record per served request; ``None`` uses the global log
               when ``repro.obs`` is enabled (and skips logging when it
               is not, keeping the disabled fast path flat).
    clock:     monotonic time source (seconds) — injectable so load
               tests drive deadlines deterministically with a fake
               clock instead of sleeping.
    deadline_grace: lateness tolerance (s) before a flush that starts
               after ``enqueue + max_delay`` counts as a deadline miss;
               defaults to ``max_delay / 4`` (absorbs timer wakeup
               jitter without hiding real scheduler stalls).
    auditor:   optional :class:`repro.obs.ExactnessAuditor`; every
               served batch is offered for sampled shadow-replay
               (``observe`` is near-free when sampling is disabled).
    slo:       default per-request deadline budget (s).  When a request
               carries a budget (this default, or an explicit
               ``deadline=`` on submit), admission control sheds it
               with :class:`Overloaded` whenever the projected queue
               wait (EWMA of recent batch service time × batches ahead,
               plus the flush delay) already exceeds the budget —
               failing fast beats queueing work that is doomed to
               expire.  ``None`` (default) disables shedding.

    Every *accepted* request resolves: with the exact answer, or with a
    typed error (:class:`DeadlineExceeded` if its budget expired in the
    queue, :class:`FrontendClosed` on ``close(drain=False)``, or the
    engine's own exception latched onto the batch).  The scheduler
    thread survives any engine failure.
    """

    def __init__(self, engine, max_batch: int = 256,
                 max_delay: float = 2e-3, max_queue: int = 8192,
                 metrics: Optional["obs_metrics.Registry"] = None,
                 query_log: Optional["obs_querylog.QueryLog"] = None,
                 clock: Optional[Callable[[], float]] = None,
                 deadline_grace: Optional[float] = None,
                 slo: Optional[float] = None,
                 auditor=None):
        if max_batch < 1 or max_queue < max_batch:
            raise ValueError(
                f"need 1 <= max_batch <= max_queue, got "
                f"{max_batch}/{max_queue}")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.max_queue = int(max_queue)
        self.metrics = metrics if metrics is not None else obs_metrics.REGISTRY
        self._query_log = query_log
        self._clock = clock if clock is not None else time.monotonic
        self._auditor = auditor
        self.deadline_grace = (float(deadline_grace)
                               if deadline_grace is not None
                               else self.max_delay / 4.0)
        self.slo = None if slo is None else float(slo)
        self._cond = threading.Condition()
        self._rect_len = None                 # fixed by the first submit
        # (u, rect, future, t_enq, t_deadline | None, TraceContext)
        self._pending: List[tuple] = []
        self._inflight = False
        self._closed = False
        self._force = False
        self._ewma_batch_s = 0.0              # recent batch service time
        self.stats: Dict[str, float] = {
            "n_requests": 0, "n_batches": 0, "n_flush_full": 0,
            "n_flush_deadline": 0, "n_flush_forced": 0,
            "batched_queries": 0, "max_pending_seen": 0,
            "n_deadline_misses": 0, "n_submit_blocked": 0,
            "n_shed": 0, "n_queue_full_timeouts": 0,
            "n_deadline_dropped": 0,
        }
        m = self.metrics
        self._g_depth = m.gauge("frontend.queue_depth")
        self._g_occupancy = m.gauge("frontend.batch_occupancy")
        self._g_inflight = m.gauge("frontend.inflight")
        self._c_requests = m.counter("frontend.requests")
        self._c_misses = m.counter("frontend.deadline_misses")
        self._c_blocked = m.counter("frontend.submit_blocked")
        self._c_shed = m.counter("frontend.shed")
        self._c_queue_full = m.counter("frontend.queue_full_timeouts")
        self._c_dl_dropped = m.counter("frontend.deadline_dropped")
        self._h_wait = m.histogram("frontend.queue_wait_us")
        self._h_lateness = m.histogram("frontend.flush_lateness_us")
        self._h_batch = m.histogram("frontend.batch_size")
        self._flush_counters = {
            r: m.counter(f"frontend.{r}")
            for r in ("n_flush_full", "n_flush_deadline", "n_flush_forced")
        }
        self._thread = threading.Thread(
            target=self._run, name="rangereach-frontend", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    # client surface
    # ------------------------------------------------------------------

    def submit(self, u: int, rect, timeout: Optional[float] = None,
               deadline: Optional[float] = None) -> "Future[bool]":
        """Enqueue one request; returns a future resolving to the answer.

        Blocks while the queue is at capacity (backpressure); with
        ``timeout=`` the block is bounded and expiry raises
        :class:`QueueFull` instead.  ``deadline=`` is this request's
        budget in seconds from now (default: the frontend ``slo``);
        requests whose budget expires while queued resolve to
        :class:`DeadlineExceeded`, and requests whose budget is already
        doomed by the projected queue wait are shed up front with
        :class:`Overloaded`.  Raises :class:`FrontendClosed` after
        :meth:`close`."""
        fut: Future = Future()
        rect = np.asarray(rect, dtype=np.float32).ravel()
        budget = self.slo if deadline is None else float(deadline)
        with self._cond:
            # reject shape mismatches in the caller's thread — a ragged
            # rect must never reach batch assembly on the scheduler
            if self._rect_len is None:
                self._rect_len = len(rect)
            elif len(rect) != self._rect_len:
                raise ValueError(
                    f"rect has {len(rect)} coords, expected "
                    f"{self._rect_len}")
            if self._closed:
                raise FrontendClosed("Frontend is closed")
            if budget is not None and budget < self._projected_wait():
                self.stats["n_shed"] += 1
                self._c_shed.inc()
                raise Overloaded(
                    f"projected queue wait {self._projected_wait():.4f}s "
                    f"exceeds deadline budget {budget:.4f}s")
            if len(self._pending) >= self.max_queue and not self._closed:
                self.stats["n_submit_blocked"] += 1
                self._c_blocked.inc()
                t_end = (None if timeout is None
                         else self._clock() + float(timeout))
                while (len(self._pending) >= self.max_queue
                       and not self._closed):
                    if t_end is None:
                        self._cond.wait()
                        continue
                    rem = t_end - self._clock()
                    if rem <= 0:
                        self.stats["n_queue_full_timeouts"] += 1
                        self._c_queue_full.inc()
                        raise QueueFull(
                            f"queue still at capacity "
                            f"({self.max_queue}) after {timeout}s")
                    self._cond.wait(timeout=rem)
            if self._closed:
                raise FrontendClosed("Frontend is closed")
            t_enq = self._clock()
            t_dl = None if budget is None else t_enq + budget
            # admission is where the causal trace starts: mint the
            # request's TraceContext here so every downstream span,
            # querylog row and exemplar joins on its id.  Minting sits
            # behind the tracer gate — disabled serving pays one
            # attribute check and shares the null context.
            if _TRACER.enabled:
                ctx = trace_context.mint(u=int(u), query_class="reach",
                                         t_admit=t_enq, deadline=budget)
            else:
                ctx = trace_context.NULL
            fut.trace_id = ctx.trace_id
            self._pending.append((int(u), rect, fut, t_enq, t_dl, ctx))
            self.stats["n_requests"] += 1
            self._c_requests.inc()
            depth = len(self._pending)
            self._g_depth.set(depth)
            self.stats["max_pending_seen"] = max(
                self.stats["max_pending_seen"], depth)
            self._cond.notify_all()
        return fut

    def _projected_wait(self) -> float:
        """Expected queue wait for a request arriving now (held lock):
        the flush delay plus one EWMA batch service time per batch that
        must drain first (inflight + queued-ahead + its own)."""
        batches_ahead = (1 if self._inflight else 0) \
            + len(self._pending) // self.max_batch + 1
        return self.max_delay + batches_ahead * self._ewma_batch_s

    def submit_many(self, us: Sequence[int], rects,
                    timeout: Optional[float] = None) -> np.ndarray:
        """Submit a request stream one by one and gather the answers —
        the convenience used by benchmarks and examples."""
        rects = np.asarray(rects, dtype=np.float32)
        futs = [self.submit(u, r) for u, r in zip(us, rects)]
        return np.array([f.result(timeout=timeout) for f in futs],
                        dtype=bool)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Force-dispatch everything pending and wait until served."""
        with self._cond:
            self._force = True
            self._cond.notify_all()
            self._cond.wait_for(
                lambda: not self._pending and not self._inflight,
                timeout=timeout)
            # don't leak the flag onto requests submitted after the
            # flush completes (they should wait for deadline-or-full)
            self._force = False

    def warmup(self, us: np.ndarray, rects: np.ndarray) -> None:
        """Pre-trace every batch bucket the flush policy can produce,
        using a representative workload (tiled up to ``max_batch``)."""
        us = np.asarray(us, dtype=np.int64)
        rects = np.asarray(rects, dtype=np.float32).reshape(len(us), -1)
        reps = -(-self.max_batch // max(len(us), 1))
        us = np.tile(us, reps)
        rects = np.tile(rects, (reps, 1))
        b = TB
        while True:
            k = min(b, self.max_batch)
            self.engine.query_batch(us[:k], rects[:k])
            if b >= self.max_batch:
                break
            b <<= 1

    def close(self, timeout: Optional[float] = None,
              drain: bool = True) -> None:
        """Stop accepting requests and stop the scheduler thread.

        ``drain=True`` (default) serves everything pending first;
        ``drain=False`` fails every pending future with
        :class:`FrontendClosed` and stops as soon as any inflight batch
        finishes — either way no accepted future is left unresolved."""
        failed: List[tuple] = []
        with self._cond:
            self._closed = True
            if not drain:
                failed = self._pending[:]
                self._pending.clear()
                self._g_depth.set(0)
            self._cond.notify_all()
        if failed:
            self._fail_batch(
                failed, FrontendClosed("Frontend closed without drain"))
        self._thread.join(timeout=timeout)

    @staticmethod
    def _fail_batch(batch: List[tuple], exc: BaseException) -> None:
        for item in batch:
            try:
                item[2].set_exception(exc)
            except InvalidStateError:       # client cancelled meanwhile
                pass

    def __enter__(self) -> "Frontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def mean_batch(self) -> float:
        b = self.stats["n_batches"]
        return self.stats["batched_queries"] / b if b else 0.0

    # ------------------------------------------------------------------
    # scheduler thread
    # ------------------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cond:
                while True:
                    if self._pending:
                        n = len(self._pending)
                        deadline = self._pending[0][3] + self.max_delay
                        now = self._clock()
                        if n >= self.max_batch:
                            reason = "n_flush_full"
                            break
                        if self._force or self._closed:
                            reason = "n_flush_forced"
                            break
                        if now >= deadline:
                            reason = "n_flush_deadline"
                            break
                        self._cond.wait(timeout=deadline - now)
                    elif self._closed:
                        return
                    else:
                        self._force = False
                        self._cond.wait()
                batch = self._pending[: self.max_batch]
                del self._pending[: self.max_batch]
                # flush lateness: how far past the oldest request's
                # deadline this batch starts serving; beyond the grace
                # it is a deadline miss (the scheduler could not keep
                # the latency SLO — usually an inflight batch ahead)
                lateness = max(0.0, self._clock() - deadline)
                self._g_depth.set(len(self._pending))
                if not self._pending:
                    self._force = False
                self._inflight = True
                self._g_inflight.set(1)
                self._cond.notify_all()       # queue space freed
            self._h_lateness.record(lateness * 1e6)
            if lateness > self.deadline_grace:
                self.stats["n_deadline_misses"] += 1
                self._c_misses.inc()
            t_serve = self._clock()
            try:
                self._serve(batch, reason)
            except BaseException as e:  # noqa: BLE001 — last-resort latch
                # _serve latches engine errors itself; this guard means
                # even a failure in its own bookkeeping cannot strand
                # futures or kill the scheduler thread
                self._fail_batch(batch, e)
            with self._cond:
                dt = self._clock() - t_serve
                self._ewma_batch_s = (dt if self._ewma_batch_s == 0.0
                                      else 0.2 * dt
                                      + 0.8 * self._ewma_batch_s)
                self._inflight = False
                self._g_inflight.set(0)
                self._cond.notify_all()

    def _serve(self, batch: List[tuple], reason: str) -> None:
        # budget-expired requests are dropped at the flush boundary —
        # serving them would spend engine time on answers nobody can
        # use within their SLO
        now = self._clock()
        expired = [b for b in batch
                   if b[4] is not None and now > b[4]]
        if expired:
            batch = [b for b in batch
                     if b[4] is None or now <= b[4]]
            self.stats["n_deadline_dropped"] += len(expired)
            self._c_dl_dropped.inc(len(expired))
            # attribute the drops: the black box keeps which requests
            # died in the queue (their traces end here, by design)
            FLIGHT.note("frontend.deadline_dropped",
                        trace_ids=[b[5].trace_id for b in expired])
            self._fail_batch(expired, DeadlineExceeded(
                "deadline budget expired while queued"))
            if not batch:
                return
        ctxs = [b[5] for b in batch]
        try:
            # assembly inside the latch too: no input may ever kill the
            # scheduler thread and strand the batch's futures.  The
            # trace scope makes the batch's ids ambient: every span the
            # engine stack opens below (padder, megakernel, shard
            # fan-out, dynamic probes) tags itself with them, and the
            # resilient engine attributes retries/degradations to them.
            # (One gate check per batch: disabled serving skips the
            # scope push — the contexts are all NULL then anyway.)
            sc = (trace_context.scope(ctxs) if _TRACER.enabled
                  else contextlib.nullcontext())
            with sc, \
                    span("frontend.flush", cat="frontend", n=len(batch),
                         reason=reason):
                fault_point("frontend.queue_stall", n=len(batch))
                us = np.array([b[0] for b in batch], dtype=np.int64)
                rects = np.stack([b[1] for b in batch])
                fault_point("frontend.flush", n=len(batch))
                if getattr(self.engine, "supports_deadline", False):
                    dls = [b[4] - now for b in batch if b[4] is not None]
                    ans = self.engine.query_batch(
                        us, rects,
                        deadline=min(dls) if dls else None)
                else:
                    ans = self.engine.query_batch(us, rects)
        except BaseException as e:  # latch the error onto every future
            self._fail_batch(batch, e)
            return
        self.stats["n_batches"] += 1
        self.stats[reason] += 1
        self.stats["batched_queries"] += len(batch)
        self._flush_counters[reason].inc()
        self._h_batch.record(len(batch))
        self._g_occupancy.set(len(batch) / self.max_batch)
        now = self._clock()
        tracing = _TRACER.enabled
        for (_, _, fut, t_enq, _, ctx), a in zip(batch, ans):
            # queue-wait exemplars join the p99 quantile back to real
            # requests; only retained while tracing (reservoir writes
            # stay off the disabled fast path)
            self._h_wait.record(
                (now - t_enq) * 1e6,
                exemplar=ctx.trace_id if tracing else None)
            try:
                fut.set_result(bool(a))
            except InvalidStateError:       # client cancelled meanwhile
                pass
        self._log_batch(us, rects, ans, batch, now)
        if self._auditor is not None:
            self._auditor.observe(us, rects, ans,
                                  trace_ids=[c.trace_id for c in ctxs])

    def _log_batch(self, us, rects, ans, batch, now) -> None:
        """Structured query-log records for a served batch — explicit
        ``query_log`` always logs; otherwise the global log, only while
        ``repro.obs`` is enabled."""
        qlog = self._query_log
        if qlog is None:
            if not _TRACER.enabled:
                return
            qlog = obs_querylog.QUERY_LOG
        shard_of = getattr(self.engine, "shard_of", None)
        shards = (shard_of(us) if shard_of is not None
                  else np.zeros(len(us), dtype=np.int64))
        vclass = obs_querylog.vertex_class_of(self.engine, us)
        lats = [now - b[3] for b in batch]
        # engine-reported serving status (resilient engines rewrite
        # last_report per batch): healthy vs exact-host-degraded split
        statuses, retries, attempts = "ok", 0, None
        rep = getattr(self.engine, "last_report", None)
        if rep is not None:
            mask = np.asarray(rep.get("degraded", ()), dtype=bool)
            if len(mask) == len(us):
                statuses = np.where(mask, "degraded", "ok")
            retries = int(rep.get("retries", 0))
            att = rep.get("attempts")
            if att is not None and len(att) == len(us):
                attempts = att
        qlog.record_batch("reach", vclass, rects, shards, lats,
                          np.asarray(ans).astype(np.int64), us=us,
                          statuses=statuses, retries=retries,
                          trace_ids=[b[5].trace_id for b in batch],
                          attempts=attempts)
