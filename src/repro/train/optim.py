"""Hand-rolled optimizer substrate (no optax in this environment).

AdamW with decoupled weight decay, global-norm gradient clipping, and
warmup-cosine/linear schedules.  Optimizer state is a pytree mirroring
the params — which is what lets the ZeRO-1 sharding rules partition the
(m, v) moments over the data axis independently of the parameter layout
(see distributed/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # cosine | linear | constant
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * t)
            )
        else:
            decay = 1.0 - (1.0 - cfg.min_lr_frac) * t
    return cfg.lr * warm * decay


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=zeros,
        v=jax.tree.map(jnp.copy, zeros),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig,
    grads: Any,
    state: AdamWState,
    params: Any,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
