"""Pallas TPU kernel: segmented-MBR reduction (R-tree bulk-load step).

Bulk-loading a packed R-tree forest is, per level, one segmented
min/max: every node's MBR is the reduction of its (at most ``fan``)
children, and after the bulk-load sort the children of node ``j`` are
contiguous.  The host path does this with ``np.minimum.reduceat``; the
device path pads every node to exactly ``fan`` child slots (inert slots
are +inf/-inf boxes) and lays the slots out **slot-major**:

    children[(k * 2*dim) + a, j] = axis ``a`` of child ``k`` of node ``j``

so one kernel block holds ``TN`` nodes along the lanes and all ``fan``
child slots along the sublanes.  The reduction is then a static unroll
over slots — mins for the low axes, maxes for the high axes — with no
gather, no scatter, and no ragged bookkeeping inside the kernel.  The
same kernel builds the R-tree node levels (``fan`` = tree fanout), the
query engine's fine tile pyramid (``fan = TP``) and its coarse plane
(``fan = COARSE_GROUP``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


TN = 128    # nodes per block (lanes)


def _seg_mbr_kernel(c_ref, o_ref, *, dim: int, fan: int):
    c = c_ref[...]                        # (fan * 2*dim, TN)
    lo = c[0:dim]
    hi = c[dim:2 * dim]
    for k in range(1, fan):
        lo = jnp.minimum(lo, c[k * 2 * dim:k * 2 * dim + dim])
        hi = jnp.maximum(hi, c[k * 2 * dim + dim:(k + 1) * 2 * dim])
    o_ref[...] = jnp.concatenate([lo, hi], axis=0)


@functools.partial(jax.jit, static_argnames=("dim", "fan", "interpret", "tn"))
def seg_mbr_pallas(
    children: jax.Array,   # (fan * 2*dim, Np) float32, Np % tn == 0
    *,
    dim: int,
    fan: int,
    interpret: bool = False,
    tn: int = TN,
) -> jax.Array:
    """(2*dim, Np) node MBRs; inert child slots must be +inf/-inf."""
    rows, np_ = children.shape
    assert rows == fan * 2 * dim, (rows, fan, dim)
    assert np_ % tn == 0, (np_, tn)
    grid = (np_ // tn,)
    return pl.pallas_call(
        functools.partial(_seg_mbr_kernel, dim=dim, fan=fan),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, tn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((2 * dim, tn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((2 * dim, np_), jnp.float32),
        interpret=interpret,
    )(children)
