"""Pallas TPU kernel: packed boolean OR-AND matmul (bitset closure step).

The reverse-topological set-merge of paper Alg. 1 is, in dense form, the
fixpoint  R <- OWN | A.R  over the boolean semiring (OR, AND), where A is
the condensation adjacency and R the reachable-set matrix.  Packing 32
spatial columns per uint32 word makes one VPU op process 32 set-union
lanes at once — this kernel computes one semiring matmul

    out[i, w] = OR_j ( A[i, j] AND R[j, w] )

with A packed along j (``(d, Wd)`` words) and R packed along columns
(``(dj, W)`` words).  Blocking: one word-column of A per grid step (32
j's), unrolled as 32 masked OR accumulations over a (32, TW) R tile held
in VMEM.  The out tile is revisited across the reduction dimension.

The MXU alternative (unpack bits to bf16 and use a real matmul, then
re-threshold) is provided in ops.py as ``bitset_mm_mxu`` — see
EXPERIMENTS.md §Perf for the crossover analysis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


TI = 8      # rows of A / out per tile (sublanes)
TW = 128    # words of R / out per tile (lanes)


def _bitset_mm_kernel(a_ref, r_ref, o_ref):
    jw = pl.program_id(2)

    @pl.when(jw == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...][:, 0]            # (TI,) uint32 — one word-column of A
    r = r_ref[...]                  # (32, TW) uint32
    acc = o_ref[...]                # (TI, TW)
    for k in range(32):
        bit = ((a >> jnp.uint32(k)) & jnp.uint32(1)) > 0      # (TI,)
        acc = acc | jnp.where(bit[:, None], r[k][None, :], jnp.uint32(0))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret", "ti", "tw"))
def bitset_mm_pallas(
    a_bits: jax.Array,   # (d, Wd) uint32; d % ti == 0
    r_bits: jax.Array,   # (Wd*32, W) uint32; W % tw == 0
    *,
    interpret: bool = False,
    ti: int = TI,
    tw: int = TW,
) -> jax.Array:
    d, Wd = a_bits.shape
    dj, W = r_bits.shape
    assert dj == Wd * 32, (dj, Wd)
    assert d % ti == 0 and W % tw == 0, (d, W)
    grid = (d // ti, W // tw, Wd)
    return pl.pallas_call(
        _bitset_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, 1), lambda i, w, jw: (i, jw)),
            pl.BlockSpec((32, tw), lambda i, w, jw: (jw, w)),
        ],
        out_specs=pl.BlockSpec((ti, tw), lambda i, w, jw: (i, w)),
        out_shape=jax.ShapeDtypeStruct((d, W), jnp.uint32),
        interpret=interpret,
    )(a_bits, r_bits)
