"""Version-compat shims for the jax surface this repo spans.

jax moved ``shard_map`` from ``jax.experimental`` to the top level, and
separately renamed the replication-check kwarg (``check_rep`` ->
``check_vma``) — on independent release schedules, so neither location
nor version number predicts the kwarg.  Detect both from what the
installed jax actually exposes.
"""

from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

_NOCHECK = (
    {"check_vma": False}
    if "check_vma" in inspect.signature(_shard_map).parameters
    else {"check_rep": False}
)


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions.  ``check=False`` (default)
    disables the static replication check under whichever kwarg the
    installed jax spells it."""
    kw = {} if check else dict(_NOCHECK)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
