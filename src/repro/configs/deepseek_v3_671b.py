"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, 256 routed experts top-8
+ 1 shared, first 3 layers dense, MTP. [arXiv:2412.19437]"""
from ..models.lm import LMConfig, MLASpec, MoESpec
from .base import ArchSpec, lm_cells

NAME = "deepseek-v3-671b"


def make_config(reduced: bool = False, dtype: str = "bfloat16") -> LMConfig:
    if reduced:
        return LMConfig(
            name=NAME + "-reduced", n_layers=4, d_model=64, n_heads=4,
            n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, attn="mla",
            mla=MLASpec(q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8,
                        v_head=16),
            moe=MoESpec(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                        d_shared=64, first_dense=1),
            mtp_depth=1, dtype="float32",
        )
    return LMConfig(
        name=NAME, n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=18432, vocab=129280, attn="mla",
        mla=MLASpec(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                    v_head=128),
        moe=MoESpec(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                    d_shared=2048, first_dense=3),
        mtp_depth=1, dtype=dtype,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="lm", make_config=make_config,
        cells=lm_cells(NAME, make_config),
        notes="MLA compact KV: long_500k cache = 500k*(512+64)*2B = 0.6 GB"
              " per layer-stack at bs=1 — decode-friendly by construction",
    )
