"""Brute-force RangeReach oracle — ground truth for every index method.

BFS over the raw graph; an index answer disagreeing with this is a bug.
Used by unit tests, hypothesis property tests and the benchmark sanity
pass (benchmarks verify a sample of queries against the oracle before
timing anything).
"""

from __future__ import annotations

import numpy as np

from .graph import GeosocialGraph


def reachable_mask(graph: GeosocialGraph, u: int) -> np.ndarray:
    """(n,) bool — vertices reachable from u (including u)."""
    csr = graph.csr
    seen = np.zeros(graph.n_nodes, dtype=bool)
    seen[u] = True
    frontier = np.array([u], dtype=np.int64)
    while frontier.size:
        starts = csr.indptr[frontier]
        ends = csr.indptr[frontier + 1]
        cnt = (ends - starts).astype(np.int64)
        if cnt.sum() == 0:
            break
        slot = np.repeat(starts, cnt) + _ragged_arange(cnt)
        nxt = np.unique(csr.indices[slot])
        nxt = nxt[~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    return seen


def rangereach_oracle(graph: GeosocialGraph, u: int, rect) -> bool:
    xmin, ymin, xmax, ymax = (float(v) for v in rect)
    seen = reachable_mask(graph, u)
    pts = graph.coords
    ok = (
        seen & graph.spatial_mask
        & (pts[:, 0] >= xmin) & (pts[:, 0] <= xmax)
        & (pts[:, 1] >= ymin) & (pts[:, 1] <= ymax)
    )
    return bool(ok.any())


def rangereach_oracle_batch(
    graph: GeosocialGraph, us: np.ndarray, rects: np.ndarray
) -> np.ndarray:
    return np.array(
        [rangereach_oracle(graph, int(u), r) for u, r in zip(us, rects)],
        dtype=bool,
    )


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
