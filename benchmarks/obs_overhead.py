"""CI gate: disabled ``repro.obs`` instrumentation costs <2%.

The observability layer promises that, when disabled, its hot-path hooks
are a single attribute check returning a shared no-op context manager.
A naive A/B wall-clock comparison of instrumented-vs-stripped serving is
too noisy to gate on (the effect is well under run-to-run variance), so
this bench gates **analytically**:

1. measure the per-call cost of a *disabled* ``span()`` directly, by
   timing a tight loop of them (amortising the loop overhead away);
2. serve a real smoke batch stream with obs disabled and measure the
   per-batch wall time;
3. count how many ``span()``/``_obs_batch`` hook sites one batch
   actually crosses (from one *enabled* batch's event count);
4. assert  hooks_per_batch x cost_per_disabled_hook  <  2% of the
   measured per-batch time.

This bounds the disabled overhead with the measured per-hook cost while
staying deterministic enough for CI.  The enabled-path cost is reported
too (informational — enabling obs is an explicit opt-in).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro import obs
from repro.core import QueryEngine, build_2dreach
from repro.data import get_dataset, workload
from repro.resilience.faults import INJECTOR, FaultPlan, fault_point, inject

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "results", "obs_overhead.json")

GATE = 0.02          # disabled instrumentation must stay under 2%
SPAN_CALLS = 200_000


def disabled_span_cost_s() -> float:
    """Per-call seconds of a disabled ``span()`` (enter + exit)."""
    assert not obs.enabled()
    # amortise timer + loop overhead over a large call count; take the
    # best of several rounds (minimum filters scheduler noise)
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _i in range(SPAN_CALLS):
            with obs.span("overhead.probe"):
                pass
        best = min(best, (time.perf_counter() - t0) / SPAN_CALLS)
    return best


def batch_time_s(eng, us, rects, repeats=20) -> float:
    """Median per-batch seconds with obs disabled (warm shapes)."""
    eng.query_batch(us, rects)   # warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        eng.query_batch(us, rects)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def hooks_per_batch(eng, us, rects) -> int:
    """Span events one engine batch records when enabled — every one of
    them is a disabled-path hook site (the registry recordings in
    ``_obs_batch`` sit behind the same gate, counted via +1)."""
    obs.enable()
    n0 = len(obs.TRACER)
    eng.query_batch(us, rects)
    n = len(obs.TRACER) - n0
    obs.disable()
    return n + 1          # + the gated _obs_batch metrics block


def disabled_fault_point_cost_s() -> float:
    """Per-call seconds of a disabled ``fault_point()`` — the same
    single-attribute-check promise the obs spans make."""
    assert not INJECTOR.enabled
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _i in range(SPAN_CALLS):
            fault_point("overhead.probe")
        best = min(best, (time.perf_counter() - t0) / SPAN_CALLS)
    return best


def fault_hooks_per_batch(eng, us, rects) -> int:
    """Fault-point crossings one engine batch makes, counted by running
    a batch with an *empty* plan installed (every hit is a no-op but
    still counted by the injector)."""
    with inject(FaultPlan()):
        n0 = INJECTOR.hits_total
        eng.query_batch(us, rects)
        n = INJECTOR.hits_total - n0
    return n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="same scale either way — flag kept for CI "
                         "symmetry with the perf benches")
    ap.parse_args()

    g = get_dataset("yelp", scale=0.1)
    idx = build_2dreach(g, variant="comp")
    eng = QueryEngine(idx)
    us, rects = workload(g, 256, extent_ratio=0.05, seed=11)

    obs.disable()
    per_hook = disabled_span_cost_s()
    per_batch = batch_time_s(eng, us, rects)
    hooks = hooks_per_batch(eng, us, rects)
    overhead = hooks * per_hook / per_batch
    fp_hook = disabled_fault_point_cost_s()
    fp_hooks = fault_hooks_per_batch(eng, us, rects)
    fp_overhead = fp_hooks * fp_hook / per_batch

    report = {
        "disabled_span_cost_ns": per_hook * 1e9,
        "hooks_per_batch": hooks,
        "batch_time_us_disabled": per_batch * 1e6,
        "disabled_overhead_fraction": overhead,
        "disabled_fault_point_cost_ns": fp_hook * 1e9,
        "fault_hooks_per_batch": fp_hooks,
        "disabled_fault_overhead_fraction": fp_overhead,
        "gate": GATE,
        "passed": bool(overhead < GATE and fp_overhead < GATE),
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))
    assert overhead < GATE, (
        f"disabled obs instrumentation costs {overhead * 100:.2f}% of a "
        f"batch ({hooks} hooks x {per_hook * 1e9:.0f}ns vs "
        f"{per_batch * 1e6:.0f}us) — over the {GATE * 100:.0f}% gate")
    assert fp_overhead < GATE, (
        f"disabled fault hooks cost {fp_overhead * 100:.2f}% of a batch "
        f"({fp_hooks} hooks x {fp_hook * 1e9:.0f}ns vs "
        f"{per_batch * 1e6:.0f}us) — over the {GATE * 100:.0f}% gate")


if __name__ == "__main__":
    main()
