"""Pallas TPU kernel: fused EmbeddingBag (gather + segment-sum).

JAX has no native EmbeddingBag; the recsys substrate (DIN's behaviour
sequences) and the GNN neighbour aggregation both reduce to

    out[s, :] = sum_{k : seg[k] == s} table[idx[k], :]

This kernel fuses the row gather with the segment accumulation so
gathered rows never round-trip through HBM: a tile of TL indices is
processed per grid step, each row loaded from the table with a dynamic
slice and accumulated into the output block (resident in VMEM across the
whole grid — the out index map is constant).  ``seg`` must be sorted
ascending (the host packs batches that way), padding rows carry
``seg == n_segments`` and land in a scratch row that is dropped.

On a real TPU the table block would be scalar-prefetched / DMA'd;
correctness here is validated in interpret mode, and the production
fallback (``jnp.take`` + ``segment_sum``) is ref.py — numerically
identical, used by the sharded training path where the table is
row-sharded over the model axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


TL = 8   # indices per grid step (unrolled)


def _segment_bag_kernel(idx_ref, seg_ref, w_ref, table_ref, o_ref, *, tl: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    for k in range(tl):
        idx = idx_ref[k]
        seg = seg_ref[k]
        row = pl.load(table_ref, (pl.dslice(idx, 1), slice(None)))  # (1, D)
        w = w_ref[k].astype(row.dtype)
        cur = pl.load(o_ref, (pl.dslice(seg, 1), slice(None)))
        pl.store(o_ref, (pl.dslice(seg, 1), slice(None)), cur + w * row)


@functools.partial(
    jax.jit, static_argnames=("n_segments", "interpret", "tl")
)
def segment_bag_pallas(
    table: jax.Array,     # (V, D) float32
    indices: jax.Array,   # (L,) int32, L % tl == 0 (padded with 0)
    segments: jax.Array,  # (L,) int32 sorted; padding -> n_segments
    weights: jax.Array,   # (L,) float32 per-lookup weight (panning: 0)
    *,
    n_segments: int,
    interpret: bool = False,
    tl: int = TL,
) -> jax.Array:
    """Returns (n_segments, D) segment-weighted sums of table rows."""
    V, D = table.shape
    L = indices.shape[0]
    assert L % tl == 0
    grid = (L // tl,)
    out = pl.pallas_call(
        functools.partial(_segment_bag_kernel, tl=tl),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tl,), lambda i: (i,)),
            pl.BlockSpec((tl,), lambda i: (i,)),
            pl.BlockSpec((tl,), lambda i: (i,)),
            pl.BlockSpec((V, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_segments + 1, D), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_segments + 1, D), table.dtype),
        interpret=interpret,
    )(indices, segments, weights, table)
    return out[:n_segments]
