"""Pure-jnp oracle for the range_query kernel."""

from __future__ import annotations

import jax.numpy as jnp


def range_query_ref(entries_soa, rects_soa, qstart, qend, *, dim: int = 2):
    """Same contract as range_query_pallas, computed densely.

    entries_soa (2*dim, P), rects_soa (2*dim, B) -> (B,) int32 0/1.
    """
    P = entries_soa.shape[1]
    gidx = jnp.arange(P, dtype=jnp.int32)[None, :]          # (1, P)
    valid = (gidx >= qstart[:, None]) & (gidx < qend[:, None])
    ok = valid
    for a in range(dim):
        ok = ok & (entries_soa[a][None, :] <= rects_soa[dim + a][:, None])
        ok = ok & (entries_soa[dim + a][None, :] >= rects_soa[a][:, None])
    return jnp.any(ok, axis=1).astype(jnp.int32)
