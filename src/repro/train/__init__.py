from .optim import AdamWConfig, AdamWState, adamw_init, adamw_update, schedule_lr
from .steps import make_eval_step, make_train_step
