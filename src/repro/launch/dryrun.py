import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in results/dryrun/<arch>__<shape>__<mesh>.json; the roofline
reader (benchmarks/roofline.py) consumes them.  The XLA_FLAGS line above
MUST precede any jax import — jax locks the device count at first init.
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict

import jax

from ..configs import all_cells, get_arch
from .mesh import make_production_mesh, mesh_axes

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "results", "dryrun",
)

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the HLO."""
    out: Dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        total = 0
        for sm in SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0) + total
        out["total"] = out.get("total", 0) + total
    return out


def arg_bytes_per_device(args, n_devices: int) -> int:
    """Honest bytes/device of the lowered inputs given their shardings."""
    total = 0
    for leaf in jax.tree.leaves(args):
        nbytes = 1
        for d in leaf.shape:
            nbytes *= d
        nbytes *= leaf.dtype.itemsize
        sh = getattr(leaf, "sharding", None)
        if sh is not None:
            try:
                shard_shape = sh.shard_shape(leaf.shape)
                nb = leaf.dtype.itemsize
                for d in shard_shape:
                    nb *= d
                total += nb
                continue
            except Exception:
                pass
        total += nbytes
    return total


def run_cell(arch: str, shape: str, multi_pod: bool,
             save: bool = True) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axes(multi_pod)
    cell = get_arch(arch).cells[shape]
    meshname = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict = {
        "arch": arch, "shape": shape, "mesh": meshname,
        "n_devices": mesh.size, "kind": cell.kind,
    }
    t0 = time.perf_counter()
    try:
        fn, args = cell.build(mesh, axes)
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not implement it
            rec["memory_analysis_error"] = str(e)
        try:
            cost = compiled.cost_analysis()
            rec["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k
                )
            }
            rec["flops"] = float(cost.get("flops", 0.0))
            rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
        except Exception as e:
            rec["cost_analysis_error"] = str(e)
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        from ..analysis import analyze_hlo

        # scan-aware totals (cost_analysis counts while bodies once)
        rec["hlo_stats"] = analyze_hlo(hlo)
        rec["hlo_bytes"] = len(hlo)
        rec["arg_bytes_per_device"] = arg_bytes_per_device(args, mesh.size)
        rec["t_lower_s"] = round(t_lower, 2)
        rec["t_compile_s"] = round(t_compile, 2)
        rec["ok"] = True
        print(f"[dryrun] {arch} x {shape} x {meshname}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
              f"flops {rec.get('flops', 0):.3e}, "
              f"coll {rec['collectives'].get('total', 0):.3e} B)")
        if "memory_analysis" in rec:
            print(f"  memory_analysis: {rec['memory_analysis']}")
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} x {shape} x {meshname}: FAIL {rec['error']}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fname = f"{arch}__{shape}__{meshname}.json"
        with open(os.path.join(RESULTS_DIR, fname), "w") as f:
            json.dump(
                {k: v for k, v in rec.items() if k != "traceback"}, f,
                indent=1,
            )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    if args.all:
        ok = fail = 0
        for arch, shape, _ in all_cells():
            meshname = "pod2x16x16" if args.multi_pod else "pod16x16"
            path = os.path.join(
                RESULTS_DIR, f"{arch}__{shape}__{meshname}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        ok += 1
                        continue
            rec = run_cell(arch, shape, args.multi_pod)
            ok += rec["ok"]
            fail += not rec["ok"]
        print(f"[dryrun] done: {ok} ok, {fail} failed")
        raise SystemExit(1 if fail else 0)
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rec = run_cell(args.arch, args.shape, args.multi_pod)
    raise SystemExit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
