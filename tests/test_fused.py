"""Fused serving megakernel — soundness sweep.

Bit-identity of the single-launch fused path against the ``query_host``
oracle AND the retained two-phase path for every 2DReach variant ×
boolean/count/collect epilogue, pow2 bucket boundaries, empty-tree /
excluded edge cases, the quantization outward-rounding property (venues
exactly on tile MBR edges), megakernel-vs-XLA-impl bit-identity, and
the zero-steady-state-recompile contract of the fused trace.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import QueryEngine, build_2dreach
from repro.core.graph import make_graph
from repro.data import get_dataset, workload
from repro.kernels.range_query import fused as F
from repro.kernels.range_query.descent import (
    build_tile_pyramid,
    prune_tiles_ref,
)
from repro.kernels.range_query.kernel import TB, TP
from repro.queries import range_collect_host, range_count_host


@pytest.fixture(scope="module")
def graph():
    return get_dataset("yelp", scale=0.05)


@pytest.fixture(scope="module")
def indexes(graph):
    return {v: build_2dreach(graph, variant=v)
            for v in ("base", "comp", "pointer")}


def _check_modes(idx, eng, us, rects, k=7):
    """Fused reach/count/collect vs host oracle and two-phase path."""
    want = idx.query_batch(us, rects)
    got = eng.query_batch(us, rects)
    assert (want == got).all()
    assert (eng.query_batch_two_phase(us, rects) == want).all()

    wc = np.asarray(range_count_host(idx, us, rects))
    assert (eng.count_batch(us, rects) == wc).all()
    assert (eng.count_batch_two_phase(us, rects) == wc).all()

    wcol = range_collect_host(idx, us, rects, k)
    gcol = eng.collect_batch(us, rects, k)
    tcol = eng.collect_batch_two_phase(us, rects, k)
    for other in (gcol, tcol):
        assert (wcol.ids == other.ids).all()
        assert (wcol.counts == other.counts).all()
        assert (wcol.overflow == other.overflow).all()


# ---------------------------------------------------------------- identity
@pytest.mark.parametrize("variant", ["base", "comp", "pointer"])
def test_fused_identity_all_variants(graph, indexes, variant):
    idx = indexes[variant]
    eng = QueryEngine(idx)
    assert eng.path == "fused"
    for seed in range(3):
        us, rects = workload(graph, 150, extent_ratio=0.06, seed=seed)
        _check_modes(idx, eng, us, rects)


@pytest.mark.parametrize("B", [1, TB, TB + 1])
def test_fused_bucket_boundaries(graph, indexes, B):
    idx = indexes["comp"]
    eng = QueryEngine(idx)
    us, rects = workload(graph, B, extent_ratio=0.05, seed=B)
    _check_modes(idx, eng, us, rects)


def test_fused_empty_tree_and_excluded_edge_cases():
    """tid==-1 vertices, empty forests, spatial-sink (excluded) query
    vertices — the fused trace must answer exactly like host."""
    edges = np.array([[0, 1]], dtype=np.int64)
    coords = np.array([[0, 0], [1, 1], [0, 0], [5, 5]], dtype=np.float32)
    spatial = np.array([False, True, False, True])
    g = make_graph(4, edges, coords, spatial)
    for variant in ("base", "comp", "pointer"):
        idx = build_2dreach(g, variant=variant)
        eng = QueryEngine(idx)
        us = np.array([0, 2, 3, 1])
        rects = np.array([[0.5, 0.5, 1.5, 1.5]] * 4, dtype=np.float32)
        _check_modes(idx, eng, us, rects, k=2)
        # excluded vertex answers by its own point (Alg. 2)
        own = np.array([[4.5, 4.5, 5.5, 5.5]] * 4, dtype=np.float32)
        assert (eng.query_batch(us, own)
                == idx.query_batch(us, own)).all()


def test_fused_megakernel_matches_xla_impl(indexes, graph):
    """The Pallas megakernel (interpret) and the fused XLA program are
    the same function bit-for-bit, through the engine surface."""
    idx = indexes["comp"]
    ex = QueryEngine(idx, fused_impl="xla")
    ep = QueryEngine(idx, fused_impl="pallas")
    us, rects = workload(graph, 2 * TB, extent_ratio=0.06, seed=4)
    assert (ex.query_batch(us, rects) == ep.query_batch(us, rects)).all()
    assert (ex.count_batch(us, rects) == ep.count_batch(us, rects)).all()
    cx = ex.collect_batch(us, rects, 5)
    cp = ep.collect_batch(us, rects, 5)
    assert (cx.ids == cp.ids).all() and (cx.counts == cp.counts).all()


# ------------------------------------------------------------ quantization
def _edge_arena(rng, P):
    """Entry arena whose venue boxes end exactly on tile-MBR edges."""
    pts = np.round(rng.uniform(0, 100, (P, 2)) * 4) / 4  # lattice coords
    pts = pts.astype(np.float32)
    Pp = max(TP, -(-P // TP) * TP)
    esoa = np.empty((4, Pp), np.float32)
    esoa[:2] = 1.0
    esoa[2:] = 0.0
    esoa[:2, :P] = pts.T
    esoa[2:, :P] = pts.T                      # degenerate boxes = points
    return esoa, pts


def test_quantized_prune_superset_of_f32_on_mbr_edges():
    """Outward-rounding property: the quantized prune mask contains the
    f32 prune mask even when rect edges coincide exactly with venue
    coords / tile MBR edges (the worst case for any rounding)."""
    rng = np.random.default_rng(0)
    esoa, pts = _edge_arena(rng, 5 * TP + 3)
    fine, coarse, nt = build_tile_pyramid(esoa, dim=2)
    extent = np.concatenate([esoa[:2, : len(pts)].min(1),
                             esoa[2:, : len(pts)].max(1)])
    grid = F.make_quant_grid(extent.astype(np.float64), 2)
    qf = F.quantize_fine(grid, jnp.asarray(fine), 2)
    qc = F.quantize_coarse(grid, jnp.asarray(coarse), 2)

    B = 4 * TB
    # rects whose edges ARE tile MBR corners / venue coords exactly
    lo = pts[rng.integers(0, len(pts), B)]
    hi = np.maximum(lo, pts[rng.integers(0, len(pts), B)])
    rsoa = np.concatenate([lo, hi], axis=1).T.astype(np.float32)
    qs = np.zeros(B, np.int32)
    qe = np.full(B, len(pts), np.int32)
    r16, r32 = F.quantize_rects(grid, jnp.asarray(rsoa), 2)

    qmask = np.asarray(F.quantized_prune_mask(
        qf, qc, r16, r32, jnp.asarray(qs), jnp.asarray(qe)))
    fmask = np.asarray(
        prune_tiles_ref(fine, coarse, rsoa, jnp.asarray(qs),
                        jnp.asarray(qe))).astype(bool)
    assert (qmask[:, : fmask.shape[1]] | ~fmask).all(), \
        "quantized prune dropped a tile the f32 prune keeps (unsound)"


def test_fused_exact_on_rect_edges():
    """End-to-end: rect edges exactly on venue coordinates — the exact
    f32 leaf predicate must decide, not the quantized prune."""
    rng = np.random.default_rng(3)
    n, nv = 80, 40
    coords = (np.round(rng.uniform(0, 50, (n, 2)) * 2) / 2).astype(np.float32)
    spatial = np.zeros(n, bool)
    spatial[:nv] = True
    edges = np.stack([np.arange(nv, n), rng.integers(0, nv, n - nv)], 1)
    g = make_graph(n, edges.astype(np.int64), coords, spatial)
    idx = build_2dreach(g, variant="comp")
    eng = QueryEngine(idx)
    us = rng.integers(nv, n, 3 * TB)
    # rect corners sit exactly on venue points: closed-interval hits
    c = coords[rng.integers(0, nv, 3 * TB)]
    rects = np.concatenate([c, c], axis=1)     # zero-area rects on venues
    assert (eng.query_batch(us, rects) == idx.query_batch(us, rects)).all()
    wc = np.asarray(range_count_host(idx, us, rects))
    assert (eng.count_batch(us, rects) == wc).all()


# ------------------------------------------------------------ compile-once
def test_fused_zero_steady_state_recompiles(graph, indexes):
    eng = QueryEngine(indexes["pointer"])
    shapes = [(0, 1), (1, TB), (2, 100), (3, 128), (4, 3)]
    # warmup pass: traces per (mode, bucket) plus possible capacity
    # ratchet reruns (monotone hwm — each bumps at most one new kcap)
    for seed, B in shapes:
        us, rects = workload(graph, B, extent_ratio=0.05, seed=seed)
        eng.query_batch(us, rects)
        eng.count_batch(us, rects)
        eng.collect_batch(us, rects, 6)
    warm = eng.n_compiles
    # steady state: previously-seen shapes and workloads, any order —
    # zero retraces and zero capacity reruns
    reruns = eng.stats["fused_reruns"]
    for seed, B in reversed(shapes):
        us, rects = workload(graph, B, extent_ratio=0.05, seed=seed)
        eng.query_batch(us, rects)
        eng.count_batch(us, rects)
        eng.collect_batch(us, rects, 6)
    assert eng.n_compiles == warm, \
        "fused steady-state serving must not retrace"
    assert eng.stats["fused_reruns"] == reruns, \
        "capacity hwm must not rerun on a previously-seen workload"


def test_resilient_two_phase_degradation(graph, indexes):
    """degraded_path='two_phase': a tripped breaker reroutes to the
    retained two-phase device path, still bit-identical."""
    from repro.resilience import ResilientEngine
    from repro.resilience.breaker import BreakerPolicy

    idx = indexes["comp"]
    eng = QueryEngine(idx)
    # reset_timeout_s large so the breaker stays open across both calls
    # (the default 1s would half-open while the first fallback compiles)
    res = ResilientEngine(eng, idx, degraded_path="two_phase",
                          breaker=BreakerPolicy(reset_timeout_s=3600.0))
    res.trip()
    us, rects = workload(graph, 50, extent_ratio=0.05, seed=8)
    assert (res.query_batch(us, rects) == idx.query_batch(us, rects)).all()
    assert res.last_report["degraded"].all()
    wc = np.asarray(range_count_host(idx, us, rects))
    assert (res.count_batch(us, rects) == wc).all()
    assert res.stats["fallback_batches"] >= 2
