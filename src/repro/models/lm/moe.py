"""Mixture-of-Experts FFN with expert parallelism.

Design (TPU-native, no torch.distributed emulation):

* Routing is computed replicated (router weights are tiny).
* Expert compute uses **capacity-packed batched matmuls**: assignments
  are sorted by expert, each expert gets a fixed-capacity row budget
  (``balance_factor`` x fair share — overflow tokens are dropped exactly
  as in GShard/Switch), and the expert FFN is one
  ``einsum('ecd,edf->ecf')`` pair that the MXU loves.  No (T, E, C)
  one-hot dispatch tensor is ever materialised — the pack/unpack is a
  scatter/gather of row indices.
* Under a mesh, the layer runs inside ``shard_map`` over the model axis:
  each shard owns E/tp experts and packs only the assignments routed to
  them; one ``psum`` over the model axis completes routed outputs AND the
  tensor-parallel shared-expert partial sums (a single fused collective).
* Without a mesh (smoke tests / single device) the same local function
  runs directly.

Gradients flow through the combine weights (softmax) and the expert
matmuls; top-k index selection is non-differentiable as usual.  The
standard load-balance auxiliary loss is returned alongside.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...compat import shard_map
from ..nn import ACT, Params, dense_init
from .config import MoESpec


def moe_init(key, d_model: int, d_ff_default: int, spec: MoESpec, dtype
             ) -> Params:
    ks = jax.random.split(key, 6)
    E, f = spec.n_experts, spec.d_expert
    s = (1.0 / d_model) ** 0.5
    p: Params = {
        "router": jax.random.normal(ks[0], (d_model, E), jnp.float32) * s,
        # packed gate+up: (E, d, 2f); down: (E, f, d)
        "w_gu": jax.random.normal(ks[1], (E, d_model, 2 * f), dtype) * s,
        "w_d": jax.random.normal(ks[2], (E, f, d_model), dtype)
        * (1.0 / f) ** 0.5,
    }
    if spec.n_shared:
        fs = (spec.d_shared or d_ff_default) * spec.n_shared
        # (d, 2, fs): gate/up stacked on axis 1 so a model-axis split of
        # the last dim keeps gate and up aligned on every shard
        p["sh_gu"] = jax.random.normal(ks[3], (d_model, 2, fs), dtype) * s
        p["sh_d"] = (
            jax.random.normal(ks[4], (fs, d_model), dtype) * (1.0 / fs) ** 0.5
        )
    return p


def _routing(x, router, spec: MoESpec):
    """x (T, d) -> (weights (T, k), experts (T, k), aux_loss)."""
    logits = x.astype(jnp.float32) @ router          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, e = jax.lax.top_k(probs, spec.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    E = spec.n_experts
    frac = jnp.mean(
        jax.nn.one_hot(e[:, 0], E, dtype=jnp.float32), axis=0
    )
    imp = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * imp) * spec.aux_loss_weight
    return w, e, aux


def _expert_ffn_local(
    x, w, e, w_gu, w_d, spec: MoESpec, e_start, e_local: int, cap: int, act
):
    """Capacity-packed local expert compute.

    x (T, d); w/e (T, k) routing; w_gu (E_loc, d, 2f); returns (T, d)
    partial output (only this shard's experts contribute)."""
    T, d = x.shape
    k = spec.top_k
    flat_e = e.reshape(-1) - e_start                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = w.reshape(-1)
    mine = (flat_e >= 0) & (flat_e < e_local)
    sort_key = jnp.where(mine, flat_e, e_local)
    order = jnp.argsort(sort_key)
    se = sort_key[order]
    st = flat_t[order]
    sw = flat_w[order]
    # position within expert group -> capacity slot
    group_sizes = jnp.bincount(se, length=e_local + 1)[:e_local]
    group_start = jnp.cumsum(group_sizes) - group_sizes
    pos = jnp.arange(T * k) - group_start[jnp.minimum(se, e_local - 1)]
    keep = (se < e_local) & (pos < cap)
    slot = jnp.where(keep, se * cap + pos, e_local * cap)  # overflow row
    # pack
    xb = jnp.zeros((e_local * cap + 1, d), x.dtype).at[slot].set(x[st])
    xb = xb[:-1].reshape(e_local, cap, d)
    # expert FFN (GLU)
    gu = jnp.einsum("ecd,edf->ecf", xb, w_gu)
    f = spec.d_expert
    h = ACT[act](gu[..., :f]) * gu[..., f:]
    yb = jnp.einsum("ecf,efd->ecd", h, w_d)
    # unpack + weighted combine
    yflat = yb.reshape(e_local * cap, d)
    contrib = jnp.where(
        keep[:, None], yflat[jnp.minimum(slot, e_local * cap - 1)], 0.0
    ) * sw[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[st].add(contrib)
    return out


def _shared_ffn(x, sh_gu, sh_d, act):
    gu = jnp.einsum("td,dgf->tgf", x, sh_gu)
    return (ACT[act](gu[:, 0]) * gu[:, 1]) @ sh_d


def moe_ffn(
    p: Params,
    x: jnp.ndarray,            # (T, d) tokens
    spec: MoESpec,
    *,
    act: str = "silu",
    mesh=None,
    model_axis: str = "model",
    data_spec: P = P(),
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (T, d), aux_loss). With a mesh: EP over model axis."""
    T, d = x.shape
    E, k = spec.n_experts, spec.top_k

    if mesh is None or model_axis not in mesh.shape:
        w, e, aux = _routing(x, p["router"], spec)
        cap = max(
            1, int(spec.balance_factor * T * k / E)
        )
        out = _expert_ffn_local(
            x, w, e, p["w_gu"], p["w_d"], spec, 0, E, cap, act
        )
        if "sh_gu" in p:
            out = out + _shared_ffn(x, p["sh_gu"], p["sh_d"], act)
        return out, aux

    tp = mesh.shape[model_axis]
    assert E % tp == 0, (E, tp)
    e_local = E // tp
    cap = max(1, int(spec.balance_factor * T * k / E))

    def local_fn(x, router, w_gu, w_d, *shared):
        # x is the data-shard slice, replicated over model
        w, e, aux = _routing(x, router, spec)
        idx = jax.lax.axis_index(model_axis)
        out = _expert_ffn_local(
            x, w, e, w_gu, w_d, spec, idx * e_local, e_local, cap, act
        )
        if shared:
            sh_gu, sh_d = shared
            out = out + _shared_ffn(x, sh_gu, sh_d, act)
        out = jax.lax.psum(out, model_axis)
        aux = jax.lax.pmean(aux, model_axis)
        return out, aux

    shared_in = ()
    shared_specs = ()
    if "sh_gu" in p:
        # tensor-parallel shared expert: split the hidden (f) dim
        shared_in = (p["sh_gu"], p["sh_d"])
        # sh_gu (d, 2, fs): last dim split keeps gate/up aligned per shard;
        # sh_d rows split -> partial d-sums completed by the routed psum.
        shared_specs = (P(None, None, model_axis), P(model_axis, None))

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            data_spec,                 # x: sharded over data axes
            P(None, None),             # router replicated
            P(model_axis, None, None),  # experts sharded
            P(model_axis, None, None),
            *shared_specs,
        ),
        out_specs=(data_spec, P()),
    )
    return fn(x, p["router"], p["w_gu"], p["w_d"], *shared_in)
