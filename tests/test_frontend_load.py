"""Frontend under load: deadline misses, backpressure, gauge truth.

These tests drive the micro-batching frontend's scheduler with an
injectable fake clock and a blockable engine, so flush deadlines, queue
saturation and lateness accounting are exercised *deterministically* —
no wall-clock sleeps gate the assertions; real time is only ever spent
waiting on state transitions that are already guaranteed to happen.
"""

from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.cluster import Frontend
from repro.obs.metrics import Registry
from repro.resilience import (
    DeadlineExceeded,
    FrontendClosed,
    Overloaded,
    QueueFull,
)

RECT = np.array([0.0, 0.0, 1.0, 1.0], dtype=np.float32)


class FakeClock:
    """Injectable monotonic clock; ``advance`` also wakes the scheduler
    so its deadline wait re-evaluates against the new time."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, fe: Frontend, dt: float) -> None:
        self.t += dt
        with fe._cond:
            fe._cond.notify_all()


class BlockableEngine:
    """Answers True for everything; optionally blocks inside the first
    ``query_batch`` until released (holds the frontend inflight)."""

    def __init__(self, block_first: bool = False):
        self.calls: list = []
        self.entered = threading.Event()   # set when a serve starts
        self.release = threading.Event()   # opens the blocked serve
        self._block_first = block_first

    def query_batch(self, us, rects):
        self.calls.append(np.asarray(us).copy())
        self.entered.set()
        if self._block_first and len(self.calls) == 1:
            assert self.release.wait(timeout=30), "engine never released"
        return np.ones(len(np.asarray(us)), dtype=bool)


def _await(predicate, timeout=10.0, what="condition"):
    """Bounded wait for a cross-thread state transition."""
    deadline = time.monotonic() + timeout
    while not predicate():
        assert time.monotonic() < deadline, f"timed out awaiting {what}"
        time.sleep(0.001)


def test_deadline_flush_on_time_is_not_a_miss():
    clock = FakeClock()
    reg = Registry()
    eng = BlockableEngine()
    with Frontend(eng, max_batch=8, max_delay=10.0, metrics=reg,
                  clock=clock) as fe:
        fut = fe.submit(0, RECT)
        # under max_batch pending and before the deadline: no flush
        assert not fut.done()
        clock.advance(fe, 10.0)            # exactly the deadline
        assert fut.result(timeout=10) is True
        assert fe.stats["n_flush_deadline"] == 1
        assert fe.stats["n_deadline_misses"] == 0
        assert reg.counter("frontend.n_flush_deadline").value == 1
        assert reg.counter("frontend.deadline_misses").value == 0
        h = reg.histogram("frontend.flush_lateness_us")
        assert h.snapshot()["count"] == 1
        assert h.snapshot()["max"] == 0.0  # flushed exactly on time


def test_deadline_miss_behind_inflight_batch():
    """A batch whose flush starts after deadline + grace (because the
    scheduler was stuck behind an inflight batch) counts as a miss, and
    the lateness histogram records how far past the SLO it started."""
    clock = FakeClock()
    reg = Registry()
    eng = BlockableEngine(block_first=True)
    fe = Frontend(eng, max_batch=1, max_queue=8, max_delay=10.0,
                  deadline_grace=5.0, metrics=reg, clock=clock)
    try:
        f1 = fe.submit(0, RECT)            # flushes full, engine blocks
        assert eng.entered.wait(timeout=10)
        f2 = fe.submit(1, RECT)            # stuck behind the inflight
        clock.advance(fe, 100.0)           # blow way past f2's deadline
        eng.release.set()
        assert f1.result(timeout=10) is True
        assert f2.result(timeout=10) is True
        assert fe.stats["n_deadline_misses"] == 1
        assert reg.counter("frontend.deadline_misses").value == 1
        h = reg.histogram("frontend.flush_lateness_us")
        # f2 started (100 - 10) fake seconds late
        assert h.snapshot()["max"] == pytest.approx(90e6)
    finally:
        fe.close()


def test_lateness_within_grace_is_not_a_miss():
    clock = FakeClock()
    reg = Registry()
    eng = BlockableEngine()
    with Frontend(eng, max_batch=8, max_delay=10.0, deadline_grace=5.0,
                  metrics=reg, clock=clock) as fe:
        fut = fe.submit(0, RECT)
        clock.advance(fe, 13.0)            # 3s late, inside 5s grace
        assert fut.result(timeout=10) is True
        assert fe.stats["n_deadline_misses"] == 0
        h = reg.histogram("frontend.flush_lateness_us")
        assert h.snapshot()["max"] == pytest.approx(3e6)


def test_queue_full_backpressure_blocks_and_recovers():
    clock = FakeClock()
    reg = Registry()
    eng = BlockableEngine(block_first=True)
    fe = Frontend(eng, max_batch=2, max_queue=2, max_delay=10.0,
                  metrics=reg, clock=clock)
    try:
        fa = fe.submit(0, RECT)
        fb = fe.submit(1, RECT)            # full flush; engine blocks
        assert eng.entered.wait(timeout=10)
        fc = fe.submit(2, RECT)            # queue 1/2
        fd = fe.submit(3, RECT)            # queue 2/2 — at capacity
        extra = {}

        def blocked_submit():
            extra["fut"] = fe.submit(4, RECT)

        th = threading.Thread(target=blocked_submit)
        th.start()
        # the 5th submit must block (counted before it waits) ...
        _await(lambda: fe.stats["n_submit_blocked"] == 1,
               what="submit to block on the full queue")
        assert th.is_alive()
        assert reg.counter("frontend.submit_blocked").value == 1
        # ... until the inflight batch completes and frees queue space
        eng.release.set()
        th.join(timeout=10)
        assert not th.is_alive()
        # the straggler sits alone under max_batch: only its deadline
        # (in fake time) can flush it
        clock.advance(fe, 50.0)
        for f in (fa, fb, fc, fd, extra["fut"]):
            assert f.result(timeout=10) is True
        assert fe.stats["n_requests"] == 5
        served = sum(len(c) for c in eng.calls)
        assert served == 5
    finally:
        fe.close()


def test_gauges_track_depth_occupancy_inflight():
    clock = FakeClock()
    reg = Registry()
    eng = BlockableEngine(block_first=True)
    fe = Frontend(eng, max_batch=4, max_queue=16, max_delay=10.0,
                  metrics=reg, clock=clock)
    try:
        for i in range(4):
            fe.submit(i, RECT)             # full flush; engine blocks
        assert eng.entered.wait(timeout=10)
        assert reg.gauge("frontend.inflight").value == 1
        for i in range(3):
            fe.submit(4 + i, RECT)         # pile up behind the inflight
        assert reg.gauge("frontend.queue_depth").max >= 3
        eng.release.set()
        clock.advance(fe, 10.0)            # deadline flush for the 3
        _await(lambda: fe.stats["n_batches"] == 2, what="both flushes")
        assert reg.gauge("frontend.inflight").value == 0
        assert reg.gauge("frontend.batch_occupancy").max == 1.0   # 4/4
        assert reg.gauge("frontend.batch_occupancy").value == \
            pytest.approx(3 / 4)                                  # 3/4
        h = reg.histogram("frontend.batch_size")
        assert h.snapshot()["count"] == 2
        assert h.snapshot()["max"] == 4.0
        assert reg.counter("frontend.requests").value == 7
        # queue-wait histogram saw one entry per request, in fake time
        assert reg.histogram(
            "frontend.queue_wait_us").snapshot()["count"] == 7
    finally:
        fe.close()


def test_fake_clock_does_not_leak_into_default_frontend():
    """Without an injected clock the frontend uses time.monotonic and
    still serves (guard against the clock plumbing regressing the real
    path)."""
    eng = BlockableEngine()
    with Frontend(eng, max_batch=4, max_delay=1e-3) as fe:
        got = fe.submit_many(np.arange(4), np.tile(RECT, (4, 1)))
    assert got.all()
    assert fe.stats["n_batches"] >= 1


# ----------------------------------------------------------------------
# typed errors: QueueFull, Overloaded, DeadlineExceeded, FrontendClosed
# ----------------------------------------------------------------------


def test_submit_timeout_raises_queue_full():
    reg = Registry()
    eng = BlockableEngine(block_first=True)
    fe = Frontend(eng, max_batch=2, max_queue=2, max_delay=10.0,
                  metrics=reg)
    try:
        fe.submit(0, RECT)
        fe.submit(1, RECT)                 # full flush; engine blocks
        assert eng.entered.wait(timeout=10)
        fe.submit(2, RECT)
        fe.submit(3, RECT)                 # queue at capacity
        with pytest.raises(QueueFull):
            fe.submit(4, RECT, timeout=0.05)
        assert fe.stats["n_queue_full_timeouts"] == 1
        assert reg.counter("frontend.queue_full_timeouts").value == 1
        # a QueueFull submit left no residue: capacity frees, serving
        # continues, and the shed request was simply never enqueued
        eng.release.set()
        assert fe.stats["n_requests"] == 4
    finally:
        fe.close()


def test_overloaded_shed_on_doomed_deadline():
    """A request whose budget cannot survive even the flush delay is
    shed with Overloaded instead of queued to die."""
    reg = Registry()
    eng = BlockableEngine()
    fe = Frontend(eng, max_batch=8, max_delay=0.5, max_queue=16,
                  metrics=reg, slo=0.01)
    try:
        with pytest.raises(Overloaded):
            fe.submit(0, RECT)             # default slo 10ms < 500ms
        # an explicit generous deadline overrides the doomed default
        fut = fe.submit(1, RECT, deadline=60.0)
        fe.flush(timeout=10)
        assert fut.result(timeout=10) is True
        assert fe.stats["n_shed"] == 1
        assert reg.counter("frontend.shed").value == 1
    finally:
        fe.close()


def test_deadline_expired_in_queue_is_dropped_typed():
    clock = FakeClock()
    reg = Registry()
    eng = BlockableEngine(block_first=True)
    fe = Frontend(eng, max_batch=1, max_queue=8, max_delay=0.1,
                  metrics=reg, clock=clock)
    try:
        fa = fe.submit(0, RECT)            # flushes alone; blocks engine
        assert eng.entered.wait(timeout=10)
        fb = fe.submit(1, RECT, deadline=0.5)
        fc = fe.submit(2, RECT, deadline=50.0)
        clock.advance(fe, 1.0)             # fb's budget expires queued
        eng.release.set()
        assert fa.result(timeout=10) is True
        with pytest.raises(DeadlineExceeded):
            fb.result(timeout=10)
        assert fc.result(timeout=10) is True
        assert fe.stats["n_deadline_dropped"] == 1
        assert reg.counter("frontend.deadline_dropped").value == 1
        # the dropped request never reached the engine
        assert sum(len(c) for c in eng.calls) == 2
    finally:
        fe.close()


def test_engine_exception_latches_and_scheduler_survives():
    """An engine blow-up resolves exactly the affected batch's futures
    with the error; the scheduler thread survives and keeps serving."""

    class Exploding:
        def __init__(self):
            self.calls = 0

        def query_batch(self, us, rects):
            self.calls += 1
            if self.calls == 1:
                raise ValueError("device on fire")
            return np.ones(len(np.asarray(us)), dtype=bool)

    eng = Exploding()
    with Frontend(eng, max_batch=2, max_delay=10.0) as fe:
        fa = fe.submit(0, RECT)
        fb = fe.submit(1, RECT)            # full flush -> boom
        for f in (fa, fb):
            with pytest.raises(ValueError):
                f.result(timeout=10)
        # same frontend, next batch: served fine by the live scheduler
        fc = fe.submit(2, RECT)
        fd = fe.submit(3, RECT)
        assert fc.result(timeout=10) is True
        assert fd.result(timeout=10) is True
    assert eng.calls == 2


def test_close_drain_false_fails_pending_typed():
    eng = BlockableEngine(block_first=True)
    fe = Frontend(eng, max_batch=2, max_queue=8, max_delay=10.0)
    fa = fe.submit(0, RECT)
    fb = fe.submit(1, RECT)                # full flush; engine blocks
    assert eng.entered.wait(timeout=10)
    fc = fe.submit(2, RECT)                # still queued
    eng.release.set()
    fe.close(timeout=10, drain=False)
    # the inflight batch finished; the queued request failed typed
    assert fa.result(timeout=10) is True
    assert fb.result(timeout=10) is True
    with pytest.raises(FrontendClosed):
        fc.result(timeout=10)
    with pytest.raises(FrontendClosed):
        fe.submit(3, RECT)
    # FrontendClosed subclasses RuntimeError: pre-existing callers that
    # caught the old error keep working
    with pytest.raises(RuntimeError):
        fe.submit(4, RECT)


def test_close_drain_true_still_serves_everything():
    eng = BlockableEngine()
    fe = Frontend(eng, max_batch=64, max_delay=10.0)
    futs = [fe.submit(i, RECT) for i in range(5)]
    fe.close(timeout=10)                   # drain=True default
    assert all(f.result(timeout=10) is True for f in futs)
