"""Strongly connected component decomposition.

Two implementations, tested against each other:

* ``scc_np``   — host-side (scipy.sparse.csgraph, Tarjan-class C code).
  Used by default for index *builds*, which are offline.
* ``scc_jax``  — device-side, jit-able **trim + coloring** algorithm
  (Orzan 2004 / Slota et al. 2014 family), the standard data-parallel SCC
  used on wide machines. This is the TPU-native adaptation of the paper's
  (sequential, pointer-chasing) Tarjan step:

    1. *Trim*: repeatedly delete vertices whose (active) in-degree or
       out-degree is zero — each is a singleton SCC. On LBSN graphs this
       removes all venue sinks and most of the long tail in a handful of
       data-parallel sweeps (one gather + two segment-sums each).
    2. *Coloring*: every active vertex starts with its own id as color;
       forward max-propagation to fixpoint (scatter-max per sweep) makes
       color[v] = max id that reaches v. Vertices with color[v] == v are
       roots. Backward propagation restricted to equal colors marks the
       root's SCC. Remove marked vertices; repeat.

Both return labels in [0, n); labels are *representative ids*, not
contiguous — use ``compact_labels`` for a dense renumbering.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import numpy as np
import scipy.sparse as sp
from scipy.sparse import csgraph

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Host (oracle / default-build) path
# --------------------------------------------------------------------------

def scc_np(n: int, edges: np.ndarray) -> np.ndarray:
    """SCC labels via scipy (Tarjan-class). Returns (n,) int32 labels in
    [0, n_comps); scipy guarantees labels are in reverse topological
    order of the condensation? (No ordering is relied upon downstream.)"""
    edges = np.asarray(edges).reshape(-1, 2)
    if edges.size == 0:
        return np.arange(n, dtype=np.int32)
    data = np.ones(len(edges), dtype=np.int8)
    adj = sp.csr_matrix((data, (edges[:, 0], edges[:, 1])), shape=(n, n))
    _, labels = csgraph.connected_components(adj, directed=True, connection="strong")
    return labels.astype(np.int32)


def compact_labels(labels: np.ndarray) -> Tuple[np.ndarray, int]:
    """Renumber arbitrary labels to dense [0, d). Returns (labels, d)."""
    uniq, dense = np.unique(np.asarray(labels), return_inverse=True)
    return dense.astype(np.int32), int(len(uniq))


# --------------------------------------------------------------------------
# Device (jit) path: trim + coloring
# --------------------------------------------------------------------------

def _trim(active, src, dst, edge_valid):
    """Iteratively deactivate vertices with zero active in- or out-degree.

    Returns the reduced ``active`` mask. Trimmed vertices are singleton
    SCCs (their final label is their own id, which the caller's color
    initialisation already provides).
    """
    n = active.shape[0]

    def body(state):
        active, _ = state
        ea = edge_valid & active[src] & active[dst]
        w = ea.astype(jnp.int32)
        outd = jnp.zeros(n, jnp.int32).at[src].add(w)
        ind = jnp.zeros(n, jnp.int32).at[dst].add(w)
        new_active = active & (outd > 0) & (ind > 0)
        changed = jnp.any(new_active != active)
        return new_active, changed

    def cond(state):
        return state[1]

    active, _ = jax.lax.while_loop(cond, body, (active, jnp.bool_(True)))
    return active


def _propagate_max(color, src, dst, live):
    """Forward max-propagation to fixpoint: color[v] = max over active
    in-edges (u,v) of color[u], iterated until no change."""

    def body(state):
        color, _ = state
        contrib = jnp.where(live, color[src], -1)
        new = color.at[dst].max(contrib)
        return new, jnp.any(new != color)

    def cond(state):
        return state[1]

    color, _ = jax.lax.while_loop(cond, body, (color, jnp.bool_(True)))
    return color


def _mark_backward(mark, color, src, dst, live):
    """Backward closure within color classes: if (u,v) live, colors equal
    and v marked, mark u. To fixpoint."""

    def body(state):
        mark, _ = state
        ok = live & (color[src] == color[dst]) & mark[dst]
        new = mark.at[src].max(ok)
        return new, jnp.any(new != mark)

    def cond(state):
        return state[1]

    mark, _ = jax.lax.while_loop(cond, body, (mark, jnp.bool_(True)))
    return mark


@partial(jax.jit, static_argnums=(0,))
def _scc_jax_impl(n: int, edges: jnp.ndarray):
    src = edges[:, 0]
    dst = edges[:, 1]
    edge_valid = src != dst  # self loops are irrelevant to SCC structure

    labels = jnp.arange(n, dtype=jnp.int32)   # default: singleton = own id
    active = jnp.ones(n, dtype=bool)
    active = _trim(active, src, dst, edge_valid)

    def outer_cond(state):
        active, _labels, it = state
        return jnp.any(active) & (it < n)

    def outer_body(state):
        active, labels, it = state
        live = edge_valid & active[src] & active[dst]
        color = jnp.where(active, jnp.arange(n, dtype=jnp.int32), -1)
        color = _propagate_max(color, src, dst, live)
        # roots: active vertices whose color is their own id
        mark = active & (color == jnp.arange(n, dtype=jnp.int32))
        mark = _mark_backward(mark, color, src, dst, live)
        # marked vertices belong to SCC labelled by their color (the root id)
        labels = jnp.where(mark, color, labels)
        active = active & ~mark
        active = _trim(active, src, dst, edge_valid)
        return active, labels, it + 1

    active, labels, _ = jax.lax.while_loop(
        outer_cond, outer_body, (active, labels, jnp.int32(0))
    )
    return labels


def scc_jax(n: int, edges: np.ndarray) -> np.ndarray:
    """Device-side SCC labels (representative vertex ids, not contiguous)."""
    edges = np.asarray(edges, dtype=np.int32).reshape(-1, 2)
    if edges.size == 0:
        return np.arange(n, dtype=np.int32)
    out = _scc_jax_impl(n, jnp.asarray(edges))
    return np.asarray(out)


def same_partition(a: np.ndarray, b: np.ndarray) -> bool:
    """True iff two labelings induce the same partition of [0, n)."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.shape != b.shape:
        return False
    # map each a-label to the b-label of its first occurrence and compare
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    # partitions equal iff the pairing (ai, bi) is a bijection
    pairs = np.unique(np.stack([ai, bi], axis=1), axis=0)
    return (
        len(np.unique(pairs[:, 0])) == len(pairs)
        and len(np.unique(pairs[:, 1])) == len(pairs)
    )
