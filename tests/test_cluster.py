"""Cluster serving: partitioning, the sharded engine's bit-identity to
the host oracle across shard counts, the micro-batching frontend, and
the sharded base probe under a DynamicIndex overlay.

Runs on however many devices the host exposes: shards stack per device,
so the 8-shard layout is exercised even single-device (CI additionally
runs this file under XLA_FLAGS=--xla_force_host_platform_device_count=8
for a real 1-shard-per-device mesh)."""

import threading
import time

import numpy as np
import pytest

from repro.cluster import (
    Frontend,
    ShardedEngine,
    balanced_assignment,
    partition_forest,
    sharded_engine_for,
)
from repro.core import batch_query, build_2dreach, build_index
from repro.core.graph import make_graph
from repro.data import get_dataset, workload
from repro.kernels.range_query.kernel import TB

SHARD_COUNTS = (1, 2, 8)


@pytest.fixture(scope="module")
def graph():
    return get_dataset("yelp", scale=0.05)


@pytest.fixture(scope="module")
def indexes(graph):
    return {v: build_2dreach(graph, variant=v)
            for v in ("base", "comp", "pointer")}


# ---------------------------------------------------------------- partition
def test_balanced_assignment_lpt():
    w = np.array([10, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1], dtype=np.int64)
    a = balanced_assignment(w, 2)
    loads = np.bincount(a, weights=w, minlength=2)
    # LPT: the heavy item alone on one shard, the ten units on the other
    assert sorted(loads.tolist()) == [10.0, 10.0]
    # deterministic
    assert (a == balanced_assignment(w, 2)).all()


def test_partition_routing_arrays(indexes):
    forest = indexes["comp"].forest
    for S in SHARD_COUNTS:
        part = partition_forest(forest, S)
        counts = np.diff(forest.entry_off)
        assert part.n_trees == forest.n_trees
        seen = np.zeros(forest.n_trees, dtype=bool)
        for s, trees in enumerate(part.shard_trees):
            lo = 0
            for t in trees:
                assert part.tree_shard[t] == s
                assert part.tree_qs[t] == lo
                assert part.tree_qe[t] == lo + counts[t]
                lo += counts[t]
                seen[t] = True
            assert part.shard_entries[s] == lo
        assert seen.all(), "every tree must land on exactly one shard"
        assert part.shard_entries.sum() == counts.sum()


def test_partition_balance(indexes):
    forest = indexes["comp"].forest
    counts = np.diff(forest.entry_off).astype(np.int64)
    for S in (2, 4):
        part = partition_forest(forest, S)
        # LPT bound: max load <= perfect + the heaviest item
        perfect = counts.sum() / S
        assert part.shard_entries.max() <= perfect + counts.max()


def test_partition_rejects_bad_shards(indexes):
    with pytest.raises(ValueError):
        partition_forest(indexes["comp"].forest, 0)


# ---------------------------------------------------------------- exactness
@pytest.mark.parametrize("variant", ["base", "comp", "pointer"])
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_sharded_matches_host_oracle(graph, indexes, variant, n_shards):
    """The acceptance gate: bit-identical to query_host on every 2DReach
    variant for shard counts {1, 2, 8}."""
    idx = indexes[variant]
    eng = ShardedEngine(idx, n_shards=n_shards)
    for seed in range(3):
        us, rects = workload(graph, 160, extent_ratio=0.05, seed=seed)
        want = idx.query_batch(us, rects)   # host path == query_host oracle
        got = eng.query_batch(us, rects)
        assert (want == got).all()
        assert got.dtype == np.bool_ and got.shape == want.shape
    # every probed query was routed to exactly one shard
    assert eng.shard_queries.sum() <= eng.stats["queries"]


def test_sharded_trees_empty_on_some_shards():
    """More shards than trees: shards with an empty arena must stay
    inert, and answers stay exact."""
    # graph: 0 -> 1 (venue), 2 isolated user, 3 isolated venue
    edges = np.array([[0, 1]], dtype=np.int64)
    coords = np.array([[0, 0], [1, 1], [0, 0], [5, 5]], dtype=np.float32)
    spatial = np.array([False, True, False, True])
    g = make_graph(4, edges, coords, spatial)
    for variant in ("base", "comp", "pointer"):
        idx = build_2dreach(g, variant=variant)
        assert idx.forest.n_trees < 8
        eng = ShardedEngine(idx, n_shards=8)
        us = np.array([0, 2, 3, 1])
        rects = np.array([[0.5, 0.5, 1.5, 1.5]] * 4, dtype=np.float32)
        want = idx.query_batch(us, rects)
        got = eng.query_batch(us, rects)
        assert (want == got).all(), variant
        assert want[0] and not want[1]


def test_sharded_empty_forest():
    """A graph with no reachable venues at all: T=0 trees, every shard
    arena empty, every answer False (or the Alg. 2 point test)."""
    edges = np.array([[0, 1]], dtype=np.int64)
    coords = np.zeros((2, 2), dtype=np.float32)
    g = make_graph(2, edges, coords, np.zeros(2, dtype=bool))
    idx = build_2dreach(g, variant="comp")
    assert idx.forest.n_trees == 0
    eng = ShardedEngine(idx, n_shards=2)
    us = np.array([0, 1])
    rects = np.array([[-1, -1, 1, 1]] * 2, dtype=np.float32)
    assert (eng.query_batch(us, rects) == idx.query_batch(us, rects)).all()


@pytest.mark.parametrize("variant", ["comp", "pointer"])
def test_sharded_spatial_query_vertices(graph, indexes, variant):
    """Alg. 2 special case: excluded (spatial-sink) query vertices answer
    by their own point — fused identically on every device."""
    idx = indexes[variant]
    eng = ShardedEngine(idx, n_shards=2)
    exc = np.nonzero(idx.excluded)[0]
    rng = np.random.default_rng(7)
    us = rng.choice(exc, size=32)
    pts = idx.coords[us]
    rects = np.concatenate([pts - 0.01, pts + 0.01], axis=1).astype(np.float32)
    rects[::2] += 1e3    # guaranteed miss
    want = idx.query_batch(us, rects)
    got = eng.query_batch(us, rects)
    assert (want == got).all()
    assert want[1::2].all() and not want[::2].any()


@pytest.mark.parametrize("B", [1, TB, TB + 1, 100])
def test_sharded_bucket_boundaries(graph, indexes, B):
    idx = indexes["comp"]
    eng = ShardedEngine(idx, n_shards=2)
    us, rects = workload(graph, B, extent_ratio=0.05, seed=B)
    assert (idx.query_batch(us, rects) == eng.query_batch(us, rects)).all()


def test_sharded_empty_batch(indexes):
    eng = ShardedEngine(indexes["comp"], n_shards=2)
    out = eng.query_batch(np.zeros(0, np.int64), np.zeros((0, 4), np.float32))
    assert out.shape == (0,) and out.dtype == np.bool_


# ---------------------------------------------------------- compile-once
def test_sharded_no_steady_state_recompiles(graph, indexes):
    idx = indexes["pointer"]
    eng = ShardedEngine(idx, n_shards=8)
    for seed, B in [(0, 1), (1, 8), (2, 100), (3, 128)]:
        us, rects = workload(graph, B, extent_ratio=0.05, seed=seed)
        eng.query_batch(us, rects)
    warm = eng.n_compiles
    for seed, B in [(10, 3), (11, 100), (12, 77), (13, 128), (14, 1)]:
        us, rects = workload(graph, B, extent_ratio=0.05, seed=seed)
        assert (idx.query_batch(us, rects) == eng.query_batch(us, rects)).all()
    assert eng.n_compiles == warm
    assert eng.stats["uploads"] == 1


def test_sharded_engine_for_memoised_and_strict(graph, indexes):
    idx = indexes["base"]
    assert sharded_engine_for(idx) is sharded_engine_for(idx)
    us = np.array([0]); rects = np.array([[0, 0, 1, 1]], np.float32)
    assert (batch_query(idx, us, rects, engine="cluster")
            == batch_query(idx, us, rects)).all()
    # n_shards change rebuilds rather than silently serving the old cut
    eng2 = sharded_engine_for(idx, n_shards=2)
    assert eng2.n_shards == 2
    # cluster serving is explicit opt-in: unsupported index types raise
    geo = build_index(graph, "georeach")
    with pytest.raises(ValueError, match="GeoReachIndex"):
        sharded_engine_for(geo)
    with pytest.raises(ValueError, match="cluster"):
        batch_query(geo, us, rects, engine="cluster")


def test_sharded_mesh_divisibility(indexes):
    import jax

    from repro.launch.mesh import make_shard_mesh

    if len(jax.devices()) >= 2:     # exercised by the CI 8-device job
        mesh = make_shard_mesh(2)
        with pytest.raises(ValueError, match="multiple"):
            ShardedEngine(indexes["comp"], n_shards=3, mesh=mesh)
    # 3 shards with no mesh given: falls back to a divisor device count
    eng = ShardedEngine(indexes["comp"], n_shards=3)
    assert eng.n_shards == 3
    assert eng.n_shards % eng.mesh.shape["data"] == 0


# ---------------------------------------------------------------- frontend
def test_frontend_answers_match_host(graph, indexes):
    idx = indexes["comp"]
    eng = ShardedEngine(idx, n_shards=2)
    us, rects = workload(graph, 300, extent_ratio=0.05, seed=5)
    want = idx.query_batch(us, rects)
    with Frontend(eng, max_batch=64, max_delay=5e-3) as fe:
        got = fe.submit_many(us, rects, timeout=60)
        assert (got == want).all()
        assert fe.stats["n_flush_full"] >= 1
        assert fe.stats["batched_queries"] == 300


def test_frontend_deadline_flush(graph, indexes):
    """A lone request (batch never fills) must still resolve within the
    deadline, via the deadline-flush path."""
    idx = indexes["comp"]
    eng = ShardedEngine(idx, n_shards=2)
    us, rects = workload(graph, 1, extent_ratio=0.05, seed=9)
    with Frontend(eng, max_batch=64, max_delay=2e-3) as fe:
        fe.warmup(us, rects)
        t0 = time.monotonic()
        got = fe.submit(int(us[0]), rects[0]).result(timeout=10)
        dt = time.monotonic() - t0
        assert got == bool(idx.query_batch(us, rects)[0])
        assert fe.stats["n_flush_deadline"] >= 1
        assert dt < 5.0   # deadline fired, not a hang


def test_frontend_steady_state_no_recompiles(graph, indexes):
    """The acceptance gate: zero recompiles in steady state under the
    micro-batching frontend."""
    idx = indexes["comp"]
    eng = ShardedEngine(idx, n_shards=8)
    us, rects = workload(graph, 400, extent_ratio=0.05, seed=6)
    with Frontend(eng, max_batch=64, max_delay=2e-3) as fe:
        fe.warmup(us[:64], rects[:64])
        fe.submit_many(us, rects, timeout=60)   # warm the K mark
        fe.warmup(us[:64], rects[:64])   # re-pin buckets at that mark
        fe.submit_many(us, rects, timeout=60)   # structure-matched
        # shakeout: same submission pattern as the asserted pass, so any
        # regrouping-induced ratchet of the K mark lands here, not below
        warm = eng.n_compiles
        got = fe.submit_many(us, rects, timeout=60)
        assert eng.n_compiles == warm, "steady-state recompile"
    assert (got == idx.query_batch(us, rects)).all()


def test_frontend_backpressure_and_close(graph, indexes):
    idx = indexes["comp"]
    eng = ShardedEngine(idx, n_shards=2)
    us, rects = workload(graph, 64, extent_ratio=0.05, seed=4)
    fe = Frontend(eng, max_batch=8, max_delay=1e-3, max_queue=8)
    errs = []

    def feed():
        try:
            for i in range(64):
                fe.submit(int(us[i]), rects[i])
        except Exception as e:  # pragma: no cover
            errs.append(e)

    t = threading.Thread(target=feed)
    t.start()
    t.join(timeout=30)
    assert not t.is_alive() and not errs
    assert fe.stats["max_pending_seen"] <= 8
    fe.close(timeout=30)
    with pytest.raises(RuntimeError):
        fe.submit(int(us[0]), rects[0])


def test_frontend_validates_config(indexes):
    eng = ShardedEngine(indexes["comp"], n_shards=1)
    with pytest.raises(ValueError):
        Frontend(eng, max_batch=0)
    with pytest.raises(ValueError):
        Frontend(eng, max_batch=64, max_queue=8)


def test_frontend_survives_cancelled_future(graph, indexes):
    """A client cancelling its future must not kill the scheduler or
    strand the rest of the batch."""
    idx = indexes["comp"]
    us, rects = workload(graph, 16, extent_ratio=0.05, seed=13)
    with Frontend(idx, max_batch=8, max_delay=50e-3) as fe:
        cancelled = fe.submit(int(us[0]), rects[0])
        assert cancelled.cancel()           # before any flush fires
        got = fe.submit_many(us[1:], rects[1:], timeout=30)
    assert (got == idx.query_batch(us[1:], rects[1:])).all()


def test_frontend_rejects_ragged_rects_and_survives(graph, indexes):
    """A malformed rect is rejected in the caller's thread; the
    scheduler thread keeps serving afterwards."""
    idx = indexes["comp"]
    us, rects = workload(graph, 8, extent_ratio=0.05, seed=12)
    with Frontend(idx, max_batch=4, max_delay=1e-3) as fe:
        fe.submit(int(us[0]), rects[0])
        with pytest.raises(ValueError, match="coords"):
            fe.submit(int(us[1]), rects[1][:3])     # 3 coords, not 4
        got = fe.submit_many(us, rects, timeout=30)
    assert (got == idx.query_batch(us, rects)).all()


def test_frontend_works_with_host_index(graph, indexes):
    """Engine-agnostic: the frontend micro-batches any query_batch."""
    idx = indexes["comp"]
    us, rects = workload(graph, 40, extent_ratio=0.05, seed=8)
    with Frontend(idx, max_batch=16, max_delay=1e-3) as fe:
        got = fe.submit_many(us, rects, timeout=30)
    assert (got == idx.query_batch(us, rects)).all()


# ---------------------------------------------------------- dynamic base
def test_dynamic_sharded_base_across_compactions():
    """DynamicIndex(engine="cluster"): sharded base probe under the
    overlay, oracle-checked interleaved mutations across >= 2 compaction
    swaps (each swap repartitions and re-uploads the shards)."""
    from repro.core import build_dynamic_index, rangereach_oracle_batch
    from repro.data import apply_stream_op, streaming_workload
    from repro.dynamic import CompactionPolicy

    g = get_dataset("yelp", scale=0.05)
    dyn = build_dynamic_index(
        g, "2dreach-comp", engine="cluster", n_shards=4,
        policy=CompactionPolicy(max_overlay_edges=30, background=False),
    )
    engines = [dyn.base_engine]     # strong refs: ids must not recycle
    assert isinstance(dyn.base_engine, ShardedEngine)
    assert dyn.base_engine.n_shards == 4
    step = 0
    for op in streaming_workload(g, n_steps=400, seed=31, p_query=0.35,
                                 p_edge=0.45, p_vertex=0.1, p_spatial=0.1):
        apply_stream_op(dyn, op)
        if dyn.base_engine is not engines[-1]:
            engines.append(dyn.base_engine)
        step += 1
        if step % 100 == 0:     # interleaved oracle checks mid-stream
            gm = dyn.snapshot_graph()
            vu, vr = workload(gm, 24, extent_ratio=0.05, seed=step)
            assert (dyn.query_batch(vu, vr)
                    == rangereach_oracle_batch(gm, vu, vr)).all(), step
    assert dyn.stats["n_compactions"] >= 2, \
        "stream too short to cross two compaction swaps"
    assert len(engines) >= 3, \
        "each compaction swap must rebuild the sharded engine"
    gm = dyn.snapshot_graph()
    vu, vr = workload(gm, 64, extent_ratio=0.05, seed=999)
    assert (dyn.query_batch(vu, vr)
            == rangereach_oracle_batch(gm, vu, vr)).all()
