"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness.  Full configs are exercised
by the dry-run (ShapeDtypeStruct only), per the assignment."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, arch_names, get_arch

LM_ARCHS = [n for n in arch_names() if get_arch(n).family == "lm"]
GNN_ARCHS = [n for n in arch_names() if get_arch(n).family == "gnn"]


def test_ten_archs_registered():
    assert len(arch_names()) == 10
    assert len(get_arch(arch_names()[0]).cells) == 4


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke(name):
    from repro.models.lm import (
        decode_step, init_params, lm_loss, prefill,
    )
    from repro.train import AdamWConfig, adamw_init, make_train_step

    cfg = get_arch(name).make_config(reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    step = jax.jit(make_train_step(
        lambda p, b: lm_loss(p, b, cfg), AdamWConfig(lr=1e-3)))
    p2, _, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
        if a.dtype.kind == "f"
    )
    assert delta > 0
    # serve path
    logits, cache = prefill(params, toks, cfg, max_len=S + 8)
    assert logits.shape == (B, cfg.vocab)
    lg, cache = decode_step(params, cache, toks[:, -1], cfg)
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.parametrize("name", GNN_ARCHS)
def test_gnn_smoke(name):
    import importlib

    from repro.train import AdamWConfig, adamw_init, make_train_step

    mod = importlib.import_module(
        f"repro.models.gnn.{name.replace('-', '_')}")
    cfg = get_arch(name).make_config(reduced=True)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    N, E = 16, 48
    src = rng.integers(0, N, E)
    dst = (src + 1 + rng.integers(0, N - 1, E)) % N  # no self loops
    batch = dict(
        pos=jnp.asarray(rng.standard_normal((N, 3)), jnp.float32),
        species=jnp.asarray(rng.integers(0, 5, N), jnp.int32),
        edge_src=jnp.asarray(src, jnp.int32),
        edge_dst=jnp.asarray(dst, jnp.int32),
    )
    if name == "graphcast":
        batch["feat"] = jnp.asarray(
            rng.standard_normal((N, cfg.n_vars)), jnp.float32)
        batch["target"] = batch["feat"] * 0.9
        out = mod.apply(params, batch, cfg)
        assert out.shape == (N, cfg.n_vars)
    else:
        if name == "dimenet":
            from repro.models.gnn.dimenet import build_triplets

            kj, ji, tm = build_triplets(src, dst, N, 256)
            batch.update(id_kj=jnp.asarray(kj), id_ji=jnp.asarray(ji),
                         triplet_mask=jnp.asarray(tm))
        out = mod.apply(params, batch, cfg)
        assert out.shape == ()
    assert np.isfinite(np.asarray(out)).all()

    def loss(p, b):
        if name == "graphcast":
            return (mod.loss_fn(p, b, cfg), {})
        pred = mod.apply(p, b, cfg)
        return ((pred - 1.0) ** 2, {})

    step = jax.jit(make_train_step(loss, AdamWConfig(lr=1e-3)))
    _, _, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))


def test_din_smoke():
    from repro.models.recsys import din
    from repro.train import AdamWConfig, adamw_init, make_train_step

    cfg = get_arch("din").make_config(reduced=True)
    params = din.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B = 8
    batch = dict(
        hist_items=jnp.asarray(
            rng.integers(0, cfg.n_items, (B, cfg.seq_len)), jnp.int32),
        hist_mask=jnp.asarray(rng.random((B, cfg.seq_len)) < 0.8),
        target_item=jnp.asarray(rng.integers(0, cfg.n_items, B), jnp.int32),
        label=jnp.asarray(rng.random(B) < 0.5, jnp.float32),
    )
    logits = din.apply(params, batch, cfg)
    assert logits.shape == (B,)
    step = jax.jit(make_train_step(
        lambda p, b: (din.loss_fn(p, b, cfg), {}), AdamWConfig(lr=1e-3)))
    _, _, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))
    # retrieval scoring path
    sc = din.score_candidates(params, dict(
        hist_items=batch["hist_items"][0],
        hist_mask=batch["hist_mask"][0],
        candidates=jnp.asarray(rng.integers(0, cfg.n_items, 64), jnp.int32),
    ), cfg, chunk=16)
    assert sc.shape == (64,)
    assert np.isfinite(np.asarray(sc)).all()
