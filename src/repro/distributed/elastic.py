"""Elastic scaling + failure-recovery glue.

Checkpoints store *logical* (unsharded) arrays, so a job can restart on a
different mesh shape: ``reshard`` places a restored pytree onto the new
mesh under the same partition rules (dims that no longer divide fall back
to replication inside the rules themselves).

``run_with_recovery`` is the supervisor loop used by launch/train.py:
it retries the training segment after transient failures, restoring from
the last committed checkpoint — the single-process stand-in for the
cluster controller behaviour (restart-on-node-failure), with the same
code path exercised by tests/test_checkpoint.py.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def reshard(tree: Any, mesh: Mesh, spec_tree: Any) -> Any:
    """Place (host or device) arrays onto ``mesh`` per ``spec_tree``."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(
        put, tree, spec_tree,
        is_leaf=lambda s: not isinstance(s, (dict, list, tuple)),
    )


def run_with_recovery(
    segment: Callable[[int], int],
    *,
    start_step: int,
    max_failures: int = 3,
    backoff_s: float = 0.5,
) -> int:
    """Run ``segment(step) -> next_step`` until it finishes, retrying after
    exceptions up to ``max_failures`` times (the caller's segment function
    re-restores from the last checkpoint on entry)."""
    failures = 0
    step = start_step
    while True:
        try:
            return segment(step)
        except KeyboardInterrupt:
            raise
        except Exception:
            failures += 1
            if failures > max_failures:
                raise
            time.sleep(backoff_s * (2 ** (failures - 1)))
            # segment re-reads the last committed checkpoint itself
