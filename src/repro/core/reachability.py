"""Reachable-spatial-set closure over the SCC condensation (paper Alg. 1).

The paper merges per-component ``std::set``s while walking the condensation
in reverse topological order.  The dense, data-parallel equivalent used here
represents every component's reachable spatial set as a row of a packed
**uint32 bitset matrix** ``(rows, W)`` with ``W = ceil(p / 32)`` and one
column per spatial vertex.  "Merging a child's set" is then a bitwise OR of
rows, and one *level* of the DAG (all components at equal longest-path
depth) is merged in a single vectorised scatter-OR sweep:

    for L in levels descending:                 # reverse topological order
        bits[src at L] |= bits[dst]             # np.bitwise_or.at

Space note: the worst case O(d*p) bits is the paper's Theorem 4.1.  Exactly
as in the paper it does not materialise in practice because (a) *leaf*
components (no DAG out-edges — e.g. every venue sink) never get a row, their
reachable set is their own member list, and (b) the compressed variants
exclude spatial sinks from the decomposition entirely.

Three implementations:

* ``closure_np``       — host build path, per-level segment-OR (sorted
                         contributions + ``np.bitwise_or.reduceat``; the
                         legacy unbuffered ``np.bitwise_or.at`` scatter
                         stays selectable via ``segment_or=False`` for
                         benchmarking).
* ``closure_jax``      — jit fixpoint on a boolean (rows, p) matrix
                         (``.at[].max`` scatter); small-graph device path.
* ``closure_bitset_mm``— the ``backend="device"`` build path: a
                         level-scheduled packed fixpoint R <- own | A.R
                         where each condensation level runs one OR-AND
                         matmul over its *frontier only* — the level's
                         source rows against its compacted unique
                         destinations — so converged rows stop paying
                         matmul work.  The matmul is the ``bitset_mm``
                         Pallas kernel on TPU and an XLA gather +
                         halving-OR reduction elsewhere.

plus ``closure_mbr_np`` which tracks only per-component reachability MBRs
(min/max scatter) — the GeoReach baseline's R-MBR tier rides on it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .condensation import Condensation


# --------------------------------------------------------------------------
# Bit packing helpers
# --------------------------------------------------------------------------

def n_words(p: int) -> int:
    return (p + 31) // 32


def pack_rows(rows_bool: np.ndarray) -> np.ndarray:
    """(r, p) bool -> (r, W) uint32, bit j of word w = column 32*w + j."""
    rows_bool = np.asarray(rows_bool, dtype=bool)
    r, p = rows_bool.shape
    W = n_words(p)
    padded = np.zeros((r, W * 32), dtype=bool)
    padded[:, :p] = rows_bool
    b = padded.reshape(r, W, 4, 8)
    # np.packbits packs MSB-first per byte; flip for LSB-first bit order
    by = np.packbits(b[..., ::-1], axis=-1).reshape(r, W, 4)
    return by.view(np.uint32).reshape(r, W) if by.flags.c_contiguous else (
        np.ascontiguousarray(by).view(np.uint32).reshape(r, W))


def unpack_rows(bits: np.ndarray, p: int) -> np.ndarray:
    """(r, W) uint32 -> (r, p) bool."""
    bits = np.asarray(bits, dtype=np.uint32)
    r, W = bits.shape
    by = np.ascontiguousarray(bits).view(np.uint8).reshape(r, W, 4)
    bl = np.unpackbits(by, axis=-1).reshape(r, W, 4, 8)[..., ::-1]
    return bl.reshape(r, W * 32)[:, :p].astype(bool)


def set_bits(bits: np.ndarray, row: np.ndarray, col: np.ndarray) -> None:
    """In-place bits[row] |= (1 << col)."""
    np.bitwise_or.at(
        bits, (row, col // 32), (np.uint32(1) << (col % 32).astype(np.uint32))
    )


def popcount32(x: np.ndarray) -> np.ndarray:
    """Element-wise SWAR popcount of uint32 words -> int64."""
    x = x.astype(np.uint32)
    x = x - ((x >> np.uint32(1)) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> np.uint32(2)) & np.uint32(0x33333333))
    x = (x + (x >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> np.uint32(24)).astype(np.int64)


def row_popcount(bits: np.ndarray) -> np.ndarray:
    """(r, W) uint32 -> (r,) int64 number of set bits.

    SWAR per word — no 32x bool expansion like ``np.unpackbits``."""
    return popcount32(bits).sum(axis=1)


def nonzero_cols(bits_row: np.ndarray, p: int) -> np.ndarray:
    """Columns set in a single (W,) uint32 row."""
    return np.nonzero(unpack_rows(bits_row[None, :], p)[0])[0].astype(np.int32)


# --------------------------------------------------------------------------
# Closure input: which components get bitset rows
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ClosureResult:
    """Per-component reachable spatial sets in split representation.

    Components with DAG out-edges ("interior") have a packed bitset row;
    leaf components (the overwhelming majority in LBSNs — every venue sink)
    are represented implicitly by their own member column lists.
    """

    p: int                       # number of spatial columns
    spatial_vertex: np.ndarray   # (p,) vertex id of each column
    col_of_vertex: np.ndarray    # (n,) column id or -1
    interior_row: np.ndarray     # (d,) row idx into ``bits`` or -1 (leaf)
    bits: np.ndarray             # (n_interior, W) uint32 closure rows
    own_indptr: np.ndarray       # (d+1,) CSR of own spatial columns per comp
    own_cols: np.ndarray         # (sum,) int32 columns

    def comp_set_cols(self, c: int) -> np.ndarray:
        """Reachable spatial columns of component ``c`` (exact)."""
        r = self.interior_row[c]
        if r >= 0:
            return nonzero_cols(self.bits[r], self.p)
        return self.own_cols[self.own_indptr[c]:self.own_indptr[c + 1]]

    def comp_nonempty(self) -> np.ndarray:
        """(d,) bool — component has at least one reachable spatial vertex."""
        d = len(self.interior_row)
        out = np.zeros(d, dtype=bool)
        leaf = self.interior_row < 0
        own_cnt = np.diff(self.own_indptr)
        out[leaf] = own_cnt[leaf] > 0
        inter = ~leaf
        if inter.any():
            pc = row_popcount(self.bits)
            out[inter] = pc[self.interior_row[inter]] > 0
        return out


def _own_columns(
    cond: Condensation,
    n: int,
    spatial_vertex: np.ndarray,
    col_of_vertex: np.ndarray,
    extra_vertex_comp: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """CSR (indptr, cols) of own spatial columns per component.

    ``extra_vertex_comp`` optionally adds (vertex_ids, comp_ids) pairs — the
    compressed variant's "spatial neighbours of n" (Alg. 1 line 4 modified).
    """
    comp_ids: List[np.ndarray] = []
    cols: List[np.ndarray] = []
    in_dec = cond.comp[spatial_vertex] >= 0
    sv = spatial_vertex[in_dec]
    if sv.size:
        comp_ids.append(cond.comp[sv].astype(np.int64))
        cols.append(col_of_vertex[sv].astype(np.int64))
    if extra_vertex_comp is not None:
        ev, ec = extra_vertex_comp
        if len(ev):
            comp_ids.append(np.asarray(ec, dtype=np.int64))
            cols.append(col_of_vertex[np.asarray(ev)].astype(np.int64))
    if comp_ids:
        comp_all = np.concatenate(comp_ids)
        col_all = np.concatenate(cols)
        # dedup (comp, col) pairs
        key = comp_all * np.int64(len(spatial_vertex) + 1) + col_all
        _, idx = np.unique(key, return_index=True)
        comp_all, col_all = comp_all[idx], col_all[idx]
        order = np.argsort(comp_all, kind="stable")
        comp_all, col_all = comp_all[order], col_all[order]
    else:
        comp_all = np.zeros(0, dtype=np.int64)
        col_all = np.zeros(0, dtype=np.int64)
    indptr = np.zeros(cond.n_comps + 1, dtype=np.int64)
    np.cumsum(np.bincount(comp_all, minlength=cond.n_comps), out=indptr[1:])
    return indptr, col_all.astype(np.int32)


def _segment_or_rows(bits: np.ndarray, targets: np.ndarray,
                     sources: np.ndarray, presorted: bool = False) -> None:
    """``bits[targets[i]] |= bits[sources[i]]`` without an unbuffered
    scatter: contributions group by target row, OR-reduce per run with
    ``np.bitwise_or.reduceat``, and write once per unique row.

    ``presorted=True`` skips the grouping sort — the closure's per-level
    edge schedule is already source-sorted."""
    if len(targets) == 0:
        return
    if not presorted:
        order = np.argsort(targets, kind="stable")
        targets, sources = targets[order], sources[order]
    starts = np.nonzero(np.r_[True, targets[1:] != targets[:-1]])[0]
    lens = np.diff(np.r_[starts, len(targets)])
    single = lens == 1
    ss = starts[single]
    if len(ss):
        # a length-1 segment's OR degenerates to one buffered row OR
        bits[targets[ss]] |= bits[sources[ss]]
    if not single.all():
        multi = np.repeat(~single, lens)
        g = bits[sources[multi]]
        tm = targets[multi]
        st = np.nonzero(np.r_[True, tm[1:] != tm[:-1]])[0]
        bits[tm[st]] |= np.bitwise_or.reduceat(g, st, axis=0)


def _segment_or_bits(bits: np.ndarray, rows: np.ndarray,
                     cols: np.ndarray, presorted: bool = False) -> None:
    """``bits[rows] |= (1 << cols)`` via the same group + reduceat
    segment-OR (duplicate (row, word) destinations collapse before the
    single indexed write).  ``presorted`` asserts (row, col) pairs
    already arrive in lexicographic order."""
    if len(rows) == 0:
        return
    W = bits.shape[1]
    cols = cols.astype(np.int64)
    key = rows.astype(np.int64) * W + cols // 32
    vals = np.uint32(1) << (cols % 32).astype(np.uint32)
    if not presorted:
        order = np.argsort(key, kind="stable")
        key, vals = key[order], vals[order]
    starts = np.nonzero(np.r_[True, key[1:] != key[:-1]])[0]
    bits.reshape(-1)[key[starts]] |= np.bitwise_or.reduceat(vals, starts)


def _closure_prologue(
    cond: Condensation,
    n: int,
    spatial_vertex: np.ndarray,
    extra_vertex_comp: Optional[Tuple[np.ndarray, np.ndarray]],
):
    """Shared host prologue of every closure implementation: column
    mapping, own-column CSR, and the interior-row numbering (components
    with at least one DAG out-edge get a packed bitset row)."""
    p = len(spatial_vertex)
    d = cond.n_comps
    col_of_vertex = np.full(n, -1, dtype=np.int64)
    col_of_vertex[spatial_vertex] = np.arange(p, dtype=np.int64)
    own_indptr, own_cols = _own_columns(
        cond, n, spatial_vertex, col_of_vertex, extra_vertex_comp
    )
    interior = np.zeros(d, dtype=bool)
    if cond.dag_edges.size:
        interior[cond.dag_edges[:, 0]] = True
    interior_ids = np.nonzero(interior)[0]
    interior_row = np.full(d, -1, dtype=np.int32)
    interior_row[interior_ids] = np.arange(len(interior_ids), dtype=np.int32)
    return p, col_of_vertex, own_indptr, own_cols, interior_row, interior_ids


def _seed_pairs(own_indptr: np.ndarray, own_cols: np.ndarray,
                interior_row: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(rows, cols) of every interior component's own columns — the
    fixpoint seed."""
    d = len(interior_row)
    if not own_cols.size:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    own_comp = np.repeat(np.arange(d, dtype=np.int64), np.diff(own_indptr))
    m0 = interior_row[own_comp] >= 0
    return (interior_row[own_comp[m0]].astype(np.int64),
            own_cols[m0].astype(np.int64))


def closure_np(
    cond: Condensation,
    n: int,
    spatial_vertex: np.ndarray,
    extra_vertex_comp: Optional[Tuple[np.ndarray, np.ndarray]] = None,
    chunk_edges: int = 1 << 22,
    segment_or: bool = True,
) -> ClosureResult:
    """Host reverse-topological closure (paper Alg. 1 lines 6-9).

    Parameters
    ----------
    cond:            SCC condensation (possibly of the social subgraph only).
    spatial_vertex:  (p,) vertex ids that define bitset columns.
    extra_vertex_comp: compressed-variant extra own-members, see
                     ``_own_columns``.
    segment_or:      per-level merge strategy.  ``True`` (default) sorts
                     each level's contributions and OR-reduces runs with
                     ``np.bitwise_or.reduceat`` — one buffered write per
                     unique target instead of ``np.bitwise_or.at``'s
                     element-at-a-time unbuffered scatter.  ``False``
                     keeps the legacy scatter (identical result; kept
                     for the before/after in ``benchmarks/perf_build``).
    """
    p, col_of_vertex, own_indptr, own_cols, interior_row, interior_ids = (
        _closure_prologue(cond, n, spatial_vertex, extra_vertex_comp))
    W = n_words(p)
    bits = np.zeros((len(interior_ids), W), dtype=np.uint32)

    # seed interior rows with own columns (vectorised over all comps)
    rr, cc = _seed_pairs(own_indptr, own_cols, interior_row)
    if len(rr):
        if segment_or:
            # own CSR is (comp, col)-sorted, so the keys arrive in order
            _segment_or_bits(bits, rr, cc, presorted=True)
        else:
            np.bitwise_or.at(
                bits, (rr, cc // 32), np.uint32(1) << (cc % 32).astype(np.uint32)
            )

    if cond.dag_edges.size:
        edges = cond.edges_by_level_desc()
        src_lv = cond.level[edges[:, 0]]
        # process one level at a time (descending); within a level the
        # merge is order-independent because no edge joins two comps of
        # the same level
        boundaries = np.nonzero(np.diff(-src_lv))[0] + 1
        seg_starts = np.concatenate([[0], boundaries, [len(edges)]])
        interior = interior_row >= 0
        leaf = ~interior
        own_cnt = np.diff(own_indptr)
        for s, e in zip(seg_starts[:-1], seg_starts[1:]):
            for cs in range(s, e, chunk_edges):
                ce = min(cs + chunk_edges, e)
                src = edges[cs:ce, 0]
                dst = edges[cs:ce, 1]
                rs = interior_row[src]
                # contribution of interior children: OR their rows
                di = interior_row[dst]
                m = di >= 0
                if m.any():
                    if segment_or:
                        # the level schedule is source-sorted already
                        _segment_or_rows(bits, rs[m], di[m], presorted=True)
                    else:
                        np.bitwise_or.at(bits, (rs[m],), bits[di[m]])
                # contribution of leaf children: OR their own columns
                lm = leaf[dst] & (own_cnt[dst] > 0)
                if lm.any():
                    ls, ld = src[lm], dst[lm]
                    cnt = own_cnt[ld]
                    rep_row = np.repeat(interior_row[ls], cnt)
                    starts = own_indptr[ld]
                    slot = np.repeat(starts, cnt) + _ragged_arange(cnt)
                    cc = own_cols[slot]
                    if segment_or:
                        _segment_or_bits(bits, rep_row, cc)
                    else:
                        np.bitwise_or.at(
                            bits,
                            (rep_row, cc // 32),
                            np.uint32(1) << (cc % 32).astype(np.uint32),
                        )

    return ClosureResult(
        p=p,
        spatial_vertex=np.asarray(spatial_vertex, dtype=np.int32),
        col_of_vertex=col_of_vertex,
        interior_row=interior_row,
        bits=bits,
        own_indptr=own_indptr,
        own_cols=own_cols,
    )


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)


# --------------------------------------------------------------------------
# MBR closure (GeoReach baseline substrate)
# --------------------------------------------------------------------------

def closure_mbr_np(
    cond: Condensation,
    coords: np.ndarray,
    spatial_mask: np.ndarray,
) -> np.ndarray:
    """(d, 4) reachability MBR [xmin, ymin, xmax, ymax] per component;
    components with empty reachable sets get an empty box (min > max)."""
    d = cond.n_comps
    mbr = np.empty((d, 4), dtype=np.float32)
    mbr[:, :2] = np.inf
    mbr[:, 2:] = -np.inf
    sv = np.nonzero(spatial_mask)[0]
    if sv.size:
        c = cond.comp[sv]
        keep = c >= 0
        c, pts = c[keep], coords[sv[keep]]
        np.minimum.at(mbr[:, 0], c, pts[:, 0])
        np.minimum.at(mbr[:, 1], c, pts[:, 1])
        np.maximum.at(mbr[:, 2], c, pts[:, 0])
        np.maximum.at(mbr[:, 3], c, pts[:, 1])
    if cond.dag_edges.size:
        # process one level at a time: np.minimum.at gathers dst values at
        # call time, so multi-hop propagation requires the same per-level
        # segmentation as the bitset closure
        edges = cond.edges_by_level_desc()
        src_lv = cond.level[edges[:, 0]]
        boundaries = np.nonzero(np.diff(src_lv))[0] + 1
        seg_starts = np.concatenate([[0], boundaries, [len(edges)]])
        for s, e in zip(seg_starts[:-1], seg_starts[1:]):
            src, dst = edges[s:e, 0], edges[s:e, 1]
            np.minimum.at(mbr[:, 0], src, mbr[dst, 0])
            np.minimum.at(mbr[:, 1], src, mbr[dst, 1])
            np.maximum.at(mbr[:, 2], src, mbr[dst, 2])
            np.maximum.at(mbr[:, 3], src, mbr[dst, 3])
    return mbr


# --------------------------------------------------------------------------
# Device (jit) closure — boolean fixpoint
# --------------------------------------------------------------------------

def closure_jax(
    n_comps: int,
    dag_edges: np.ndarray,
    own_bool: np.ndarray,
    n_sweeps: int,
) -> np.ndarray:
    """jit boolean closure: rows (d, p) bool; ``n_sweeps`` scatter-max
    sweeps (>= DAG depth guarantees convergence; one sweep propagates at
    least one DAG hop)."""
    if dag_edges.size == 0:
        return np.asarray(own_bool, dtype=bool)
    out = _closure_jax_impl(
        jnp.asarray(dag_edges, jnp.int32),
        jnp.asarray(own_bool, bool),
        n_sweeps,
    )
    return np.asarray(out)


@jax.jit
def _closure_sweep(bits, src, dst):
    return bits.at[src].max(bits[dst])


def _closure_jax_impl(edges, bits, n_sweeps):
    src, dst = edges[:, 0], edges[:, 1]
    for _ in range(int(n_sweeps)):
        bits = _closure_sweep(bits, src, dst)
    return bits


# --------------------------------------------------------------------------
# Device (packed) closure — the backend="device" build path
# --------------------------------------------------------------------------

def _leaf_row_scatter(
    rows: jax.Array, local: np.ndarray, dst: np.ndarray,
    own_indptr: np.ndarray, own_cols: np.ndarray,
) -> jax.Array:
    """OR the own columns of leaf components ``dst`` into packed device
    ``rows`` at row indices ``local``.  Distinct (row, column) pairs map
    to distinct bits, so a scatter-add is an OR."""
    cnt = np.diff(own_indptr)[dst]
    rep = np.repeat(local, cnt)
    slot = np.repeat(own_indptr[dst], cnt) + _ragged_arange(cnt)
    cc = own_cols[slot].astype(np.int64)
    return rows.at[
        jnp.asarray(rep, jnp.int32), jnp.asarray(cc // 32, jnp.int32)
    ].add(jnp.asarray(
        np.uint32(1) << (cc % 32).astype(np.uint32)))


def closure_bitset_mm(
    cond: Condensation,
    n: int,
    spatial_vertex: np.ndarray,
    extra_vertex_comp: Optional[Tuple[np.ndarray, np.ndarray]] = None,
    *,
    kernel: Optional[str] = None,
    interpret: Optional[bool] = None,
    chunk_edges: int = 1 << 22,
) -> ClosureResult:
    """Device closure: level-scheduled packed fixpoint R <- own | A.R.

    Produces a :class:`ClosureResult` with *identical* bits to
    ``closure_np`` (set union is order-independent), but the expensive
    per-level merges run on the accelerator against the packed uint32
    bitset matrix.  Scheduling is the frontier-compacted form of the
    reverse-topological sweep: level L touches only its source rows and
    the compacted block of their unique destinations, so rows that
    converged at deeper levels pay no further matmul work.

    kernel:    ``"pallas"`` — per level, pack the frontier adjacency and
               run the ``bitset_mm`` OR-AND matmul kernel (the TPU
               path); ``"xla"`` — per level, gather destination rows and
               OR-reduce runs by halving (the fast path on CPU hosts,
               where the Pallas interpreter would dominate);
               ``None`` picks per backend.
    interpret: Pallas interpret mode for ``kernel="pallas"``.
    """
    from ..kernels.bitset_mm.ops import bitset_mm_dev
    from ..kernels.forest_build.ops import default_build_kernel

    if kernel is None:
        kernel = default_build_kernel()
    if kernel not in ("pallas", "xla"):
        raise ValueError(
            f"unknown closure kernel {kernel!r}; expected pallas|xla")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    p, col_of_vertex, own_indptr, own_cols, interior_row, interior_ids = (
        _closure_prologue(cond, n, spatial_vertex, extra_vertex_comp))
    W = n_words(p)
    n_int = len(interior_ids)
    own_cnt = np.diff(own_indptr)

    # seed: every interior row starts as its own packed columns
    R = jnp.zeros((n_int, max(W, 1)), jnp.uint32)
    rr, cc = _seed_pairs(own_indptr, own_cols, interior_row)
    if len(rr):
        R = R.at[
            jnp.asarray(rr, jnp.int32), jnp.asarray(cc // 32, jnp.int32)
        ].add(jnp.asarray(np.uint32(1) << (cc % 32).astype(np.uint32)))

    if cond.dag_edges.size:
        edges = cond.edges_by_level_desc()
        src_lv = cond.level[edges[:, 0]]
        boundaries = np.nonzero(np.diff(-src_lv))[0] + 1
        seg_starts = np.concatenate([[0], boundaries, [len(edges)]])
        for s, e in zip(seg_starts[:-1], seg_starts[1:]):
            # chunk wide levels like closure_np does: the dense frontier
            # matrix is (chunk, W) words, never (level_width, W).  A
            # source run split across chunks just ORs into its row twice
            for cs in range(s, e, chunk_edges):
                ce = min(cs + chunk_edges, e)
                src = edges[cs:ce, 0].astype(np.int64)
                dst = edges[cs:ce, 1].astype(np.int64)
                if kernel == "pallas":
                    R = _level_step_pallas(
                        R, src, dst, interior_row, own_indptr, own_cols,
                        own_cnt, interpret, bitset_mm_dev)
                else:
                    R = _level_step_xla(
                        R, src, dst, interior_row, own_indptr, own_cols,
                        own_cnt)

    return ClosureResult(
        p=p,
        spatial_vertex=np.asarray(spatial_vertex, dtype=np.int32),
        col_of_vertex=col_of_vertex,
        interior_row=interior_row,
        bits=np.asarray(R[:, :W]).reshape(n_int, W),
        own_indptr=own_indptr,
        own_cols=own_cols,
    )


def _level_step_xla(
    R: jax.Array, src: np.ndarray, dst: np.ndarray,
    interior_row: np.ndarray, own_indptr: np.ndarray,
    own_cols: np.ndarray, own_cnt: np.ndarray,
) -> jax.Array:
    """One level as gather + bucketed halving-OR.

    Contributions (one packed row per DAG edge of the level — an
    interior destination's current row, or a leaf destination's own
    columns) land in a dense frontier matrix C; runs of equal source
    (contiguous: the level schedule preserves the source-sorted edge
    order) OR-reduce through power-of-two bucketed halving, then one
    scatter updates the level's source rows."""
    E = len(src)
    Wc = R.shape[1]
    C = jnp.zeros((E + 1, Wc), jnp.uint32)    # +1: zero pad row
    di = interior_row[dst]
    im = di >= 0
    if im.any():
        C = C.at[jnp.asarray(np.nonzero(im)[0], jnp.int32)].set(
            R[jnp.asarray(di[im], jnp.int32)])
    lm = ~im & (own_cnt[dst] > 0)
    if lm.any():
        C = _leaf_row_scatter(
            C, np.nonzero(lm)[0], dst[lm], own_indptr, own_cols)

    run_start = np.nonzero(np.r_[True, src[1:] != src[:-1]])[0]
    usrc = src[run_start]
    run_len = np.diff(np.r_[run_start, E])
    lb = np.ones(len(usrc), dtype=np.int64)
    big = run_len > 1
    lb[big] = 1 << np.ceil(np.log2(run_len[big])).astype(np.int64)
    for L in np.unique(lb):
        rid = np.nonzero(lb == L)[0]
        k = np.arange(L)
        gidx = run_start[rid][:, None] + k[None, :]
        gi = np.where(k[None, :] < run_len[rid][:, None], gidx, E)
        M = C[jnp.asarray(gi, jnp.int32)]      # (Rb, L, Wc)
        Lh = int(L)
        while Lh > 1:
            Lh //= 2
            M = M[:, :Lh] | M[:, Lh:2 * Lh]
        tr = jnp.asarray(interior_row[usrc[rid]], jnp.int32)
        R = R.at[tr].set(R[tr] | M[:, 0])
    return R


def _level_step_pallas(
    R: jax.Array, src: np.ndarray, dst: np.ndarray,
    interior_row: np.ndarray, own_indptr: np.ndarray,
    own_cols: np.ndarray, own_cnt: np.ndarray,
    interpret: bool, bitset_mm_dev,
) -> jax.Array:
    """One level as a frontier-compacted OR-AND matmul.

    The level's unique destinations become the contraction axis: their
    packed rows (gathered for interior comps, materialised from own
    columns for leaves) stack into R_L, the level's edges scatter into a
    packed frontier adjacency A_L, and the ``bitset_mm`` kernel computes
    all of the level's merges in one call."""
    udst, dst_inv = np.unique(dst, return_inverse=True)
    m = len(udst)
    Wm = (m + 31) // 32
    Wc = R.shape[1]

    R_L = jnp.zeros((m, Wc), jnp.uint32)
    di = interior_row[udst]
    im = di >= 0
    if im.any():
        R_L = R_L.at[jnp.asarray(np.nonzero(im)[0], jnp.int32)].set(
            R[jnp.asarray(di[im], jnp.int32)])
    lm = ~im & (own_cnt[udst] > 0)
    if lm.any():
        R_L = _leaf_row_scatter(
            R_L, np.nonzero(lm)[0], udst[lm], own_indptr, own_cols)

    run_start = np.nonzero(np.r_[True, src[1:] != src[:-1]])[0]
    usrc = src[run_start]
    f = len(usrc)
    src_local = np.searchsorted(usrc, src)
    A = jnp.zeros((f, Wm), jnp.uint32).at[
        jnp.asarray(src_local, jnp.int32),
        jnp.asarray(dst_inv // 32, jnp.int32),
    ].add(jnp.asarray(np.uint32(1) << (dst_inv % 32).astype(np.uint32)))

    out = bitset_mm_dev(A, R_L, interpret=interpret)
    tr = jnp.asarray(interior_row[usrc], jnp.int32)
    return R.at[tr].set(R[tr] | out)
