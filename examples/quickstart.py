"""Quickstart: build a 2DReach index and answer RangeReach queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import build_index, batch_query, index_nbytes
from repro.core import rangereach_oracle_batch
from repro.data import get_dataset, workload

# 1. a geosocial graph (scaled synthetic Gowalla: one giant social SCC,
#    87% of nodes are venues — see data/lbsn.py for the shaping)
g = get_dataset("gowalla", scale=0.1)
print(f"graph: {g.n_nodes} nodes, {g.n_edges} edges, "
      f"{g.n_spatial} spatial (venues)")

# 2. build the paper's index (compressed variant) and two baselines
for method in ("2dreach-comp", "2dreach-pointer", "3dreach"):
    idx = build_index(g, method)
    nb = index_nbytes(idx)
    print(f"{method:17s} size {nb['total'] / 1e6:6.2f} MB "
          f"(rtree {nb['rtree'] / 1e6:.2f} / aux {nb['aux'] / 1e6:.2f})")

# 3. a RangeReach workload (paper defaults: 5% region extent)
us, rects = workload(g, n_queries=200, extent_ratio=0.05, seed=0)
idx = build_index(g, "2dreach-comp")
ans = batch_query(idx, us, rects)
print(f"answered 200 queries, {int(ans.sum())} TRUE")

# 4. verify against the brute-force BFS oracle
want = rangereach_oracle_batch(g, us[:50], rects[:50])
assert (ans[:50] == want).all()
print("first 50 verified against BFS oracle: OK")

# 5. single-query API (the paper's Fig. 1 running example)
tiny = get_dataset("tiny")
idx = build_index(tiny, "2dreach-comp")
print("Fig.1 RangeReach(a, R) =", idx.query(0, [5.5, 1.5, 6.5, 2.5]))
