"""Packed, pointer-free R-tree forest with spatial bulk loading.

The paper uses boost's insert-based, pointer-chasing R-trees — one heap
allocation per node.  That representation is hostile to accelerators and
to checkpointing, so the TPU-native adaptation stores the *whole forest*
(one R-tree per SCC as paper Alg. 1 requires) in a handful of dense
arrays:

* Leaf **entries** are boxes ``(P, 2*dim)`` (points are degenerate boxes;
  3DReach-Rev's vertical line segments are real boxes), concatenated over
  trees in spatial sort order, ``entry_off`` giving each tree's slice.
* Bulk load = one global lexsort by ``(tree, morton(coord))`` — the
  vectorised equivalent of Sort-Tile-Recursive (what flatbush/Hilbert
  packing does in production); consecutive groups of ``fanout`` entries
  form the leaf nodes.
* Every upper level is a dense ``(count_l, 2*dim)`` MBR array; the child
  range of local node ``j`` is arithmetic: ``[j*F, min((j+1)*F, c_below))``
  — no pointers anywhere.
* All trees are padded to the forest's max depth by repeating their root,
  so a batched query kernel descends uniformly from ``level D-1`` with
  exactly one root per tree.

Query engines:

* ``query_host``          — vectorised NumPy ragged-wavefront descent with
                            per-query early exit (benchmark engine).
* ``query_jax_wavefront`` — jit fixed-capacity wavefront (device engine).
* the ``range_query`` Pallas kernel consumes ``entries`` + ``entry_off``
  directly (tiled leaf scan, OR-reduce per query) — see kernels/.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..obs import span


DEFAULT_FANOUT = 16


# --------------------------------------------------------------------------
# Morton order
# --------------------------------------------------------------------------

def _part1by1(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64) & np.uint64(0xFFFF)
    x = (x | (x << np.uint64(8))) & np.uint64(0x00FF00FF)
    x = (x | (x << np.uint64(4))) & np.uint64(0x0F0F0F0F)
    x = (x | (x << np.uint64(2))) & np.uint64(0x33333333)
    x = (x | (x << np.uint64(1))) & np.uint64(0x55555555)
    return x


def _part1by2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64) & np.uint64(0x3FF)
    x = (x | (x << np.uint64(16))) & np.uint64(0x030000FF)
    x = (x | (x << np.uint64(8))) & np.uint64(0x0300F00F)
    x = (x | (x << np.uint64(4))) & np.uint64(0x030C30C3)
    x = (x | (x << np.uint64(2))) & np.uint64(0x09249249)
    return x


def morton_code(centers: np.ndarray, extent: np.ndarray) -> np.ndarray:
    """Interleaved Morton code of box centers for bulk-load ordering.

    ``extent`` is the global [mins, maxs] (2*dim,) used to quantise.
    """
    dim = centers.shape[1]
    lo = extent[:dim].astype(np.float64)
    hi = extent[dim:].astype(np.float64)
    span = np.where(hi > lo, hi - lo, 1.0)
    unit = np.clip((centers.astype(np.float64) - lo) / span, 0.0, 1.0)
    if dim == 2:
        q = (unit * 0xFFFF).astype(np.uint64)
        return _part1by1(q[:, 0]) | (_part1by1(q[:, 1]) << np.uint64(1))
    elif dim == 3:
        q = (unit * 0x3FF).astype(np.uint64)
        return (
            _part1by2(q[:, 0])
            | (_part1by2(q[:, 1]) << np.uint64(1))
            | (_part1by2(q[:, 2]) << np.uint64(2))
        )
    raise ValueError(f"dim {dim} unsupported")


# --------------------------------------------------------------------------
# Forest container
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RTreeForest:
    """Packed forest of R-trees; see module docstring for layout.

    Levels are numbered 0 (leaf MBRs) .. depth-1 (roots); ``level_mbr[l]``
    is the global (count_l, 2*dim) array for level l, nodes of tree t at
    ``tree_off[l][t] : tree_off[l][t+1]``.
    """

    dim: int
    fanout: int
    entries: np.ndarray            # (P, 2*dim) float32 leaf boxes
    entry_ids: np.ndarray          # (P,) int32 payload (original vertex id)
    entry_off: np.ndarray          # (T+1,) int64
    level_mbr: List[np.ndarray]    # depth arrays, each (count_l, 2*dim)
    tree_off: List[np.ndarray]     # depth arrays, each (T+1,) int64
    # device-resident serving arrays (set by ``build_forest_device``);
    # engines adopt these instead of re-uploading the host arrays
    device: Optional["DeviceForest"] = None

    @property
    def n_trees(self) -> int:
        return len(self.entry_off) - 1

    @property
    def depth(self) -> int:
        return len(self.level_mbr)

    def nbytes_nodes(self) -> int:
        return int(sum(l.nbytes for l in self.level_mbr))

    def nbytes_entries(self) -> int:
        return int(self.entries.nbytes)

    def nbytes_total(self) -> int:
        return (
            self.nbytes_nodes()
            + self.nbytes_entries()
            + int(self.entry_ids.nbytes)
            + int(self.entry_off.nbytes)
            + int(sum(o.nbytes for o in self.tree_off))
        )

    def tree_n_entries(self) -> np.ndarray:
        return np.diff(self.entry_off)

    # -- device views ----------------------------------------------------
    def device_arrays(self):
        """Pad per-level arrays into stacked device tensors for the jit
        wavefront engine: mbr (D, Nmax, 2*dim), off (D, T+1)."""
        D = self.depth
        nmax = max(int(l.shape[0]) for l in self.level_mbr) if D else 0
        T = self.n_trees
        mbr = np.zeros((D, nmax, 2 * self.dim), dtype=np.float32)
        # empty padding boxes must never intersect: min > max
        mbr[..., : self.dim] = 1.0
        mbr[..., self.dim:] = 0.0
        off = np.zeros((D, T + 1), dtype=np.int64)
        for l in range(D):
            mbr[l, : len(self.level_mbr[l])] = self.level_mbr[l]
            off[l] = self.tree_off[l]
        return jnp.asarray(mbr), jnp.asarray(off)


def build_forest(
    boxes: np.ndarray,
    ids: np.ndarray,
    tree_of_entry: np.ndarray,
    n_trees: int,
    fanout: int = DEFAULT_FANOUT,
    extent: Optional[np.ndarray] = None,
) -> RTreeForest:
    """Bulk-load a forest.

    Parameters
    ----------
    boxes:          (P, 2*dim) leaf boxes ([mins, maxs]); for point data
                    pass ``np.concatenate([pts, pts], axis=1)``.
    ids:            (P,) payload ids.
    tree_of_entry:  (P,) tree assignment in [0, n_trees).
    """
    boxes = np.asarray(boxes, dtype=np.float32)
    P, two_dim = boxes.shape
    dim = two_dim // 2
    ids = np.asarray(ids, dtype=np.int32)
    tree_of_entry = np.asarray(tree_of_entry, dtype=np.int64)

    if extent is None:
        if P:
            extent = np.concatenate(
                [boxes[:, :dim].min(0), boxes[:, dim:].max(0)]
            )
        else:
            extent = np.zeros(2 * dim, dtype=np.float32)

    centers = (boxes[:, :dim] + boxes[:, dim:]) * 0.5
    code = morton_code(centers, np.asarray(extent)) if P else np.zeros(0, np.uint64)
    order = np.lexsort((code, tree_of_entry)) if P else np.zeros(0, np.int64)
    boxes = boxes[order]
    ids = ids[order]
    sorted_tree = tree_of_entry[order]

    counts = np.bincount(sorted_tree, minlength=n_trees).astype(np.int64)
    entry_off = np.zeros(n_trees + 1, dtype=np.int64)
    np.cumsum(counts, out=entry_off[1:])

    level_mbr: List[np.ndarray] = []
    tree_off: List[np.ndarray] = []
    cur_boxes = boxes
    cur_counts = counts
    while True:
        node_counts = -(-cur_counts // fanout)  # ceil div; 0 stays 0
        off = np.zeros(n_trees + 1, dtype=np.int64)
        np.cumsum(node_counts, out=off[1:])
        n_nodes = int(off[-1])
        mbr = np.empty((n_nodes, 2 * dim), dtype=np.float32)
        if n_nodes:
            # segment boundaries of each node's children in the packed
            # child-level array
            child_off = np.zeros(n_trees + 1, dtype=np.int64)
            np.cumsum(cur_counts, out=child_off[1:])
            # start index of node j of tree t = child_off[t] + j*fanout
            node_tree = np.repeat(np.arange(n_trees), node_counts)
            local = _ragged_arange(node_counts)
            starts = child_off[node_tree] + local * fanout
            ends = np.minimum(starts + fanout, child_off[node_tree + 1])
            # reduceat over [starts, ends) — contiguous coverage lets us use
            # reduceat with the starts only (segments tile the child array)
            mbr[:, :dim] = np.minimum.reduceat(cur_boxes[:, :dim], starts, axis=0)
            mbr[:, dim:] = np.maximum.reduceat(cur_boxes[:, dim:], starts, axis=0)
            # reduceat caveat: a start equal to the next start (empty tree)
            # cannot occur because node_counts==0 trees emit no nodes; a
            # final segment runs to the end of cur_boxes which is correct.
            del ends
        level_mbr.append(mbr)
        tree_off.append(off)
        if np.all(node_counts <= 1):
            break
        cur_boxes = mbr
        cur_counts = node_counts

    return RTreeForest(
        dim=dim,
        fanout=fanout,
        entries=boxes,
        entry_ids=ids,
        entry_off=entry_off,
        level_mbr=level_mbr,
        tree_off=tree_off,
    )


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)


# --------------------------------------------------------------------------
# Device bulk load (backend="device" build pipeline)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceForest:
    """Device-resident serving arrays produced by ``build_forest_device``.

    Exactly the arrays :class:`~repro.core.engine.TileArena` consumes —
    SoA entry planes plus the fine/coarse tile-MBR pyramid — already on
    device, so engines *adopt* them instead of re-transposing and
    re-uploading the host forest (the zero-copy handoff).
    """

    entries: jax.Array     # (2*dim, Pp) float32 SoA planes, inert padding
    fine: jax.Array        # (2*dim, NTp) float32 leaf-tile MBRs
    coarse: jax.Array      # (2*dim, NCp) float32
    entry_off: jax.Array   # (T+1,) int32
    n_tiles: int


def _part1by1_jnp(x: jax.Array) -> jax.Array:
    x = x & np.uint64(0xFFFF)
    x = (x | (x << np.uint64(8))) & np.uint64(0x00FF00FF)
    x = (x | (x << np.uint64(4))) & np.uint64(0x0F0F0F0F)
    x = (x | (x << np.uint64(2))) & np.uint64(0x33333333)
    x = (x | (x << np.uint64(1))) & np.uint64(0x55555555)
    return x


def _part1by2_jnp(x: jax.Array) -> jax.Array:
    x = x & np.uint64(0x3FF)
    x = (x | (x << np.uint64(16))) & np.uint64(0x030000FF)
    x = (x | (x << np.uint64(8))) & np.uint64(0x0300F00F)
    x = (x | (x << np.uint64(4))) & np.uint64(0x030C30C3)
    x = (x | (x << np.uint64(2))) & np.uint64(0x09249249)
    return x


def _morton_code_jnp(centers: jax.Array, lo: jax.Array,
                     hi: jax.Array) -> jax.Array:
    """Device mirror of ``morton_code`` — identical float64 math, so the
    codes (and hence the bulk-load order) are bit-identical to the host
    build.  Must run under ``enable_x64``."""
    dim = centers.shape[1]
    span = jnp.where(hi > lo, hi - lo, 1.0)
    unit = jnp.clip((centers.astype(jnp.float64) - lo) / span, 0.0, 1.0)
    if dim == 2:
        q = (unit * 0xFFFF).astype(jnp.uint64)
        return _part1by1_jnp(q[:, 0]) | (_part1by1_jnp(q[:, 1]) << np.uint64(1))
    elif dim == 3:
        q = (unit * 0x3FF).astype(jnp.uint64)
        return (
            _part1by2_jnp(q[:, 0])
            | (_part1by2_jnp(q[:, 1]) << np.uint64(1))
            | (_part1by2_jnp(q[:, 2]) << np.uint64(2))
        )
    raise ValueError(f"dim {dim} unsupported")


@jax.jit
def _morton_key_jit(soa: jax.Array, lo: jax.Array, hi: jax.Array
                    ) -> jax.Array:
    """(P,) uint64 sort keys ``morton_code << 32 | entry_index``, fused
    into one pass over the entry planes.  Runs under ``enable_x64``."""
    dim = soa.shape[0] // 2
    centers = ((soa[:dim] + soa[dim:]) * 0.5).T       # (P, dim) f32
    code = _morton_code_jnp(centers, lo, hi)
    P = soa.shape[1]
    return (code << np.uint64(32)) | jnp.arange(P, dtype=jnp.uint64)


@partial(jax.jit, static_argnames=("L",), donate_argnums=(3,))
def _bucket_sort_step(key, starts, cnts, order, *, L: int):
    P = key.shape[0]
    idx = starts[:, None] + jnp.arange(L, dtype=jnp.int32)[None, :]
    mask = idx < (starts + cnts)[:, None]
    km = jnp.where(
        mask,
        key[jnp.clip(idx, 0, max(P - 1, 0))],
        np.uint64(0xFFFFFFFFFFFFFFFF),
    )
    sm = jnp.sort(km, axis=1)
    perm = (sm & np.uint64(0xFFFFFFFF)).astype(jnp.int32)
    return order.at[jnp.where(mask, idx, P)].set(perm, mode="drop")


def _bucketed_tree_sort(
    key: jax.Array,         # (P,) uint64 keys: code << 32 | entry index
    entry_off: np.ndarray,  # (T+1,) int64 per-tree slices (generation order)
    counts: np.ndarray,     # (T,) int64
) -> jax.Array:
    """(P,) int32 device permutation = ``np.lexsort((code, tree))``.

    XLA's fast sort path is values-only (payload sorts fall back to a
    comparator network an order of magnitude slower), so the permutation
    is packed *into* the key and the per-tree segments become rows of
    power-of-two-bucketed matrices sorted along the lanes.  Tree
    separation comes from the rows (no tree bits in the key), ties
    resolve by entry index (exactly ``np.lexsort`` stability), and
    padding keys of all-ones sort to the end of every row.  Each bucket
    runs as one fused jit with row counts padded to powers of two, so
    repeated builds (compaction swaps) reuse a handful of traces.
    Must run under ``enable_x64``.
    """
    P = int(counts.sum())
    order = jnp.zeros(P, dtype=jnp.int32)
    lb = np.ones(len(counts), dtype=np.int64)
    pos = counts > 0
    lb[pos] = 1 << np.ceil(np.log2(counts[pos])).astype(np.int64)
    for L in np.unique(lb[pos]):
        trees = np.nonzero(pos & (lb == L))[0]
        rb = 1 << int(np.ceil(np.log2(len(trees)))) if len(trees) else 1
        starts = np.zeros(rb, dtype=np.int32)
        cnts = np.zeros(rb, dtype=np.int32)
        starts[: len(trees)] = entry_off[trees]
        cnts[: len(trees)] = counts[trees]
        order = _bucket_sort_step(
            key, jnp.asarray(starts), jnp.asarray(cnts), order, L=int(L))
    return order


def build_forest_device(
    boxes: np.ndarray,
    ids: np.ndarray,
    tree_of_entry: np.ndarray,
    n_trees: int,
    fanout: int = DEFAULT_FANOUT,
    extent: Optional[np.ndarray] = None,
    *,
    kernel: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> RTreeForest:
    """Bulk-load a forest on the accelerator (same contract — and same
    resulting arrays, bit for bit — as :func:`build_forest`).

    The pipeline stays device-resident end to end: Morton encode (jnp,
    float64 math identical to host), one bucketed ``(tree, code)``
    values-only key sort, then the segmented-MBR reduction of
    :mod:`repro.kernels.forest_build` builds every R-tree node level and
    the query engines' fine/coarse tile pyramid.  The returned forest
    carries host mirrors of every array (so ``query_host`` and
    checkpointing work unchanged) plus a :class:`DeviceForest` handoff
    (``forest.device``) that engines adopt without re-uploading.

    ``tree_of_entry`` must be non-decreasing (entries generated per tree
    in tree order — what ``build_2dreach`` produces); the device sort
    exploits that contiguity for its segmented bucketing.

    kernel:    ``"pallas"`` (the TPU reduction kernel) or ``"xla"`` (jnp
               reduction, the fast path on CPU hosts); ``None`` picks
               per backend.
    interpret: Pallas interpret mode for ``kernel="pallas"``; ``None``
               picks real kernels on TPU and interpret elsewhere.
    """
    from ..kernels.forest_build import (
        default_build_kernel,
        level_mbr,
        np_inert_plane,
        tile_pyramid_device,
    )
    from ..kernels.range_query.descent import COARSE_GROUP, TPT
    from ..kernels.range_query.kernel import TP

    if kernel is None:
        kernel = default_build_kernel()
    if kernel not in ("pallas", "xla"):
        raise ValueError(
            f"unknown forest-build kernel {kernel!r}; expected pallas|xla")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    boxes = np.asarray(boxes, dtype=np.float32)
    P, two_dim = boxes.shape
    dim = two_dim // 2
    ids = np.asarray(ids, dtype=np.int32)
    tree_of_entry = np.asarray(tree_of_entry, dtype=np.int64)
    if P and (np.diff(tree_of_entry) < 0).any():
        raise ValueError(
            "build_forest_device requires tree-contiguous input entries "
            "(tree_of_entry non-decreasing)")

    if extent is None:
        if P:
            extent = np.concatenate(
                [boxes[:, :dim].min(0), boxes[:, dim:].max(0)]
            )
        else:
            extent = np.zeros(2 * dim, dtype=np.float32)
    extent = np.asarray(extent)

    counts = np.bincount(tree_of_entry, minlength=n_trees).astype(np.int64)
    entry_off = np.zeros(n_trees + 1, dtype=np.int64)
    np.cumsum(counts, out=entry_off[1:])

    # ---- device sort: morton encode + bucketed (tree, code) key sort ----
    with span("build.forest.morton_sort", cat="build", entries=int(P)):
        Pp = max(TP, -(-P // TP) * TP)
        soa_ext = jnp.concatenate([
            jnp.asarray(np.ascontiguousarray(boxes.T)),
            jnp.asarray(np_inert_plane(dim, 1)),   # padding gather target
        ], axis=1)                                          # (2*dim, P+1)
        if P:
            with enable_x64():
                key = _morton_key_jit(
                    soa_ext[:, :P],
                    jnp.asarray(extent[:dim], jnp.float64),
                    jnp.asarray(extent[dim:], jnp.float64),
                )
                order = _bucketed_tree_sort(key, entry_off, counts)
            # one gather builds the permuted AND padded serving plane
            order_pad = jnp.concatenate([
                order, jnp.full((Pp - P,), P, jnp.int32)])
            plane = soa_ext[:, order_pad]                   # (2*dim, Pp)
            ids_host = np.asarray(jnp.asarray(ids)[order])
        else:
            plane = jnp.asarray(np_inert_plane(dim, Pp))
            ids_host = ids
        boxes_host = np.ascontiguousarray(np.asarray(plane[:, :P]).T)

    # ---- level loop: fused segmented-MBR reduction per R-tree level -----
    level_mbrs: List[np.ndarray] = []
    tree_off: List[np.ndarray] = []
    cur_soa = plane          # level 0 gathers only indices < P
    cur_counts = counts
    with span("build.forest.mbr_reduce", cat="build", entries=int(P)):
        while True:
            node_counts = -(-cur_counts // fanout)  # ceil div; 0 stays 0
            off = np.zeros(n_trees + 1, dtype=np.int64)
            np.cumsum(node_counts, out=off[1:])
            n_nodes = int(off[-1])
            if n_nodes:
                child_off = np.zeros(n_trees + 1, dtype=np.int64)
                np.cumsum(cur_counts, out=child_off[1:])
                node_tree = np.repeat(np.arange(n_trees), node_counts)
                local = _ragged_arange(node_counts)
                starts = child_off[node_tree] + local * fanout
                ends = np.minimum(
                    starts + fanout, child_off[node_tree + 1])
                mbr_soa = level_mbr(cur_soa, starts, ends, fanout, dim,
                                    kernel=kernel, interpret=interpret)
            else:
                mbr_soa = jnp.zeros((2 * dim, 0), jnp.float32)
            level_mbrs.append(
                np.ascontiguousarray(np.asarray(mbr_soa[:, :n_nodes]).T))
            tree_off.append(off)
            if np.all(node_counts <= 1):
                break
            cur_soa = mbr_soa  # padded tail rows are inert, never used
            cur_counts = node_counts

    # ---- device serving arrays (the zero-copy engine handoff) ----------
    with span("build.forest.pyramid", cat="build", entries=int(P)):
        fine, coarse, nt = tile_pyramid_device(
            plane, dim, tp=TP, tpt=TPT, group=COARSE_GROUP,
            kernel=kernel, interpret=interpret,
        )

    forest = RTreeForest(
        dim=dim,
        fanout=fanout,
        entries=boxes_host,
        entry_ids=ids_host,
        entry_off=entry_off,
        level_mbr=level_mbrs,
        tree_off=tree_off,
        device=DeviceForest(
            entries=plane,
            fine=fine,
            coarse=coarse,
            entry_off=jnp.asarray(entry_off, jnp.int32),
            n_tiles=nt,
        ),
    )
    return forest


def intersects(boxes: np.ndarray, rect: np.ndarray, dim: int) -> np.ndarray:
    """boxes (..., 2*dim) vs rect broadcastable (..., 2*dim) AABB test."""
    lo_ok = boxes[..., :dim] <= rect[..., dim:]
    hi_ok = boxes[..., dim:] >= rect[..., :dim]
    return np.all(lo_ok & hi_ok, axis=-1)


# --------------------------------------------------------------------------
# Host batched query engine (ragged wavefront)
# --------------------------------------------------------------------------

def query_host(
    forest: RTreeForest,
    tree_ids: np.ndarray,
    rects: np.ndarray,
) -> np.ndarray:
    """Batched "does tree contain any entry intersecting rect" probe.

    tree_ids: (B,) int; rects: (B, 2*dim). Returns (B,) bool. Trees with
    id < 0 answer False (empty reachable set).
    """
    dim = forest.dim
    F = forest.fanout
    B = len(tree_ids)
    tree_ids = np.asarray(tree_ids, dtype=np.int64)
    rects = np.asarray(rects, dtype=np.float32).reshape(B, 2 * dim)
    hit = np.zeros(B, dtype=bool)

    valid = tree_ids >= 0
    if forest.depth == 0 or not valid.any():
        return hit
    top = forest.depth - 1
    top_off = forest.tree_off[top]
    has_root = np.zeros(B, dtype=bool)
    has_root[valid] = (
        top_off[tree_ids[valid] + 1] - top_off[tree_ids[valid]]
    ) > 0
    q = np.nonzero(has_root)[0]
    node = top_off[tree_ids[q]]  # global root index (one root per tree)

    for l in range(top, -1, -1):
        if q.size == 0:
            break
        ok = intersects(forest.level_mbr[l][node], rects[q], dim) & ~hit[q]
        q, node = q[ok], node[ok]
        if q.size == 0:
            break
        t = tree_ids[q]
        if l > 0:
            below_off = forest.tree_off[l - 1]
            local = node - forest.tree_off[l][t]
            c_start = below_off[t] + local * F
            c_end = np.minimum(c_start + F, below_off[t + 1])
        else:
            local = node - forest.tree_off[0][t]
            c_start = forest.entry_off[t] + local * F
            c_end = np.minimum(c_start + F, forest.entry_off[t + 1])
        cnt = (c_end - c_start).astype(np.int64)
        nq = np.repeat(q, cnt)
        child = np.repeat(c_start, cnt) + _ragged_arange(cnt)
        if l > 0:
            q, node = nq, child
        else:
            leaf_ok = intersects(forest.entries[child], rects[nq], dim)
            np.logical_or.at(hit, nq[leaf_ok], True)
            q = np.zeros(0, dtype=np.int64)
    return hit


def query_host_collect(
    forest: RTreeForest, tree_id: int, rect: np.ndarray
) -> np.ndarray:
    """Single-tree probe returning the payload ids of ALL hits (used by
    tests and the GeoReach grid tier)."""
    if tree_id < 0:
        return np.zeros(0, dtype=np.int32)
    dim = forest.dim
    rect = np.asarray(rect, dtype=np.float32)
    s, e = forest.entry_off[tree_id], forest.entry_off[tree_id + 1]
    boxes = forest.entries[s:e]
    ok = intersects(boxes, rect, dim)
    return forest.entry_ids[s:e][ok]


def _descend_leaves(forest: RTreeForest, tree_ids: np.ndarray,
                    rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Shared ragged-wavefront descent (no early exit): returns
    ``(qi, leaf)`` — for every (query, leaf entry) pair whose leaf box
    intersects the query rect, the query index and global entry index.
    Each pair appears exactly once (trees are proper trees), which is
    what makes the count/collect variants exact."""
    dim = forest.dim
    F = forest.fanout
    B = len(tree_ids)
    tree_ids = np.asarray(tree_ids, dtype=np.int64)
    rects = np.asarray(rects, dtype=np.float32).reshape(B, 2 * dim)
    empty = (np.zeros(0, dtype=np.int64),) * 2
    valid = tree_ids >= 0
    if forest.depth == 0 or not valid.any():
        return empty
    top = forest.depth - 1
    top_off = forest.tree_off[top]
    has_root = np.zeros(B, dtype=bool)
    has_root[valid] = (
        top_off[tree_ids[valid] + 1] - top_off[tree_ids[valid]]
    ) > 0
    q = np.nonzero(has_root)[0]
    node = top_off[tree_ids[q]]

    for l in range(top, -1, -1):
        if q.size == 0:
            return empty
        ok = intersects(forest.level_mbr[l][node], rects[q], dim)
        q, node = q[ok], node[ok]
        if q.size == 0:
            return empty
        t = tree_ids[q]
        if l > 0:
            below_off = forest.tree_off[l - 1]
            local = node - forest.tree_off[l][t]
            c_start = below_off[t] + local * F
            c_end = np.minimum(c_start + F, below_off[t + 1])
        else:
            local = node - forest.tree_off[0][t]
            c_start = forest.entry_off[t] + local * F
            c_end = np.minimum(c_start + F, forest.entry_off[t + 1])
        cnt = (c_end - c_start).astype(np.int64)
        nq = np.repeat(q, cnt)
        child = np.repeat(c_start, cnt) + _ragged_arange(cnt)
        if l > 0:
            q, node = nq, child
        else:
            leaf_ok = intersects(forest.entries[child], rects[nq], dim)
            return nq[leaf_ok], child[leaf_ok]
    return empty


def query_host_count(
    forest: RTreeForest,
    tree_ids: np.ndarray,
    rects: np.ndarray,
) -> np.ndarray:
    """Batched "how many entries of tree t intersect rect" descent.

    tree_ids: (B,) int (< 0 answers 0); rects (B, 2*dim).  Returns (B,)
    int64 exact counts — the host oracle for the device count kernel.
    """
    qi, _ = _descend_leaves(forest, tree_ids, rects)
    counts = np.zeros(len(tree_ids), dtype=np.int64)
    if qi.size:
        np.add.at(counts, qi, 1)
    return counts


def query_host_collect_batch(
    forest: RTreeForest,
    tree_ids: np.ndarray,
    rects: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Batched collect descent: all hit payload ids per query.

    Returns ``(indptr (B+1,) int64, ids int32)`` in CSR form — query
    b's hits are ``ids[indptr[b]:indptr[b+1]]``, sorted ascending by
    payload id (the canonical collect order every engine reproduces).
    """
    B = len(tree_ids)
    qi, leaf = _descend_leaves(forest, tree_ids, rects)
    indptr = np.zeros(B + 1, dtype=np.int64)
    if qi.size == 0:
        return indptr, np.zeros(0, dtype=np.int32)
    ids = forest.entry_ids[leaf]
    order = np.lexsort((ids, qi))
    qi, ids = qi[order], ids[order]
    np.cumsum(np.bincount(qi, minlength=B), out=indptr[1:])
    return indptr, ids.astype(np.int32)


def _mindist2(box: np.ndarray, p: np.ndarray, dim: int) -> float:
    """Squared Euclidean point-to-box distance, float64."""
    d2 = 0.0
    for a in range(dim):
        lo, hi = float(box[a]), float(box[dim + a])
        dx = lo - p[a] if p[a] < lo else (p[a] - hi if p[a] > hi else 0.0)
        d2 += dx * dx
    return d2


def query_host_knn(
    forest: RTreeForest,
    tree_id: int,
    point: np.ndarray,
    k: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """k nearest entries of one tree to ``point`` — best-first
    branch-and-bound with a node priority queue (mindist² lower bounds).

    Returns ``(ids (<=k,) int32, dist2 (<=k,) float64)`` ordered by
    ``(dist², id)`` ascending — distances in float64 over the float32
    coordinates, the canonical kNN order every engine reproduces.  Ties
    at the kth distance resolve by payload id, so the heap keeps
    popping until the next lower bound strictly exceeds the running
    kth-smallest distance before the final sort.
    """
    import heapq

    if tree_id < 0 or k <= 0 or forest.depth == 0:
        return np.zeros(0, dtype=np.int32), np.zeros(0, dtype=np.float64)
    dim = forest.dim
    F = forest.fanout
    p = np.asarray(point, dtype=np.float64).reshape(dim)
    top = forest.depth - 1
    top_off = forest.tree_off[top]
    if top_off[tree_id + 1] - top_off[tree_id] <= 0:
        return np.zeros(0, dtype=np.int32), np.zeros(0, dtype=np.float64)

    # heap items: (mindist2, seq, level, global node index); level -1
    # marks a leaf entry (exact distance)
    seq = 0
    heap = [(0.0, seq, top, int(top_off[tree_id]))]
    got: list = []          # (dist2, id) of popped entries
    kth = np.inf            # running kth-smallest entry distance
    while heap:
        d2, _, l, node = heapq.heappop(heap)
        if len(got) >= k and d2 > kth:
            break           # no remaining node/entry can enter the top-k
        if l == -1:
            got.append((d2, int(forest.entry_ids[node])))
            if len(got) >= k:
                kth = np.partition(
                    np.array([g[0] for g in got]), k - 1)[k - 1]
            continue
        t = tree_id
        if l > 0:
            below_off = forest.tree_off[l - 1]
            local = node - forest.tree_off[l][t]
            c_start = below_off[t] + local * F
            c_end = min(c_start + F, below_off[t + 1])
            boxes = forest.level_mbr[l - 1]
            nl = l - 1
        else:
            local = node - forest.tree_off[0][t]
            c_start = forest.entry_off[t] + local * F
            c_end = min(c_start + F, forest.entry_off[t + 1])
            boxes = forest.entries
            nl = -1
        for c in range(int(c_start), int(c_end)):
            cd2 = _mindist2(boxes[c], p, dim)
            if len(got) >= k and cd2 > kth:
                continue
            seq += 1
            heapq.heappush(heap, (cd2, seq, nl, c))
    if not got:
        return np.zeros(0, dtype=np.int32), np.zeros(0, dtype=np.float64)
    arr_d = np.array([g[0] for g in got], dtype=np.float64)
    arr_i = np.array([g[1] for g in got], dtype=np.int64)
    order = np.lexsort((arr_i, arr_d))[:k]
    return arr_i[order].astype(np.int32), arr_d[order]


# --------------------------------------------------------------------------
# Device batched query engine (fixed-capacity wavefront)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("fanout", "dim", "capacity"))
def _wavefront_impl(mbr, off, entry_boxes, entry_off, tree_ids, rects,
                    fanout, dim, capacity):
    D = mbr.shape[0]
    B = tree_ids.shape[0]

    def isect(boxes, rect):
        # boxes (B, K, 2*dim) vs rect (B, 2*dim)
        lo_ok = boxes[..., :dim] <= rect[:, None, dim:]
        hi_ok = boxes[..., dim:] >= rect[:, None, :dim]
        return jnp.all(lo_ok & hi_ok, axis=-1)

    valid = tree_ids >= 0
    t = jnp.maximum(tree_ids, 0)
    # frontier: (B, capacity) global node ids at current level, -1 = empty
    root = off[D - 1][t]
    has_root = (off[D - 1][t + 1] - root) > 0
    frontier = jnp.full((B, capacity), -1, dtype=jnp.int32)
    frontier = frontier.at[:, 0].set(jnp.where(valid & has_root, root, -1))
    overflow = jnp.zeros((B,), dtype=bool)
    hit = jnp.zeros((B,), dtype=bool)

    for l in range(D - 1, -1, -1):
        fmask = frontier >= 0
        node = jnp.maximum(frontier, 0)
        ok = isect(mbr[l][node], rects) & fmask    # (B, C)
        local = node - off[l][t][:, None]
        if l == 0:
            base, bound = entry_off[t][:, None], entry_off[t + 1][:, None]
        else:
            base, bound = off[l - 1][t][:, None], off[l - 1][t + 1][:, None]
        c_start = base + local * fanout
        c_end = jnp.minimum(c_start + fanout, bound)
        child = c_start[..., None] + jnp.arange(fanout)      # (B, C, F)
        cmask = ok[..., None] & (child < c_end[..., None])
        child_flat = jnp.where(cmask, child, -1).reshape(B, -1)
        if l == 0:
            eb = entry_boxes[jnp.maximum(child_flat, 0)]
            hit = hit | jnp.any(
                isect(eb, rects) & (child_flat >= 0), axis=1
            )
        else:
            cnt = (child_flat >= 0).sum(axis=1)
            overflow = overflow | (cnt > capacity)
            # descending sort puts valid children first; if cnt <= capacity
            # nothing is lost
            cand = -jnp.sort(-child_flat, axis=1)
            frontier = cand[:, :capacity]
    return hit, overflow


def query_jax_wavefront(
    forest: RTreeForest,
    tree_ids: np.ndarray,
    rects: np.ndarray,
    capacity: int = 128,
) -> Tuple[np.ndarray, np.ndarray]:
    """jit wavefront probe. Returns (hit, overflow); entries of queries
    whose frontier overflowed ``capacity`` must be recomputed on host
    (callers assert ~overflow in tests; production falls back)."""
    mbr, off = forest.device_arrays()
    hit, overflow = _wavefront_impl(
        mbr,
        off,
        jnp.asarray(forest.entries),
        jnp.asarray(forest.entry_off, jnp.int32),
        jnp.asarray(tree_ids, jnp.int32),
        jnp.asarray(rects, jnp.float32),
        forest.fanout,
        forest.dim,
        capacity,
    )
    return np.asarray(hit), np.asarray(overflow)
