"""KNNReach — k nearest *reachable* venues to a query point.

Two engines, one canonical answer (the exact k nearest by ``(dist²,
vertex id)`` ascending, distances float64 over the float32 coords):

* **host** (:func:`knn_reach_host`) — classic best-first branch-and-
  bound over the packed R-tree (``core.rtree.query_host_knn``): a
  priority queue of nodes ordered by mindist² lower bounds, popped
  until no subtree can beat the running kth distance.

* **device** (:func:`knn_radius_doubling`) — a radius-doubling loop
  over the engine's compile-once RangeCount/RangeCollect: grow a square
  region around the query point until it counts >= k reachable venues
  (or provably covers the whole venue extent), bound the kth distance
  by the box diagonal, then collect *every* venue inside the bounding
  disk's box and select the exact top-k by true distance.  All boxes
  are rounded outward (float64 -> float32 nextafter) so the candidate
  superset provably contains the true top-k; the final NumPy selection
  makes the answer bit-identical to the host descent.

  On a fused-path engine the loop **hoists the routing state**: the
  vertex→tree lookup (``qs``/``qe``/coords/excluded) is computed once
  on the padded batch and every doubling iteration re-enters only the
  fused prune+scan trace with the new rects (one dispatch per round
  instead of a full ``count_batch`` re-route).  Doubling rounds are
  capped at :data:`_MAX_DOUBLINGS`; queries still unresolved at the cap
  (a query point astronomically far from the venue extent) fall back to
  the exact host best-first descent — the same top-up already used for
  collect overflow — so the answer stays bit-identical.

Both resolve the Alg. 2 spatial-sink special case first: an excluded
query vertex reaches exactly itself.
"""

from __future__ import annotations

import numpy as np

from ..core.engine import _bucket
from ..core.polygon import round_bounds_outward
from ..core.rtree import query_host_knn
from ..core.two_d_reach import TwoDReachIndex
from ..obs import span
from .program import KNNResult

# Doubling-round cap: the initial radius is extent-span / 2^16, so ~17
# rounds reach a box covering the whole extent from any in-extent point;
# the slack covers far-out points before the exact host top-up takes
# over (capped rounds + top-up replaces the old unbounded 128-round
# loop that raised on non-convergence).
_MAX_DOUBLINGS = 24


def _fused_count(engine, us_sub: np.ndarray, rects: np.ndarray,
                 state: dict) -> np.ndarray:
    """One radius-doubling count round through the fused trace with
    hoisted routing: pad rects on-device, reuse the routing computed on
    the first round, ratchet-and-rerun on capacity overflow (the same
    monotone hwm contract as ``QueryEngine._fused_serve``)."""
    n = len(us_sub)
    Bb, us_dev, rsoa_dev = engine._padder.pad(us_sub, rects)
    routing = state.get("routing")
    if routing is None:
        routing = state["routing"] = engine._route(us_dev)
    qs, qe, pts, exc = routing
    with span("engine.fused", cat="engine", batch=n, mode="count"):
        while True:
            kcap = min(engine._kb_hwm, engine.n_tiles)
            forced, out, cnt, mx = engine._fused_routed(
                rsoa_dev, qs, qe, pts, exc, mode="count", kcap=kcap)
            mxi = int(mx)
            if mxi <= kcap or kcap >= engine.n_tiles:
                break
            engine._kb_hwm = min(_bucket(mxi, 1), engine.n_tiles)
            engine.stats["fused_reruns"] += 1
    engine.stats["batches"] += 1
    engine.stats["queries"] += n
    engine.stats["tiles_scanned"] += int(np.asarray(cnt).sum())
    return (np.asarray(out).astype(np.int64)
            + np.asarray(forced).astype(np.int64))[:n]


def outward_rect(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """(B, 2) float64 lo/hi -> (B, 4) float32 rects rounded outward
    (:func:`repro.core.polygon.round_bounds_outward`), so the f32 box
    always contains the intended f64 box."""
    lo32, hi32 = round_bounds_outward(lo, hi)
    return np.concatenate([lo32, hi32], axis=1).astype(np.float32)


def _pt_d2(coords: np.ndarray, p: np.ndarray) -> np.ndarray:
    """Canonical squared distances: float64 over float32 coords, x term
    then y term — the exact op order of the R-tree descent."""
    dx = coords[:, 0].astype(np.float64) - float(p[0])
    dy = coords[:, 1].astype(np.float64) - float(p[1])
    return dx * dx + dy * dy


def _empty(B: int, k: int) -> KNNResult:
    return KNNResult(
        ids=np.full((B, k), -1, dtype=np.int32),
        dist2=np.full((B, k), np.inf, dtype=np.float64),
    )


def knn_reach_host(index: TwoDReachIndex, us: np.ndarray,
                   points: np.ndarray, k: int) -> KNNResult:
    """Host KNNReach: per-query best-first branch-and-bound descent."""
    us = np.asarray(us, dtype=np.int64)
    B = len(us)
    k = int(k)
    if k < 1:
        raise ValueError(f"knn needs k >= 1, got {k}")
    points = np.asarray(points, dtype=np.float32).reshape(B, 2)
    res = _empty(B, k)
    exc = index.excluded[us]
    for b in range(B):
        if exc[b]:
            res.ids[b, 0] = us[b]
            res.dist2[b, 0] = _pt_d2(
                index.coords[us[b]][None], points[b])[0]
            continue
        tid = int(index.lookup_tree(us[b:b + 1])[0])
        ids, d2 = query_host_knn(index.forest, tid, points[b], k)
        res.ids[b, : len(ids)] = ids
        res.dist2[b, : len(d2)] = d2
    return res


def knn_radius_doubling(engine, us: np.ndarray, points: np.ndarray,
                        k: int) -> KNNResult:
    """Device KNNReach over a :class:`~repro.core.engine.QueryEngine`'s
    count/collect kernels (see module docstring)."""
    us = np.asarray(us, dtype=np.int64)
    B = len(us)
    k = int(k)
    if k < 1:
        raise ValueError(f"knn needs k >= 1, got {k}")
    points = np.asarray(points, dtype=np.float32).reshape(B, 2)
    res = _empty(B, k)
    if B == 0:
        return res
    exc = engine._excluded_host[us]
    for b in np.nonzero(exc)[0]:
        res.ids[b, 0] = us[b]
        res.dist2[b, 0] = _pt_d2(
            engine._coords_host[us[b]][None], points[b])[0]
    rest = np.nonzero(~exc)[0]
    ext = engine._extent_host
    if rest.size == 0 or ext is None:
        return res       # no venues at all — every tree probe is empty

    # ---- phase 1: double the count box until it holds k venues -------
    n = len(rest)
    p = points[rest].astype(np.float64)
    ext_span = max(float(ext[2] - ext[0]), float(ext[3] - ext[1]), 1e-6)
    r = np.full(n, ext_span / 2 ** 16, dtype=np.float64)
    resolved = np.zeros(n, dtype=bool)
    final_rects = np.zeros((n, 4), dtype=np.float32)
    # fused engines hoist the routing out of the loop (state carries it
    # between rounds); two-phase/older engines re-enter count_batch
    fused = getattr(engine, "path", None) == "fused"
    state: dict = {}
    for _ in range(_MAX_DOUBLINGS):
        rects = outward_rect(p - r[:, None], p + r[:, None])
        if fused:
            counts = _fused_count(engine, us[rest], rects, state)
        else:
            counts = engine.count_batch(us[rest], rects)
        covers = (
            (rects[:, 0].astype(np.float64) <= ext[0])
            & (rects[:, 1].astype(np.float64) <= ext[1])
            & (rects[:, 2].astype(np.float64) >= ext[2])
            & (rects[:, 3].astype(np.float64) >= ext[3])
        )
        newly = ~resolved & ((counts >= k) | covers)
        if newly.any():
            idx = np.nonzero(newly)[0]
            cov = idx[covers[idx]]
            # a covering box already holds the whole venue set
            final_rects[cov] = rects[cov]
            cnt = idx[~covers[idx]]
            if cnt.size:
                # kth distance <= diagonal of the box's true half-widths
                # (from the f32 bounds actually counted, so the bound
                # survives the outward rounding)
                hwx = np.maximum(p[cnt, 0] - rects[cnt, 0],
                                 rects[cnt, 2].astype(np.float64) - p[cnt, 0])
                hwy = np.maximum(p[cnt, 1] - rects[cnt, 1],
                                 rects[cnt, 3].astype(np.float64) - p[cnt, 1])
                R = np.sqrt(hwx * hwx + hwy * hwy)
                final_rects[cnt] = outward_rect(
                    p[cnt] - R[:, None], p[cnt] + R[:, None])
            resolved |= newly
        if resolved.all():
            break
        r = np.where(resolved, r, r * 2)
    if not resolved.all():
        # capped out: answer the stragglers with the exact host
        # best-first descent (the same top-up used for collect
        # overflow) and drop them from the device collect phase
        index = getattr(engine, "_index", None)
        if index is None:
            raise RuntimeError("kNN radius doubling failed to converge")
        for j in np.nonzero(~resolved)[0]:
            b = rest[j]
            tid = int(index.lookup_tree(us[b:b + 1])[0])
            ids, d2 = query_host_knn(index.forest, tid, points[b], k)
            res.ids[b, : len(ids)] = ids
            res.dist2[b, : len(d2)] = d2
        rest = rest[resolved]
        final_rects = final_rects[resolved]
        if rest.size == 0:
            return res

    # ---- phase 2: collect every candidate in the bounding box --------
    # collect totals are exact even when capped, so one overflow is
    # enough to jump the cap straight to the largest box population;
    # the cap rides a per-engine high-water mark so it only ratchets up
    # and a smaller later batch never traces a new collect shape
    kcap = max(getattr(engine, "_knn_kcap_hwm", 1), k)
    col = engine.collect_batch(us[rest], final_rects, kcap)
    if col.overflow.any():
        kcap = max(kcap, int(col.counts.max()))
        col = engine.collect_batch(us[rest], final_rects, kcap)
    engine._knn_kcap_hwm = kcap

    # ---- exact final selection (shared with the host path) -----------
    for j, b in enumerate(rest):
        cand = col.row(j)
        if cand.size == 0:
            continue
        d2 = _pt_d2(engine._coords_host[cand], points[b])
        order = np.lexsort((cand, d2))[:k]
        res.ids[b, : len(order)] = cand[order]
        res.dist2[b, : len(order)] = d2[order]
    return res
