"""train_step / eval_step factories: grads -> clip -> AdamW, with optional
gradient accumulation (microbatching) and remat plumbed through the model
loss functions.

The returned step is a pure function ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` ready for ``jax.jit`` with in/out
shardings from distributed/sharding.py.  Gradient accumulation scans over
microbatch slices so peak activation memory is one microbatch.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .optim import AdamWConfig, AdamWState, adamw_update


LossFn = Callable[[Any, Dict[str, jnp.ndarray]], Any]


def make_train_step(
    loss_fn: LossFn,
    opt_cfg: AdamWConfig,
    *,
    grad_accum: int = 1,
    has_metrics: bool = True,
) -> Callable:
    """loss_fn(params, batch) -> scalar | (scalar, metrics dict)."""

    def full_loss(params, batch):
        out = loss_fn(params, batch)
        if has_metrics:
            loss, metrics = out
        else:
            loss, metrics = out, {}
        return loss, metrics

    grad_fn = jax.value_and_grad(full_loss, has_aux=True)

    def step(params, opt_state: AdamWState, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # split the leading batch dim into microbatches and scan
            def micro(carry, mb):
                acc_g, acc_l = carry
                (l, _), g = grad_fn(params, mb)
                return (
                    jax.tree.map(jnp.add, acc_g, g),
                    acc_l + l,
                ), None

            def reshape_mb(x):
                b = x.shape[0]
                assert b % grad_accum == 0, (b, grad_accum)
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

            mbs = jax.tree.map(reshape_mb, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zero_g, jnp.float32(0.0)), mbs
            )
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = {}
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params
        )
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return step


def make_eval_step(loss_fn: LossFn, has_metrics: bool = True) -> Callable:
    def step(params, batch):
        out = loss_fn(params, batch)
        return out[0] if has_metrics else out

    return step
