"""Attention: flash-scan (blockwise online-softmax), banded SWA, GQA, MLA.

Memory discipline is what matters at 32k/500k sequence lengths: a naive
(S x S) score matrix is 4 GB/head at 32k, so *all* attention here is
blockwise with f32 online-softmax accumulators:

* ``flash_attention``  — lax.scan over KV blocks per Q block; causal
  masking; optional score softcap (gemma2).  The masked upper-triangle
  blocks still cost FLOPs (recorded honestly in §Roofline — a splash-style
  Pallas kernel is the real-TPU answer; the §Perf log quantifies it).
* ``banded_attention`` — sliding-window layers only *gather the KV blocks
  inside the band* (ceil(w/blk)+1 per Q block) so local layers cost
  O(S*w) not O(S^2) — this is what makes the 500k cells feasible.
* ``decode_attention`` — single-token step against a KV cache (ring
  buffer for SWA layers, linear for global).

All functions take (B, S, H, dh) q and (B, S, KV, dh) k/v and handle GQA
by reshaping q to (KV, H/KV) groups.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


NEG_INF = -1e30


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding; x (..., S, H, dh), positions (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def _softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap is not None else x


def _block_attn(q, k, v, mask, scale, softcap):
    """One (Bq, Bk) tile: returns (scores_exp, row_max, out_partial) in f32.

    q (B, G, Hg, Bq, dh), k (B, G, Bk, dh), v (B, G, Bk, dh), mask
    broadcastable (B, 1, 1, Bq, Bk)."""
    s = jnp.einsum(
        "bghqd,bgkd->bghqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    s = _softcap(s, softcap)
    s = jnp.where(mask, s, NEG_INF)
    return s


def flash_attention(
    q: jnp.ndarray,          # (B, S, H, dh)
    k: jnp.ndarray,          # (B, Sk, KV, dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,       # absolute position of q[0] (prefill chunks)
    softcap: Optional[float] = None,
    blk_q: int = 512,
    blk_k: int = 512,
    scale: Optional[float] = None,
    block_skip: bool = False,
) -> jnp.ndarray:
    B, S, H, dh = q.shape
    _, Sk, KV, _ = k.shape
    dv = v.shape[-1]          # MLA: v head dim differs from qk head dim
    G = KV
    Hg = H // KV
    scale = dh ** -0.5 if scale is None else scale
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, Sk)
    assert S % blk_q == 0 and Sk % blk_k == 0, (S, Sk, blk_q, blk_k)
    nq, nk = S // blk_q, Sk // blk_k

    qr = q.reshape(B, nq, blk_q, G, Hg, dh).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(B, nk, blk_k, G, dh).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, blk_k, G, dv).transpose(1, 0, 3, 2, 4)

    q_pos = q_offset + jnp.arange(S).reshape(nq, blk_q)
    k_pos = jnp.arange(Sk).reshape(nk, blk_k)

    def per_q_block(qb, qp, n_kv: Optional[int] = None):
        # qb (B, G, Hg, blk_q, dh); scan over kv blocks (first n_kv)
        def step(carry, inp):
            m, l, acc = carry
            kb, vb, kp = inp
            mask = jnp.ones((1, 1, 1, blk_q, blk_k), bool)
            if causal:
                mask = (qp[None, None, None, :, None]
                        >= kp[None, None, None, None, :])
            s = _block_attn(qb, kb, vb, mask, scale, softcap)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bghqk,bgkd->bghqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, Hg, blk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, Hg, blk_q), jnp.float32)
        a0 = jnp.zeros((B, G, Hg, blk_q, dv), jnp.float32)
        xs = ((kr, vr, k_pos) if n_kv is None
              else (kr[:n_kv], vr[:n_kv], k_pos[:n_kv]))
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out  # (B, G, Hg, blk_q, dv)

    if block_skip and causal and q_offset == 0 and nq <= 64:
        # causal block skipping: q block i only scans kv blocks [0..i] —
        # halves attention FLOPs vs the masked full scan at the cost of
        # nq unrolled scan bodies in the HLO (see EXPERIMENTS.md §Perf)
        outs = [per_q_block(qr[i], q_pos[i], n_kv=i + 1)
                for i in range(nq)]
        out = jnp.stack(outs)
    else:
        out = jax.lax.map(lambda x: per_q_block(*x), (qr, q_pos))
    # (nq, B, G, Hg, blk_q, dv) -> (B, S, H, dv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, dv)
    return out.astype(q.dtype)


def banded_attention(
    q: jnp.ndarray,          # (B, S, H, dh)
    k: jnp.ndarray,          # (B, S, KV, dh)
    v: jnp.ndarray,
    *,
    window: int,
    softcap: Optional[float] = None,
    blk: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Causal sliding-window attention touching only in-band KV blocks.

    Query at position i attends to j in (i - window, i]."""
    B, S, H, dh = q.shape
    _, _, KV, _ = k.shape
    G, Hg = KV, H // KV
    scale = dh ** -0.5 if scale is None else scale
    blk = min(blk, S)
    assert S % blk == 0
    nq = S // blk
    nw = min(-(-window // blk) + 1, nq)  # kv blocks per band

    qr = q.reshape(B, nq, blk, G, Hg, dh).transpose(1, 0, 3, 4, 2, 5)

    def per_q_block(i, qb):
        # gather nw kv blocks ending at block i (clamped at 0)
        start_blk = jnp.maximum(i - (nw - 1), 0)
        start = start_blk * blk
        kb = jax.lax.dynamic_slice_in_dim(k, start, nw * blk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, nw * blk, axis=1)
        kb = kb.transpose(0, 2, 1, 3)    # (B, G, nw*blk, dh)
        vb = vb.transpose(0, 2, 1, 3)
        qp = i * blk + jnp.arange(blk)
        kp = start + jnp.arange(nw * blk)
        mask = (
            (qp[:, None] >= kp[None, :])
            & (qp[:, None] - kp[None, :] < window)
        )[None, None, None]
        s = _block_attn(qb, kb, vb, mask, scale, softcap)
        m = s.max(-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bghqk,bgkd->bghqd", p, vb.astype(jnp.float32))
        out = out / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
        return out

    out = jax.lax.map(
        lambda x: per_q_block(*x), (jnp.arange(nq), qr)
    )
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, dh)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,          # (B, 1, H, dh)
    k_cache: jnp.ndarray,    # (B, Sc, KV, dh)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # () int32 — number of valid cache rows
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    ring: bool = False,      # SWA ring buffer: all Sc rows valid once full
) -> jnp.ndarray:
    B, _, H, dh = q.shape
    _, Sc, KV, _ = k_cache.shape
    G, Hg = KV, H // KV
    scale = dh ** -0.5 if scale is None else scale
    qr = q.reshape(B, G, Hg, dh)
    s = jnp.einsum(
        "bghd,bsgd->bghs", qr.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * scale
    s = _softcap(s, softcap)
    pos = jnp.arange(Sc)
    valid = (pos < cache_len) if not ring else (
        pos < jnp.minimum(cache_len, Sc)
    )
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bghs,bsgd->bghd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dh).astype(q.dtype)
