"""Construction-time bench: host vs device 2DReach build pipelines.

The paper's headline experimental claim is fast *index construction*;
this bench tracks it per stage (scc / closure / assign / forest /
pointers) across the three 2DReach variants and both build backends:

    host    — NumPy: per-level segment-OR closure (the reduceat path,
              with the legacy ``np.bitwise_or.at`` scatter timed next to
              it as the before/after record) + lexsort bulk load.
    device  — ``backend="device"``: level-scheduled ``bitset_mm``
              closure fixpoint + bucketed values-only key sort +
              segmented-MBR reduction, reported both cold (first build,
              includes jit tracing) and warm (steady-state shapes — the
              number a DynamicIndex compaction swap pays).

Every device build is verified against the host build before timing:
identical forest arrays and identical answers on a query sample.  The
zero-copy handoff is asserted too (a ``QueryEngine`` over the device
build must adopt, not re-upload).

Outputs ``results/perf_build.json`` (full rows) and a root-level
``BENCH_build.json`` summary, and prints the markdown construction-time
table the README quotes.  ``--smoke`` runs a seconds-scale subset for
CI (structure + exactness gates only); the full run additionally gates
on the device closure+forest stages beating the host path on the
largest config.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro import obs
from repro.core import QueryEngine, build_2dreach, condense, scc_np
from repro.core import engine as engine_mod
from repro.core.reachability import closure_np
from repro.data import get_dataset, workload
from repro.kernels.range_query import ops as rq_ops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "results", "perf_build.json")
BENCH_OUT = os.path.join(ROOT, "BENCH_build.json")

VARIANTS = ("base", "comp", "pointer")
STAGES = ("t_scc", "t_closure", "t_assign", "t_forest", "t_pointers",
          "t_total")


def _stage_dict(stats: Dict[str, float]) -> Dict[str, float]:
    return {k: float(stats[k]) for k in STAGES}


def closure_before_after(g) -> Dict[str, float]:
    """Satellite record: the host closure's legacy unbuffered scatter
    (``np.bitwise_or.at``) vs the sort + ``np.bitwise_or.reduceat``
    segment-OR that replaced it (identical bits, asserted)."""
    labels = scc_np(g.n_nodes, g.edges)
    cond = condense(g.n_nodes, g.edges, labels)

    def best(fn, repeats=3):
        out, ts = None, []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            ts.append(time.perf_counter() - t0)
        return out, min(ts)

    a, t_at = best(lambda: closure_np(
        cond, g.n_nodes, g.spatial_ids, segment_or=False))
    b, t_seg = best(lambda: closure_np(
        cond, g.n_nodes, g.spatial_ids, segment_or=True))
    assert np.array_equal(a.bits, b.bits), "segment-OR changed the closure"
    return {
        "scatter_at_s": t_at,
        "segment_or_reduceat_s": t_seg,
        "speedup": t_at / max(t_seg, 1e-12),
    }


def bench_config(name: str, scale: float, n_check: int = 512) -> Dict:
    g = get_dataset(name, scale=scale)
    us, rects = workload(g, n_check, extent_ratio=0.05, seed=17)
    row: Dict = {
        "dataset": name, "scale": scale,
        "n_nodes": int(g.n_nodes), "n_edges": int(g.n_edges),
        "n_spatial": int(g.n_spatial),
        "host_closure_before_after": closure_before_after(g),
        "variants": {},
    }
    for variant in VARIANTS:
        host = build_2dreach(g, variant=variant)
        want = host.query_batch(us, rects)
        cold = build_2dreach(g, variant=variant, backend="device")
        # exactness gates before any timing claims
        assert np.array_equal(host.forest.entries, cold.forest.entries), \
            f"{name} {variant}: device forest differs from host"
        assert np.array_equal(want, cold.query_batch(us, rects)), \
            f"{name} {variant}: device answers differ from host"
        cold_stats = _stage_dict(cold.stats)
        del cold
        # warm build under span recording: the obs substage totals
        # (morton sort / segmented-MBR / tile pyramid inside t_forest)
        # ride along with the coarse t_* stage dict
        was = obs.enabled()
        obs.enable()
        sub0 = obs.stage_totals("build.")
        warm = build_2dreach(g, variant=variant, backend="device")
        sub1 = obs.stage_totals("build.")
        if not was:
            obs.disable()
        substage_us = {
            k: round(sub1.get(k, 0.0) - sub0.get(k, 0.0), 1)
            for k in sub1 if sub1.get(k, 0.0) > sub0.get(k, 0.0)}
        row["variants"][variant] = {
            "entries": int(len(host.forest.entries)),
            "trees": int(host.stats["distinct_rtrees"]),
            "host": _stage_dict(host.stats),
            "device_cold": cold_stats,
            "device_warm": _stage_dict(warm.stats),
            "device_warm_substage_us": substage_us,
        }
        if variant == "comp":
            # zero-copy handoff gate: serving the device build adopts
            c0 = dict(engine_mod.UPLOAD_COUNTERS)
            soa0 = rq_ops.SOA_BUILDS
            eng = QueryEngine(warm)
            assert eng.stats["adopted"] == 1, "engine did not adopt"
            assert engine_mod.UPLOAD_COUNTERS["host_uploads"] == \
                c0["host_uploads"], "device build re-uploaded from host"
            assert rq_ops.SOA_BUILDS == soa0, "device build re-transposed"
            assert np.array_equal(want, eng.query_batch(us, rects))
            row["handoff"] = {
                "engine_adopted": True,
                "host_uploads_delta": 0,
                "retranspositions_delta": 0,
            }
        del host, warm
    return row


def bench_summary(rows: List[Dict]) -> Dict:
    largest = max(rows, key=lambda r: r["n_nodes"])
    per_variant = {}
    for variant in VARIANTS:
        v = largest["variants"][variant]
        host_cf = v["host"]["t_closure"] + v["host"]["t_forest"]
        dev_cf = (v["device_warm"]["t_closure"]
                  + v["device_warm"]["t_forest"])
        per_variant[variant] = {
            "host_closure_forest_s": host_cf,
            "device_warm_closure_forest_s": dev_cf,
            "speedup": host_cf / max(dev_cf, 1e-12),
            "host_total_s": v["host"]["t_total"],
            "device_warm_total_s": v["device_warm"]["t_total"],
            "device_warm_substage_us": v.get("device_warm_substage_us"),
        }
    return {
        "schema_version": 2,
        "unit": "seconds per build stage",
        "configs": [
            {"dataset": r["dataset"], "scale": r["scale"],
             "n_nodes": r["n_nodes"]} for r in rows
        ],
        "largest_config": {
            "dataset": largest["dataset"], "scale": largest["scale"],
            "n_nodes": largest["n_nodes"],
            "per_variant": per_variant,
            # the gate targets the base variant: its forest holds the
            # whole per-component reachable-set blowup (tens of millions
            # of entries at full scale), which is where construction
            # time actually lives; comp/pointer forests are hundreds of
            # times smaller and their stage sums are noise-dominated
            "device_beats_host_closure_forest": bool(
                per_variant["base"]["speedup"] > 1.0),
        },
        "host_closure_scatter_vs_reduceat": {
            f'{r["dataset"]}x{r["scale"]}': r["host_closure_before_after"]
            for r in rows
        },
        "handoff": largest.get("handoff", {}),
    }


def markdown_table(rows: List[Dict]) -> str:
    """The construction-time table quoted in the README."""
    lines = [
        "| config | variant | entries | host total | device total (warm)"
        " | closure h/d | forest h/d |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        for variant in VARIANTS:
            v = r["variants"][variant]
            h, d = v["host"], v["device_warm"]
            lines.append(
                f'| {r["dataset"]} x{r["scale"]} | {variant} '
                f'| {v["entries"]:,} '
                f'| {h["t_total"]:.2f}s | {d["t_total"]:.2f}s '
                f'| {h["t_closure"]:.3f}s / {d["t_closure"]:.3f}s '
                f'| {h["t_forest"]:.2f}s / {d["t_forest"]:.2f}s |'
            )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI: one small config, "
                         "exactness + handoff gates only (no perf gate)")
    args = ap.parse_args()

    if args.smoke:
        configs = [("yelp", 0.12)]
    else:
        configs = [("gowalla", 0.5), ("yelp", 0.5), ("yelp", 1.0)]

    rows = [bench_config(name, scale) for name, scale in configs]
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({"configs": rows}, f, indent=1)
    summary = bench_summary(rows)
    with open(BENCH_OUT, "w") as f:
        json.dump(summary, f, indent=1)

    print(markdown_table(rows))
    print(json.dumps(summary, indent=1))

    for r in rows:
        assert r["host_closure_before_after"]["segment_or_reduceat_s"] > 0
    assert summary["handoff"].get("engine_adopted"), \
        "device build -> engine handoff was not zero-copy"
    if not args.smoke:
        assert summary["largest_config"][
            "device_beats_host_closure_forest"], (
            "device closure+forest did not beat the host path on the "
            "largest config")


if __name__ == "__main__":
    main()
