from .config import LMConfig, MLASpec, MoESpec
from .model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    prefill,
)
