"""Structured query log: the durable record of what was actually served.

Every served query can append one bounded-memory record — query vertex,
vertex class, query class, log2 rect-area bucket, owning shard, latency,
result cardinality, and the engine-reported serving status (healthy vs
degraded, retry count) — the direct input for the workload analytics
(:mod:`repro.obs.workload`), the planned result cache (cache key =
``(vertex_class, rect_bucket)``) and query-log-driven hot-shard
repartitioning (shard load = records per shard).  The log is a
ring buffer (oldest records drop once ``capacity`` is reached, with a
drop counter, never unbounded growth) plus always-cheap aggregate
counters that survive ring eviction; ``to_jsonl`` exports the retained
window for offline analysis (first line: a schema header).

Streaming consumers (the Space-Saving sketches in
:mod:`~repro.obs.workload`) attach with :meth:`QueryLog.add_sink` and
see every record *before* ring eviction, so their aggregates cover the
whole stream even when the ring only retains a window of it.

Schema v2 grew ``u`` (the query vertex id — heavy-hitter detection
needs the key, not just its class), ``status`` (``ok`` / ``degraded``:
whether the engine answered on the device path or the exact host
fallback) and ``retries`` (device attempts the batch burned beyond the
first); v1 consumers keyed on field names keep working, the JSONL dump
carries ``schema_version`` in its header line.

Schema v3 grew the causal columns: ``trace_id`` (the per-request
:class:`~repro.obs.trace_context.TraceContext` id minted at
``Frontend.submit`` — the join key against span ``trace_ids`` and
histogram exemplars in a flight bundle) and ``attempt`` (device
attempts that included *this* query, attributed per trace id by
``ResilientEngine.last_report`` instead of the batch-level ``retries``
count, which stays for v2 consumers).  Both default to their "unknown"
values (-1 / 0) for producers without a trace context; the aggregate
surfaces (``by_status`` et al.) are unchanged.
"""

from __future__ import annotations

import collections
import json
import math
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

SCHEMA_VERSION = 3

FIELDS = ("t", "query_class", "u", "vertex_class", "rect_bucket", "shard",
          "latency_us", "cardinality", "status", "retries",
          "trace_id", "attempt")

# tuple indices for consumers iterating raw records (v3 appends fields,
# so v2 consumers indexing by these constants keep working)
I_T, I_QUERY_CLASS, I_U, I_VERTEX_CLASS, I_RECT_BUCKET, I_SHARD, \
    I_LATENCY_US, I_CARDINALITY, I_STATUS, I_RETRIES, \
    I_TRACE_ID, I_ATTEMPT = range(len(FIELDS))


def rect_bucket(rect) -> int:
    """log2 bucket of the rect's area — the workload-skew key.

    Degenerate (zero-area) rects bucket to -64; buckets clamp to
    [-63, 63] so the key space stays enumerable for cache sizing.
    """
    r = np.asarray(rect, dtype=np.float64).ravel()
    dim = len(r) // 2
    area = 1.0
    for a in range(dim):
        area *= max(float(r[dim + a] - r[a]), 0.0)
    if area <= 0.0:
        return -64
    return int(np.clip(math.floor(math.log2(area)), -63, 63))


def vertex_class_of(index_like, us) -> np.ndarray:
    """Coarse per-vertex classes from whatever the serving object
    exposes: ``sink`` (excluded spatial sink — Alg. 2's special case),
    ``user`` (routed through a tree probe), ``unknown`` otherwise."""
    us = np.asarray(us, dtype=np.int64)
    exc = getattr(index_like, "_excluded_host", None)
    if exc is None:
        exc = getattr(index_like, "excluded", None)
    if exc is None:
        return np.full(len(us), "unknown", dtype=object)
    out = np.full(len(us), "user", dtype=object)
    out[np.asarray(exc)[us]] = "sink"
    return out


class QueryLog:
    """Bounded ring of per-query records + eviction-proof aggregates."""

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=self.capacity)
        self.total = 0
        self.by_class: Dict[str, int] = {}
        self.by_shard: Dict[int, int] = {}
        self.by_status: Dict[str, int] = {}
        self._sinks: List[Callable[[tuple], None]] = []

    def add_sink(self, sink: Callable[[tuple], None]) -> None:
        """Register a streaming consumer called with every record
        appended from now on (before any ring eviction drops it)."""
        with self._lock:
            self._sinks.append(sink)

    def remove_sink(self, sink: Callable[[tuple], None]) -> None:
        with self._lock:
            if sink in self._sinks:
                self._sinks.remove(sink)

    def record(self, query_class: str, vertex_class: str, rect_b: int,
               shard: int, latency_s: float, cardinality: int,
               t: Optional[float] = None, u: int = -1,
               status: str = "ok", retries: int = 0,
               trace_id: int = -1, attempt: int = 0) -> None:
        rec = (t if t is not None else time.time(), query_class, int(u),
               vertex_class, int(rect_b), int(shard),
               float(latency_s) * 1e6, int(cardinality), status,
               int(retries), int(trace_id), int(attempt))
        with self._lock:
            self._ring.append(rec)
            self.total += 1
            self.by_class[query_class] = self.by_class.get(query_class, 0) + 1
            self.by_shard[rec[I_SHARD]] = \
                self.by_shard.get(rec[I_SHARD], 0) + 1
            self.by_status[status] = self.by_status.get(status, 0) + 1
            sinks = list(self._sinks)
        for sink in sinks:
            sink(rec)

    def record_batch(self, query_class: str, vertex_classes, rects,
                     shards, latencies_s, cardinalities,
                     us=None, statuses=None, retries: int = 0,
                     trace_ids=None, attempts=None) -> None:
        """Vectorised append for a served batch (one lock per record,
        shared wall timestamp).  ``statuses`` is a per-query string
        sequence (or one string for the whole batch); ``retries`` is
        the batch-level device retry count the engine reported;
        ``trace_ids`` / ``attempts`` are the per-query causal columns
        (schema v3) the frontend reads off the batch's trace contexts
        and the resilient engine's per-trace attribution."""
        now = time.time()
        shards = np.asarray(shards)
        lats = np.asarray(latencies_s, dtype=np.float64)
        cards = np.asarray(cardinalities)
        for i in range(len(lats)):
            if statuses is None:
                st = "ok"
            elif isinstance(statuses, str):
                st = statuses
            else:
                st = str(statuses[i])
            self.record(query_class, str(vertex_classes[i]),
                        rect_bucket(rects[i]), int(shards[i]),
                        float(lats[i]), int(cards[i]), t=now,
                        u=int(us[i]) if us is not None else -1,
                        status=st, retries=retries,
                        trace_id=(int(trace_ids[i])
                                  if trace_ids is not None else -1),
                        attempt=(int(attempts[i])
                                 if attempts is not None else 0))

    # -- introspection --------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    @property
    def dropped(self) -> int:
        """Records evicted from the ring (aggregates still count them)."""
        with self._lock:
            return self.total - len(self._ring)

    def records(self) -> list:
        with self._lock:
            return list(self._ring)

    def snapshot(self) -> dict:
        with self._lock:
            n = len(self._ring)
            lat = np.fromiter((r[I_LATENCY_US] for r in self._ring),
                              dtype=np.float64, count=n)
            out = {
                "schema_version": SCHEMA_VERSION,
                "retained": n,
                "total": self.total,
                "dropped": self.total - n,
                "capacity": self.capacity,
                "by_class": dict(self.by_class),
                "by_shard": {str(k): v
                             for k, v in sorted(self.by_shard.items())},
                "by_status": dict(self.by_status),
            }
        if n:
            out["latency_us"] = {
                f"p{p}": float(np.percentile(lat, p)) for p in (50, 95, 99)}
        return out

    def to_jsonl(self, path: str) -> str:
        """Export the retained window, one JSON object per line; the
        first line is a schema header (``schema_version`` + field
        list), the rest are records."""
        with open(path, "w") as f:
            f.write(json.dumps({"schema_version": SCHEMA_VERSION,
                                "fields": list(FIELDS)}) + "\n")
            for rec in self.records():
                f.write(json.dumps(dict(zip(FIELDS, rec))) + "\n")
        return path

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.total = 0
            self.by_class = {}
            self.by_shard = {}
            self.by_status = {}


QUERY_LOG = QueryLog()
