"""Host-side input pipelines, shardable across data-parallel hosts.

Deterministic, step-keyed synthetic data for each architecture family.
Determinism by (seed, step, host) is the property the fault-tolerance
story relies on: after a restart at step k, host h regenerates exactly
the batch it would have seen — no data-loader state in checkpoints.

All pipelines yield numpy (host) arrays shaped for the *local* shard:
``global_batch // n_hosts`` rows per host; the launcher feeds them to a
``jax.jit`` step whose in_shardings glue the shards into the global
array (standard multi-host JAX data loading).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    host_id: int = 0
    n_hosts: int = 1

    def slice_of(self, global_batch: int) -> int:
        assert global_batch % self.n_hosts == 0
        return global_batch // self.n_hosts


def _rng(seed: int, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, host])
    )


# --------------------------------------------------------------------------
# LM: token batches
# --------------------------------------------------------------------------

def lm_batches(
    vocab_size: int,
    seq_len: int,
    global_batch: int,
    seed: int = 0,
    shard: ShardInfo = ShardInfo(),
    start_step: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic LM stream: Zipf-distributed tokens with local structure
    (bigram coupling) so the loss has signal to descend."""
    b = shard.slice_of(global_batch)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    step = start_step
    while True:
        rng = _rng(seed, step, shard.host_id)
        toks = rng.choice(vocab_size, size=(b, seq_len + 1), p=probs)
        # bigram coupling: with p=0.5, next token = (prev*31) % vocab
        mask = rng.random((b, seq_len)) < 0.5
        nxt = (toks[:, :-1] * 31 + 7) % vocab_size
        toks[:, 1:][mask] = nxt[mask]
        yield {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        step += 1


# --------------------------------------------------------------------------
# RecSys: DIN batches
# --------------------------------------------------------------------------

def din_batches(
    n_items: int,
    n_cates: int,
    hist_len: int,
    global_batch: int,
    seed: int = 0,
    shard: ShardInfo = ShardInfo(),
    start_step: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """User-behaviour sequences + target item + click label.  Labels are
    planted: click iff the target's category appears in the recent half
    of the history (gives DIN's target-attention something real)."""
    b = shard.slice_of(global_batch)
    step = start_step
    cate_of = np.arange(n_items) % n_cates
    while True:
        rng = _rng(seed, step, shard.host_id)
        hist = rng.integers(0, n_items, size=(b, hist_len))
        hist_len_real = rng.integers(hist_len // 4, hist_len + 1, size=b)
        mask = np.arange(hist_len)[None, :] < hist_len_real[:, None]
        target = rng.integers(0, n_items, size=b)
        tc = cate_of[target]
        recent = hist[:, hist_len // 2:]
        match = (cate_of[recent] == tc[:, None]) & mask[:, hist_len // 2:]
        label = (match.sum(1) >= 1).astype(np.float32)
        yield {
            "hist_items": hist.astype(np.int32),
            "hist_mask": mask,
            "target_item": target.astype(np.int32),
            "label": label,
        }
        step += 1


# --------------------------------------------------------------------------
# GNN: batched molecules
# --------------------------------------------------------------------------

def molecule_batches(
    n_nodes: int,
    n_edges: int,
    batch: int,
    n_species: int = 10,
    seed: int = 0,
    shard: ShardInfo = ShardInfo(),
    start_step: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Random 3-D point-cloud molecules with radius-graph edges, padded to
    (n_nodes, n_edges) per molecule; regression target = a smooth function
    of pairwise distances (so message passing must use geometry)."""
    b = shard.slice_of(batch) if batch >= shard.n_hosts else batch
    step = start_step
    while True:
        rng = _rng(seed, step, shard.host_id)
        pos = rng.standard_normal((b, n_nodes, 3)).astype(np.float32) * 2.0
        species = rng.integers(0, n_species, size=(b, n_nodes))
        src = np.zeros((b, n_edges), dtype=np.int32)
        dst = np.zeros((b, n_edges), dtype=np.int32)
        for i in range(b):
            d = np.linalg.norm(pos[i][:, None] - pos[i][None], axis=-1)
            np.fill_diagonal(d, np.inf)
            cand = np.argwhere(d < 3.0)
            if len(cand) == 0:
                cand = np.array([[0, 1]])
            if len(cand) > n_edges:
                cand = cand[rng.choice(len(cand), n_edges, replace=False)]
            src[i, : len(cand)] = cand[:, 0]
            dst[i, : len(cand)] = cand[:, 1]
        edge_mask = ~((src == 0) & (dst == 0))
        edge_mask[:, 0] = True
        dvec = np.take_along_axis(pos, dst[..., None], 1) - np.take_along_axis(
            pos, src[..., None], 1
        )
        dist = np.linalg.norm(dvec, axis=-1)
        energy = (np.exp(-dist) * edge_mask).sum(1) + 0.1 * species.sum(1)
        yield {
            "pos": pos,
            "species": species.astype(np.int32),
            "edge_src": src,
            "edge_dst": dst,
            "edge_mask": edge_mask,
            "energy": energy.astype(np.float32),
        }
        step += 1
