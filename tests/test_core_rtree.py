"""Packed R-tree forest: bulk load invariants + query engines vs brute
force, 2-D points and 3-D boxes (the 3DReach-Rev leaf type)."""

import numpy as np
from conftest import given, st

from repro.core import build_forest, query_host, query_host_collect
from repro.core import query_jax_wavefront
from repro.core.rtree import intersects


def brute(boxes, tree_of, tid, rect, dim):
    sel = tree_of == tid
    if not sel.any():
        return False
    return bool(intersects(boxes[sel], rect, dim).any())


@given(st.integers(0, 10_000), st.sampled_from([2, 3]),
       st.sampled_from([2, 4, 16]))
def test_forest_query_vs_brute(seed, dim, fanout):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 6))
    P = int(rng.integers(0, 120))
    lo = rng.random((P, dim)).astype(np.float32) * 10
    hi = lo + rng.random((P, dim)).astype(np.float32) * (
        0.0 if dim == 2 else 2.0)   # 2-D: points; 3-D: boxes
    boxes = np.concatenate([lo, hi], axis=1)
    tree_of = rng.integers(0, T, size=P)
    forest = build_forest(boxes, np.arange(P, dtype=np.int32), tree_of, T,
                          fanout=fanout)
    # forest structural invariants
    assert forest.n_trees == T
    assert (np.sort(forest.entry_ids) == np.arange(P)).all()
    B = 24
    tids = rng.integers(-1, T, size=B)
    c = rng.random((B, dim)).astype(np.float32) * 10
    r = rng.random((B, dim)).astype(np.float32) * 3
    rects = np.concatenate([c - r, c + r], axis=1)
    got = query_host(forest, tids, rects)
    want = np.array([
        t >= 0 and brute(boxes, tree_of, t, rect, dim)
        for t, rect in zip(tids, rects)
    ])
    assert (got == want).all()


def test_node_mbrs_contain_children():
    rng = np.random.default_rng(3)
    P, T = 300, 4
    pts = rng.random((P, 2)).astype(np.float32) * 50
    boxes = np.concatenate([pts, pts], axis=1)
    tree_of = rng.integers(0, T, size=P)
    f = build_forest(boxes, np.arange(P, dtype=np.int32), tree_of, T,
                     fanout=8)
    # leaf-level MBRs contain their points
    for t in range(T):
        s, e = f.entry_off[t], f.entry_off[t + 1]
        if s == e:
            continue
        n0s, n0e = f.tree_off[0][t], f.tree_off[0][t + 1]
        for j in range(n0e - n0s):
            cs = s + j * f.fanout
            ce = min(cs + f.fanout, e)
            mbr = f.level_mbr[0][n0s + j]
            assert (f.entries[cs:ce, :2] >= mbr[:2] - 1e-6).all()
            assert (f.entries[cs:ce, 2:] <= mbr[2:] + 1e-6).all()


@given(st.integers(0, 10_000))
def test_wavefront_engine_matches_host(seed):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 5))
    P = int(rng.integers(1, 150))
    pts = rng.random((P, 2)).astype(np.float32) * 10
    boxes = np.concatenate([pts, pts], axis=1)
    tree_of = rng.integers(0, T, size=P)
    forest = build_forest(boxes, np.arange(P, dtype=np.int32), tree_of, T)
    B = 16
    tids = rng.integers(-1, T, size=B)
    c = rng.random((B, 2)).astype(np.float32) * 10
    r = rng.random((B, 2)).astype(np.float32) * 3
    rects = np.concatenate([c - r, c + r], axis=1)
    host = query_host(forest, tids, rects)
    dev, ovf = query_jax_wavefront(forest, tids, rects, capacity=256)
    assert not ovf.any()
    assert (host == dev).all()


def test_collect_matches_scan():
    rng = np.random.default_rng(5)
    P = 100
    pts = rng.random((P, 2)).astype(np.float32)
    boxes = np.concatenate([pts, pts], axis=1)
    f = build_forest(boxes, np.arange(P, dtype=np.int32),
                     np.zeros(P, np.int64), 1)
    rect = np.array([0.2, 0.2, 0.6, 0.6], np.float32)
    got = set(query_host_collect(f, 0, rect).tolist())
    want = {
        i for i in range(P)
        if 0.2 <= pts[i, 0] <= 0.6 and 0.2 <= pts[i, 1] <= 0.6
    }
    assert got == want


# --------------------------------------------------------------------------
# Bulk-load edge cases — both build backends, exhaustively checked
# against query_host_collect
# --------------------------------------------------------------------------

def _points_forest(build, pts, tree_of, n_trees, fanout):
    boxes = np.concatenate([pts, pts], axis=1)
    return build(boxes, np.arange(len(pts), dtype=np.int32),
                 tree_of, n_trees, fanout=fanout)


def _check_collect_exhaustive(forest, pts, tree_of, n_trees, rects):
    """Every tree x rect: collected payloads == brute-force point set."""
    for t in range(-1, n_trees):
        for rect in rects:
            got = set(query_host_collect(forest, t, rect).tolist())
            if t < 0:
                want = set()
            else:
                sel = np.nonzero(tree_of == t)[0]
                want = {
                    int(i) for i in sel
                    if rect[0] <= pts[i, 0] <= rect[2]
                    and rect[1] <= pts[i, 1] <= rect[3]
                }
            assert got == want, (t, rect, got, want)
    # the batched probe agrees with the collector
    tids = np.repeat(np.arange(n_trees), len(rects))
    rb = np.tile(rects, (max(n_trees, 1), 1))[: len(tids)]
    hit = query_host(forest, tids, rb)
    for k, (t, rect) in enumerate(zip(tids, rb)):
        assert hit[k] == bool(
            len(query_host_collect(forest, int(t), rect)))


def _both_builders():
    from repro.core import build_forest_device

    return [("host", build_forest), ("device", build_forest_device)]


def test_bulkload_empty_forest():
    for name, build in _both_builders():
        for T in (0, 1, 5):
            f = _points_forest(
                build, np.zeros((0, 2), np.float32),
                np.zeros(0, np.int64), T, 16)
            assert f.n_trees == T
            assert f.depth == 1 and len(f.level_mbr[0]) == 0
            assert not query_host(
                f, np.arange(-1, T), np.zeros((T + 1, 4), np.float32)
            ).any(), name


def test_bulkload_zero_and_one_entry_trees_interleaved():
    # trees 0,2,4,... empty; odd trees hold exactly one point each
    T = 9
    occupied = np.arange(1, T, 2)
    pts = np.stack([occupied.astype(np.float32),
                    occupied.astype(np.float32)], axis=1)
    tree_of = occupied.astype(np.int64)
    rects = np.array([[0, 0, 10, 10], [2.5, 2.5, 3.5, 3.5],
                      [-1, -1, -0.5, -0.5]], np.float32)
    for name, build in _both_builders():
        f = _points_forest(build, pts, tree_of, T, 16)
        assert (np.diff(f.entry_off) == np.isin(np.arange(T), occupied)).all()
        _check_collect_exhaustive(f, pts, tree_of, T, rects)


def test_bulkload_fanout_two_minimum():
    rng = np.random.default_rng(11)
    P, T = 77, 3
    pts = (rng.random((P, 2)) * 8).astype(np.float32)
    tree_of = np.sort(rng.integers(0, T, P)).astype(np.int64)
    rects = np.array([[0, 0, 8, 8], [1, 1, 3, 3], [6.5, 0.5, 7.5, 7.5]],
                     np.float32)
    for name, build in _both_builders():
        f = _points_forest(build, pts, tree_of, T, 2)
        # fanout=2 gives the deepest pyramid: depth >= log2(max tree)
        assert f.depth >= int(np.ceil(np.log2(max(
            np.diff(f.entry_off).max(), 2))))
        _check_collect_exhaustive(f, pts, tree_of, T, rects)


def test_bulkload_counts_at_fanout_power_boundaries():
    # tree sizes F**k - 1, F**k, F**k + 1 around every level boundary
    F = 4
    sizes = []
    for k in (1, 2, 3):
        sizes += [F ** k - 1, F ** k, F ** k + 1]
    rng = np.random.default_rng(13)
    pts_l, tree_l = [], []
    for t, s in enumerate(sizes):
        pts_l.append((rng.random((s, 2)) * 5).astype(np.float32))
        tree_l.append(np.full(s, t, np.int64))
    pts = np.concatenate(pts_l)
    tree_of = np.concatenate(tree_l)
    rects = np.array([[0, 0, 5, 5], [1, 2, 2, 3]], np.float32)
    for name, build in _both_builders():
        f = _points_forest(build, pts, tree_of, len(sizes), F)
        # a tree of exactly F**k entries closes at one root after k levels
        for t, s in enumerate(sizes):
            nodes_l1 = f.tree_off[0][t + 1] - f.tree_off[0][t]
            assert nodes_l1 == -(-s // F)
        _check_collect_exhaustive(f, pts, tree_of, len(sizes), rects)


def test_bulkload_morton_tie_determinism():
    """Entries with identical coordinates (identical Morton codes) keep
    their generation order under both backends — the sorts are stable —
    so repeated builds are byte-identical and host == device."""
    from repro.core import build_forest_device

    P, T = 64, 2
    pts = np.tile(np.array([[1.5, 2.5]], np.float32), (P, 1))
    pts[::7] = [3.0, 3.0]      # a second tie class
    tree_of = np.sort(np.tile(np.arange(T), P // T)).astype(np.int64)
    boxes = np.concatenate([pts, pts], axis=1)
    ids = np.arange(P, dtype=np.int32)[::-1].copy()
    builds = [build_forest(boxes, ids, tree_of, T, fanout=4)
              for _ in range(2)]
    builds += [build_forest_device(boxes, ids, tree_of, T, fanout=4)
               for _ in range(2)]
    ref = builds[0]
    for f in builds[1:]:
        assert np.array_equal(ref.entries, f.entries)
        assert np.array_equal(ref.entry_ids, f.entry_ids)
        assert np.array_equal(ref.entry_off, f.entry_off)
    # ties are resolved by input position: within a tree the reversed
    # payload ids appear in descending order (== generation order)
    for t in range(T):
        s, e = ref.entry_off[t], ref.entry_off[t + 1]
        grp = ref.entry_ids[s:e]
        tie_classes = ref.entries[s:e, 0]
        for v in np.unique(tie_classes):
            cls = grp[tie_classes == v]
            assert (np.diff(cls) < 0).all(), (t, v, cls)
    # exhaustive collect check on identity payloads (both backends)
    for name, build in _both_builders():
        f = _points_forest(build, pts, tree_of, T, 4)
        _check_collect_exhaustive(
            f, pts, tree_of, T,
            np.array([[0, 0, 4, 4], [2.9, 2.9, 3.1, 3.1]], np.float32))
