"""Train a small LM end-to-end with checkpointing (framework demo).

Uses the gemma2-style reduced config (softcaps + alternating local/global
attention) on the synthetic token stream; shows the loss descending and a
mid-run checkpoint + restore.

    PYTHONPATH=src python examples/train_lm.py
"""

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import lm_batches
from repro.distributed import restore_checkpoint, save_checkpoint
from repro.models.lm import init_params, lm_loss
from repro.train import AdamWConfig, adamw_init, make_train_step

cfg = get_arch("gemma2-2b").make_config(reduced=True)
params = init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=120)
step_fn = jax.jit(make_train_step(
    lambda p, b: lm_loss(p, b, cfg), opt_cfg, grad_accum=2))
opt = adamw_init(params)

data = lm_batches(cfg.vocab, seq_len=64, global_batch=16, seed=7)
t0 = time.perf_counter()
losses = []
with tempfile.TemporaryDirectory() as ckdir:
    for step in range(120):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if (step + 1) % 20 == 0:
            print(f"step {step + 1:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"|g| {float(m['grad_norm']):.2f}")
        if step + 1 == 60:
            save_checkpoint(ckdir, 60, {"params": params, "opt": opt})
    # restore and confirm bit-exact params
    restored, _ = restore_checkpoint(ckdir, {"params": params, "opt": opt},
                                     step=60)
print(f"\nfirst-10 mean loss {np.mean(losses[:10]):.4f} -> "
      f"last-10 {np.mean(losses[-10:]):.4f} "
      f"({time.perf_counter() - t0:.1f}s)")
assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss must descend"
print("checkpoint roundtrip + loss descent: OK")
