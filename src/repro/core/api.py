"""Unified front door for every RangeReach method.

    index = build_index(graph, method)        # offline
    ans   = batch_query(index, us, rects)     # online

``method`` is one of METHODS (the five evaluated in the paper's Section 5
plus the GeoReach baseline).  Benchmarks, examples and the serving stack
all go through this module so the methods stay interchangeable.
"""

from __future__ import annotations

import warnings
from typing import Union

import numpy as np

from .georeach import GeoReachIndex, build_georeach
from .graph import GeosocialGraph
from .three_d_reach import ThreeDReachIndex, build_3dreach
from .two_d_reach import TwoDReachIndex, build_2dreach

METHODS = (
    "2dreach",
    "2dreach-comp",
    "2dreach-pointer",
    "3dreach",
    "3dreach-rev",
    "georeach",
)

AnyIndex = Union[TwoDReachIndex, ThreeDReachIndex, GeoReachIndex]


def build_index(graph: GeosocialGraph, method: str, **kw) -> AnyIndex:
    """Build the offline index for ``method`` (one of ``METHODS``).

    Keyword arguments are forwarded to the method's builder (``fanout``,
    ``dedup``, ...).  ``backend`` selects the *build* pipeline and is a
    2DReach-only option: ``backend="host"`` (default) builds in NumPy;
    ``backend="device"`` runs the reachable-set closure and the forest
    bulk-load on the accelerator and leaves the serving arrays device-
    resident, so a subsequent ``QueryEngine`` / ``ShardedEngine`` (or a
    ``DynamicIndex(engine="device"|"cluster")`` compaction swap) adopts
    them without re-uploading.  Asking for ``backend="device"`` with a
    method that has no device builder raises a ``ValueError`` naming the
    method and the supported pairings — it never falls back silently.
    """
    method = method.lower()
    if method not in METHODS:
        raise ValueError(
            f"unknown method {method!r}; expected one of {METHODS}")
    if not method.startswith("2dreach"):
        backend = kw.pop("backend", "host")   # host build == the default
        if backend != "host":
            raise ValueError(
                f"no {backend!r} build backend for method {method!r}: "
                f"backend='device' is implemented for the 2DReach "
                f"variants only (2dreach, 2dreach-comp, 2dreach-pointer);"
                f" build {method!r} with backend='host' (the default)")
    if method == "2dreach":
        return build_2dreach(graph, variant="base", **kw)
    if method == "2dreach-comp":
        return build_2dreach(graph, variant="comp", **kw)
    if method == "2dreach-pointer":
        return build_2dreach(graph, variant="pointer", **kw)
    if method == "3dreach":
        return build_3dreach(graph, variant="3d", **kw)
    if method == "3dreach-rev":
        return build_3dreach(graph, variant="3drev", **kw)
    if method == "georeach":
        return build_georeach(graph, **kw)
    # unreachable while the if-chain covers METHODS — fail loudly if a
    # new METHODS entry lands without a branch here
    raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")


def build_dynamic_index(graph: GeosocialGraph, method: str, policy=None, **kw):
    """Wrap ``method`` in a :class:`repro.dynamic.DynamicIndex`: the same
    offline build plus online ``add_edge``/``add_vertex``/``add_spatial``
    and policy-driven compaction.  Method-agnostic — every METHODS entry
    works as the static base."""
    from ..dynamic import DynamicIndex  # deferred: dynamic imports core

    return DynamicIndex(graph, method, policy=policy, **kw)


# (reason, index type) pairs batch_query has already warned about
# falling back to the host path for — one warning per distinct cause,
# not one per batch and not one globally: an unsupported index type and
# a wrapper that was *constructed* for host serving are different
# operator mistakes and each deserves its own (single) warning.  Every
# fallback, warned or not, increments the ``api.host_fallback.<reason>``
# metric so dashboards see the full count.
_FALLBACK_WARNED = set()
FALLBACK_REASONS = ("unsupported-index", "wrapper-host-engine")


def _warn_host_fallback(index, reason: str) -> None:
    from ..obs import REGISTRY  # deferred: keep api importable early

    name = type(index).__name__
    REGISTRY.counter(f"api.host_fallback.{reason}").inc()
    key = (reason, name)
    if key in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(key)
    if reason == "wrapper-host-engine":
        detail = (f"{name} was constructed with engine='host', so its "
                  f"query_batch serves the host path; construct it with "
                  f"engine='device' for device base probes")
    else:
        detail = (f"no device QueryEngine for {name}; falling back to "
                  f"the host path")
    warnings.warn(
        f"batch_query(engine='device') [{reason}]: {detail} (pass "
        f"required=True to make this an error)",
        RuntimeWarning, stacklevel=3)


def batch_query(index, us: np.ndarray, rects: np.ndarray,
                engine: str = "host", required: bool = False) -> np.ndarray:
    """Batched RangeReach through ``index``.

    ``engine="host"`` is the NumPy path every index supports.
    ``engine="device"`` routes 2DReach indexes through the
    compile-once :class:`~repro.core.engine.QueryEngine` (uploaded and
    memoised on first use); index types without a device engine fall
    back to the host path with one ``RuntimeWarning`` per distinct
    (reason, index type) cause — counted per fallback under the
    ``api.host_fallback.<reason>`` metric — or, with ``required=True``,
    raise a ``ValueError`` naming the index, so a benchmark asking for
    the device engine can never silently measure the host path.
    ``engine="cluster"`` routes through the sharded multi-device
    :class:`~repro.cluster.ShardedEngine` (forest partitioned over the
    mesh, memoised on first use); cluster serving is an explicit opt-in,
    so an unsupported index type always raises instead of falling back.
    """
    if engine == "device":
        from .engine import engine_for  # deferred: engine imports kernels

        eng = engine_for(index)
        if eng is not None:
            return eng.query_batch(np.asarray(us), np.asarray(rects))
        wrapped = getattr(index, "engine", None)
        if wrapped is not None and wrapped != "host":
            # a wrapper (DynamicIndex) already configured for device or
            # cluster base serving: its own query_batch IS the device
            # path, not a fallback
            return index.query_batch(np.asarray(us), np.asarray(rects))
        if required:
            engine_for(index, required=True)  # raises, naming the index
        _warn_host_fallback(
            index, "wrapper-host-engine" if wrapped == "host"
            else "unsupported-index")
    elif engine == "cluster":
        from ..cluster import sharded_engine_for  # deferred: imports core

        eng = sharded_engine_for(index)
        return eng.query_batch(np.asarray(us), np.asarray(rects))
    elif engine != "host":
        raise ValueError(
            f"unknown engine {engine!r}; expected host|device|cluster")
    return index.query_batch(np.asarray(us), np.asarray(rects))


def run_queries(index, program, engine: str = "host"):
    """Execute a :class:`~repro.queries.QueryProgram` through ``index``.

    The unified front door for the analytics query classes (see
    :mod:`repro.queries`): ``reach`` works on every index (it delegates
    to :func:`batch_query`); ``count`` / ``collect`` / ``knn`` /
    ``polygon`` are exact on the 2DReach variants — static indexes on
    both engines, :class:`~repro.dynamic.DynamicIndex` (host engine
    routing, with its device base probes when so configured).  Asking
    for an analytics class on an index without one raises a
    ``ValueError`` naming the index — never a silent wrong answer.

    engine: ``"host"`` (NumPy descents) or ``"device"`` (the
    compile-once ``QueryEngine`` kernels; bit-identical to host).
    """
    from ..queries import host as qhost  # deferred: queries imports core

    if engine not in ("host", "device"):
        raise ValueError(
            f"unknown engine {engine!r}; expected host|device "
            f"(run_queries serves single-index engines; use batch_query "
            f"for cluster boolean serving)")
    kind = program.kind
    is_static = isinstance(index, (TwoDReachIndex, ThreeDReachIndex,
                                   GeoReachIndex))
    if not is_static and engine == "device":
        # wrappers (DynamicIndex) pick their serving engine at
        # construction; asking run_queries for a device pass must not
        # silently measure host base probes.  reach is served by both
        # device and cluster wrappers; the analytics classes need the
        # single-device QueryEngine (the cluster ShardedEngine is
        # boolean-only, so a cluster wrapper's analytics base probes
        # would fall back to the host descents)
        wrapped = getattr(index, "engine", "host")
        ok = ("device", "cluster") if kind == "reach" else ("device",)
        if wrapped not in ok:
            raise ValueError(
                f"run_queries(engine='device', kind={kind!r}) on a "
                f"{type(index).__name__} configured with "
                f"engine={wrapped!r}: its base probes for this class "
                f"would run on the host path — construct it with "
                f"engine='device', or pass engine='host' here")
    if kind == "reach":
        if is_static:
            return batch_query(index, program.us, program.rects,
                               engine=engine,
                               required=(engine == "device"))
        # wrapper query_batch is the full mutated-graph answer, routed
        # through whatever base engine the wrapper was built with
        return index.query_batch(program.us, program.rects)

    # analytics classes: one argument table drives every target surface
    # (host descents, device engine methods, DynamicIndex methods)
    try:
        args = {
            "count": (program.us, program.rects),
            "collect": (program.us, program.rects, program.k),
            "knn": (program.us, program.points, program.k),
            "polygon": (program.us, program.polygons),
        }[kind]
    except KeyError:
        raise ValueError(
            f"unknown query kind {kind!r}; expected one of "
            f"('reach', 'count', 'collect', 'knn', 'polygon')") from None
    method = f"{kind}_batch"

    if isinstance(index, TwoDReachIndex):
        if engine == "device":
            from .engine import engine_for

            return getattr(engine_for(index, required=True), method)(*args)
        from ..queries.knn import knn_reach_host

        host_fns = {
            "count": qhost.range_count_host,
            "collect": qhost.range_collect_host,
            "knn": knn_reach_host,
            "polygon": qhost.polygon_reach_host,
        }
        return host_fns[kind](index, *args)

    # DynamicIndex (or anything exposing the analytics surface)
    if hasattr(index, method):
        return getattr(index, method)(*args)
    raise ValueError(
        f"no {kind!r} query class for {type(index).__name__}: the "
        f"analytics classes are implemented for the 2DReach variants "
        f"(and DynamicIndex over them); use kind='reach' for boolean "
        f"RangeReach on every method")


def index_nbytes(index) -> dict:
    """Size decomposition mirroring the paper's Table 4 parentheses.

    The ``rtree`` entry is the spatial structure (GeoReach has no R-tree;
    its MBR summaries + per-component venue lists play that role) and
    ``aux`` the social/lookup side, so size comparisons across methods
    are apples-to-apples.
    """
    if isinstance(index, TwoDReachIndex):
        return {
            "rtree": index.nbytes_rtree(),
            "aux": index.nbytes_pointers(),
            "total": index.nbytes_total(),
        }
    if isinstance(index, ThreeDReachIndex):
        return {
            "rtree": index.nbytes_rtree(),
            "aux": index.nbytes_labels(),
            "total": index.nbytes_total(),
        }
    if isinstance(index, GeoReachIndex):
        return {
            "rtree": index.nbytes_spatial(),
            "aux": index.nbytes_social(),
            "total": index.nbytes_total(),
        }
    # DynamicIndex (or anything else wrapping a base index)
    if hasattr(index, "nbytes"):
        return index.nbytes()
    return {"rtree": 0, "aux": index.nbytes_total(), "total": index.nbytes_total()}
