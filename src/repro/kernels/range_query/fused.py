"""Fused serving megakernel: single-launch route→prune→scan.

The two-phase engine (:mod:`.descent`) answers a batch with *three*
dispatches — a prune ``pallas_call``, a host round trip that buckets the
candidate capacity, and a scan ``pallas_call`` — plus a host-side pad.
The paper's point is that a 2DReach query is **one** R-tree lookup; this
module makes the device path agree:

* **Quantized MBR planes** (:class:`QuantGrid`): rects and tile MBRs are
  snapped onto an integer grid over the arena's extent — ``int16`` for
  the fine (leaf-tile) plane, ``int32`` for the coarse plane — with
  every bound rounded *outward* (mins down, maxs up, ±1 grid cell of
  slack so float32 scaling error can never round inward).  The
  quantized intersection test is therefore a provable superset of the
  float32 truth: pruning stays sound, the final leaf predicate stays
  exact f32, and the fine plane moves half the bytes through VMEM.
  Padding (±inf) bounds map to reserved sentinel codes that fail both
  halves of the intersect test, so padding tiles can never activate.

* **The megakernel** (:func:`fused_serve_pallas`): ONE ``pallas_call``
  over grid ``(B // TB,)``.  Each step holds its query tile's rects
  (quantized + exact), the whole quantized pyramid (VMEM-resident —
  ~64 KB at a million venues), and the entry arena left in HBM/ANY.
  In-kernel it (1) evaluates the hierarchical coarse→fine prune, (2)
  compacts the surviving leaf tiles into an ascending worklist via a
  lane prefix-sum (no host compaction, no materialized candidate
  matrix), and (3) walks the worklist with double-buffered DMA — the
  next tile's HBM→VMEM copy is in flight while the current tile's
  exact f32 predicate evaluates.  A ``mode`` flag selects the epilogue
  — boolean OR, exact count, or collect (ids-or-sentinel written per
  worklist slot) — so one kernel serves ``query/count/collect_batch``.

* **The fused XLA path** (:func:`fused_serve_xla`): the same
  route→prune→compact→scan semantics as one fused XLA program (dense
  quantized prune, ascending compaction, gathered leaf tiles).  It is
  bit-identical to the megakernel and serves two roles: the oracle the
  kernel is tested against, and the serving implementation on backends
  where Pallas only interprets (CPU), where one compiled XLA program
  beats an emulated kernel.

Capacity contract: both paths scan at most ``kcap`` candidate tiles per
query tile and report the *true* per-tile candidate counts.  When any
count exceeds ``kcap`` the results are a partial scan — callers
(the engine's ratcheting high-water mark) must re-run at the next
power-of-two bucket.  Steady state never ratchets, so the fused trace
is compile-once like the two-phase path it replaces.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .analytics import ID_SENTINEL
from .descent import COARSE_GROUP
from .kernel import TB, TP

# int16 fine-plane code space: finite bounds clip to [I16_LO, I16_HI];
# the values just outside are reserved for ±inf padding so an inert
# tile/rect fails both halves of the intersect test by construction.
I16_LO, I16_HI = -32767, 32766
I16_PAD_MIN, I16_PAD_MAX = 32767, -32768          # min=+inf / max=-inf
# int32 coarse-plane code space (2^20-cell grid, clip well inside int32)
I32_LO, I32_HI = -2_000_000, 2_000_000
I32_PAD_MIN, I32_PAD_MAX = 2_100_000, -2_100_000
_GRID16 = 60000.0       # fine grid cells across the arena extent
_GRID32 = float(2 ** 20)  # coarse grid cells


# --------------------------------------------------------------------------
# Quantization (outward-rounded, provably conservative)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantGrid:
    """Per-axis affine maps onto the int16 / int32 code grids.

    ``code = (x - mid) * scale`` with mins floored (−1 slack) and maxs
    ceiled (+1 slack) before clipping into the finite code range: the
    slack cell absorbs the float32 scaling error (≤ ~0.01 cells for the
    int16 grid, ≤ ~0.1 for the int32 grid), so a quantized bound is
    always at least as permissive as the exact one.  Clipping is
    monotone, hence also conservative: out-of-extent values saturate
    toward "intersects more", never less.
    """

    mid: jax.Array   # (dim,) float32 extent midpoint
    s16: jax.Array   # (dim,) float32 cells-per-unit, fine grid
    s32: jax.Array   # (dim,) float32 cells-per-unit, coarse grid


def make_quant_grid(extent, dim: int) -> QuantGrid:
    """Grid from a ``(2*dim,)`` [mins..., maxs...] extent (``None`` /
    empty arena → a degenerate grid under which every finite bound maps
    near 0 — maximally permissive, still exact downstream)."""
    if extent is None:
        lo = np.zeros(dim, np.float64)
        hi = np.zeros(dim, np.float64)
    else:
        extent = np.asarray(extent, np.float64)
        lo, hi = extent[:dim], extent[dim:2 * dim]
    width = np.maximum(hi - lo, 1e-9)
    return QuantGrid(
        mid=jnp.asarray((lo + hi) / 2.0, jnp.float32),
        s16=jnp.asarray(_GRID16 / width, jnp.float32),
        s32=jnp.asarray(_GRID32 / width, jnp.float32),
    )


def _q_bounds(x, mid, s, *, lo_code, hi_code, pad_min, pad_max,
              is_min: bool, dtype):
    """Outward-rounded quantization of one bound plane (see QuantGrid)."""
    v = (x - mid) * s
    if is_min:
        q = jnp.clip(jnp.floor(v) - 1.0, lo_code, hi_code)
        q = jnp.where(x == jnp.inf, float(pad_min), q)
    else:
        q = jnp.clip(jnp.ceil(v) + 1.0, lo_code, hi_code)
        q = jnp.where(x == -jnp.inf, float(pad_max), q)
    return q.astype(dtype)


def _q_plane(plane, mid, s, dim, *, lo_code, hi_code, pad_min, pad_max,
             dtype):
    """Quantize a (2*dim, N) [mins..., maxs...] SoA plane outward."""
    rows = []
    for a in range(dim):
        rows.append(_q_bounds(plane[a], mid[a], s[a], lo_code=lo_code,
                              hi_code=hi_code, pad_min=pad_min,
                              pad_max=pad_max, is_min=True, dtype=dtype))
    for a in range(dim):
        rows.append(_q_bounds(plane[dim + a], mid[a], s[a],
                              lo_code=lo_code, hi_code=hi_code,
                              pad_min=pad_min, pad_max=pad_max,
                              is_min=False, dtype=dtype))
    return jnp.stack(rows)


def quantize_fine(grid: QuantGrid, fine, dim: int) -> jax.Array:
    """(2*dim, NTp) f32 fine tile MBRs -> int16 codes (outward)."""
    return _q_plane(fine, grid.mid, grid.s16, dim, lo_code=I16_LO,
                    hi_code=I16_HI, pad_min=I16_PAD_MIN,
                    pad_max=I16_PAD_MAX, dtype=jnp.int16)


def quantize_coarse(grid: QuantGrid, coarse, dim: int) -> jax.Array:
    """(2*dim, NCp) f32 coarse MBRs -> int32 codes (outward)."""
    return _q_plane(coarse, grid.mid, grid.s32, dim, lo_code=I32_LO,
                    hi_code=I32_HI, pad_min=I32_PAD_MIN,
                    pad_max=I32_PAD_MAX, dtype=jnp.int32)


def quantize_rects(grid: QuantGrid, rsoa,
                   dim: int) -> Tuple[jax.Array, jax.Array]:
    """(2*dim, B) f32 rects -> (int16, int32) outward-rounded codes.

    Rects round outward too (mins down, maxs up): expanding *both*
    sides of the intersect test keeps the quantized candidate set a
    superset of the float32 one.
    """
    r16 = _q_plane(rsoa, grid.mid, grid.s16, dim, lo_code=I16_LO,
                   hi_code=I16_HI, pad_min=I16_PAD_MIN,
                   pad_max=I16_PAD_MAX, dtype=jnp.int16)
    r32 = _q_plane(rsoa, grid.mid, grid.s32, dim, lo_code=I32_LO,
                   hi_code=I32_HI, pad_min=I32_PAD_MIN,
                   pad_max=I32_PAD_MAX, dtype=jnp.int32)
    return r16, r32


# --------------------------------------------------------------------------
# Quantized hierarchical prune (dense reference / XLA building block)
# --------------------------------------------------------------------------

def quantized_prune_mask(
    qfine, qcoarse, r16, r32, qstart, qend, *,
    dim: int = 2, tb: int = TB, tp: int = TP, group: int = COARSE_GROUP,
) -> jax.Array:
    """(B // tb, NTp) bool — quantized coarse∧fine∧slice prune.

    Same contract as ``descent.prune_tiles_pallas`` but over integer
    code planes; by the outward rounding the mask is a superset of the
    f32 prune mask (property-tested), which is all soundness needs.
    """
    ntp = qfine.shape[1]
    B = r16.shape[1]
    gidx = jnp.arange(ntp, dtype=jnp.int32)[None, :]
    ok = (gidx * tp < qend[:, None]) & (gidx * tp + tp > qstart[:, None])
    for a in range(dim):
        ok = ok & (qfine[a][None, :] <= r16[dim + a][:, None])
        ok = ok & (qfine[dim + a][None, :] >= r16[a][:, None])
    cok = jnp.ones((B, qcoarse.shape[1]), dtype=bool)
    for a in range(dim):
        cok = cok & (qcoarse[a][None, :] <= r32[dim + a][:, None])
        cok = cok & (qcoarse[dim + a][None, :] >= r32[a][:, None])
    ok = ok & jnp.repeat(cok, group, axis=1)[:, :ntp]
    return jnp.any(ok.reshape(B // tb, tb, ntp), axis=1)


def compact_ascending(mask: jax.Array, nt: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """Prune mask (NB, >=nt) -> (cand (NB, nt) int32 ascending actives
    then the last active repeated, cnt (NB,) int32).  Same contract as
    ``core.engine.compact_candidates`` (which now delegates here)."""
    active = mask[:, :nt] > 0
    cnt = active.sum(axis=1).astype(jnp.int32)
    j = jnp.arange(nt, dtype=jnp.int32)
    order = jnp.argsort(
        jnp.where(active, j[None, :], nt + j[None, :]), axis=1
    ).astype(jnp.int32)
    last = order[jnp.arange(order.shape[0]), jnp.maximum(cnt - 1, 0)]
    cand = jnp.where(j[None, :] < cnt[:, None], order, last[:, None])
    return cand, cnt


# --------------------------------------------------------------------------
# The megakernel (one pallas_call: prune + compact + double-buffered scan)
# --------------------------------------------------------------------------

def _prefix_lanes(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the lane axis of a (1, N) int32 —
    log2(N) shifted adds (static Python loop, Mosaic-friendly)."""
    n = x.shape[1]
    d = 1
    while d < n:
        x = x + jnp.pad(x, ((0, 0), (d, 0)))[:, :n]
        d <<= 1
    return x


def _fused_kernel(qf_ref, qc_ref, r16_ref, r32_ref, q_ref, qs_ref, qe_ref,
                  e_any, *rest, mode: str, kcap: int, nt: int, dim: int,
                  tp: int, group: int):
    if mode == "collect":
        ids_any, o_ref, cnt_ref, ebuf, esem, ibuf, isem = rest
    else:
        o_ref, cnt_ref, ebuf, esem = rest
        ids_any = ibuf = isem = None

    qs = qs_ref[...][:, None]               # (TB, 1)
    qe = qe_ref[...][:, None]

    # ---- phase 1: quantized hierarchical prune (all in VMEM) ----------
    qf = qf_ref[...]                        # (2*dim, NTp) int16
    qc = qc_ref[...]                        # (2*dim, NCp) int32
    r16 = r16_ref[...]                      # (2*dim, TB) int16
    r32 = r32_ref[...]
    ntp = qf.shape[1]
    gidx = jax.lax.broadcasted_iota(jnp.int32, (1, ntp), 1)
    ok = (gidx * tp < qe) & (gidx * tp + tp > qs)       # (TB, NTp)
    for a in range(dim):
        ok = ok & (qf[a][None, :] <= r16[dim + a][:, None])
        ok = ok & (qf[dim + a][None, :] >= r16[a][:, None])
    cok = jnp.ones((qs.shape[0], qc.shape[1]), dtype=bool)
    for a in range(dim):
        cok = cok & (qc[a][None, :] <= r32[dim + a][:, None])
        cok = cok & (qc[dim + a][None, :] >= r32[a][:, None])
    ncg = ntp // group
    cexp = jnp.broadcast_to(
        cok[:, :ncg, None], (cok.shape[0], ncg, group)
    ).reshape(cok.shape[0], ncg * group)
    ok = ok & cexp
    act = jnp.any(ok, axis=0)[None, :]                  # (1, NTp) bool

    # ---- phase 2: in-kernel compaction (lane prefix sum) --------------
    csum = _prefix_lanes(act.astype(jnp.int32))         # (1, NTp)
    cnt = csum[0, ntp - 1]
    cnt_ref[0] = cnt
    n = jnp.minimum(cnt, kcap)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, ntp), 1)

    def tile_of(s):
        """Worklist slot s -> ascending s-th active tile id (scalar)."""
        match = act & (csum == s + 1)
        return jnp.min(jnp.where(match, lanes, ntp)).astype(jnp.int32)

    # ---- phase 3: double-buffered masked scan over the worklist -------
    q = q_ref[...]                          # (2*dim, TB) exact f32 rects

    def dma(k, slot):
        """The (deterministic) copy descriptors for worklist slot k —
        rebuilt identically at start and wait time."""
        off = pl.multiple_of(tile_of(k) * tp, tp)
        cps = [pltpu.make_async_copy(
            e_any.at[:, pl.ds(off, tp)], ebuf.at[slot], esem.at[slot])]
        if mode == "collect":
            cps.append(pltpu.make_async_copy(
                ids_any.at[:, pl.ds(off, tp)], ibuf.at[slot],
                isem.at[slot]))
        return cps

    @pl.when(n > 0)
    def _first():
        for cp in dma(0, 0):
            cp.start()

    if mode == "collect":
        o_ref[...] = jnp.full(o_ref.shape, ID_SENTINEL, dtype=jnp.int32)

    def body(k, acc):
        slot = jax.lax.rem(k, 2)

        @pl.when(k + 1 < n)
        def _next():
            for cp in dma(k + 1, jax.lax.rem(k + 1, 2)):
                cp.start()

        for cp in dma(k, slot):
            cp.wait()
        e = ebuf[slot]                      # (2*dim, TP) exact f32
        t = tile_of(k)
        g = t * tp + jax.lax.broadcasted_iota(jnp.int32, (1, tp), 1)
        hit = (g >= qs) & (g < qe)          # (TB, TP) exact leaf test
        for a in range(dim):
            hit = hit & (e[a][None, :] <= q[dim + a][:, None])
            hit = hit & (e[dim + a][None, :] >= q[a][:, None])
        if mode == "reach":
            return acc | jnp.any(hit, axis=1).astype(jnp.int32)
        if mode == "count":
            return acc + jnp.sum(hit, axis=1).astype(jnp.int32)
        ids = ibuf[slot][0][None, :]        # (1, TP)
        vals = jnp.where(hit, ids, ID_SENTINEL)
        o_ref[:, pl.ds(pl.multiple_of(k * tp, tp), tp)] = vals
        return acc

    acc = jax.lax.fori_loop(
        0, n, body, jnp.zeros((qs.shape[0],), jnp.int32))
    if mode != "collect":
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "mode", "kcap", "nt", "dim", "interpret", "tb", "tp", "group"))
def fused_serve_pallas(
    qfine: jax.Array,         # (2*dim, NTp) int16 quantized fine MBRs
    qcoarse: jax.Array,       # (2*dim, NTp // group) int32 quantized
    entries_soa: jax.Array,   # (2*dim, P) float32 arena (stays in HBM)
    ids_soa: jax.Array,       # (1, P) int32 payload ids (collect mode)
    r16: jax.Array,           # (2*dim, B) int16 quantized rects
    r32: jax.Array,           # (2*dim, B) int32 quantized rects
    rects_soa: jax.Array,     # (2*dim, B) float32 exact rects
    qstart: jax.Array,        # (B,) int32
    qend: jax.Array,          # (B,) int32
    *,
    mode: str,                # "reach" | "count" | "collect"
    kcap: int,                # worklist capacity (tiles per query tile)
    nt: int,                  # true fine tile count
    dim: int = 2,
    interpret: bool = False,
    tb: int = TB,
    tp: int = TP,
    group: int = COARSE_GROUP,
) -> Tuple[jax.Array, jax.Array]:
    """Single-launch fused serve.  Returns ``(out, cnt)``:

    * ``out`` — mode reach/count: (B,) int32 hits / exact counts;
      mode collect: (B, kcap*tp) int32 ids-or-sentinel matrix;
    * ``cnt`` — (B // tb,) int32 true candidate-tile counts.  Any
      value > ``kcap`` means the scan was truncated and the caller must
      re-run at a larger capacity (the engine's ratchet).
    """
    two_dim, P = entries_soa.shape
    _, B = rects_soa.shape
    ntp = qfine.shape[1]
    assert two_dim == 2 * dim
    assert P % tp == 0 and B % tb == 0, (P, B)
    assert ntp % group == 0 and qcoarse.shape == (two_dim, ntp // group)
    assert mode in ("reach", "count", "collect"), mode
    nb = B // tb
    kcap = max(int(kcap), 1)

    in_specs = [
        pl.BlockSpec((two_dim, ntp), lambda i: (0, 0)),
        pl.BlockSpec((two_dim, ntp // group), lambda i: (0, 0)),
        pl.BlockSpec((two_dim, tb), lambda i: (0, i)),
        pl.BlockSpec((two_dim, tb), lambda i: (0, i)),
        pl.BlockSpec((two_dim, tb), lambda i: (0, i)),
        pl.BlockSpec((tb,), lambda i: (i,)),
        pl.BlockSpec((tb,), lambda i: (i,)),
        pl.BlockSpec(memory_space=pltpu.ANY),           # entry arena
    ]
    args = [qfine, qcoarse, r16, r32, rects_soa, qstart, qend,
            entries_soa]
    scratch = [
        pltpu.VMEM((2, two_dim, tp), jnp.float32),      # tile buffers
        pltpu.SemaphoreType.DMA((2,)),
    ]
    if mode == "collect":
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        args.append(ids_soa)
        scratch += [pltpu.VMEM((2, 1, tp), jnp.int32),
                    pltpu.SemaphoreType.DMA((2,))]
        out_spec = pl.BlockSpec((tb, kcap * tp), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((B, kcap * tp), jnp.int32)
    else:
        out_spec = pl.BlockSpec((tb,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((B,), jnp.int32)

    out, cnt = pl.pallas_call(
        functools.partial(
            _fused_kernel, mode=mode, kcap=kcap, nt=nt, dim=dim, tp=tp,
            group=group),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[out_spec, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[out_shape, jax.ShapeDtypeStruct((nb,), jnp.int32)],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)
    return out, cnt


# --------------------------------------------------------------------------
# Fused XLA implementation (oracle for the kernel; serving path on CPU)
# --------------------------------------------------------------------------

def fused_serve_xla(
    qfine, qcoarse, entries_soa, ids_soa, r16, r32, rects_soa,
    qstart, qend, *, mode: str, kcap: int, nt: int, dim: int = 2,
    tb: int = TB, tp: int = TP, group: int = COARSE_GROUP,
) -> Tuple[jax.Array, jax.Array]:
    """Same contract as :func:`fused_serve_pallas`, as one fused XLA
    program: dense quantized prune → ascending compaction → gathered
    leaf-tile scan.  Bit-identical to the megakernel (tested)."""
    B = rects_soa.shape[1]
    nb = B // tb
    kcap = max(int(kcap), 1)
    mask = quantized_prune_mask(qfine, qcoarse, r16, r32, qstart, qend,
                                dim=dim, tb=tb, tp=tp, group=group)
    cand, cnt = compact_ascending(mask, nt)
    if kcap <= nt:                                       # (nb, kcap)
        ck = cand[:, :kcap]
    else:                    # capacity beyond the tile count: repeat the
        ck = jnp.concatenate(  # last column; the live mask inerts it
            [cand, jnp.broadcast_to(cand[:, -1:], (nb, kcap - nt))],
            axis=1)
    live = (jnp.arange(kcap, dtype=jnp.int32)[None, :]
            < cnt[:, None])                              # (nb, kcap)
    # gather the candidate leaf tiles: global entry index per lane
    g = (ck[:, :, None] * tp
         + jnp.arange(tp, dtype=jnp.int32)[None, None, :]
         ).reshape(nb, kcap * tp)                        # (nb, K*tp)
    tiles = jnp.take(entries_soa, g, axis=1)             # (2*dim, nb, K*tp)
    qs = qstart.reshape(nb, tb)[:, :, None]
    qe = qend.reshape(nb, tb)[:, :, None]
    q = rects_soa.reshape(2 * dim, nb, tb)
    hit = (g[:, None, :] >= qs) & (g[:, None, :] < qe)   # (nb, tb, K*tp)
    for a in range(dim):
        hit = hit & (tiles[a][:, None, :] <= q[dim + a][:, :, None])
        hit = hit & (tiles[dim + a][:, None, :] >= q[a][:, :, None])
    hit = hit & jnp.repeat(live, tp, axis=1)[:, None, :]
    if mode == "reach":
        out = jnp.any(hit, axis=2).astype(jnp.int32).reshape(B)
    elif mode == "count":
        out = jnp.sum(hit, axis=2).astype(jnp.int32).reshape(B)
    elif mode == "collect":
        ids = jnp.take(ids_soa[0], g, axis=0)            # (nb, K*tp)
        out = jnp.where(hit, ids[:, None, :], ID_SENTINEL).reshape(
            B, kcap * tp)
    else:
        raise ValueError(f"unknown fused mode {mode!r}")
    return out, cnt
