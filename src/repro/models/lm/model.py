"""Decoder-only transformer covering the five assigned LM architectures.

Structure:

* Layers are grouped into **scan segments** (config.scan_segments): each
  segment is a ``lax.scan`` over stacked params of one repeating unit
  (e.g. gemma3's [L,L,L,L,L,G], llama4's [dense, MoE]), keeping compiled
  HLO size flat in depth — essential for 61-layer dry-runs on one CPU.
* Attention: GQA or MLA; global layers use blockwise flash-scan, 'L'
  layers use banded SWA (O(S*w)); gemma2 softcaps supported.
* FFN: GLU dense or the EP MoE of moe.py.
* Loss: chunked cross-entropy (the (B, S, V) logits tensor is never
  materialised — V=262k at S=4k would dominate HBM otherwise).
* Decode: per-layer KV caches (ring buffers for SWA layers, compact
  (c_kv, k_pe) for MLA with the weight-absorption trick), ``prefill`` +
  ``decode_step``.

Sharding: the model annotates activations with PartitionSpecs when a mesh
is ambient (see distributed/sharding.py for the parameter rules); all
annotations no-op on a bare CPU.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..nn import Params, dense, dense_init, embed_init, norm_init, rmsnorm, ACT
from ..nn import softcap as _softcap
from .attention import (
    banded_attention,
    decode_attention,
    flash_attention,
    rope,
)
from .config import LMConfig, MLASpec, MoESpec
from .moe import moe_ffn, moe_init


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


def shard(x, spec: Optional[P]):
    """Best-effort sharding annotation (no-op without an ambient mesh)."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _attn_init(key, cfg: LMConfig) -> Params:
    dt = _dtype(cfg)
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    s = (1.0 / d) ** 0.5
    if cfg.attn == "mla":
        m = cfg.mla
        qk = m.qk_nope + m.qk_rope
        return {
            "wq_a": jax.random.normal(ks[0], (d, m.q_lora), dt) * s,
            "q_norm": norm_init(m.q_lora, dt),
            "wq_b": jax.random.normal(ks[1], (m.q_lora, H * qk), dt)
            * (1.0 / m.q_lora) ** 0.5,
            "wkv_a": jax.random.normal(
                ks[2], (d, m.kv_lora + m.qk_rope), dt) * s,
            "kv_norm": norm_init(m.kv_lora, dt),
            "wkv_b": jax.random.normal(
                ks[3], (m.kv_lora, H * (m.qk_nope + m.v_head)), dt)
            * (1.0 / m.kv_lora) ** 0.5,
            "wo": jax.random.normal(ks[4], (H * m.v_head, d), dt)
            * (1.0 / (H * m.v_head)) ** 0.5,
        }
    return {
        "wq": jax.random.normal(ks[0], (d, H * dh), dt) * s,
        "wk": jax.random.normal(ks[1], (d, KV * dh), dt) * s,
        "wv": jax.random.normal(ks[2], (d, KV * dh), dt) * s,
        "wo": jax.random.normal(ks[3], (H * dh, d), dt)
        * (1.0 / (H * dh)) ** 0.5,
    }


def _ffn_init(key, cfg: LMConfig) -> Params:
    dt = _dtype(cfg)
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "w_gu": jax.random.normal(k1, (d, 2, f), dt) * (1.0 / d) ** 0.5,
        "w_d": jax.random.normal(k2, (f, d), dt) * (1.0 / f) ** 0.5,
    }


def _block_init(key, cfg: LMConfig, is_moe: bool) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln_attn": norm_init(cfg.d_model, _dtype(cfg)),
        "attn": _attn_init(ks[0], cfg),
        "ln_ffn": norm_init(cfg.d_model, _dtype(cfg)),
    }
    if is_moe:
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.moe, _dtype(cfg))
    else:
        p["ffn"] = _ffn_init(ks[1], cfg)
    return p


def init_params(key, cfg: LMConfig) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dt),
        "ln_f": norm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": jax.random.normal(ks[1], (cfg.d_model, cfg.vocab), dt)
            * (1.0 / cfg.d_model) ** 0.5
        }
    segs = cfg.scan_segments()
    seg_keys = jax.random.split(ks[2], len(segs))
    for si, (unit, n_rep) in enumerate(segs):
        def unit_init(k, unit=unit):
            uks = jax.random.split(k, len(unit))
            return {
                f"u{j}": _block_init(uks[j], cfg, unit[j][1])
                for j in range(len(unit))
            }
        if n_rep == 1:
            params[f"seg{si}"] = unit_init(seg_keys[si])
        else:
            params[f"seg{si}"] = jax.vmap(unit_init)(
                jax.random.split(seg_keys[si], n_rep)
            )
    if cfg.mtp_depth > 0:
        k1, k2 = jax.random.split(ks[3])
        params["mtp"] = {
            "proj": dense_init(k1, 2 * cfg.d_model, cfg.d_model, dt,
                               bias=False),
            "block": _block_init(k2, cfg, False),
            "ln": norm_init(cfg.d_model, dt),
        }
    return params


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _dense_ffn(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    gu = jnp.einsum("bsd,dgf->bsgf", x, p["w_gu"])
    h = ACT[act](gu[..., 0, :]) * gu[..., 1, :]
    return h @ p["w_d"]


def _attn_train(p, x, cfg: LMConfig, is_local: bool, positions,
                act_spec: Optional[P]):
    B, S, d = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.attn == "mla":
        m = cfg.mla
        qk = m.qk_nope + m.qk_rope
        q = rmsnorm(p["q_norm"], x @ p["wq_a"], cfg.norm_eps) @ p["wq_b"]
        q = q.reshape(B, S, H, qk)
        kv_a = x @ p["wkv_a"]
        ckv = rmsnorm(p["kv_norm"], kv_a[..., : m.kv_lora], cfg.norm_eps)
        kpe = kv_a[..., m.kv_lora:]
        kv = (ckv @ p["wkv_b"]).reshape(B, S, H, m.qk_nope + m.v_head)
        k_nope, v = kv[..., : m.qk_nope], kv[..., m.qk_nope:]
        q_pe = rope(q[..., m.qk_nope:], positions, cfg.rope_theta)
        k_pe = rope(kpe[:, :, None, :], positions, cfg.rope_theta)
        q = jnp.concatenate([q[..., : m.qk_nope], q_pe], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe, (B, S, H, m.qk_rope))], axis=-1
        )
        scale = (m.qk_nope + m.qk_rope) ** -0.5
        o = flash_attention(
            q, k, v, causal=True, softcap=cfg.attn_softcap,
            blk_q=cfg.blk_q, blk_k=cfg.blk_k, scale=scale,
            block_skip=cfg.attn_block_skip,
        )
        return o.reshape(B, S, H * m.v_head) @ p["wo"]
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, KV, dh)
    v = (x @ p["wv"]).reshape(B, S, KV, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if is_local and cfg.window is not None and cfg.window < S:
        o = banded_attention(
            q, k, v, window=cfg.window, softcap=cfg.attn_softcap,
            blk=min(cfg.blk_q, S),
        )
    else:
        o = flash_attention(
            q, k, v, causal=True, softcap=cfg.attn_softcap,
            blk_q=cfg.blk_q, blk_k=cfg.blk_k,
            block_skip=cfg.attn_block_skip,
        )
    return o.reshape(B, S, H * dh) @ p["wo"]


def _block_train(p, x, aux, cfg: LMConfig, flags, positions, mesh,
                 act_spec: Optional[P]):
    is_local, is_moe = flags
    h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    x = x + _attn_train(p["attn"], h, cfg, is_local, positions, act_spec)
    x = shard(x, act_spec)
    h = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
    if is_moe:
        B, S, d = h.shape
        data_spec = (
            P(act_spec[0]) if act_spec is not None else P()
        )
        out, a = moe_ffn(
            p["moe"], h.reshape(B * S, d), cfg.moe, act=cfg.act,
            mesh=mesh, data_spec=data_spec,
        )
        x = x + out.reshape(B, S, d)
        aux = aux + a
    else:
        x = x + _dense_ffn(p["ffn"], h, cfg.act)
    x = shard(x, act_spec)
    return x, aux


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------

def forward(
    params: Params,
    tokens: jnp.ndarray,            # (B, S) int32
    cfg: LMConfig,
    *,
    mesh=None,
    act_spec: Optional[P] = None,   # e.g. P(('pod','data'), None, None)
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (hidden (B, S, d), aux_loss). Call ``logits``/``loss`` next."""
    B, S = tokens.shape
    x = jnp.take(params["embed"]["emb"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard(x, act_spec)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux = jnp.float32(0.0)

    segs = cfg.scan_segments()
    for si, (unit, n_rep) in enumerate(segs):
        seg_p = params[f"seg{si}"]

        def unit_body(carry, up, unit=unit):
            x, aux = carry
            for j, flags in enumerate(unit):
                x, aux = _block_train(
                    up[f"u{j}"], x, aux, cfg, flags, positions, mesh,
                    act_spec,
                )
            return (x, aux), None

        body = jax.checkpoint(unit_body) if remat else unit_body
        if n_rep == 1:
            (x, aux), _ = body((x, aux), seg_p)
        else:
            (x, aux), _ = jax.lax.scan(body, (x, aux), seg_p)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, aux


def _head_weight(params, cfg: LMConfig):
    if cfg.tie_embeddings:
        return params["embed"]["emb"].T
    return params["lm_head"]["w"]


def chunked_ce_loss(
    params: Params,
    hidden: jnp.ndarray,      # (B, S, d)
    labels: jnp.ndarray,      # (B, S) int32
    cfg: LMConfig,
    chunk: int = 512,
    label_mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Cross-entropy without materialising (B, S, V)."""
    B, S, d = hidden.shape
    w = _head_weight(params, cfg)
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    h = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    y = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    if label_mask is None:
        m = jnp.ones((n, B, chunk), jnp.float32)
    else:
        m = label_mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def step(acc, inp):
        hc, yc, mc = inp
        logits = (hc @ w).astype(jnp.float32)
        logits = _softcap(logits, cfg.final_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, yc[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        nll = (logz - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (h, y, m))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(
    params: Params,
    batch: Dict[str, jnp.ndarray],
    cfg: LMConfig,
    *,
    mesh=None,
    act_spec: Optional[P] = None,
    remat: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    hidden, aux = forward(
        params, batch["tokens"], cfg, mesh=mesh, act_spec=act_spec,
        remat=remat,
    )
    loss = chunked_ce_loss(params, hidden, batch["labels"], cfg,
                           chunk=cfg.loss_chunk)
    metrics = {"ce": loss, "aux": aux}
    if cfg.mtp_depth > 0:
        # MTP(1): predict t+2 from [h_t ; emb(label_t)] through one block
        mp = params["mtp"]
        emb_next = jnp.take(params["embed"]["emb"], batch["labels"], axis=0)
        h2 = dense(mp["proj"], jnp.concatenate([hidden, emb_next], -1))
        pos = jnp.broadcast_to(
            jnp.arange(h2.shape[1])[None], h2.shape[:2]
        )
        h2, _ = _block_train(
            mp["block"], h2, jnp.float32(0), cfg, (False, False), pos,
            mesh, act_spec,
        )
        h2 = rmsnorm(mp["ln"], h2, cfg.norm_eps)
        # labels shifted one more step; mask the last column
        mtp_labels = jnp.concatenate(
            [batch["labels"][:, 1:], batch["labels"][:, -1:]], axis=1
        )
        mask = jnp.ones_like(mtp_labels, jnp.float32).at[:, -1].set(0.0)
        mtp = chunked_ce_loss(params, h2, mtp_labels, cfg, label_mask=mask)
        metrics["mtp"] = mtp
        loss = loss + 0.3 * mtp
    loss = loss + aux
    return loss, metrics


# --------------------------------------------------------------------------
# decode (serve path)
# --------------------------------------------------------------------------

def _cache_len_for(cfg: LMConfig, is_local: bool, max_len: int) -> int:
    if is_local and cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Nested cache pytree aligned with scan segments."""
    dt = _dtype(cfg)
    cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    for si, (unit, n_rep) in enumerate(cfg.scan_segments()):
        seg = {}
        # unscanned segments (n_rep == 1) carry NO leading rep dim, matching
        # the unstacked param layout consumed by decode_step's direct call
        lead = () if n_rep == 1 else (n_rep,)
        for j, (is_local, _) in enumerate(unit):
            L = _cache_len_for(cfg, is_local, max_len)
            if cfg.attn == "mla":
                m = cfg.mla
                c = {
                    "ckv": jnp.zeros((*lead, batch, L, m.kv_lora), dt),
                    "kpe": jnp.zeros((*lead, batch, L, m.qk_rope), dt),
                }
            else:
                c = {
                    "k": jnp.zeros(
                        (*lead, batch, L, cfg.n_kv_heads, cfg.head_dim), dt),
                    "v": jnp.zeros(
                        (*lead, batch, L, cfg.n_kv_heads, cfg.head_dim), dt),
                }
            seg[f"u{j}"] = c
        cache[f"seg{si}"] = seg
    return cache


def _attn_decode(p, x, cfg: LMConfig, is_local: bool, lc, pos):
    """Single-token attention against a cache slice lc (no leading rep dim).

    Returns (attn_out (B, 1, d), updated lc)."""
    B = x.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.attn == "mla":
        m = cfg.mla
        qk = m.qk_nope + m.qk_rope
        q = rmsnorm(p["q_norm"], x @ p["wq_a"], cfg.norm_eps) @ p["wq_b"]
        q = q.reshape(B, 1, H, qk)
        q_pe = rope(q[..., m.qk_nope:], positions, cfg.rope_theta)
        q_nope = q[..., : m.qk_nope]
        kv_a = x @ p["wkv_a"]
        ckv_t = rmsnorm(p["kv_norm"], kv_a[..., : m.kv_lora], cfg.norm_eps)
        kpe_t = rope(
            kv_a[:, :, None, m.kv_lora:], positions, cfg.rope_theta
        )[:, :, 0, :]
        L = lc["ckv"].shape[1]
        slot = pos % L
        ckv = jax.lax.dynamic_update_slice_in_dim(
            lc["ckv"], ckv_t, slot, axis=1
        )
        kpe = jax.lax.dynamic_update_slice_in_dim(
            lc["kpe"], kpe_t, slot, axis=1
        )
        # weight absorption: score = q_nope . (W_uk^T) . ckv
        wkv_b = p["wkv_b"].reshape(m.kv_lora, H, m.qk_nope + m.v_head)
        w_uk = wkv_b[..., : m.qk_nope]          # (kv_lora, H, qk_nope)
        w_uv = wkv_b[..., m.qk_nope:]           # (kv_lora, H, v_head)
        q_abs = jnp.einsum("bthn,lhn->bthl", q_nope, w_uk)  # (B,1,H,kvl)
        s = jnp.einsum("bthl,bsl->bhs", q_abs, ckv)
        s = s + jnp.einsum("bthr,bsr->bhs", q_pe, kpe)
        s = s * ((m.qk_nope + m.qk_rope) ** -0.5)
        valid = jnp.arange(ckv.shape[1]) <= pos
        s = jnp.where(valid[None, None, :], s, -1e30)
        pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        ctx = jnp.einsum("bhs,bsl->bhl", pr, ckv.astype(jnp.float32))
        o = jnp.einsum("bhl,lhv->bhv", ctx, w_uv.astype(jnp.float32))
        o = o.reshape(B, 1, H * m.v_head).astype(x.dtype)
        return o @ p["wo"], {"ckv": ckv, "kpe": kpe}
    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    k_t = (x @ p["wk"]).reshape(B, 1, KV, dh)
    v_t = (x @ p["wv"]).reshape(B, 1, KV, dh)
    q = rope(q, positions, cfg.rope_theta)
    k_t = rope(k_t, positions, cfg.rope_theta)
    L = lc["k"].shape[1]
    slot = pos % L if (is_local and cfg.window is not None) else pos
    slot = jnp.minimum(slot, L - 1)
    k = jax.lax.dynamic_update_slice_in_dim(lc["k"], k_t, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(lc["v"], v_t, slot, axis=1)
    ring = is_local and cfg.window is not None
    o = decode_attention(
        q, k, v, cache_len=pos + 1, softcap=cfg.attn_softcap, ring=ring,
    )
    return o.reshape(B, 1, H * dh) @ p["wo"], {"k": k, "v": v}


def _block_decode(p, x, cfg, flags, lc, pos, mesh):
    is_local, is_moe = flags
    h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    o, lc = _attn_decode(p["attn"], h, cfg, is_local, lc, pos)
    x = x + o
    h = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
    if is_moe:
        B, S, d = h.shape
        out, _ = moe_ffn(
            p["moe"], h.reshape(B * S, d), cfg.moe, act=cfg.act, mesh=mesh,
        )
        x = x + out.reshape(B, S, d)
    else:
        x = x + _dense_ffn(p["ffn"], h, cfg.act)
    return x, lc


def decode_step(
    params: Params,
    cache: Dict[str, Any],
    token: jnp.ndarray,       # (B,) int32
    cfg: LMConfig,
    *,
    mesh=None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One autoregressive step: returns (logits (B, V), new cache)."""
    B = token.shape[0]
    pos = cache["len"]
    x = jnp.take(params["embed"]["emb"], token[:, None], axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    new_cache: Dict[str, Any] = {"len": pos + 1}
    for si, (unit, n_rep) in enumerate(cfg.scan_segments()):
        seg_p = params[f"seg{si}"]
        seg_c = cache[f"seg{si}"]

        def unit_body(x, up, uc, unit=unit):
            nc = {}
            for j, flags in enumerate(unit):
                x, nc[f"u{j}"] = _block_decode(
                    up[f"u{j}"], x, cfg, flags, uc[f"u{j}"], pos, mesh
                )
            return x, nc

        if n_rep == 1:
            x, nc = unit_body(x, seg_p, seg_c)
        else:
            def scan_body(carry, inp):
                up, uc = inp
                y, nc = unit_body(carry, up, uc)
                return y, nc

            x, nc = jax.lax.scan(scan_body, x, (seg_p, seg_c))
        new_cache[f"seg{si}"] = nc
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = (x[:, 0] @ _head_weight(params, cfg)).astype(jnp.float32)
    logits = _softcap(logits, cfg.final_softcap)
    return logits, new_cache


def prefill(
    params: Params,
    tokens: jnp.ndarray,      # (B, S)
    cfg: LMConfig,
    max_len: int,
    *,
    mesh=None,
    act_spec: Optional[P] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Process a prompt, building the cache; returns (last-token logits,
    cache).  Implemented as the train-path forward plus cache extraction
    — one pass, no per-token loop."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    x = jnp.take(params["embed"]["emb"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard(x, act_spec)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    for si, (unit, n_rep) in enumerate(cfg.scan_segments()):
        seg_p = params[f"seg{si}"]

        def unit_body(x, up, unit=unit):
            caches = {}
            for j, flags in enumerate(unit):
                is_local, is_moe = flags
                p = up[f"u{j}"]
                h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
                x = x + _attn_train(
                    p["attn"], h, cfg, is_local, positions, act_spec)
                caches[f"u{j}"] = _extract_cache(
                    p["attn"], h, cfg, is_local, positions, max_len)
                h = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
                if is_moe:
                    Bx, Sx, dx = h.shape
                    out, _ = moe_ffn(
                        p["moe"], h.reshape(Bx * Sx, dx), cfg.moe,
                        act=cfg.act, mesh=mesh,
                        data_spec=(P(act_spec[0]) if act_spec is not None
                                   else P()),
                    )
                    x = x + out.reshape(Bx, Sx, dx)
                else:
                    x = x + _dense_ffn(p["ffn"], h, cfg.act)
                x = shard(x, act_spec)
            return x, caches

        if n_rep == 1:
            x, nc = unit_body(x, seg_p)
        else:
            def scan_body(carry, up):
                y, nc = unit_body(carry, up)
                return y, nc

            x, nc = jax.lax.scan(scan_body, x, seg_p)
        cache[f"seg{si}"] = nc
    cache["len"] = jnp.asarray(S, jnp.int32)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = (x[:, -1] @ _head_weight(params, cfg)).astype(jnp.float32)
    return _softcap(logits, cfg.final_softcap), cache


def _extract_cache(p, h, cfg: LMConfig, is_local: bool, positions, max_len):
    """Recompute the (cheap) KV projections of a prompt into cache layout."""
    B, S, _ = h.shape
    L = _cache_len_for(cfg, is_local, max_len)
    if cfg.attn == "mla":
        m = cfg.mla
        kv_a = h @ p["wkv_a"]
        ckv = rmsnorm(p["kv_norm"], kv_a[..., : m.kv_lora], cfg.norm_eps)
        kpe = rope(
            kv_a[:, :, None, m.kv_lora:], positions, cfg.rope_theta
        )[:, :, 0, :]
        out_ckv = jnp.zeros((B, L, m.kv_lora), ckv.dtype)
        out_kpe = jnp.zeros((B, L, m.qk_rope), kpe.dtype)
        n = min(S, L)
        out_ckv = out_ckv.at[:, :n].set(ckv[:, S - n:])
        out_kpe = out_kpe.at[:, :n].set(kpe[:, S - n:])
        return {"ckv": out_ckv, "kpe": out_kpe}
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    k = rope((h @ p["wk"]).reshape(B, S, KV, dh), positions, cfg.rope_theta)
    v = (h @ p["wv"]).reshape(B, S, KV, dh)
    ck = jnp.zeros((B, L, KV, dh), k.dtype)
    cv = jnp.zeros((B, L, KV, dh), v.dtype)
    n = min(S, L)
    if is_local and cfg.window is not None:
        # ring layout: absolute position p lives in slot p % L
        src = jnp.arange(S - n, S)
        ck = ck.at[:, src % L].set(k[:, S - n:])
        cv = cv.at[:, src % L].set(v[:, S - n:])
    else:
        ck = ck.at[:, :n].set(k[:, S - n:])
        cv = cv.at[:, :n].set(v[:, S - n:])
    return {"k": ck, "v": cv}
