"""Device-side forest bulk-load building blocks.

The bulk-load proper (sort + level loop) lives in
:func:`repro.core.rtree.build_forest_device`; this module owns the
device-resident segmented-MBR reduction it loops over, in two
interchangeable implementations:

* ``kernel="pallas"`` — the :mod:`kernel` slot-major reduction kernel
  (the TPU path; ``interpret=True`` runs it on CPU for tests);
* ``kernel="xla"``    — the :mod:`ref` jnp reduction (XLA fuses it into
  a plain strided min/max — the fast path on CPU hosts, where the
  Pallas interpreter would dominate the build).

``default_build_kernel()`` picks per backend, mirroring how the query
engines pick interpret mode.  Both implementations are exact (min/max
over identical float32 values), so backend choice never changes the
built index — asserted in tests.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import TN, seg_mbr_pallas
from .ref import seg_mbr_ref


def default_build_kernel() -> str:
    """Pallas on TPU, XLA everywhere else (same policy as the engines'
    interpret-mode default, but for build throughput)."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pow2(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


def slot_major(x: jax.Array, fan: int) -> jax.Array:
    """(2*dim, N*fan) node-major child planes -> (fan*2*dim, N)
    slot-major layout the reduction kernel consumes."""
    two_dim, m = x.shape
    n = m // fan
    return x.reshape(two_dim, n, fan).transpose(2, 0, 1).reshape(
        fan * two_dim, n)


def gather_child_slots(
    src_soa: jax.Array,     # (2*dim, C) float32 child-level planes
    starts: jax.Array,      # (N,) int32 first child of each node
    ends: jax.Array,        # (N,) int32 one past the last child
    fan: int,
    dim: int,
) -> jax.Array:
    """(2*dim, N*fan) node-major slots; ragged tails filled inert.

    Node ``j`` owns children ``[starts[j], ends[j])`` of the child
    level (contiguous after the bulk-load sort); slots past the end get
    +inf mins / -inf maxes so they never move a min/max.
    """
    C = src_soa.shape[1]
    idx = starts[:, None] + jnp.arange(fan, dtype=jnp.int32)[None, :]
    mask = idx < ends[:, None]                       # (N, fan)
    g = src_soa[:, jnp.clip(idx, 0, max(C - 1, 0))]  # (2*dim, N, fan)
    inert = jnp.concatenate([
        jnp.full((dim,), jnp.inf, jnp.float32),
        jnp.full((dim,), -jnp.inf, jnp.float32),
    ])[:, None, None]
    g = jnp.where(mask[None, :, :], g, inert)
    n = starts.shape[0]
    return g.reshape(2 * dim, n * fan)


def mbr_reduce(
    children_soa: jax.Array,   # (2*dim, N*fan) node-major child planes
    dim: int,
    fan: int,
    *,
    kernel: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """(2*dim, N) segmented MBRs — one reduction per ``fan`` slots."""
    if kernel == "xla":
        # node-major reduce directly: XLA fuses the reshape + min/max
        # into one pass (no slot-major transpose materialised)
        two_dim, m = children_soa.shape
        c = children_soa.reshape(two_dim, m // fan, fan)
        return jnp.concatenate(
            [c[:dim].min(axis=2), c[dim:].max(axis=2)], axis=0)
    arr = slot_major(children_soa, fan)
    n = arr.shape[1]
    npad = max(TN, -(-n // TN) * TN)
    if npad != n:
        inert = jnp.concatenate([
            jnp.full((dim,), jnp.inf, jnp.float32),
            jnp.full((dim,), -jnp.inf, jnp.float32),
        ])
        pad = jnp.tile(inert, fan)[:, None]
        arr = jnp.concatenate(
            [arr, jnp.broadcast_to(pad, (arr.shape[0], npad - n))], axis=1)
    out = seg_mbr_pallas(arr, dim=dim, fan=fan, interpret=interpret)
    return out[:, :n]


@functools.partial(
    jax.jit,
    static_argnames=("dim", "tp", "tpt", "group", "kernel", "interpret"),
)
def _tile_pyramid_jit(esoa, *, dim, tp, tpt, group, kernel, interpret):
    two_dim, pp = esoa.shape
    nt = pp // tp
    fine = mbr_reduce(esoa, dim, tp, kernel=kernel, interpret=interpret)

    nc = -(-nt // group)
    pad_f = nc * group
    inert = jnp.concatenate([
        jnp.full((dim,), jnp.inf, jnp.float32),
        jnp.full((dim,), -jnp.inf, jnp.float32),
    ])[:, None]
    if pad_f != nt:
        fine_in = jnp.concatenate(
            [fine, jnp.broadcast_to(inert, (two_dim, pad_f - nt))], axis=1)
    else:
        fine_in = fine
    coarse = mbr_reduce(fine_in, dim, group, kernel=kernel,
                        interpret=interpret)

    ntp = max(tpt, -(-nt // tpt) * tpt)
    ncp = ntp // group
    fine_soa = jnp.concatenate(
        [fine, jnp.broadcast_to(inert, (two_dim, ntp - nt))], axis=1)
    coarse_soa = jnp.concatenate(
        [coarse, jnp.broadcast_to(inert, (two_dim, ncp - nc))], axis=1)
    return fine_soa, coarse_soa


def tile_pyramid_device(
    esoa: jax.Array,   # (2*dim, Pp) float32 entry planes, Pp % tp == 0
    dim: int,
    *,
    tp: int,
    tpt: int,
    group: int,
    kernel: str = "xla",
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, int]:
    """Device mirror of ``descent.build_tile_pyramid`` (same shapes,
    same float32 values): (fine (2*dim, NTp), coarse (2*dim, NCp),
    n_tiles).  One fused jit — the reductions and the padding
    concatenations compile to a single pass over the plane."""
    two_dim, pp = esoa.shape
    assert two_dim == 2 * dim and pp % tp == 0
    fine_soa, coarse_soa = _tile_pyramid_jit(
        esoa, dim=dim, tp=tp, tpt=tpt, group=group, kernel=kernel,
        interpret=interpret)
    return fine_soa, coarse_soa, pp // tp


@functools.partial(
    jax.jit, static_argnames=("fan", "dim", "kernel", "interpret"))
def _level_mbr_jit(src_soa, starts, ends, *, fan, dim, kernel, interpret):
    slots = gather_child_slots(src_soa, starts, ends, fan, dim)
    return mbr_reduce(slots, dim, fan, kernel=kernel, interpret=interpret)


def level_mbr(
    src_soa: jax.Array,     # (2*dim, C) float32 child-level planes
    starts: np.ndarray,     # (N,) host int — first child per node
    ends: np.ndarray,       # (N,) host int — one past the last child
    fan: int,
    dim: int,
    *,
    kernel: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """(2*dim, Np2) node MBRs for one bulk-load level, fused gather +
    reduction in a single jit.  ``N`` is padded up to a power of two
    with empty segments (inert +inf/-inf rows past ``N``) so repeated
    builds — compaction swaps above all — reuse a handful of traces."""
    n = len(starts)
    np2 = _pow2(max(n, 1), TN)
    sp = np.zeros(np2, dtype=np.int32)
    ep = np.zeros(np2, dtype=np.int32)
    sp[:n] = starts
    ep[:n] = ends
    return _level_mbr_jit(
        src_soa, jnp.asarray(sp), jnp.asarray(ep),
        fan=fan, dim=dim, kernel=kernel, interpret=interpret)


def np_inert_plane(dim: int, width: int) -> np.ndarray:
    """Host helper: (2*dim, width) impossible-box plane (min > max),
    matching ``forest_to_soa``'s padding convention."""
    soa = np.empty((2 * dim, width), dtype=np.float32)
    soa[:dim] = 1.0
    soa[dim:] = 0.0
    return soa
