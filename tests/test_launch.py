"""Launcher-level coverage: the dry-run entry point end-to-end (512
forced devices in a subprocess), serve CLI, and perf_lm override
parsing."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH="src")


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """Lower+compile one real cell on the 256-chip mesh, exactly as the
    campaign does (subprocess so the 512-device XLA flag stays isolated)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "schnet",
         "--shape", "molecule"],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=500)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
    path = os.path.join(ROOT, "results", "dryrun",
                        "schnet__molecule__pod16x16.json")
    rec = json.load(open(path))
    assert rec["ok"] and rec["n_devices"] == 256
    assert rec["hlo_stats"]["flops"] > 0


@pytest.mark.slow
def test_serve_cli(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--dataset", "tiny",
         "--method", "2dreach-comp", "--queries", "50", "--verify", "20"],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "verified" in r.stdout


def test_perf_lm_overrides():
    from repro.launch.perf_lm import apply_overrides, parse_val
    from repro.configs import get_arch

    assert parse_val("true") is True
    assert parse_val("2") == 2
    assert parse_val("1.5") == 1.5
    cfg = get_arch("deepseek-v3-671b").make_config()
    out = apply_overrides(cfg, {
        "attn_block_skip": True, "moe.balance_factor": 1.0})
    assert out.attn_block_skip is True
    assert out.moe.balance_factor == 1.0
    assert out.moe.n_experts == cfg.moe.n_experts  # untouched fields kept


def test_mesh_factories():
    # importing mesh.py must not touch device state; factories produce
    # the contracted shapes
    from repro.launch import mesh as m

    axes = m.mesh_axes(multi_pod=True)
    assert axes.data == ("pod", "data")
    axes1 = m.mesh_axes(multi_pod=False)
    assert axes1.data == ("data",)
