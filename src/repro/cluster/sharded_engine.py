"""Sharded multi-device RangeReach serving over a partitioned forest.

:class:`ShardedEngine` is the cluster-scale sibling of the single-device
:class:`~repro.core.engine.QueryEngine`.  The 2DReach forest is
partitioned by tree id (size-balanced bin packing over per-tree entry
counts, :mod:`repro.cluster.partition`), one ``QueryEngine``-style SoA
arena + tile pyramid is uploaded **per shard** (stacked and sharded over
the mesh's ``data`` axis), and the vertex→tree pointer arrays are
replicated on every device.  ``query_batch`` runs as **one**
``shard_map``-ed collective program (the fused path, mirroring the
single-device :mod:`repro.kernels.range_query.fused` megakernel): every
device routes the replicated batch, masks it to the queries whose trees
live on its shards, runs the quantized-plane fused prune+scan per local
shard, and the per-query hits ``psum``-OR-reduce across the mesh in the
same trace that ``pmax``-es the candidate max — no prune→host→scan
round trip, one dispatch per batch per capacity bucket (the capacity is
a monotone high-water mark: an overflowing batch ratchets and re-runs
once; steady state runs exactly once).  The pre-fusion two-phase
structure (separate route+prune and scan ``shard_map`` jits with a host
bucket step between them) is retained as ``query_batch_two_phase`` —
the reference the fused program is bit-compared against:

1. **route + prune** — every device evaluates the fused pointer lookup
   for the whole (replicated) batch, masks it down to the queries whose
   tree lives on one of its shards (everyone else gets an empty arena
   slice, so the kernels do no work for them), and runs the Pallas
   hierarchical prune against its own tile pyramid;
2. **masked scan** — after a host-side power-of-two bucket of the global
   candidate max (``pmax`` across shards, so every device traces the
   same K), each device runs the scalar-prefetch descent scan over its
   own arena and the per-query hits ``OR``-reduce across the mesh
   (``psum`` of 0/1 ints).

Every query's tree lives on exactly one shard and that shard's arena
holds exactly the tree's entries (same boxes, same slice contents), so
answers are **bit-identical** to ``query_host`` — the same guarantee the
single-device engine gives, asserted across shard counts in tests.

More shards than devices is legal (and how single-host tests exercise
the 8-shard layout): each device serves ``n_shards / n_devices`` stacked
shards with an unrolled loop inside the same trace, so the program is
identical SPMD everywhere and steady state still recompiles nothing.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.engine import (
    DevicePadder,
    PointerSide,
    _bucket,
    _unsupported_msg,
    compact_candidates,
    pad_batch,
)
from ..core.two_d_reach import TwoDReachIndex
from ..distributed.sharding import index_shard_specs
from ..kernels.range_query.descent import (
    descent_scan_pallas,
    prune_tiles_pallas,
)
from ..kernels.range_query.fused import (
    fused_serve_pallas,
    fused_serve_xla,
    make_quant_grid,
    quantize_coarse,
    quantize_fine,
    quantize_rects,
)
from ..kernels.range_query.kernel import TB
from ..launch.mesh import make_shard_mesh
from ..obs import REGISTRY, span
from ..obs.tracer import TRACER as _TRACER
from ..resilience.faults import fault_point
from .partition import partition_forest, shard_arenas

_AXIS = "data"


def _devices_for(n_shards: int, n_avail: int) -> int:
    """Largest device count <= n_avail that divides n_shards evenly."""
    for d in range(min(n_shards, n_avail), 0, -1):
        if n_shards % d == 0:
            return d
    return 1


class ShardedEngine:
    """Compile-once sharded engine over a built ``TwoDReachIndex``.

    Parameters
    ----------
    index:     any 2DReach variant (``base`` / ``comp`` / ``pointer``).
    n_shards:  forest partitions; defaults to the local device count.
               May exceed it — shards then stack per device.
    mesh:      1-D mesh with a ``data`` axis; ``None`` builds one over
               the largest device count that divides ``n_shards``.
    interpret: Pallas interpret mode; ``None`` picks real kernels on
               TPU and interpret elsewhere.
    """

    def __init__(self, index: TwoDReachIndex,
                 n_shards: Optional[int] = None,
                 mesh=None,
                 interpret: Optional[bool] = None):
        if not isinstance(index, TwoDReachIndex):
            raise ValueError(_unsupported_msg(index, "cluster ShardedEngine"))
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._interpret = bool(interpret)
        self.variant = index.variant
        self.dim = index.forest.dim

        if n_shards is None:
            n_shards = (mesh.shape[_AXIS] if mesh is not None
                        else len(jax.devices()))
        n_shards = int(n_shards)
        if mesh is None:
            mesh = make_shard_mesh(_devices_for(n_shards, len(jax.devices())))
        n_dev = mesh.shape[_AXIS]
        if n_shards % n_dev:
            raise ValueError(
                f"n_shards={n_shards} must be a multiple of the mesh's "
                f"{_AXIS} axis size {n_dev}")
        self.mesh = mesh
        self.n_shards = n_shards
        self._shards_per_dev = n_shards // n_dev

        # ---- partition + one-time sharded upload -----------------------
        self.partition = partition_forest(index.forest, n_shards)
        entries, fine, coarse, nt = shard_arenas(index.forest, self.partition)
        self.n_tiles = nt                       # per shard, uniform
        specs = index_shard_specs(_AXIS)

        def put(x, spec):
            return jax.device_put(x, NamedSharding(mesh, spec))

        self._entries = put(entries, specs["entries"])
        self._fine = put(fine, specs["fine"])
        self._coarse = put(coarse, specs["coarse"])
        # quantized MBR planes for the fused collective program: one
        # grid over the whole forest extent (soundness only needs the
        # rounding to be outward; sharing the grid keeps the replicated
        # rect quantization identical on every device)
        ent = index.forest.entries
        self._grid = make_quant_grid(
            np.concatenate([ent[:, : self.dim].min(0),
                            ent[:, self.dim:].max(0)]).astype(np.float64)
            if len(ent) else None,
            self.dim)
        self._qfine = put(
            jax.vmap(lambda p: quantize_fine(self._grid, p, self.dim))(
                jnp.asarray(fine)), specs["fine"])
        self._qcoarse = put(
            jax.vmap(lambda p: quantize_coarse(self._grid, p, self.dim))(
                jnp.asarray(coarse)), specs["coarse"])
        self._tree_shard = put(
            jnp.asarray(self.partition.tree_shard), specs["tree_shard"])
        self._tree_qs = put(
            jnp.asarray(self.partition.tree_qs), specs["tree_qs"])
        self._tree_qe = put(
            jnp.asarray(self.partition.tree_qe), specs["tree_qe"])
        self._side = PointerSide(index)

        self.stats: Dict[str, float] = {
            "uploads": 1, "batches": 0, "queries": 0,
            "adopted": int(getattr(index.forest, "device", None) is not None),
            "tiles_scanned": 0, "tiles_grid": 0, "tiles_full_scan": 0,
            "fused_reruns": 0,
        }
        self.shard_queries = np.zeros(n_shards, dtype=np.int64)
        # per-shard hit counters ride next to the query routing counts:
        # together they are the load signal the future query-log-driven
        # repartitioner consumes (queries = routing pressure, hits =
        # result pressure)
        self.shard_hits = np.zeros(n_shards, dtype=np.int64)
        # host-side mirrors for query-log classification/routing: the
        # structured log records (vertex class, shard) per served query
        self._excluded_host = index.excluded
        self._lookup_tree_host = index.lookup_tree
        # candidate-capacity high-water mark: K only ever ratchets up, so
        # a smaller batch never traces a new K shape and lifetime scan
        # retraces are bounded by log2(n_tiles) per batch bucket.  A
        # regrouped frontend flush (deadline-or-full boundaries are
        # timing-dependent) can still ratchet once if a new query-tile
        # window's candidate union crosses the warmed power-of-two
        # bucket; after that the mark covers it for good
        self._kb_hwm = 1
        self._fused_impl = ("pallas" if jax.default_backend() == "tpu"
                            else "xla")
        self._padder = DevicePadder(self.dim)
        # fused collective programs, memoised per static capacity —
        # shard_map cannot take static kwargs, so each ratcheted kcap
        # gets its own program object (bounded: the hwm is monotone
        # pow2, so at most log2(n_tiles) of these ever exist)
        self._fused_progs: Dict[int, object] = {}
        self._prepare = jax.jit(self._make_prepare())
        self._scan = jax.jit(self._make_scan())

    # ------------------------------------------------------------------
    # shard_map-ed jit closures
    # ------------------------------------------------------------------

    def _make_prepare(self):
        side, dim = self._side, self.dim
        interpret = self._interpret
        L, nt = self._shards_per_dev, self.n_tiles
        tshard, tqs, tqe = self._tree_shard, self._tree_qs, self._tree_qe

        def prepare(fine, coarse, us, rsoa):
            # fine/coarse: (L, 2*dim, ·) local shard stack; us/rsoa
            # replicated.  Routing is replicated compute (identical on
            # every device); only the prune runs against local pyramids.
            tid, valid, forced = side.route(us, rsoa)
            t = jnp.maximum(tid, 0)
            own = jnp.where(valid, tshard[t], -1)   # replicated routing
            first = jax.lax.axis_index(_AXIS) * L
            qs_l, qe_l, cand_l, cnt_l = [], [], [], []
            for l in range(L):
                mine = own == first + l
                qs = jnp.where(mine, tqs[t], 0)
                qe = jnp.where(mine, tqe[t], 0)
                mask = prune_tiles_pallas(
                    fine[l], coarse[l], rsoa, qs, qe,
                    dim=dim, interpret=interpret,
                )
                cand, cnt = compact_candidates(mask, nt)
                qs_l.append(qs)
                qe_l.append(qe)
                cand_l.append(cand)
                cnt_l.append(cnt)
            cnt = jnp.stack(cnt_l)
            mx = jax.lax.pmax(cnt.max(), _AXIS)
            return (forced, own, jnp.stack(qs_l), jnp.stack(qe_l),
                    jnp.stack(cand_l), cnt, mx)

        return shard_map(
            prepare, self.mesh,
            in_specs=(P(_AXIS), P(_AXIS), P(), P()),
            out_specs=(P(), P(), P(_AXIS), P(_AXIS), P(_AXIS),
                       P(_AXIS), P()),
        )

    def _make_scan(self):
        dim, interpret = self.dim, self._interpret
        L = self._shards_per_dev

        def scan(entries, cand, qs, qe, rsoa):
            # entries (L, 2*dim, Pp); cand (L, NB, K); qs/qe (L, Bb)
            hit = jnp.zeros((rsoa.shape[1],), jnp.int32)
            for l in range(L):
                hit = hit | descent_scan_pallas(
                    cand[l], entries[l], rsoa, qs[l], qe[l],
                    dim=dim, interpret=interpret,
                )
            # OR-reduce across shards: hits are 0/1 and each query's
            # tree lives on exactly one shard, so a sum is an OR
            return jax.lax.psum(hit, _AXIS)

        return shard_map(
            scan, self.mesh,
            in_specs=(P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS), P()),
            out_specs=P(),
        )

    def _fused_prog(self, kcap: int):
        """The single collective serving program at one static candidate
        capacity: replicated route + rect quantization, per-local-shard
        fused prune+compact+scan, and the cross-shard ``psum`` OR-reduce
        and ``pmax`` capacity check — all in ONE ``shard_map``-ed jit,
        collapsing the old two-dispatch (+ host bucket sync) round."""
        prog = self._fused_progs.get(kcap)
        if prog is not None:
            return prog
        side, dim = self._side, self.dim
        interpret = self._interpret
        impl = self._fused_impl
        L, nt = self._shards_per_dev, self.n_tiles
        tshard, tqs, tqe = self._tree_shard, self._tree_qs, self._tree_qe
        grid = self._grid

        def fused(qfine, qcoarse, entries, us, rsoa):
            # qfine/qcoarse/entries: (L, ...) local shard stacks;
            # us/rsoa replicated.  Everything below the routing runs
            # against local shards only.
            tid, valid, forced = side.route(us, rsoa)
            t = jnp.maximum(tid, 0)
            own = jnp.where(valid, tshard[t], -1)
            r16, r32 = quantize_rects(grid, rsoa, dim)
            first = jax.lax.axis_index(_AXIS) * L
            dummy_ids = jnp.zeros((1, entries.shape[-1]), jnp.int32)
            hit = jnp.zeros((rsoa.shape[1],), jnp.int32)
            cnts = []
            for l in range(L):
                mine = own == first + l
                qs = jnp.where(mine, tqs[t], 0)
                qe = jnp.where(mine, tqe[t], 0)
                if impl == "pallas":
                    out, cnt = fused_serve_pallas(
                        qfine[l], qcoarse[l], entries[l], dummy_ids,
                        r16, r32, rsoa, qs, qe, mode="reach", kcap=kcap,
                        nt=nt, dim=dim, interpret=interpret)
                else:
                    out, cnt = fused_serve_xla(
                        qfine[l], qcoarse[l], entries[l], dummy_ids,
                        r16, r32, rsoa, qs, qe, mode="reach", kcap=kcap,
                        nt=nt, dim=dim)
                hit = hit | out
                cnts.append(cnt)
            cnt = jnp.stack(cnts)
            mx = jax.lax.pmax(cnt.max(), _AXIS)
            # OR-reduce across shards: hits are 0/1 and each query's
            # tree lives on exactly one shard, so a sum is an OR
            return forced, own, jax.lax.psum(hit, _AXIS), cnt, mx

        prog = jax.jit(shard_map(
            fused, self.mesh,
            in_specs=(P(_AXIS), P(_AXIS), P(_AXIS), P(), P()),
            out_specs=(P(), P(), P(), P(_AXIS), P()),
        ))
        self._fused_progs[kcap] = prog
        return prog

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------

    @property
    def n_compiles(self) -> int:
        """Distinct (bucketed) shapes traced so far — flat in steady
        state; tests assert it via this introspection hook."""
        return int(
            self._prepare._cache_size() + self._scan._cache_size()
            + self._padder._cache_size()
            + sum(p._cache_size() for p in self._fused_progs.values())
        )

    def shard_of(self, us: np.ndarray) -> np.ndarray:
        """Host-side vertex -> owning shard (-1: excluded / no tree) —
        the routing key the structured query log records."""
        t = np.asarray(self._lookup_tree_host(np.asarray(us, np.int64)))
        out = np.full(len(t), -1, dtype=np.int64)
        ok = t >= 0
        out[ok] = self.partition.tree_shard[t[ok]]
        return out

    def _finish_batch(self, B, Bb, kb, forced, own, hit, cnt, t0):
        """Shared batch epilogue (fused + two-phase): stats, sync,
        per-shard routing/hit counters, gated registry recording."""
        S = self.n_shards
        self.stats["batches"] += 1
        self.stats["queries"] += B
        self.stats["tiles_scanned"] += int(np.asarray(cnt).sum())
        self.stats["tiles_grid"] += (Bb // TB) * kb * S
        self.stats["tiles_full_scan"] += (Bb // TB) * self.n_tiles * S
        with span("cluster.sync", cat="cluster"):
            # routing stats over the *real* lanes only (padding
            # reuses vertex 0, which routes to a real shard but
            # answers nothing)
            own_b = np.asarray(own)[:B]
            out = (np.asarray(hit) > 0) | np.asarray(forced)
        routed = own_b >= 0
        self.shard_queries += np.bincount(
            own_b[routed], minlength=S).astype(np.int64)
        self.shard_hits += np.bincount(
            own_b[routed & out[:B]], minlength=S).astype(np.int64)
        if _TRACER.enabled:
            dt_us = (time.perf_counter() - t0) * 1e6
            REGISTRY.histogram("cluster.batch_us").record(dt_us)
            REGISTRY.gauge("cluster.n_compiles").set(self.n_compiles)
            for s in np.nonzero(np.bincount(own_b[routed],
                                            minlength=S))[0]:
                REGISTRY.counter(f"cluster.shard{s}.queries").inc(
                    int((own_b == s).sum()))
                REGISTRY.counter(f"cluster.shard{s}.hits").inc(
                    int((routed & out[:B] & (own_b == s)).sum()))
        return out[:B]

    def query_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Batched RangeReach, bit-identical to the host path — one
        fused collective dispatch per batch (per capacity bucket)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=bool)
        fault_point("cluster.query_batch", n=B)
        t0 = time.perf_counter()
        with span("cluster.query_batch", cat="cluster", n=B):
            with span("cluster.pad_batch", cat="cluster"):
                Bb, us_dev, rsoa_dev = self._padder.pad(us, rects)
            with span("cluster.fused", cat="cluster", batch=B):
                while True:
                    kcap = min(self._kb_hwm, self.n_tiles)
                    forced, own, hit, cnt, mx = self._fused_prog(kcap)(
                        self._qfine, self._qcoarse, self._entries,
                        us_dev, rsoa_dev)
                    # int(mx) blocks on the whole collective launch
                    mxi = int(mx)
                    if mxi <= kcap or kcap >= self.n_tiles:
                        break
                    self._kb_hwm = min(_bucket(mxi, 1), self.n_tiles)
                    self.stats["fused_reruns"] += 1
            return self._finish_batch(B, Bb, kcap, forced, own, hit,
                                      cnt, t0)

    def query_batch_two_phase(self, us: np.ndarray,
                              rects: np.ndarray) -> np.ndarray:
        """The retained two-dispatch reference path (sharded prune →
        host capacity bucket → sharded scan + psum) — the fused
        collective program's oracle."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=bool)
        fault_point("cluster.query_batch", n=B)
        t0 = time.perf_counter()
        with span("cluster.query_batch", cat="cluster", n=B):
            with span("cluster.pad_batch", cat="cluster"):
                Bb, us_dev, rsoa_dev = self._padder.pad(us, rects)

            with span("cluster.route_prune", cat="cluster"):
                forced, own, qs, qe, cand, cnt, mx = self._prepare(
                    self._fine, self._coarse, us_dev, rsoa_dev
                )
                # int(mx) blocks on the sharded prune + pmax round
                self._kb_hwm = max(
                    self._kb_hwm,
                    min(_bucket(max(int(mx), 1), 1), self.n_tiles))
            kb = self._kb_hwm
            with span("cluster.scan", cat="cluster"):
                hit = self._scan(
                    self._entries, cand[:, :, :kb], qs, qe, rsoa_dev
                )
            return self._finish_batch(B, Bb, kb, forced, own, hit,
                                      cnt, t0)

    def query(self, u: int, rect) -> bool:
        return bool(self.query_batch(np.array([u]), np.array([rect]))[0])


def sharded_engine_for(index, n_shards: Optional[int] = None,
                       interpret: Optional[bool] = None) -> ShardedEngine:
    """Memoised ``ShardedEngine`` for a built 2DReach index.

    One engine is cached per index instance: an explicit ``n_shards`` or
    ``interpret`` that disagrees with the cached engine rebuilds and
    *replaces* it (two shard layouts of the same index are never
    resident at once), while ``n_shards=None`` accepts whatever layout
    is cached — callers that need a specific count must say so.  Unlike
    ``engine_for`` there is no silent fallback: cluster serving is an
    explicit opt-in, so an unsupported index type raises a
    ``ValueError`` naming it."""
    if not isinstance(index, TwoDReachIndex):
        raise ValueError(_unsupported_msg(index, "cluster ShardedEngine"))
    eng = getattr(index, "_cluster_engine", None)
    if eng is None or (
        n_shards is not None and eng.n_shards != int(n_shards)
    ) or (
        interpret is not None and eng._interpret != bool(interpret)
    ):
        eng = ShardedEngine(index, n_shards=n_shards, interpret=interpret)
        index._cluster_engine = eng
    return eng
