from . import dimenet, equiformer_v2, graphcast, schnet
from .dimenet import DimeNetConfig
from .equiformer_v2 import EquiformerV2Config
from .graphcast import GraphCastConfig
from .schnet import SchNetConfig
