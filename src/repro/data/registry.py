"""Dataset registry: named graphs with in-process caching.

``get_dataset("yelp")`` etc. return the scaled synthetic LBSN shaped to
that dataset's Table-2 statistics; ``get_dataset("yelp", scale=0.2)``
re-generates at a different size.  Tiny fixed graphs for tests are
registered under ``tiny*``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..core.graph import GeosocialGraph, make_graph
from .lbsn import SPECS, LBSNSpec, generate_lbsn

_CACHE: Dict[str, GeosocialGraph] = {}


def dataset_names():
    return tuple(SPECS) + ("tiny", "tiny_cyclic")


def get_dataset(name: str, scale: float = 1.0, seed: Optional[int] = None
                ) -> GeosocialGraph:
    key = f"{name}:{scale}:{seed}"
    if key in _CACHE:
        return _CACHE[key]
    if name == "tiny":
        g = _tiny()
    elif name == "tiny_cyclic":
        g = _tiny_cyclic()
    else:
        spec = SPECS[name]
        if scale != 1.0 or seed is not None:
            spec = dataclasses.replace(
                spec,
                n_nodes=max(64, int(spec.n_nodes * scale)),
                seed=spec.seed if seed is None else seed,
            )
        g = generate_lbsn(spec)
    _CACHE[key] = g
    return g


def _tiny() -> GeosocialGraph:
    """The paper's Figure 1 running example: SCCs C1={a,b,c}, C2={d,e},
    spatial sinks f,g (from C1) and h,i (from C2)."""
    a, b, c, d, e, f, g_, h, i = range(9)
    edges = [
        (a, b), (b, c), (c, a),          # C1 cycle
        (d, e), (e, d),                  # C2 cycle
        (c, d),                          # C1 -> C2
        (a, f), (b, g_),                 # C1's own venues
        (d, h), (e, i),                  # C2's venues
    ]
    coords = np.zeros((9, 2), dtype=np.float32)
    coords[f] = (1.0, 1.0)
    coords[g_] = (2.0, 4.0)
    coords[h] = (6.0, 2.0)
    coords[i] = (7.0, 5.0)
    sm = np.zeros(9, dtype=bool)
    sm[[f, g_, h, i]] = True
    return make_graph(9, np.array(edges), coords, sm)


def _tiny_cyclic() -> GeosocialGraph:
    """Spatial vertices with outgoing edges + cycles through venues —
    exercises the general (non-LBSN) data model paths."""
    rng = np.random.default_rng(7)
    n = 40
    edges = rng.integers(0, n, size=(120, 2))
    sm = rng.random(n) < 0.5
    coords = (rng.random((n, 2)) * 10).astype(np.float32)
    return make_graph(n, edges, coords, sm)
