"""Online exactness auditor: shadow-replay a sample of served answers.

The repo's exactness story is build-time: tier-1 tests prove the device
path bit-identical to the host path on fixed seeds.  That proves the
*code*; it does not watch the *serving process* — a corrupted device
buffer, a bad degradation fallback, or an injected wrong answer (the
``engine.answer`` / ``kind="corrupt"`` fault in
:mod:`repro.resilience.faults`) would sail through untested, because a
**wrong answer is silent**: latency fine, status ``ok``, SLOs green.

:class:`ExactnessAuditor` closes that gap online.  The frontend hands
it every served batch (``observe`` — a seeded Bernoulli sample into a
bounded queue, near-free when disabled); a background drain (or a
synchronous :meth:`drain` in tests) **replays the sampled queries
through the bit-identical host path** (``TwoDReachIndex.query_batch``)
and diffs the answers.  A (lower-rate) sub-sample goes all the way to
the BFS oracle (:func:`repro.core.oracle.rangereach_oracle_batch`),
guarding against the host index itself being wrong.  Any divergence:

* increments ``audit.divergences`` (and keeps the offending
  ``(u, rect, served, expected, trace_id)`` tuples, bounded);
* lands a note in the flight recorder's black-box ring;
* fires a ``audit-divergence`` flight-bundle trigger, so the spans /
  querylog / events around the wrong answer are frozen for replay.

Everything is seeded and deterministic: a fixed seed samples a fixed
subset of a fixed stream.
"""

from __future__ import annotations

import collections
import random
import threading
import time
from typing import Callable, List, Optional

import numpy as np

from . import metrics as _metrics
from .flight import FLIGHT

#: divergent answers retained for inspection (counters are unbounded)
MAX_KEPT_DIVERGENCES = 64


class ExactnessAuditor:
    """Sampled online diff of served answers vs the exact host path.

    Parameters
    ----------
    index:  host-path authority — anything with a bit-identical
            ``query_batch(us, rects) -> bool[n]`` (a
            ``TwoDReachIndex``, or ``QueryEngine._index``).
    graph:  optional :class:`~repro.core.graph.GeosocialGraph` enabling
            the BFS-oracle sub-sample (``oracle_sample`` is ignored
            without it).
    sample: fraction of served queries shadow-replayed (0 disables:
            ``observe`` returns after one comparison).
    oracle_sample: fraction of *checked* queries also diffed against
            the BFS oracle.
    capacity: bounded pending queue; overflow drops oldest (counted).
    interval: background drain period (s) for :meth:`start`.
    seed:   Bernoulli sampling seed (deterministic audit of a
            deterministic stream).
    """

    def __init__(self, index, graph=None, sample: float = 0.05,
                 oracle_sample: float = 0.0, capacity: int = 4096,
                 interval: float = 0.05, seed: int = 0,
                 registry: Optional[_metrics.Registry] = None,
                 clock: Callable[[], float] = time.time):
        self.index = index
        self.graph = graph
        self.sample = float(sample)
        self.oracle_sample = float(oracle_sample)
        self.interval = float(interval)
        self.seed = int(seed)
        self._clock = clock
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._pending: collections.deque = collections.deque(
            maxlen=int(capacity))
        self.divergences: List[dict] = []
        reg = registry if registry is not None else _metrics.REGISTRY
        self._c_sampled = reg.counter("audit.sampled")
        self._c_checked = reg.counter("audit.checked")
        self._c_diverged = reg.counter("audit.divergences")
        self._c_oracle = reg.counter("audit.oracle_checked")
        self._c_dropped = reg.counter("audit.dropped")
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- ingest (frontend hot path) -------------------------------------

    def observe(self, us, rects, answers, trace_ids=None) -> int:
        """Offer a served batch for auditing; returns how many queries
        were sampled into the pending queue.  ``sample <= 0`` exits
        after one float comparison — the disabled-overhead case the
        obs_overhead gate measures."""
        if self.sample <= 0.0:
            return 0
        us = np.asarray(us)
        rects = np.asarray(rects, dtype=np.float64)
        answers = np.asarray(answers, dtype=bool)
        taken = 0
        with self._lock:
            for i in range(len(us)):
                if self._rng.random() >= self.sample:
                    continue
                if len(self._pending) == self._pending.maxlen:
                    self._c_dropped.inc()
                item = (int(us[i]), rects[i].copy(), bool(answers[i]),
                        int(trace_ids[i]) if trace_ids is not None else -1,
                        self._clock())
                self._pending.append(item)
                taken += 1
        if taken:
            self._c_sampled.inc(taken)
        return taken

    # -- replay ---------------------------------------------------------

    def drain(self) -> int:
        """Replay everything pending through the host path (and the
        oracle sub-sample); returns how many queries were checked.
        Thread-safe; the background drain calls this on ``interval``."""
        with self._lock:
            items = list(self._pending)
            self._pending.clear()
        if not items:
            return 0
        us = np.array([it[0] for it in items], dtype=np.int64)
        rects = np.stack([it[1] for it in items])
        served = np.array([it[2] for it in items], dtype=bool)
        expected = np.asarray(self.index.query_batch(us, rects),
                              dtype=bool)
        self._c_checked.inc(len(items))
        bad = served != expected
        if self.graph is not None and self.oracle_sample > 0.0:
            osel = np.array([self._rng.random() < self.oracle_sample
                             for _ in items], dtype=bool)
            if osel.any():
                from ..core.oracle import rangereach_oracle_batch
                oans = rangereach_oracle_batch(
                    self.graph, us[osel], rects[osel])
                self._c_oracle.inc(int(osel.sum()))
                obad = np.zeros(len(items), dtype=bool)
                obad[osel] = served[osel] != np.asarray(oans, dtype=bool)
                bad |= obad
        n_bad = int(bad.sum())
        if n_bad:
            self._record_divergences(items, expected, bad)
        return len(items)

    def _record_divergences(self, items, expected, bad) -> None:
        self._c_diverged.inc(int(bad.sum()))
        first = None
        for i in np.flatnonzero(bad):
            d = {"u": items[i][0], "rect": [float(v) for v in items[i][1]],
                 "served": bool(items[i][2]),
                 "expected": bool(expected[i]),
                 "trace_id": items[i][3], "t": items[i][4]}
            if first is None:
                first = d
            if len(self.divergences) < MAX_KEPT_DIVERGENCES:
                self.divergences.append(d)
            FLIGHT.note("audit.divergence", trace_id=d["trace_id"],
                        u=d["u"], served=d["served"],
                        expected=d["expected"])
        # one bundle per drain, carrying the first offender — the rest
        # are in the events ring the bundle freezes anyway
        FLIGHT.trigger("audit-divergence", detail=first)

    # -- background drain ----------------------------------------------

    def start(self) -> "ExactnessAuditor":
        """Start the background drain thread (idempotent)."""
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="obs-audit", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            self.drain()

    def stop(self, final_drain: bool = True) -> None:
        """Stop the drain thread; by default drains what is pending so
        a short run still gets audited."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if final_drain:
            self.drain()

    # -- introspection --------------------------------------------------

    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def report(self) -> dict:
        return {
            "sample": self.sample,
            "oracle_sample": self.oracle_sample,
            "sampled": int(self._c_sampled.value),
            "checked": int(self._c_checked.value),
            "oracle_checked": int(self._c_oracle.value),
            "divergences": int(self._c_diverged.value),
            "dropped": int(self._c_dropped.value),
            "pending": self.pending(),
            "kept": list(self.divergences),
        }
