"""Geosocial analytics query subsystem — beyond boolean RangeReach.

The paper answers one sentence: ``bool RangeReach(u, rect)``.  Its
footnote 2 ("the proposed method can be easily extended to handle other
types of geometric objects") and the GeoReach/TopCom framing of spatial
reachability as one member of a query *family* motivate four exact
analytics classes over every 2DReach variant:

* **RangeCount**    — how many reachable venues intersect the region;
* **RangeCollect**  — materialise the K smallest reachable venue ids in
  the region (exact totals + overflow flags);
* **KNNReach**      — the k nearest reachable venues to a point (host
  best-first branch-and-bound / device radius-doubling over
  count+collect);
* **polygon RangeReach** — convex-polygon regions, the half-plane
  postfilter pushed into the leaf scan.

Every class has a NumPy oracle (:mod:`repro.core.oracle`), a host path
(this package) and a compile-once device path
(:class:`~repro.core.engine.QueryEngine` methods over the
:mod:`repro.kernels.range_query.analytics` kernels) that answer
bit-identically.  Entry point: ``core.api.run_queries(index, program)``
with a :class:`QueryProgram`.
"""

from .host import (
    collect_csr_host,
    polygon_reach_host,
    range_collect_host,
    range_count_host,
)
from .knn import knn_radius_doubling, knn_reach_host, outward_rect
from .program import QUERY_KINDS, CollectResult, KNNResult, QueryProgram

__all__ = [
    "QUERY_KINDS", "CollectResult", "KNNResult", "QueryProgram",
    "collect_csr_host", "polygon_reach_host", "range_collect_host",
    "range_count_host",
    "knn_radius_doubling", "knn_reach_host", "outward_rect",
]
