"""repro.resilience — fault-tolerant serving for the RangeReach stack.

Four pieces, wired through engine → cluster → frontend → dynamic:

* :mod:`~repro.resilience.faults` — deterministic, seedable fault
  injection at named failure points (raise / bounded hang / latency
  spike), a single attribute check when disabled;
* :mod:`~repro.resilience.retry` — :class:`Deadline` budgets and
  :class:`RetryPolicy` (exponential backoff, decorrelated jitter);
* :mod:`~repro.resilience.breaker` — :class:`CircuitBreaker`
  (closed → open → half-open with a single probe) per engine and per
  shard;
* :mod:`~repro.resilience.engine` — :class:`ResilientEngine`: retries
  transient device failures, breaks on persistent ones, and degrades
  **exactly** to the bit-identical host descent instead of failing.

The global invariant (asserted by ``tests/test_chaos.py``): every
request submitted to the stack resolves to the exact answer or one of
the typed errors in :mod:`~repro.resilience.errors` — no hangs, no
wrong answers.
"""

from .breaker import BreakerPolicy, CircuitBreaker
from .engine import ResilientEngine
from .errors import (
    CircuitOpen,
    DeadlineExceeded,
    FrontendClosed,
    InjectedFault,
    Overloaded,
    QueueFull,
    ResilienceError,
    ShardDropout,
)
from .faults import INJECTOR, FaultPlan, FaultSpec, fault_point, inject
from .retry import Deadline, RetryPolicy

__all__ = [
    "BreakerPolicy", "CircuitBreaker", "CircuitOpen", "Deadline",
    "DeadlineExceeded", "FaultPlan", "FaultSpec", "FrontendClosed",
    "INJECTOR", "InjectedFault", "Overloaded", "QueueFull",
    "ResilienceError", "ResilientEngine", "RetryPolicy", "ShardDropout",
    "fault_point", "inject",
]
