from .din import DINConfig
from . import din
