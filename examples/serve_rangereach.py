"""End-to-end driver: a RangeReach serving node (the paper's workload).

Builds the 2DReach-Comp index over a Yelp-shaped graph, verifies the
three query engines against each other and the oracle, then serves
batched request streams and reports latency/throughput per engine —
host wavefront, jit wavefront, and the Pallas leaf-scan kernel
(interpret mode on CPU; the same call compiles to the real kernel on
TPU).

    PYTHONPATH=src python examples/serve_rangereach.py
"""

import time

import numpy as np

from repro.core import (
    batch_query,
    build_index,
    query_host,
    query_jax_wavefront,
    rangereach_oracle_batch,
)
from repro.data import get_dataset, workload
from repro.kernels.range_query.ops import range_query_forest

g = get_dataset("yelp", scale=0.2)
print(f"[build] yelp x0.2: {g.n_nodes} nodes, {g.n_edges} edges")
t0 = time.perf_counter()
index = build_index(g, "2dreach-comp")
print(f"[build] 2dreach-comp in {time.perf_counter() - t0:.2f}s, "
      f"{int(index.stats['distinct_rtrees'])} distinct R-trees")

# ----- request stream ------------------------------------------------------
BATCHES = 10
BATCH = 256
lat = {"host": [], "wavefront": [], "kernel": []}
for b in range(BATCHES):
    us, rects = workload(g, BATCH, extent_ratio=0.05, seed=100 + b)
    tid = index.lookup_tree(us)
    spatialq = index.excluded[us]

    t0 = time.perf_counter()
    host = query_host(index.forest, tid, rects)
    lat["host"].append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    wf, ovf = query_jax_wavefront(index.forest, tid, rects)
    lat["wavefront"].append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    kr = range_query_forest(index.forest, tid, rects)
    lat["kernel"].append(time.perf_counter() - t0)

    assert not ovf.any()
    assert (host == wf).all() and (host == kr).all(), "engine mismatch"
    if b == 0:  # full-pipeline (Alg. 2) answers vs oracle
        full = batch_query(index, us, rects)
        want = rangereach_oracle_batch(g, us[:64], rects[:64])
        assert (full[:64] == want).all()
        print("[verify] engines agree; oracle check OK")

for name, ts in lat.items():
    ts = np.array(ts[1:])  # drop warmup/compile batch
    print(f"[serve] {name:<10} p50 {np.median(ts) / BATCH * 1e6:7.2f} "
          f"us/query   p max {ts.max() / BATCH * 1e6:7.2f} us/query "
          f"({BATCHES - 1} batches x {BATCH})")
