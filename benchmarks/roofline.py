"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

Hardware model (TPU v5e, per chip):
    peak bf16 compute   197 TFLOP/s
    HBM bandwidth       819 GB/s
    ICI link bandwidth  ~50 GB/s

The SPMD-partitioned HLO module is the *per-device* program, so the
scan-aware ``hlo_stats`` totals are per-device quantities and each term
is simply value / per-chip-peak (seconds per step on that device):

    compute    = flops / 197e12
    memory     = bytes / 819e9
    collective = collective_bytes / 50e9

MODEL_FLOPS (the "useful" compute) is analytic per family — 6*N_active*D
for LM training, 2*N_active*D for single-pass inference, operation counts
for GNN/recsys — divided by the device count for comparability.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "dryrun",
)


# --------------------------------------------------------------------------
# analytic MODEL_FLOPS per cell (global; caller divides by n_devices)
# --------------------------------------------------------------------------

def lm_model_flops(arch: str, shape: str) -> Optional[float]:
    from repro.configs import get_arch
    from repro.configs.base import LM_SHAPES

    cfg = get_arch(arch).make_config()
    n_active = cfg.param_counts()["active"]
    s = LM_SHAPES[shape]
    if s["kind"] == "train":
        tokens = s["seq"] * s["batch"]
        return 6.0 * n_active * tokens
    if s["kind"] == "prefill":
        tokens = s["seq"] * s["batch"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * s["batch"]


def gnn_model_flops(arch: str, shape: str) -> Optional[float]:
    from repro.configs import get_arch
    from repro.configs.base import GNN_SHAPES, round_up

    cfg = get_arch(arch).make_config()
    s = GNN_SHAPES[shape]
    if s["batched"]:
        N, E, rep = s["n"] * s["batch"], s["e"] * s["batch"], 1
    else:
        N, E, rep = round_up(s["n"]), round_up(s["e"]), 1
    d = cfg.d_hidden
    if arch == "graphcast":
        fwd = cfg.n_layers * (E * (3 * d) * d * 2 + N * (2 * d) * d * 2)
    elif arch == "schnet":
        fwd = cfg.n_interactions * (
            E * (cfg.n_rbf * d + d * d) * 2 + N * 2 * d * d * 2)
    elif arch == "dimenet":
        T = min(2 * E, 1 << 26) if not s["batched"] else 256 * s["batch"]
        fwd = cfg.n_blocks * (
            T * (cfg.n_bilinear ** 2 * d) * 2 + E * 2 * d * d * 2)
    else:  # equiformer-v2
        # per edge: rotate in/out (block-diag Wigner matmuls over C
        # channels) + SO(2) linear maps (m=0 full, m>=1 complex pairs)
        wig = sum((2 * l + 1) ** 2 for l in range(cfg.l_max + 1))
        so2 = 2 * ((cfg.l_max + 1) * d) ** 2
        for m in range(1, cfg.m_max + 1):
            so2 += 2 * 4 * ((cfg.l_max + 1 - m) * d) ** 2
        fwd = cfg.n_layers * E * (2 * 2 * wig * d + so2)
    return 3.0 * fwd  # fwd + bwd (train cells)


def recsys_model_flops(arch: str, shape: str) -> Optional[float]:
    from repro.configs import get_arch
    from repro.configs.base import RECSYS_SHAPES

    cfg = get_arch(arch).make_config()
    s = RECSYS_SHAPES[shape]
    de = 2 * cfg.embed_dim
    attn = cfg.seq_len * (
        4 * de * cfg.attn_hidden[0]
        + cfg.attn_hidden[0] * cfg.attn_hidden[1] + cfg.attn_hidden[1]
    ) * 2
    out = (3 * de * cfg.mlp_hidden[0]
           + cfg.mlp_hidden[0] * cfg.mlp_hidden[1]
           + cfg.mlp_hidden[1]) * 2
    per_sample = attn + out
    if s["kind"] == "train":
        return 3.0 * per_sample * s["batch"]
    if s["kind"] == "retrieval":
        return float(per_sample) * s["n_candidates"]
    return float(per_sample) * s["batch"]


def model_flops(arch: str, shape: str) -> Optional[float]:
    from repro.configs import get_arch

    fam = get_arch(arch).family
    try:
        if fam == "lm":
            return lm_model_flops(arch, shape)
        if fam == "gnn":
            return gnn_model_flops(arch, shape)
        return recsys_model_flops(arch, shape)
    except Exception:
        return None


# --------------------------------------------------------------------------
# table assembly
# --------------------------------------------------------------------------

def load_records(results_dir: str = RESULTS_DIR) -> List[Dict]:
    recs = []
    if not os.path.isdir(results_dir):
        return recs
    for name in sorted(os.listdir(results_dir)):
        if name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def roofline_row(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok") or "hlo_stats" not in rec:
        return None
    st = rec["hlo_stats"]
    f, c = st["flops"], st["collective_bytes"]
    ma = rec.get("memory_analysis", {})
    io_bytes = ma.get("argument_size_in_bytes", 0) + ma.get(
        "output_size_in_bytes", 0)
    # HBM traffic model: program inputs+outputs cross HBM once, plus the
    # fusion-surviving op traffic (dots, gathers/scatters, cache updates).
    # The raw unfused op traffic ("bytes") is kept as a diagnostic — the
    # CPU-backend HLO leaves elementwise chains unfused, so it wildly
    # overstates what a TPU program would move (see EXPERIMENTS.md).
    b = io_bytes + st.get("hbm_floor_bytes", st["bytes"])
    t_comp = f / PEAK_FLOPS
    t_mem = b / HBM_BW
    t_coll = c / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    mf_dev = mf / rec["n_devices"] if mf else None
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dom,
        "bound_s": terms[dom],
        "model_flops_dev": mf_dev,
        "useful_ratio": (mf_dev / f) if (mf_dev and f) else None,
        "roofline_frac": (
            (mf_dev / PEAK_FLOPS) / terms[dom]
            if (mf_dev and terms[dom] > 0) else None
        ),
        "flops_dev": f,
        "bytes_dev": b,
        "bytes_unfused_dev": st["bytes"],
        "coll_dev": c,
    }


def table(results_dir: str = RESULTS_DIR, mesh: str = "pod16x16"
          ) -> List[Dict]:
    rows = []
    for rec in load_records(results_dir):
        if rec.get("mesh") != mesh:
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':<28}{'shape':<15}{'comp(s)':>10}{'mem(s)':>10}"
           f"{'coll(s)':>10}{'dom':>6}{'useful':>8}{'roof%':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        u = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        rf = f"{100 * r['roofline_frac']:.1f}" if r["roofline_frac"] else "-"
        lines.append(
            f"{r['arch']:<28}{r['shape']:<15}{r['t_compute_s']:>10.4f}"
            f"{r['t_memory_s']:>10.4f}{r['t_collective_s']:>10.4f}"
            f"{r['dominant'][:4]:>6}{u:>8}{rf:>7}"
        )
    return "\n".join(lines)
