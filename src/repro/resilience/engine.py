"""Resilient engine wrapper: retries, breakers, exact degradation.

:class:`ResilientEngine` wraps a device serving engine (single-device
``QueryEngine`` or cluster ``ShardedEngine``) **plus the host index it
was built from**, and turns untyped infrastructure failures into one of
two outcomes — the exact answer, or a typed error:

* **bounded retry** — a failed device call is retried up to
  ``RetryPolicy.max_attempts`` with exponential backoff + decorrelated
  jitter, never sleeping past the request's :class:`Deadline` budget;
* **circuit breakers** — consecutive failures open the engine's
  breaker (and a :class:`~repro.resilience.errors.ShardDropout` opens
  only the dropped shard's), so a dead device degrades in O(1) instead
  of paying the full retry schedule per batch;
* **exact degradation** — whatever the device path cannot answer
  (breaker open, retries exhausted, deadline spent) is answered by the
  **bit-identical host descent** of the same index.  The engines are
  bit-identical to ``query_host`` by construction (PR 2/5 invariants),
  so degradation changes latency, never answers.  Downgrades are
  counted (``resilience.fallback_*``) and their latency lands in the
  ``resilience.degraded_query_us`` histogram, not silently mixed into
  the healthy numbers.  With ``degraded_path="two_phase"`` the
  degradation target is the engine's retained two-phase device path
  (``*_batch_two_phase``) instead of host NumPy — the right lever when
  only the *fused* serving path is suspect (it shares no prune/compact
  trace with two-phase), while ``"host"`` stays the refuge from device
  failures generally; classes without a two-phase variant (kNN,
  polygon) always degrade to host.

Per-shard degradation: when the wrapped engine exposes ``shard_of``
(the cluster engine does), a shard whose breaker is open only reroutes
*its own* queries to the host path — the healthy shards keep serving on
device.  Shard breakers are created lazily on the first dropout, so the
healthy fast path never pays a routing pass.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import numpy as np

from ..obs import metrics as obs_metrics
from ..obs import span
from ..obs import trace_context
from ..obs.flight import FLIGHT
from ..obs.tracer import TRACER, _now_ns
from .breaker import BreakerPolicy, CircuitBreaker, CLOSED
from .errors import ShardDropout
from .retry import Deadline, RetryPolicy

#: trace ids carried per flight-recorder note (the full lists live in
#: ``last_report``; the black-box ring stays bounded per event)
_NOTE_ID_CAP = 32


def _ids_for(tids, mask) -> Optional[list]:
    """The trace ids the boolean ``mask`` selects, or None without an
    ambient trace context of matching length."""
    if tids is None:
        return None
    return [tids[i] for i in np.flatnonzero(mask)]


class ResilientEngine:
    """Fault-tolerant facade over a device engine + its host index.

    Parameters
    ----------
    engine:   anything with ``query_batch(us, rects)`` — the device
              path (``QueryEngine`` / ``ShardedEngine``); analytics
              classes are wrapped too when the engine exposes them.
    index:    the built index the engine serves — its host path is the
              bit-identical degradation target (``TwoDReachIndex
              .query_batch`` and the ``repro.queries`` host descents).
    retry:    transient-failure schedule; default ``RetryPolicy()``.
    breaker:  breaker thresholds (shared by the engine-level breaker
              and every lazily created shard breaker).
    name:     metric prefix (``resilience.breaker.<name>.*``).
    degraded_path: ``"host"`` (default) degrades to the host descent;
              ``"two_phase"`` degrades to the engine's retained
              two-phase device path where it exists (see module
              docstring), host otherwise.
    clock / sleep / seed: injectable time + jitter sources so chaos
              tests replay deterministic schedules without wall sleeps.
    """

    #: the frontend passes per-batch deadline budgets when it sees this
    supports_deadline = True

    def __init__(self, engine, index,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[BreakerPolicy] = None,
                 name: str = "engine",
                 degraded_path: str = "host",
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 seed: int = 0,
                 registry: Optional[obs_metrics.Registry] = None):
        if degraded_path not in ("host", "two_phase"):
            raise ValueError(f"unknown degraded_path {degraded_path!r}")
        self.engine = engine
        self.index = index
        self.degraded_path = degraded_path
        self.retry = retry or RetryPolicy()
        self.breaker_policy = breaker or BreakerPolicy()
        self.name = name
        self._clock = clock
        self._sleep = sleep
        self._rng = np.random.default_rng(seed)
        self._reg = registry if registry is not None else obs_metrics.REGISTRY
        self._breaker = CircuitBreaker(
            name, self.breaker_policy, clock=clock, registry=self._reg)
        self._shard_breakers: Dict[int, CircuitBreaker] = {}
        self._shard_of = getattr(engine, "shard_of", None)
        self.stats: Dict[str, int] = {
            "device_batches": 0, "retries": 0, "device_failures": 0,
            "fallback_batches": 0, "fallback_queries": 0,
        }
        self._c_retries = self._reg.counter("resilience.retries")
        self._c_failures = self._reg.counter("resilience.device_failures")
        self._c_fb_batches = self._reg.counter("resilience.fallback_batches")
        self._c_fb_queries = self._reg.counter("resilience.fallback_queries")
        self._h_degraded = self._reg.histogram("resilience.degraded_query_us")
        #: per-batch serving report, rewritten by every ``*_batch`` call:
        #: {"degraded": (B,) bool — answered by the host fallback,
        #:  "retries": device attempts burned beyond the first,
        #:  "attempts": (B,) int — device attempts that *included* each
        #:  query (0 = never reached the device),
        #:  "trace_ids": the ambient per-request trace ids when a
        #:  :mod:`~repro.obs.trace_context` scope of matching length is
        #:  active (else None),
        #:  "retried_trace_ids" / "degraded_trace_ids": the specific
        #:  requests retries and degradations are attributed to}.  The
        #: frontend copies it into the structured query log so workload
        #: analytics can split healthy vs degraded traffic and flight
        #: bundles can resolve a trace id to its serving decisions.
        self.last_report: Dict[str, object] = {
            "degraded": np.zeros(0, dtype=bool), "retries": 0,
            "attempts": np.zeros(0, dtype=np.int32), "trace_ids": None,
            "retried_trace_ids": [], "degraded_trace_ids": []}

    # ------------------------------------------------------------------
    # breaker surface
    # ------------------------------------------------------------------

    @property
    def breaker(self) -> CircuitBreaker:
        return self._breaker

    def shard_breaker(self, shard: int) -> CircuitBreaker:
        """The (lazily created) breaker guarding one shard."""
        br = self._shard_breakers.get(int(shard))
        if br is None:
            br = CircuitBreaker(
                f"{self.name}.shard{int(shard)}", self.breaker_policy,
                clock=self._clock, registry=self._reg)
            self._shard_breakers[int(shard)] = br
        return br

    def trip(self) -> None:
        """Force full degradation: open the engine breaker (ops switch;
        the ``--degraded`` bench arm measures through this)."""
        self._breaker.trip()

    @property
    def degraded(self) -> bool:
        """True when *some* breaker currently refuses device traffic."""
        return self._breaker.state != CLOSED or any(
            b.state != CLOSED for b in self._shard_breakers.values())

    # n_compiles passthrough keeps the frontend's steady-state
    # no-recompile assertions meaningful through the wrapper
    @property
    def n_compiles(self) -> int:
        return getattr(self.engine, "n_compiles", 0)

    # ------------------------------------------------------------------
    # grant / settle around one device attempt
    # ------------------------------------------------------------------

    def _grants(self, us: np.ndarray, pending: np.ndarray):
        """(device-eligible mask, granted breakers) for one attempt.
        A granted breaker must be settled (success / failure /
        release) by the caller."""
        if not self._breaker.allow():
            return np.zeros(len(us), dtype=bool), []
        granted = [self._breaker]
        mask = pending.copy()
        if self._shard_breakers and self._shard_of is not None:
            shards = np.asarray(self._shard_of(us))
            for s, br in list(self._shard_breakers.items()):
                mine = shards == s
                if not (mask & mine).any():
                    continue
                if br.allow():
                    granted.append(br)
                else:
                    mask &= ~mine
        return mask, granted

    def _settle_failure(self, granted, exc: BaseException) -> None:
        """Attribute one failed attempt to the right failure domain."""
        self.stats["device_failures"] += 1
        self._c_failures.inc()
        if isinstance(exc, ShardDropout):
            # the dropped shard is the failing domain; everyone else's
            # grant went unproven — release, don't score
            dropped = self.shard_breaker(exc.shard)
            dropped.record_failure()
            for br in granted:
                if br is not dropped:
                    br.release()
        else:
            self._breaker.record_failure()
            for br in granted:
                if br is not self._breaker:
                    br.release()

    # ------------------------------------------------------------------
    # boolean RangeReach (per-shard splitting)
    # ------------------------------------------------------------------

    def query_batch(self, us: np.ndarray, rects: np.ndarray,
                    deadline: Optional[float] = None) -> np.ndarray:
        """Batched RangeReach: exact on every path.  ``deadline`` is a
        seconds budget for the whole call (retry sleeps never exceed
        it; on exhaustion the remainder degrades to host)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=bool)
        rects = np.asarray(rects, dtype=np.float32).reshape(B, -1)
        dl = Deadline(deadline, clock=self._clock)
        out = np.zeros(B, dtype=bool)
        pending = np.ones(B, dtype=bool)
        tids = trace_context.current_ids()
        if tids is not None and len(tids) != B:
            tids = None      # ambient scope is not per-query for this batch
        attempts_arr = np.zeros(B, dtype=np.int32)
        report = {"degraded": np.zeros(B, dtype=bool), "retries": 0,
                  "attempts": attempts_arr, "trace_ids": tids,
                  "retried_trace_ids": [], "degraded_trace_ids": []}
        self.last_report = report
        attempts, prev_sleep = 0, 0.0
        while attempts < self.retry.max_attempts and not dl.expired():
            mask, granted = self._grants(us, pending)
            if not mask.any():
                for br in granted:
                    br.release()
                break
            attempts += 1
            attempts_arr[mask] += 1
            try:
                got = self.engine.query_batch(us[mask], rects[mask])
            except Exception as e:  # noqa: BLE001 — converted to fallback
                self._settle_failure(granted, e)
                if attempts < self.retry.max_attempts and not dl.expired():
                    prev_sleep = self.retry.next_backoff(
                        prev_sleep, self._rng)
                    self.stats["retries"] += 1
                    report["retries"] += 1
                    self._c_retries.inc()
                    self._note_decision("retry", mask, tids, report,
                                        "retried_trace_ids",
                                        attempt=attempts, error=type(e).__name__)
                    s = min(prev_sleep, max(dl.remaining(), 0.0))
                    if s > 0:
                        self._sleep(s)
                continue
            for br in granted:
                br.record_success()
            out[mask] = np.asarray(got, dtype=bool)
            pending &= ~mask
            self.stats["device_batches"] += 1
            if not pending.any():
                return out
            # only shard-excluded queries remain: degrade just those
            break
        if pending.any():
            report["degraded"] = pending.copy()
            self._note_decision("degraded", pending, tids, report,
                                "degraded_trace_ids",
                                path=self.degraded_path)
            target = self._degrade_target(
                "query_batch", self.index.query_batch)
            out[pending] = self._host_fallback(
                lambda sel: target(us[sel], rects[sel]), pending)
        return out

    def _note_decision(self, what: str, mask: np.ndarray, tids, report,
                       report_key: str, **fields) -> None:
        """Attribute one retry/degradation decision to the specific
        trace ids it affects: extend ``last_report[report_key]``, land a
        black-box note, and (tracing enabled) drop an instant event next
        to the stage spans."""
        ids = _ids_for(tids, mask)
        if ids is not None:
            report[report_key].extend(ids)
        note = dict(fields, n=int(mask.sum()))
        if ids is not None:
            note["trace_ids"] = ids[:_NOTE_ID_CAP]
        FLIGHT.note(f"engine.{what}", **note)
        if TRACER.enabled:
            TRACER.record(f"resilience.{what}", "resilience",
                          _now_ns(), 0, note)

    def _degrade_target(self, method: str, host_fn):
        """The degradation callable for one query class: the engine's
        retained two-phase path when selected and present, else host."""
        if self.degraded_path == "two_phase":
            fn = getattr(self.engine, f"{method}_two_phase", None)
            if fn is not None:
                return fn
        return host_fn

    def _host_fallback(self, call, pending: np.ndarray):
        """Serve the degraded remainder on the exact host path, counted
        and latency-attributed separately from healthy traffic."""
        n = int(pending.sum())
        t0 = time.perf_counter()
        # the degraded serve is itself a span: a breaker-open window
        # where no device engine runs must still leave causal evidence
        # of who served each trace (the flight replay requires it)
        with span("resilience.degraded_serve", cat="resilience", n=n):
            got = call(pending)
        self._h_degraded.record(
            (time.perf_counter() - t0) * 1e6 / max(n, 1))
        self.stats["fallback_batches"] += 1
        self.stats["fallback_queries"] += n
        self._c_fb_batches.inc()
        self._c_fb_queries.inc(n)
        return got

    def query(self, u: int, rect) -> bool:
        return bool(self.query_batch(np.array([u]), np.array([rect]))[0])

    # ------------------------------------------------------------------
    # analytics classes (whole-batch retry + fallback)
    # ------------------------------------------------------------------

    def _whole_batch(self, method: str, n: int, dev_call, host_call,
                     deadline: Optional[float]):
        """Generic wrapper for the structured-result classes: retry the
        device call whole, degrade the whole batch to the host descent
        (structured results do not merge across a per-shard split)."""
        dl = Deadline(deadline, clock=self._clock)
        attempts, prev_sleep = 0, 0.0
        whole = np.ones(max(n, 0), dtype=bool)
        tids = trace_context.current_ids()
        if tids is not None and len(tids) != n:
            tids = None
        attempts_arr = np.zeros(max(n, 0), dtype=np.int32)
        report = {"degraded": np.zeros(max(n, 0), dtype=bool), "retries": 0,
                  "attempts": attempts_arr, "trace_ids": tids,
                  "retried_trace_ids": [], "degraded_trace_ids": []}
        self.last_report = report
        have_dev = hasattr(self.engine, method)
        while have_dev and attempts < self.retry.max_attempts \
                and not dl.expired():
            if not self._breaker.allow():
                break
            attempts += 1
            attempts_arr += 1
            try:
                got = dev_call()
            except Exception as e:  # noqa: BLE001 — converted to fallback
                self._settle_failure([self._breaker], e)
                if attempts < self.retry.max_attempts and not dl.expired():
                    prev_sleep = self.retry.next_backoff(
                        prev_sleep, self._rng)
                    self.stats["retries"] += 1
                    report["retries"] += 1
                    self._c_retries.inc()
                    self._note_decision("retry", whole, tids, report,
                                        "retried_trace_ids",
                                        attempt=attempts, method=method,
                                        error=type(e).__name__)
                    s = min(prev_sleep, max(dl.remaining(), 0.0))
                    if s > 0:
                        self._sleep(s)
                continue
            self._breaker.record_success()
            self.stats["device_batches"] += 1
            return got
        report["degraded"] = np.ones(max(n, 0), dtype=bool)
        self._note_decision("degraded", report["degraded"], tids, report,
                            "degraded_trace_ids", method=method,
                            path=self.degraded_path)
        return self._host_fallback(lambda _sel: host_call(),
                                   np.ones(max(n, 1), dtype=bool))

    def count_batch(self, us, rects, deadline: Optional[float] = None):
        from ..queries.host import range_count_host  # deferred: no cycle

        us = np.asarray(us, dtype=np.int64)
        degrade = self._degrade_target(
            "count_batch",
            lambda u, r: range_count_host(self.index, u, r))
        return self._whole_batch(
            "count_batch", len(us),
            lambda: self.engine.count_batch(us, rects),
            lambda: degrade(us, rects),
            deadline)

    def collect_batch(self, us, rects, k: int,
                      deadline: Optional[float] = None):
        from ..queries.host import range_collect_host  # deferred

        us = np.asarray(us, dtype=np.int64)
        degrade = self._degrade_target(
            "collect_batch",
            lambda u, r, kk: range_collect_host(self.index, u, r, kk))
        return self._whole_batch(
            "collect_batch", len(us),
            lambda: self.engine.collect_batch(us, rects, k),
            lambda: degrade(us, rects, k),
            deadline)

    def knn_batch(self, us, points, k: int,
                  deadline: Optional[float] = None):
        from ..queries.knn import knn_reach_host  # deferred

        us = np.asarray(us, dtype=np.int64)
        return self._whole_batch(
            "knn_batch", len(us),
            lambda: self.engine.knn_batch(us, points, k),
            lambda: knn_reach_host(self.index, us, points, k),
            deadline)

    def polygon_batch(self, us, polygons,
                      deadline: Optional[float] = None):
        from ..queries.host import polygon_reach_host  # deferred

        us = np.asarray(us, dtype=np.int64)
        return self._whole_batch(
            "polygon_batch", len(us),
            lambda: self.engine.polygon_batch(us, polygons),
            lambda: polygon_reach_host(self.index, us, polygons),
            deadline)
