"""Every index method vs the BFS oracle — the paper's correctness core."""

import numpy as np
import pytest
from repro.core import (
    METHODS,
    batch_query,
    build_index,
    index_nbytes,
    rangereach_oracle_batch,
)
from repro.data import get_dataset
from conftest import given, random_geosocial, random_queries, st


@given(st.integers(0, 10_000))
def test_all_methods_match_oracle(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 60))
    g = random_geosocial(rng, n, int(rng.integers(2, 4 * n)))
    us, rects = random_queries(rng, g, 30)
    want = rangereach_oracle_batch(g, us, rects)
    for method in METHODS:
        got = batch_query(build_index(g, method), us, rects)
        assert (got == want).all(), method


@given(st.integers(0, 10_000))
def test_methods_on_spatial_nonsinks(seed):
    """General data model: spatial vertices WITH out-edges (the paper's
    §4.1 caveat — compression must only exclude spatial sinks)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 50))
    g = random_geosocial(rng, n, int(rng.integers(4, 4 * n)),
                         spatial_frac=0.5, sink_bias=0.2)
    us, rects = random_queries(rng, g, 25)
    want = rangereach_oracle_batch(g, us, rects)
    for method in METHODS:
        got = batch_query(build_index(g, method), us, rects)
        assert (got == want).all(), method


def test_figure1_running_example():
    g = get_dataset("tiny")
    a, h = 0, 7
    # R containing h only (6,2)
    rect = np.array([5.5, 1.5, 6.5, 2.5], np.float32)
    for method in METHODS:
        idx = build_index(g, method)
        assert idx.query(a, rect), method           # a ~> d ~> h in R
        # region with no venues
        assert not idx.query(a, np.array([90, 90, 95, 95], np.float32))
    # 2DReach builds trees for C1 = {f,g,h,i} and C2 = {h,i}
    idx = build_index(g, "2dreach-comp")
    assert idx.stats["distinct_rtrees"] == 2
    sizes = sorted(idx.forest.tree_n_entries().tolist())
    assert sizes == [2, 4]


def test_spatial_sink_query_vertex():
    """Alg. 2 line 1: a spatial sink query vertex answers via delta(q)."""
    g = get_dataset("tiny")
    f = 5  # spatial sink at (1, 1)
    for method in METHODS:
        idx = build_index(g, method)
        assert idx.query(f, np.array([0.5, 0.5, 1.5, 1.5], np.float32))
        assert not idx.query(f, np.array([5, 1, 8, 6], np.float32))


def test_sharing_and_sizes():
    rng = np.random.default_rng(7)
    g = random_geosocial(rng, 200, 700)
    base = build_index(g, "2dreach")
    comp = build_index(g, "2dreach-comp")
    ptr = build_index(g, "2dreach-pointer")
    # compressed variants never build MORE trees than base
    assert comp.stats["distinct_rtrees"] <= base.stats["distinct_rtrees"]
    # pointer variant: smallest aux storage
    assert ptr.nbytes_pointers() < comp.nbytes_pointers()
    for idx in (base, comp, ptr):
        nb = index_nbytes(idx)
        assert nb["total"] == nb["rtree"] + nb["aux"]


def test_global_dedup_beyond_paper():
    rng = np.random.default_rng(9)
    g = random_geosocial(rng, 150, 500)
    from repro.core import build_2dreach

    paper = build_2dreach(g, variant="comp", dedup="paper")
    glob = build_2dreach(g, variant="comp", dedup="global")
    assert glob.stats["distinct_rtrees"] <= paper.stats["distinct_rtrees"]
    us, rects = random_queries(rng, g, 40)
    assert (
        paper.query_batch(us, rects) == glob.query_batch(us, rects)
    ).all()


def test_3dreach_interval_counts():
    rng = np.random.default_rng(11)
    g = random_geosocial(rng, 120, 500)
    idx = build_index(g, "3dreach")
    assert idx.labels.total_intervals >= idx.cond.n_comps  # >= 1 each
    # every comp's own post is covered by its own label
    from repro.core.interval_labels import labels_reachable

    for c in range(0, idx.cond.n_comps, 7):
        assert labels_reachable(idx.labels, c, c)


def test_bitrank_property():
    """BitRank rank/member vs a numpy popcount oracle."""
    from repro.core import BitRank

    rng = np.random.default_rng(123)
    for n in (1, 31, 32, 33, 300, 1000):
        mask = rng.random(n) < 0.3
        br = BitRank.from_mask(mask)
        ids = np.arange(n)
        member, rank = br.test_rank(ids)
        assert (member == mask).all()
        want_rank = np.concatenate([[0], np.cumsum(mask)[:-1]])
        assert (rank == want_rank).all()


def test_duplicate_points_and_degenerate_rects():
    """All spatial vertices at one location; zero-area query rects."""
    from repro.core import make_graph

    n = 30
    rng = np.random.default_rng(5)
    edges = rng.integers(0, n, size=(60, 2))
    sm = np.zeros(n, bool)
    sm[:10] = True
    coords = np.zeros((n, 2), np.float32)
    coords[:10] = 3.25  # all venues identical
    g = make_graph(n, edges, coords, sm)
    us = np.arange(n)
    exact = np.array([[3.25, 3.25, 3.25, 3.25]] * n, np.float32)
    miss = exact + 1.0
    want_exact = rangereach_oracle_batch(g, us, exact)
    for method in METHODS:
        idx = build_index(g, method)
        assert (batch_query(idx, us, exact) == want_exact).all(), method
        assert not batch_query(idx, us, miss).any(), method


def test_polygon_queries_vs_oracle():
    """Footnote-2 extension: convex polygon regions (bbox prefilter +
    exact half-plane test) vs a BFS + point-in-polygon oracle."""
    from repro.core.polygon import polygon_oracle, polygon_query
    from repro.core import build_2dreach

    rng = np.random.default_rng(21)
    g = random_geosocial(rng, 120, 400)
    for variant in ("base", "comp", "pointer"):
        idx = build_2dreach(g, variant=variant)
        for q in range(40):
            u = int(rng.integers(0, g.n_nodes))
            # random convex polygon: hull of 5 points around a center
            c = rng.random(2) * 100
            pts = c + rng.standard_normal((8, 2)) * 15
            from scipy.spatial import ConvexHull

            hull = pts[ConvexHull(pts).vertices]
            got = polygon_query(idx, u, hull)
            want = polygon_oracle(g, u, hull)
            assert got == want, (variant, u)
