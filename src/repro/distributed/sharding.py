"""Named-sharding rules: parameters, optimizer state (ZeRO-1), batches.

Axis roles (see launch/mesh.py):
    pod    — inter-pod data parallelism (the multi-pod dry-run axis)
    data   — intra-pod data parallelism (+ ZeRO-1 optimizer sharding)
    model  — tensor/expert parallelism

Rules are name+shape pattern matchers producing PartitionSpecs; any dim
not divisible by the axis size falls back to replication (e.g. gemma2's
8 heads on a 16-way model axis — its FFN and vocab still shard).  Specs
are padded with leading ``None`` for stacked (scanned) layer params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: Tuple[str, ...] = ("data",)     # ("pod", "data") multi-pod
    model: str = "model"

    def data_size(self, mesh: Mesh) -> int:
        s = 1
        for a in self.data:
            s *= mesh.shape.get(a, 1)
        return s

    def model_size(self, mesh: Mesh) -> int:
        return mesh.shape.get(self.model, 1)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _pad(spec: Tuple, ndim: int) -> P:
    spec = tuple(spec)
    assert len(spec) <= ndim, (spec, ndim)
    return P(*((None,) * (ndim - len(spec)) + spec))


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


# --------------------------------------------------------------------------
# LM parameter rules
# --------------------------------------------------------------------------

def lm_param_spec(path: str, shape: Tuple[int, ...], axes: MeshAxes,
                  tp: int) -> P:
    nd = len(shape)
    m = axes.model

    def last2(a, b):
        return _pad((a, b), nd)

    if path.endswith("embed/emb") or "lm_head/w" in path:
        # vocab over model (vocab dim is first for embed, last for head)
        if path.endswith("embed/emb"):
            return last2(m if _div(shape[-2], tp) else None, None)
        return last2(None, m if _div(shape[-1], tp) else None)
    if "/attn/" in path:
        name = path.rsplit("/", 1)[-1]
        if name in ("wq", "wk", "wv", "wq_b", "wkv_b"):
            return last2(None, m if _div(shape[-1], tp) else None)
        if name == "wo":
            return last2(m if _div(shape[-2], tp) else None, None)
        return _pad((), nd)  # wq_a / wkv_a / norms: replicated
    if "/ffn/" in path or "/mtp/" in path and path.endswith(("w_gu", "w_d")):
        if path.endswith("w_gu"):       # (d, 2, f)
            return _pad((None, None, m if _div(shape[-1], tp) else None), nd)
        if path.endswith("w_d"):        # (f, d)
            return last2(m if _div(shape[-2], tp) else None, None)
    if "/moe/" in path:
        name = path.rsplit("/", 1)[-1]
        if name in ("w_gu", "w_d"):     # (E, d, f*) — experts over model
            return _pad(
                (m if _div(shape[-3], tp) else None, None, None), nd)
        if name == "sh_gu":             # (d, 2, fs)
            return _pad((None, None, m if _div(shape[-1], tp) else None), nd)
        if name == "sh_d":              # (fs, d)
            return last2(m if _div(shape[-2], tp) else None, None)
        return _pad((), nd)             # router replicated
    return _pad((), nd)                 # norms, scalars


# --------------------------------------------------------------------------
# Generic MLP-family rules (GNN / recsys)
# --------------------------------------------------------------------------

def mlp_param_spec(path: str, shape: Tuple[int, ...], axes: MeshAxes,
                   tp: int) -> P:
    nd = len(shape)
    m = axes.model
    name = path.rsplit("/", 1)[-1]
    if name == "emb" and nd >= 2:
        # embedding tables row-sharded (the recsys layout)
        return _pad((m if _div(shape[-2], tp) else None, None), nd)
    if name == "w" and nd >= 2:
        # Megatron pairing inside MLPs: first layer col-shard, last row-shard
        if "/l0/" in path:
            return _pad((None, m if _div(shape[-1], tp) else None), nd)
        # find the layer index: .../l{k}/w — row-shard the final projection
        import re

        mt = re.search(r"/l(\d+)/w$", path)
        if mt is not None and _div(shape[-2], tp):
            return _pad((m, None), nd)
        return _pad((), nd)
    if name in ("bilin",):
        return _pad((), nd)
    if name.startswith("so2_") or name in ("w_gu", "w_d"):
        return _pad((), nd)
    return _pad((), nd)


# --------------------------------------------------------------------------
# Application helpers
# --------------------------------------------------------------------------

def param_specs(
    params: Any, rule: Callable[[str, Tuple[int, ...], MeshAxes, int], P],
    axes: MeshAxes, mesh: Mesh,
) -> Any:
    tp = axes.model_size(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, x: rule(_path_str(path), x.shape, axes, tp), params
    )


def zero1_specs(params: Any, pspecs: Any, axes: MeshAxes, mesh: Mesh) -> Any:
    """ZeRO-1: optimizer moments additionally sharded over the data axes
    on the first dim that is still replicated and divisible."""
    dsize = axes.data_size(mesh)

    def one(x, spec: P):
        parts = list(spec) + [None] * (x.ndim - len(spec))
        for i, (dim, s) in enumerate(zip(x.shape, parts)):
            if s is None and _div(dim, dsize) and dim >= dsize:
                parts[i] = axes.data
                break
        return P(*parts)

    return jax.tree.map(one, params, pspecs)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def batch_spec(axes: MeshAxes) -> P:
    """Leading-dim data-parallel spec for host batches."""
    return P(axes.data)


# --------------------------------------------------------------------------
# RangeReach index sharding (cluster serving)
# --------------------------------------------------------------------------

def index_shard_specs(axis: str = "data") -> Dict[str, P]:
    """PartitionSpecs for the cluster ``ShardedEngine``'s device arrays.

    The stacked per-shard R-tree arenas — SoA entry planes plus the
    fine/coarse tile-pyramid planes, all shaped ``(S, 2*dim, width)`` —
    shard over ``axis`` on the leading (shard) dim; the vertex→tree
    routing arrays are replicated on every device (the pointer side is
    tiny next to the arenas, and every device must route every query).
    """
    arena = P(axis, None, None)
    replicated = P()
    return {
        "entries": arena,
        "fine": arena,
        "coarse": arena,
        "tree_shard": replicated,
        "tree_qs": replicated,
        "tree_qe": replicated,
    }


def opt_state_specs(opt_state, params, pspecs, axes: MeshAxes, mesh: Mesh,
                    zero1: bool = True):
    """Specs for AdamWState(step, m, v)."""
    from ..train.optim import AdamWState

    mspec = zero1_specs(params, pspecs, axes, mesh) if zero1 else pspecs
    return AdamWState(step=P(), m=mspec, v=jax.tree.map(lambda s: s, mspec))
