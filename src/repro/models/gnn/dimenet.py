"""DimeNet (Klicpera et al., 2020) — directional message passing.

Assigned config: 6 blocks, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6.  The defining kernel regime is the **triplet gather**: for
each pair of incident edges (k->j, j->i) the angle ∠(kji) feeds a
spherical basis that modulates message m_kj before it is aggregated into
m_ji.  Triplet index lists (id_kj, id_ji) are built host-side
(``build_triplets``) with a static padded budget — the same
static-shape discipline the rest of the framework uses.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..nn import ACT, Params, dense, dense_init, embed_init, mlp, mlp_init
from .common import bessel_rbf, edge_vectors, seg_sum, smooth_cutoff


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 10.0
    n_species: int = 100
    d_feat: int | None = None


def build_triplets(
    src: np.ndarray, dst: np.ndarray, n_nodes: int, budget: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side triplet enumeration: pairs (edge kj, edge ji) with
    kj.dst == ji.src and kj.src != ji.dst.  Returns (id_kj, id_ji, mask)
    padded/truncated to ``budget``."""
    E = len(src)
    kj, ji = [], []
    # edge e is src->dst; for triplet (k->j->i): e_kj has dst == j,
    # e_ji has src == j
    by_dst = {}
    for e in range(E):
        by_dst.setdefault(int(dst[e]), []).append(e)
    for e_ji in range(E):
        j = int(src[e_ji])
        i = int(dst[e_ji])
        for e_kj in by_dst.get(j, ()):
            if int(src[e_kj]) != i:
                kj.append(e_kj)
                ji.append(e_ji)
    kj = np.asarray(kj[:budget], dtype=np.int32)
    ji = np.asarray(ji[:budget], dtype=np.int32)
    mask = np.zeros(budget, dtype=bool)
    mask[: len(kj)] = True
    out_kj = np.zeros(budget, dtype=np.int32)
    out_ji = np.zeros(budget, dtype=np.int32)
    out_kj[: len(kj)] = kj
    out_ji[: len(ji)] = ji
    return out_kj, out_ji, mask


def _angular_basis(cos_angle: jnp.ndarray, dist_kj: jnp.ndarray,
                   cfg: DimeNetConfig) -> jnp.ndarray:
    """(T, n_spherical * n_radial) joint basis: Chebyshev in the angle x
    Bessel in the radius (a faithful-rank stand-in for the exact spherical
    Bessel * Legendre product of the paper)."""
    t = jnp.clip(cos_angle, -1.0, 1.0)
    cheb = [jnp.ones_like(t), t]
    for _ in range(cfg.n_spherical - 2):
        cheb.append(2 * t * cheb[-1] - cheb[-2])
    ang = jnp.stack(cheb[: cfg.n_spherical], axis=-1)          # (T, S)
    rad = bessel_rbf(dist_kj, cfg.n_radial, cfg.cutoff)        # (T, R)
    return (ang[:, :, None] * rad[:, None, :]).reshape(
        -1, cfg.n_spherical * cfg.n_radial)


def init_params(key, cfg: DimeNetConfig) -> Params:
    d = cfg.d_hidden
    nsr = cfg.n_spherical * cfg.n_radial
    ks = jax.random.split(key, 5 + cfg.n_blocks)
    p: Params = {
        "embed": embed_init(ks[0], cfg.n_species, d),
        "rbf_proj": dense_init(ks[1], cfg.n_radial, d, bias=False),
        "msg_init": mlp_init(ks[2], (3 * d, d)),
        "out_final": mlp_init(ks[3], (d, d // 2, 1)),
    }
    if cfg.d_feat is not None:
        p["enc"] = dense_init(ks[4], cfg.d_feat, d)
    for b in range(cfg.n_blocks):
        k1, k2, k3, k4, k5 = jax.random.split(ks[5 + b], 5)
        p[f"blk{b}"] = {
            "sbf_proj": dense_init(k1, nsr, cfg.n_bilinear, bias=False),
            "down": dense_init(k2, d, cfg.n_bilinear, bias=False),
            "bilin": jax.random.normal(
                k3, (cfg.n_bilinear, cfg.n_bilinear, d), jnp.float32
            ) * (1.0 / cfg.n_bilinear),
            "msg_mlp": mlp_init(k4, (d, d, d)),
            "out": mlp_init(k5, (d, d)),
        }
    return p


def apply(params: Params, batch: Dict, cfg: DimeNetConfig) -> jnp.ndarray:
    pos = batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    id_kj, id_ji = batch["id_kj"], batch["id_ji"]
    tmask = batch["triplet_mask"]
    N = pos.shape[0]

    vec, dist = edge_vectors(pos, src, dst)     # vec = x_src - x_dst
    rbf = bessel_rbf(dist, cfg.n_radial, cfg.cutoff)
    if emask is not None:
        rbf = rbf * emask[:, None].astype(rbf.dtype)
    rbf_h = dense(params["rbf_proj"], rbf)      # (E, d)

    if cfg.d_feat is not None:
        hnode = dense(params["enc"], batch["feat"])
    else:
        hnode = jnp.take(params["embed"]["emb"], batch["species"], axis=0)
    m = mlp(
        params["msg_init"],
        jnp.concatenate([hnode[src], hnode[dst], rbf_h], -1),
        act="silu", final_act="silu",
    )                                            # (E, d)

    # triplet geometry: angle between edge ji (j->i) and kj (k->j)
    v_ji = vec[id_ji]
    v_kj = -vec[id_kj]                          # orient k->j at node j
    cosang = jnp.sum(v_ji * v_kj, -1) / jnp.maximum(
        jnp.linalg.norm(v_ji, axis=-1) * jnp.linalg.norm(v_kj, axis=-1),
        1e-9,
    )
    sbf = _angular_basis(cosang, dist[id_kj], cfg)
    sbf = sbf * tmask[:, None].astype(sbf.dtype)

    E = m.shape[0]
    out_acc = jnp.zeros((N, cfg.d_hidden), m.dtype)
    for b in range(cfg.n_blocks):
        bp = params[f"blk{b}"]
        sb = dense(bp["sbf_proj"], sbf)                   # (T, nb)
        mk = dense(bp["down"], m)[id_kj]                  # (T, nb)
        tr = jnp.einsum("tb,tc,bcd->td", sb, mk, bp["bilin"])
        agg = seg_sum(tr, id_ji, E)                       # (E, d)
        m = m + mlp(bp["msg_mlp"], m * rbf_h + agg, act="silu")
        out_acc = out_acc + seg_sum(dense0(bp["out"], m), dst, N)
    out = mlp(params["out_final"], out_acc, act="silu")   # (N, 1)
    nmask = batch.get("node_mask")
    if nmask is not None:
        out = out * nmask[:, None].astype(out.dtype)
    return out.sum()


def dense0(p, x):
    return mlp(p, x, act="silu", final_act="silu")


def loss_fn(params: Params, batch: Dict, cfg: DimeNetConfig) -> jnp.ndarray:
    pred = jax.vmap(lambda b: apply(params, b, cfg))(batch)
    return jnp.mean((pred - batch["energy"]) ** 2)
