"""Micro-batching frontend: request queue -> engine-sized batches.

A serving node receives single RangeReach requests; the engines want
batches (the jit cache is keyed on power-of-two buckets, and per-query
overhead amortises across a tile).  :class:`Frontend` sits between:

* ``submit(u, rect)`` enqueues a request onto a **bounded** queue
  (backpressure: submit blocks while ``max_queue`` requests are
  pending) and returns a future;
* a scheduler thread flushes the queue into the engine on
  **deadline-or-full**: as soon as ``max_batch`` requests are pending,
  or when the oldest pending request has waited ``max_delay`` seconds —
  whichever comes first.  Flushed batches are at most ``max_batch``
  (keep it a power of two so steady state re-uses the engine's compiled
  buckets), and the engine's own bucket padding absorbs ragged tails.

The frontend is engine-agnostic: anything with a
``query_batch(us, rects) -> bool array`` works — the single-device
``QueryEngine``, the cluster ``ShardedEngine``, or a host index.
``warmup`` pre-traces every batch bucket the flush policy can produce,
so a steady-state stream recompiles nothing (asserted in tests via the
engine's ``n_compiles`` introspection).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..kernels.range_query.kernel import TB


class Frontend:
    """Deadline-or-full micro-batch scheduler in front of a query engine.

    Parameters
    ----------
    engine:    anything with ``query_batch(us, rects)``.
    max_batch: flush as soon as this many requests are pending (keep it
               a power of two to reuse the engine's compiled buckets).
    max_delay: flush when the oldest pending request is this old (s).
    max_queue: bounded-queue capacity; ``submit`` blocks above it.
    """

    def __init__(self, engine, max_batch: int = 256,
                 max_delay: float = 2e-3, max_queue: int = 8192):
        if max_batch < 1 or max_queue < max_batch:
            raise ValueError(
                f"need 1 <= max_batch <= max_queue, got "
                f"{max_batch}/{max_queue}")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.max_queue = int(max_queue)
        self._cond = threading.Condition()
        self._rect_len = None                 # fixed by the first submit
        self._pending: List[tuple] = []       # (u, rect, future, t_enq)
        self._inflight = False
        self._closed = False
        self._force = False
        self.stats: Dict[str, float] = {
            "n_requests": 0, "n_batches": 0, "n_flush_full": 0,
            "n_flush_deadline": 0, "n_flush_forced": 0,
            "batched_queries": 0, "max_pending_seen": 0,
        }
        self._thread = threading.Thread(
            target=self._run, name="rangereach-frontend", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    # client surface
    # ------------------------------------------------------------------

    def submit(self, u: int, rect) -> "Future[bool]":
        """Enqueue one request; returns a future resolving to the answer.
        Blocks while the queue is at capacity (backpressure)."""
        fut: Future = Future()
        rect = np.asarray(rect, dtype=np.float32).ravel()
        with self._cond:
            # reject shape mismatches in the caller's thread — a ragged
            # rect must never reach batch assembly on the scheduler
            if self._rect_len is None:
                self._rect_len = len(rect)
            elif len(rect) != self._rect_len:
                raise ValueError(
                    f"rect has {len(rect)} coords, expected "
                    f"{self._rect_len}")
            while len(self._pending) >= self.max_queue and not self._closed:
                self._cond.wait()
            if self._closed:
                raise RuntimeError("Frontend is closed")
            self._pending.append((int(u), rect, fut, time.monotonic()))
            self.stats["n_requests"] += 1
            self.stats["max_pending_seen"] = max(
                self.stats["max_pending_seen"], len(self._pending))
            self._cond.notify_all()
        return fut

    def submit_many(self, us: Sequence[int], rects,
                    timeout: Optional[float] = None) -> np.ndarray:
        """Submit a request stream one by one and gather the answers —
        the convenience used by benchmarks and examples."""
        rects = np.asarray(rects, dtype=np.float32)
        futs = [self.submit(u, r) for u, r in zip(us, rects)]
        return np.array([f.result(timeout=timeout) for f in futs],
                        dtype=bool)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Force-dispatch everything pending and wait until served."""
        with self._cond:
            self._force = True
            self._cond.notify_all()
            self._cond.wait_for(
                lambda: not self._pending and not self._inflight,
                timeout=timeout)
            # don't leak the flag onto requests submitted after the
            # flush completes (they should wait for deadline-or-full)
            self._force = False

    def warmup(self, us: np.ndarray, rects: np.ndarray) -> None:
        """Pre-trace every batch bucket the flush policy can produce,
        using a representative workload (tiled up to ``max_batch``)."""
        us = np.asarray(us, dtype=np.int64)
        rects = np.asarray(rects, dtype=np.float32).reshape(len(us), -1)
        reps = -(-self.max_batch // max(len(us), 1))
        us = np.tile(us, reps)
        rects = np.tile(rects, (reps, 1))
        b = TB
        while True:
            k = min(b, self.max_batch)
            self.engine.query_batch(us[:k], rects[:k])
            if b >= self.max_batch:
                break
            b <<= 1

    def close(self, timeout: Optional[float] = None) -> None:
        """Serve everything pending, then stop the scheduler thread."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "Frontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def mean_batch(self) -> float:
        b = self.stats["n_batches"]
        return self.stats["batched_queries"] / b if b else 0.0

    # ------------------------------------------------------------------
    # scheduler thread
    # ------------------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cond:
                while True:
                    if self._pending:
                        n = len(self._pending)
                        deadline = self._pending[0][3] + self.max_delay
                        now = time.monotonic()
                        if n >= self.max_batch:
                            reason = "n_flush_full"
                            break
                        if self._force or self._closed:
                            reason = "n_flush_forced"
                            break
                        if now >= deadline:
                            reason = "n_flush_deadline"
                            break
                        self._cond.wait(timeout=deadline - now)
                    elif self._closed:
                        return
                    else:
                        self._force = False
                        self._cond.wait()
                batch = self._pending[: self.max_batch]
                del self._pending[: self.max_batch]
                if not self._pending:
                    self._force = False
                self._inflight = True
                self._cond.notify_all()       # queue space freed
            self._serve(batch, reason)
            with self._cond:
                self._inflight = False
                self._cond.notify_all()

    def _serve(self, batch: List[tuple], reason: str) -> None:
        try:
            # assembly inside the latch too: no input may ever kill the
            # scheduler thread and strand the batch's futures
            us = np.array([b[0] for b in batch], dtype=np.int64)
            rects = np.stack([b[1] for b in batch])
            ans = self.engine.query_batch(us, rects)
        except BaseException as e:  # latch the error onto every future
            for _, _, fut, _ in batch:
                try:
                    fut.set_exception(e)
                except InvalidStateError:   # client cancelled meanwhile
                    pass
            return
        self.stats["n_batches"] += 1
        self.stats[reason] += 1
        self.stats["batched_queries"] += len(batch)
        for (_, _, fut, _), a in zip(batch, ans):
            try:
                fut.set_result(bool(a))
            except InvalidStateError:       # client cancelled meanwhile
                pass
