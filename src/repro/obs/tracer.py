"""Span tracer: Chrome-trace-format timing for every serving layer.

The serving stack (engine -> cluster -> frontend, plus the offline build
stages) is instrumented with :func:`span` context managers.  Disabled —
the default — a span is one module-attribute check and the return of a
shared no-op context manager, so the hot path pays ~100ns per span
(gated <2% of the smoke bench by ``benchmarks/obs_overhead.py``).
Enabled, each span records a Chrome trace "complete" event (``ph: "X"``)
into a bounded in-memory buffer: name, category, thread id, start/dur in
microseconds, and any keyword args.  The buffer is thread-safe (the
frontend scheduler thread and compaction builders trace concurrently
with the caller) and drops-with-a-counter rather than growing without
bound.

Open the dump in ``chrome://tracing`` / https://ui.perfetto.dev:

    from repro import obs
    obs.enable()
    ... serve ...
    obs.dump("results/obs")          # writes trace.json
"""

from __future__ import annotations

import functools
import json
import math
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from . import trace_context

# Timestamps are perf_counter_ns throughout, so spans recorded on any
# thread share one monotonic clock and line up in the trace viewer.
_now_ns = time.perf_counter_ns


class Tracer:
    """Bounded, thread-safe buffer of completed spans."""

    def __init__(self, max_events: int = 1_000_000):
        self.enabled = False
        self.max_events = int(max_events)
        self._lock = threading.Lock()
        self._events: List[tuple] = []   # (name, cat, tid, t0_ns, dur_ns, args)
        self.dropped = 0

    # -- recording ------------------------------------------------------

    def record(self, name: str, cat: str, t0_ns: int, dur_ns: int,
               args: Optional[dict]) -> None:
        tid = threading.get_ident()
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append((name, cat, tid, t0_ns, dur_ns, args))

    # -- control --------------------------------------------------------

    def start(self) -> None:
        self.enabled = True

    def stop(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self.dropped = 0

    # -- introspection --------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> List[tuple]:
        """Snapshot of the raw event tuples (name, cat, tid, t0_ns,
        dur_ns, args)."""
        with self._lock:
            return list(self._events)

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name totals: {name: {count, total_us, mean_us}}."""
        out: Dict[str, Dict[str, float]] = {}
        for name, _cat, _tid, _t0, dur, _args in self.events():
            s = out.setdefault(name, {"count": 0, "total_us": 0.0})
            s["count"] += 1
            s["total_us"] += dur / 1e3
        for s in out.values():
            s["mean_us"] = s["total_us"] / s["count"]
        return out

    @staticmethod
    def _union_len(iv: List[Tuple[float, float]]) -> float:
        """Total length of the union of (start, end) intervals."""
        iv.sort()
        covered, end = 0.0, -math.inf
        for a, b in iv:
            if a > end:
                covered += b - a
                end = b
            elif b > end:
                covered += b - end
                end = b
        return covered

    @staticmethod
    def _merge_per_thread(per_thread: Dict[int, List[Tuple[float, float]]]
                          ) -> List[Tuple[float, float]]:
        """Union each thread's intervals first, then pool the per-thread
        unions — the two-level shape both :meth:`stage_totals` and
        :meth:`coverage` attribute through, so spans that overlap
        (nested same-name spans on one thread, or concurrent frontend
        flush threads) can never count the same wall time twice."""
        pooled: List[Tuple[float, float]] = []
        for iv in per_thread.values():
            iv.sort()
            start = end = None
            for a, b in iv:
                if start is None:
                    start, end = a, b
                elif a > end:
                    pooled.append((start, end))
                    start, end = a, b
                elif b > end:
                    end = b
            if start is not None:
                pooled.append((start, end))
        return pooled

    def stage_totals(self, prefix: str = "") -> Dict[str, float]:
        """{name: total_us} over spans whose name starts with ``prefix``
        — the per-stage attribution the benchmarks record.

        Totals are interval *unions* computed per thread before merging
        across threads: wall time during which at least one thread was
        inside the stage.  Sequential spans sum as before; overlapping
        same-name spans (recursion on one thread, concurrent frontend
        flush threads) no longer double-count, so a stage total can
        never exceed the wall interval it ran in.
        """
        per: Dict[str, Dict[int, List[Tuple[float, float]]]] = {}
        for name, _cat, tid, t0, dur, _args in self.events():
            if name.startswith(prefix):
                per.setdefault(name, {}).setdefault(tid, []).append(
                    (t0, t0 + dur))
        return {name: self._union_len(self._merge_per_thread(by_tid)) / 1e3
                for name, by_tid in per.items()}

    def coverage(self, t0_s: float, t1_s: float,
                 prefixes: Sequence[str] = ()) -> float:
        """Fraction of the wall interval ``[t0_s, t1_s]`` (perf_counter
        seconds) covered by the union of matching spans.

        Intervals are clipped to the window, unioned **per thread
        first**, then unioned across threads — concurrent spans
        (frontend scheduler thread vs caller, or several flush threads)
        merge rather than add, so coverage is capped at 1.0 by
        construction.  The result answers "how much of the end-to-end
        wall time is attributed to *some* instrumented stage".
        """
        lo, hi = t0_s * 1e9, t1_s * 1e9
        if hi <= lo:
            return 0.0
        per_thread: Dict[int, List[Tuple[float, float]]] = {}
        for name, _cat, tid, t0, dur, _args in self.events():
            if prefixes and not any(name.startswith(p) for p in prefixes):
                continue
            a, b = max(t0, lo), min(t0 + dur, hi)
            if b > a:
                per_thread.setdefault(tid, []).append((a, b))
        covered = self._union_len(self._merge_per_thread(per_thread))
        return covered / (hi - lo)

    def chrome_trace(self) -> dict:
        """The buffer as a Chrome trace (``chrome://tracing`` /
        Perfetto): one ``ph:"X"`` complete event per span, microsecond
        timestamps on the shared monotonic clock."""
        ev = []
        for name, cat, tid, t0, dur, args in self.events():
            e = {
                "name": name, "cat": cat or "repro", "ph": "X",
                "ts": t0 / 1e3, "dur": dur / 1e3, "pid": 0, "tid": tid,
            }
            if args:
                e["args"] = args
            ev.append(e)
        return {
            "traceEvents": ev,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


TRACER = Tracer()


class _NullSpan:
    """Shared no-op context manager — the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("_name", "_cat", "_args", "_t0")

    def __init__(self, name: str, cat: str, args: Optional[dict]):
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = _now_ns()
        return self

    def __exit__(self, *exc):
        t1 = _now_ns()
        args = self._args
        # an active per-request scope stamps its trace ids onto every
        # span recorded within it — the causal key the flight-recorder
        # replay resolves.  Enabled-only cost: one thread-local read.
        ids = trace_context.current_ids()
        if ids is not None:
            args = dict(args) if args else {}
            args["trace_ids"] = ids
        TRACER.record(self._name, self._cat, self._t0, t1 - self._t0,
                      args)
        return False


def span(name: str, cat: str = "", **args):
    """Context manager timing one stage.  ``with span("engine.scan"): ...``

    Disabled (the default) this returns a shared no-op — the check is a
    single attribute load, so instrumented hot paths stay hot.  Keyword
    args land in the Chrome trace event's ``args`` field.
    """
    if not TRACER.enabled:
        return _NULL
    return _Span(name, cat, args or None)


def traced(name: Optional[str] = None, cat: str = ""):
    """Decorator form of :func:`span`; defaults to the function's
    qualified name.  ``@traced()`` or ``@traced("engine.scan")``."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not TRACER.enabled:
                return fn(*a, **kw)
            t0 = _now_ns()
            try:
                return fn(*a, **kw)
            finally:
                TRACER.record(label, cat, t0, _now_ns() - t0, None)

        return wrapper

    return deco
