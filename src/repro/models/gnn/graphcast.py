"""GraphCast-style encoder-processor-decoder mesh GNN (Lam et al., 2022).

Assigned config: 16 processor layers, d_hidden=512, sum aggregation,
n_vars=227, mesh refinement 6 (icosahedral mesh ~40k nodes — the `native`
input shape; the four assigned graph shapes are also runnable since the
model only needs (feat, pos, edges)).

Faithful skeleton: node/edge MLP encoders with LayerNorm, interaction-
network processor blocks (edge update from [e, h_src, h_dst], node update
from [h, sum_e]), residual connections, MLP decoder back to n_vars.
The grid2mesh/mesh2grid bipartite stages of full GraphCast collapse onto
the single supplied graph (noted in DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from ..nn import Params, dense, layernorm, mlp, mlp_init, norm_init
from .common import edge_vectors, seg_sum


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    mesh_refinement: int = 6
    aggregator: str = "sum"


def _mlp_ln_init(key, dims):
    k1, _ = jax.random.split(key)
    return {"mlp": mlp_init(k1, dims), "ln": norm_init(dims[-1])}


def _mlp_ln(p, x, act="silu"):
    return layernorm(p["ln"], mlp(p["mlp"], x, act=act))


def init_params(key, cfg: GraphCastConfig) -> Params:
    d = cfg.d_hidden
    ks = jax.random.split(key, 4 + cfg.n_layers)
    p: Params = {
        "enc_node": _mlp_ln_init(ks[0], (cfg.n_vars + 3, d, d)),
        "enc_edge": _mlp_ln_init(ks[1], (4, d, d)),
        "dec": {"mlp": mlp_init(ks[2], (d, d, cfg.n_vars))},
    }

    def layer_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge": _mlp_ln_init(k1, (3 * d, d, d)),
            "node": _mlp_ln_init(k2, (2 * d, d, d)),
        }

    p["proc"] = jax.vmap(layer_init)(
        jax.random.split(ks[3], cfg.n_layers)
    )
    return p


def apply(params: Params, batch: Dict, cfg: GraphCastConfig) -> jnp.ndarray:
    """feat (N, n_vars), pos (N, 3) -> next-state prediction (N, n_vars)."""
    pos = batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    N = pos.shape[0]
    vec, dist = edge_vectors(pos, src, dst)
    efeat = jnp.concatenate([vec, dist[:, None]], axis=-1)
    h = _mlp_ln(params["enc_node"],
                jnp.concatenate([batch["feat"], pos], -1))
    e = _mlp_ln(params["enc_edge"], efeat)
    if emask is not None:
        e = e * emask[:, None].astype(e.dtype)

    def proc(carry, lp):
        h, e = carry
        eu = _mlp_ln(lp["edge"], jnp.concatenate([e, h[src], h[dst]], -1))
        if emask is not None:
            eu = eu * emask[:, None].astype(eu.dtype)
        e = e + eu
        agg = seg_sum(e, dst, N)
        h = h + _mlp_ln(lp["node"], jnp.concatenate([h, agg], -1))
        return (h, e), None

    (h, e), _ = jax.lax.scan(proc, (h, e), params["proc"])
    return batch["feat"] + mlp(params["dec"]["mlp"], h)   # residual step


def loss_fn(params: Params, batch: Dict, cfg: GraphCastConfig) -> jnp.ndarray:
    pred = apply(params, batch, cfg)
    tgt = batch["target"]
    mask = batch.get("node_mask")
    err = (pred - tgt) ** 2
    if mask is not None:
        err = err * mask[:, None].astype(err.dtype)
        return err.sum() / jnp.maximum(
            mask.sum() * tgt.shape[-1], 1.0)
    return err.mean()
