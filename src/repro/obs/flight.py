"""Flight recorder: an always-on black box + SLO-triggered debug bundles.

A tail-latency incident in a serving run is unreproducible by
definition — by the time a human looks, the queue has drained, the
breaker has closed and the interesting spans have been evicted.  The
flight recorder keeps the recent past in bounded rings (the span
tracer's buffer, the structured query log's window, its own event ring
of fault / breaker / SLO / audit transitions) and **freezes** them into
a self-contained ``flightdump/`` bundle the moment something goes
wrong:

* a PR-8 SLO burn-rate monitor fires (``slo.py`` notifies on every
  ``fired`` transition);
* a circuit breaker opens (``resilience.breaker`` notifies on every
  closed/half-open → open transition);
* the online exactness auditor observes a divergence
  (:mod:`repro.obs.audit`);
* someone calls :func:`repro.obs.dump_flight` (manual, e.g. from a
  debugger or an ops shell).

Triggers are **rate-limited** (default: one bundle per 30s, bounded
total per run) so a burning SLO cannot fill a disk, and the recorder
only writes when **armed** (``serve.py --obs`` arms it; unit tests stay
silent).  ``note()`` — the always-on black-box append — is one bounded
deque append and is safe from any thread.

Bundle layout (all paths relative to the bundle directory)::

    manifest.json     schema, trigger, counts, exemplars, worst traces
    trace.json        Chrome-trace of the span ring (chrome://tracing)
    spans.jsonl       the same spans as JSONL (header line first)
    querylog.jsonl    the query-log window (schema v3: trace_id/attempt)
    events.jsonl      fault / breaker / SLO / audit event ring
    metrics.json      registry snapshot at freeze time

Replay CLI — prints the causal story (admission → kernel/shards →
retries/degradation → completion) of the worst traces in the bundle::

    python -m repro.obs.flight results/flightdump/000-slo-latency
"""

from __future__ import annotations

import json
import math
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from . import metrics as _metrics
from . import querylog as _querylog
from .tracer import TRACER

SCHEMA_VERSION = 1

#: spans written per bundle (newest retained; the tracer ring itself
#: may hold up to a million)
MAX_BUNDLE_SPANS = 50_000


class FlightRecorder:
    """Bounded black-box ring + rate-limited bundle freezing."""

    def __init__(self, capacity_events: int = 4096):
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=int(capacity_events))
        self.events_total = 0
        self.armed = False
        self._dir: Optional[str] = None
        self.min_interval_s = 30.0
        self.max_dumps = 16
        self._last_dump_t = -math.inf
        self._seq = 0

    # -- black box ------------------------------------------------------

    def note(self, kind: str, **fields) -> None:
        """Append one event to the always-on bounded ring (breaker
        transitions, SLO fired/cleared, injected faults, audit
        divergences).  Cheap and thread-safe; never triggers a dump by
        itself."""
        evt = {"t": time.time(), "kind": kind}
        evt.update(fields)
        with self._lock:
            self._events.append(evt)
            self.events_total += 1

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    # -- arming ---------------------------------------------------------

    def arm(self, dirpath: str, min_interval_s: float = 30.0,
            max_dumps: int = 16) -> "FlightRecorder":
        """Arm the recorder: triggers now freeze bundles under
        ``dirpath`` (rate-limited).  Unarmed (the default), triggers
        are counted but write nothing — unit tests and library users
        who never opted in stay file-free."""
        with self._lock:
            self._dir = str(dirpath)
            self.min_interval_s = float(min_interval_s)
            self.max_dumps = int(max_dumps)
            self.armed = True
        return self

    def disarm(self) -> None:
        with self._lock:
            self.armed = False

    def reset(self) -> None:
        """Forget events and disarm (test isolation; the rate-limit
        clock and dump sequence restart too)."""
        with self._lock:
            self._events.clear()
            self.events_total = 0
            self.armed = False
            self._dir = None
            self._last_dump_t = -math.inf
            self._seq = 0

    def snapshot(self) -> dict:
        with self._lock:
            return {"armed": self.armed, "dir": self._dir,
                    "events": len(self._events),
                    "events_total": self.events_total,
                    "dumps": self._seq}

    # -- triggering -----------------------------------------------------

    def trigger(self, reason: str, detail: Optional[dict] = None,
                force: bool = False) -> Optional[str]:
        """Freeze a bundle for ``reason``; returns its directory, or
        ``None`` when unarmed / rate-limited / over the dump budget.
        ``force`` (the manual ``obs.dump_flight`` path) skips the rate
        limit but still respects arming and ``max_dumps``."""
        reg = _metrics.REGISTRY
        reg.counter(f"flight.trigger.{reason}").inc()
        with self._lock:
            if not self.armed or self._dir is None:
                reg.counter("flight.unarmed").inc()
                return None
            now = time.monotonic()
            if not force and now - self._last_dump_t < self.min_interval_s:
                reg.counter("flight.suppressed").inc()
                return None
            if self._seq >= self.max_dumps:
                reg.counter("flight.suppressed").inc()
                return None
            self._last_dump_t = now
            seq = self._seq
            self._seq += 1
            root = self._dir
        path = self._write_bundle(root, seq, reason, detail)
        reg.counter("flight.dumps").inc()
        return path

    # -- bundle writing -------------------------------------------------

    def _write_bundle(self, root: str, seq: int, reason: str,
                      detail: Optional[dict]) -> str:
        safe = "".join(c if c.isalnum() or c in "-_" else "-"
                       for c in reason)
        bundle = os.path.join(root, f"{seq:03d}-{safe}")
        os.makedirs(bundle, exist_ok=True)

        spans = TRACER.events()[-MAX_BUNDLE_SPANS:]
        with open(os.path.join(bundle, "spans.jsonl"), "w") as f:
            f.write(json.dumps({"schema_version": SCHEMA_VERSION,
                                "fields": ["name", "cat", "tid", "t0_us",
                                           "dur_us", "args"]}) + "\n")
            for name, cat, tid, t0, dur, args in spans:
                f.write(json.dumps({
                    "name": name, "cat": cat, "tid": tid,
                    "t0_us": t0 / 1e3, "dur_us": dur / 1e3,
                    "args": args or {}}) + "\n")
        TRACER.dump(os.path.join(bundle, "trace.json"))
        _querylog.QUERY_LOG.to_jsonl(os.path.join(bundle, "querylog.jsonl"))
        with open(os.path.join(bundle, "events.jsonl"), "w") as f:
            for evt in self.events():
                f.write(json.dumps(evt) + "\n")
        _metrics.REGISTRY.dump(os.path.join(bundle, "metrics.json"))

        qrecs = _querylog.QUERY_LOG.records()
        manifest = {
            "schema_version": SCHEMA_VERSION,
            "reason": reason,
            "detail": detail,
            "t_wall": time.time(),
            "files": ["manifest.json", "trace.json", "spans.jsonl",
                      "querylog.jsonl", "events.jsonl", "metrics.json"],
            "counts": {
                "spans": len(spans),
                "spans_dropped": TRACER.dropped,
                "querylog": len(qrecs),
                "events": len(self.events()),
            },
            "exemplars": self._exemplar_index(),
            "worst_traces": _worst_trace_ids(
                [dict(zip(_querylog.FIELDS, r)) for r in qrecs]),
        }
        with open(os.path.join(bundle, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        return bundle

    @staticmethod
    def _exemplar_index() -> Dict[str, dict]:
        """{histogram name: {bucket: [[trace_id, value], ...]}} over
        every registry histogram that retained exemplars — the lookup
        the replay CLI resolves p99 requests through."""
        out: Dict[str, dict] = {}
        for name, m in _metrics.REGISTRY.items():
            if isinstance(m, _metrics.Histogram):
                ex = m.exemplars()
                if ex:
                    out[name] = {
                        str(i): [[tid, val] for tid, val in res]
                        for i, res in sorted(ex.items())}
        return out


FLIGHT = FlightRecorder()


def _worst_trace_ids(records: List[dict], p: float = 99.0,
                     cap: int = 32) -> List[dict]:
    """Trace summaries for the records in the window's p99 latency
    bucket (ties included), worst first."""
    lats = [r["latency_us"] for r in records if r.get("trace_id", -1) >= 0]
    if not lats:
        return []
    lats_sorted = sorted(lats)
    k = max(0, min(len(lats_sorted) - 1,
                   int(math.ceil(p / 100.0 * len(lats_sorted))) - 1))
    thresh = lats_sorted[k]
    worst = [r for r in records
             if r.get("trace_id", -1) >= 0 and r["latency_us"] >= thresh]
    worst.sort(key=lambda r: -r["latency_us"])
    return [{"trace_id": r["trace_id"], "latency_us": r["latency_us"],
             "status": r["status"], "attempt": r.get("attempt", 0),
             "u": r["u"], "query_class": r["query_class"],
             "shard": r["shard"]} for r in worst[:cap]]


# ---------------------------------------------------------------------------
# replay: load a bundle and reconstruct causal stories
# ---------------------------------------------------------------------------

def _load_jsonl(path: str, skip_header: bool = True) -> List[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            if i == 0 and skip_header and "schema_version" in obj \
                    and "fields" in obj:
                continue
            out.append(obj)
    return out


def load_bundle(bundle: str) -> dict:
    """Parse a flight bundle directory into plain dicts/lists."""
    with open(os.path.join(bundle, "manifest.json")) as f:
        manifest = json.load(f)
    return {
        "manifest": manifest,
        "spans": _load_jsonl(os.path.join(bundle, "spans.jsonl")),
        "querylog": _load_jsonl(os.path.join(bundle, "querylog.jsonl")),
        "events": _load_jsonl(os.path.join(bundle, "events.jsonl"),
                              skip_header=False),
    }


def resolve_trace(data: dict, trace_id: int) -> dict:
    """One trace id's causal story out of a loaded bundle: the
    admission record, every span that served it, every black-box event
    that names it, and a completeness verdict (admission → engine work
    → completion all present)."""
    tid = int(trace_id)
    record = next((r for r in data["querylog"]
                   if r.get("trace_id") == tid), None)
    spans = [s for s in data["spans"]
             if tid in (s.get("args") or {}).get("trace_ids", ())]
    spans.sort(key=lambda s: s["t0_us"])
    events = [e for e in data["events"]
              if tid in e.get("trace_ids", ())
              or e.get("trace_id") == tid]
    worked = any(s["name"].split(".")[0] in
                 ("engine", "cluster", "dynamic", "resilience")
                 for s in spans)
    return {
        "trace_id": tid,
        "record": record,
        "spans": spans,
        "events": events,
        "complete": record is not None and worked,
    }


def replay(bundle: str, top: int = 5) -> dict:
    """The replay the CLI prints: resolve the worst traces (manifest
    ``worst_traces`` ∪ the p99-bucket exemplars of every latency
    histogram) against the bundle's spans / querylog / events."""
    data = load_bundle(bundle)
    manifest = data["manifest"]
    targets: List[int] = []
    for w in manifest.get("worst_traces", []):
        if w["trace_id"] not in targets:
            targets.append(w["trace_id"])
    # every exemplar in the top occupied bucket of each histogram —
    # "the p99 latency bucket of the dump window" resolved by lookup
    exemplar_ids: List[int] = []
    for _name, buckets in manifest.get("exemplars", {}).items():
        if not buckets:
            continue
        top_bucket = max(buckets, key=lambda b: int(b))
        for tid, _v in buckets[top_bucket]:
            if tid not in exemplar_ids:
                exemplar_ids.append(tid)
    for tid in exemplar_ids:
        if tid not in targets:
            targets.append(tid)
    stories = [resolve_trace(data, t) for t in targets[:max(top, 1)]]
    return {
        "bundle": bundle,
        "reason": manifest.get("reason"),
        "counts": manifest.get("counts", {}),
        "targets": targets,
        "exemplar_ids": exemplar_ids,
        "stories": stories,
        "resolved": sum(1 for s in stories if s["complete"]),
    }


def _print_story(story: dict) -> None:
    tid = story["trace_id"]
    rec = story["record"]
    print(f"trace {tid}" + ("" if story["complete"]
                            else "  [INCOMPLETE]"))
    if rec is not None:
        dl = rec.get("attempt", 0)
        print(f"  admitted  u={rec['u']} class={rec['query_class']} "
              f"rect_bucket={rec['rect_bucket']} shard={rec['shard']}")
        print(f"  completed status={rec['status']} attempt={dl} "
              f"retries={rec.get('retries', 0)} "
              f"latency={rec['latency_us']:.0f}us "
              f"cardinality={rec['cardinality']}")
    else:
        print("  (no querylog record retained in the window)")
    for s in story["spans"]:
        n_ids = len((s.get("args") or {}).get("trace_ids", ()))
        print(f"    span {s['name']:<28} {s['dur_us']:>10.1f}us "
              f"(batch of {n_ids})")
    for e in story["events"]:
        kind = e.get("kind", "?")
        extra = {k: v for k, v in e.items()
                 if k not in ("t", "kind", "trace_ids")}
        print(f"    event {kind} {extra}")


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.flight",
        description="Replay a flight-recorder bundle: print the causal "
                    "story of the worst traces.")
    ap.add_argument("bundle", help="bundle directory (contains "
                                   "manifest.json)")
    ap.add_argument("--top", type=int, default=5,
                    help="how many worst traces to print")
    args = ap.parse_args(argv)

    rep = replay(args.bundle, top=args.top)
    print(f"[flight] bundle {rep['bundle']}  trigger={rep['reason']}  "
          f"spans={rep['counts'].get('spans')}  "
          f"querylog={rep['counts'].get('querylog')}  "
          f"events={rep['counts'].get('events')}")
    if not rep["stories"]:
        print("[flight] no traced requests in the window")
        return 0
    print(f"[flight] {rep['resolved']}/{len(rep['stories'])} worst "
          f"traces resolve to a full causal chain "
          f"(admission -> kernel/shards -> completion)")
    for story in rep["stories"]:
        _print_story(story)
    return 0 if rep["resolved"] == len(rep["stories"]) else 1


if __name__ == "__main__":
    raise SystemExit(main())
