"""Pallas TPU kernel: batched AABB range probe over packed R-tree leaves.

The RangeReach hot path after 2DReach reduces a query to "does any leaf
entry of tree t intersect rect R".  On TPU the winning layout is not a
pointer descent but a **tiled scan with an OR-reduce**: queries are the
sublane axis (TB=8), leaf entries the lane axis (TP=128), and each grid
step tests a (TB x TP) tile of (query, entry) pairs on the VPU.  Each
query carries its tree's ``[start, end)`` slice of the global entry
arena; tiles outside the slice are masked.  The output is revisited
across the entry-tile grid dimension (constant index map) so the OR
accumulates in VMEM without touching HBM per tile.

Layout notes (structure-of-arrays): entries and rects are passed as
``(2*dim, N)`` — coordinate planes on the sublane axis, N on the lane
axis — so a single tile holds 128 entries x all coordinates and the
containment test is pure element-wise VPU work with no transposes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


TB = 8     # query tile (sublanes)
TP = 128   # entry tile (lanes)


def _range_query_kernel(e_ref, q_ref, qs_ref, qe_ref, o_ref, *, dim: int,
                        tp: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    e = e_ref[...]                     # (2*dim, TP)  [mins..., maxs...]
    q = q_ref[...]                     # (2*dim, TB)
    gidx = j * tp + jax.lax.broadcasted_iota(jnp.int32, (1, tp), 1)
    qs = qs_ref[...][:, None]          # (TB, 1)
    qe = qe_ref[...][:, None]
    valid = (gidx >= qs) & (gidx < qe)  # (TB, TP)

    ok = valid
    for a in range(dim):
        # entry_min <= rect_max  and  entry_max >= rect_min
        ok = ok & (e[a][None, :] <= q[dim + a][:, None])
        ok = ok & (e[dim + a][None, :] >= q[a][:, None])
    hit = jnp.any(ok, axis=1).astype(jnp.int32)   # (TB,)
    o_ref[...] = o_ref[...] | hit


@functools.partial(
    jax.jit, static_argnames=("dim", "interpret", "tb", "tp")
)
def range_query_pallas(
    entries_soa: jax.Array,   # (2*dim, P) float32, P % tp == 0
    rects_soa: jax.Array,     # (2*dim, B) float32, B % tb == 0
    qstart: jax.Array,        # (B,) int32 — entry-arena slice per query
    qend: jax.Array,          # (B,) int32
    *,
    dim: int = 2,
    interpret: bool = False,
    tb: int = TB,
    tp: int = TP,
) -> jax.Array:
    """Returns (B,) int32 (0/1) — any entry in [qstart, qend) intersecting."""
    two_dim, P = entries_soa.shape
    _, B = rects_soa.shape
    assert two_dim == 2 * dim
    assert P % tp == 0 and B % tb == 0, (P, B)
    grid = (B // tb, P // tp)
    return pl.pallas_call(
        functools.partial(_range_query_kernel, dim=dim, tp=tp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((two_dim, tp), lambda i, j: (0, j)),
            pl.BlockSpec((two_dim, tb), lambda i, j: (0, i)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(entries_soa, rects_soa, qstart, qend)
