"""Pure-jnp oracle for the bitset_mm kernel."""

from __future__ import annotations

import jax.numpy as jnp


def unpack_bits_jnp(bits: jnp.ndarray, n_cols: int) -> jnp.ndarray:
    """(r, W) uint32 -> (r, n_cols) bool, LSB-first per word."""
    r, W = bits.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    b = (bits[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return b.reshape(r, W * 32)[:, :n_cols] > 0


def pack_bits_jnp(rows: jnp.ndarray) -> jnp.ndarray:
    """(r, p) bool -> (r, ceil(p/32)) uint32, LSB-first per word."""
    r, p = rows.shape
    W = (p + 31) // 32
    pad = jnp.zeros((r, W * 32), dtype=jnp.uint32)
    pad = pad.at[:, :p].set(rows.astype(jnp.uint32))
    lanes = pad.reshape(r, W, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(lanes << shifts[None, None, :], axis=-1).astype(jnp.uint32)


def bitset_mm_ref(a_bits: jnp.ndarray, r_bits: jnp.ndarray) -> jnp.ndarray:
    """out[i, w] = OR_j (A[i,j] & R[j, w]) — dense boolean semiring."""
    d, Wd = a_bits.shape
    dj, W = r_bits.shape
    a = unpack_bits_jnp(a_bits, dj)              # (d, dj) bool
    # boolean matmul per output bit: out_bool[i, c] = any_j a[i,j] & r[j,c]
    r_bool = unpack_bits_jnp(r_bits, W * 32)     # (dj, W*32) bool
    out_bool = (a.astype(jnp.float32) @ r_bool.astype(jnp.float32)) > 0
    return pack_bits_jnp(out_bool)
