"""Paper Tables 2-4: graph statistics, build time, index size.

Datasets are the scaled synthetic LBSNs shaped to the paper's Table 2
statistics (see data/lbsn.py); absolute numbers therefore differ from the
paper by the scale factor, but the paper's *claims* — relative build
times (2DReach < 3DReach), relative sizes (Pointer smallest), SCC
structure — are what these tables verify.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import METHODS, build_index, index_nbytes
from repro.data import SPECS, dataset_stats, get_dataset

DATASETS = ("foursquare", "gowalla", "weeplaces", "yelp")
BENCH_SCALE = 0.5


def table2(scale: float = BENCH_SCALE) -> List[Dict]:
    rows = []
    for name in DATASETS:
        g = get_dataset(name, scale=scale)
        s = dataset_stats(g)
        ref = SPECS[name].ref
        idx = build_index(g, "2dreach-comp")
        s["distinct_rtrees"] = int(idx.stats["distinct_rtrees"])
        s["dataset"] = name
        s["paper_user_scc_pct"] = round(
            100 * ref["user_sccs"] / ref["sccs"], 1)
        s["ours_user_scc_pct"] = round(100 * s["user_sccs"] / s["sccs"], 1)
        rows.append(s)
    return rows


def table3(scale: float = BENCH_SCALE, repeats: int = 3) -> List[Dict]:
    rows = []
    for name in DATASETS:
        g = get_dataset(name, scale=scale)
        row = {"dataset": name}
        for method in METHODS:
            if method == "georeach":
                continue  # the paper's Table 3 lists the five index methods
            best = min(
                _timed_build(g, method) for _ in range(repeats)
            )
            row[method] = round(best, 3)
        rows.append(row)
    return rows


def _timed_build(g, method):
    t0 = time.perf_counter()
    build_index(g, method)
    return time.perf_counter() - t0


def table4(scale: float = BENCH_SCALE) -> List[Dict]:
    rows = []
    for name in DATASETS:
        g = get_dataset(name, scale=scale)
        row = {"dataset": name}
        for method in METHODS:
            if method == "georeach":
                continue
            nb = index_nbytes(build_index(g, method))
            row[method] = (
                f"{nb['total'] / 1e6:.1f} "
                f"({nb['rtree'] / 1e6:.1f}/{nb['aux'] / 1e6:.1f})"
            )
        rows.append(row)
    return rows


def check_claims(t3: List[Dict], t4raw: List[Dict]) -> List[str]:
    """The paper's headline claims, asserted on our data."""
    out = []
    for row in t3:
        fastest_3d = min(row["3dreach"], row["3dreach-rev"])
        ok = all(
            row[m] < fastest_3d
            for m in ("2dreach", "2dreach-comp", "2dreach-pointer")
        )
        out.append(
            f"T3 {row['dataset']}: all 2DReach builds faster than "
            f"3DReach(-Rev): {'PASS' if ok else 'FAIL'}"
        )
    for row in t4raw:
        sizes = {m: row[m]["total"] for m in row if m != "dataset"}
        smallest = min(sizes, key=sizes.get)
        ok = smallest == "2dreach-pointer"
        out.append(
            f"T4 {row['dataset']}: 2DReach-Pointer smallest index "
            f"({'PASS' if ok else f'FAIL: {smallest}'})"
        )
    return out


def table4_raw(scale: float = BENCH_SCALE) -> List[Dict]:
    rows = []
    for name in DATASETS:
        g = get_dataset(name, scale=scale)
        row = {"dataset": name}
        for method in METHODS:
            if method == "georeach":
                continue
            row[method] = index_nbytes(build_index(g, method))
        rows.append(row)
    return rows
