"""Sharding rules, ZeRO-1, small-mesh jit execution, elastic reshard."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_arch
from repro.distributed import (
    MeshAxes,
    lm_param_spec,
    opt_state_specs,
    param_specs,
    reshard,
    zero1_specs,
)
from repro.models.lm import init_params
from repro.train import adamw_init


def _axes():
    return MeshAxes(data=("data",), model="model")


def test_lm_rules_shard_the_big_things():
    axes = _axes()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_arch("gemma3-12b").make_config()  # full-size shapes
    sds = jax.eval_shape(lambda k: init_params(k, cfg),
                         jax.random.PRNGKey(0))
    # pretend model axis is 16 for divisibility checks
    specs = jax.tree_util.tree_map_with_path(
        lambda path, x: lm_param_spec(
            "/".join(str(getattr(k, 'key', k)) for k in path),
            x.shape, axes, 16),
        sds)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    d = {"/".join(str(getattr(k, "key", k)) for k in p): s
         for p, s in flat}
    emb = [v for k, v in d.items() if k.endswith("embed/emb")][0]
    assert "model" in str(emb)
    wq = [v for k, v in d.items() if k.endswith("attn/wq")][0]
    assert "model" in str(wq)  # 16 heads * 256 = 4096 divisible
    norm = [v for k, v in d.items() if "ln_f" in k][0]
    assert "model" not in str(norm)


def test_rules_fall_back_on_indivisible():
    axes = _axes()
    # gemma2: 8 heads * 256 = 2048 % 16 == 0 -> attention shards;
    # but a fake 17-way model axis must fall back everywhere
    cfg = get_arch("gemma2-2b").make_config()
    sds = jax.eval_shape(lambda k: init_params(k, cfg),
                         jax.random.PRNGKey(0))
    specs = jax.tree_util.tree_map_with_path(
        lambda path, x: lm_param_spec(
            "/".join(str(getattr(k, 'key', k)) for k in path),
            x.shape, axes, 17),
        sds)
    for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        assert "model" not in str(s)


def test_zero1_adds_data_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    axes = _axes()
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((3,))}
    pspecs = {"w": P(None, "model"), "b": P()}
    # pretend data axis is 16
    import repro.distributed.sharding as sh

    out = sh.zero1_specs.__wrapped__ if hasattr(sh.zero1_specs, "__wrapped__") else None
    specs = sh.zero1_specs(params, pspecs, axes, mesh)
    # with data size 1 nothing changes
    assert str(specs["w"]) == str(P(("data",), "model")) or \
        str(specs["w"]) == str(P(None, "model"))


def test_small_mesh_train_step_runs():
    """Actually execute a sharded train step on a (1,1) mesh — exercises
    with_sharding_constraint, shard_map MoE, and zero1 spec plumbing."""
    from repro.models.lm import lm_loss
    from repro.train import AdamWConfig, make_train_step

    cfg = get_arch("llama4-maverick-400b-a17b").make_config(reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    axes = _axes()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    step = make_train_step(
        lambda p, b: lm_loss(p, b, cfg, mesh=mesh,
                             act_spec=P(("data",), None, None), remat=True),
        AdamWConfig(lr=1e-3))
    with mesh:
        p2, o2, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))


def test_reshard_roundtrip():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.ones((8, 4)), "b": jnp.zeros(3)}
    specs = {"w": P(None, "model"), "b": P()}
    out = reshard(tree, mesh, specs)
    assert np.array_equal(np.asarray(out["w"]), np.ones((8, 4)))


def test_run_with_recovery_retries():
    from repro.distributed import run_with_recovery

    calls = []

    def segment(step):
        calls.append(step)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return 99

    out = run_with_recovery(segment, start_step=5, max_failures=5,
                            backoff_s=0.0)
    assert out == 99 and len(calls) == 3
