"""LM transformer configuration covering the five assigned architectures.

One flexible block implementation instantiates llama4-maverick (GQA +
interleaved MoE, top-1, 128 experts + shared), deepseek-v3 (MLA + 256
routed top-8 + shared + MTP), gemma3 (GQA 5:1 local:global), h2o-danube
(all-SWA GQA) and gemma2 (alternating local/global + logit softcaps).

``layer_schedule`` is a repeating pattern of 'L' (sliding-window) and 'G'
(global attention); MoE placement is ``first_dense`` dense layers then
MoE every ``interleave`` layers.  Layers are grouped into scan segments
(see model.py) so the compiled HLO stays flat in depth.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLASpec:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    d_shared: Optional[int] = None    # shared-expert ff dim (default d_ff)
    first_dense: int = 0              # leading dense-FFN layers
    interleave: int = 1               # MoE every k-th layer (1 = all)
    balance_factor: float = 1.25      # per-expert capacity slack
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    attn: str = "gqa"                   # "gqa" | "mla"
    mla: Optional[MLASpec] = None
    window: Optional[int] = None        # SWA width for 'L' layers
    layer_schedule: str = "G"           # repeating 'L'/'G' pattern
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    moe: Optional[MoESpec] = None
    mtp_depth: int = 0                  # deepseek multi-token prediction
    act: str = "silu"
    tie_embeddings: bool = True
    embed_scale: bool = False           # gemma multiplies embed by sqrt(d)
    norm_eps: float = 1e-6
    dtype: str = "float32"
    # attention blocking (flash-scan)
    blk_q: int = 512
    blk_k: int = 512
    attn_block_skip: bool = False   # causal block skipping (§Perf)
    loss_chunk: int = 512           # CE loss sequence chunking

    # ---- layer plan -----------------------------------------------------
    def layer_flags(self) -> List[Tuple[bool, bool]]:
        """[(is_local, is_moe)] per layer."""
        out = []
        for i in range(self.n_layers):
            is_local = self.layer_schedule[
                i % len(self.layer_schedule)
            ] == "L"
            is_moe = False
            if self.moe is not None and i >= self.moe.first_dense:
                is_moe = (i - self.moe.first_dense) % self.moe.interleave == 0
            out.append((is_local, is_moe))
        return out

    def scan_segments(self) -> List[Tuple[Tuple[Tuple[bool, bool], ...], int]]:
        """Group layers into (unit, n_repeats) segments with identical
        per-unit structure, so each segment is one ``lax.scan``."""
        flags = self.layer_flags()
        segments: List[Tuple[Tuple[Tuple[bool, bool], ...], int]] = []
        # unit length: repeat period of (schedule, moe pattern)
        import math
        period = len(self.layer_schedule)
        if self.moe is not None and self.moe.interleave > 1:
            period = math.lcm(period, self.moe.interleave)
        fd = self.moe.first_dense if self.moe is not None else 0
        if fd:
            segments.append((tuple(flags[:fd]), 1))
        rest = flags[fd:]
        n_units = len(rest) // period
        if n_units:
            segments.append((tuple(rest[:period]), n_units))
        tail = rest[n_units * period:]
        if tail:
            segments.append((tuple(tail), 1))
        return segments

    # ---- parameter counting (roofline MODEL_FLOPS) ----------------------
    def param_counts(self) -> dict:
        d, f, V = self.d_model, self.d_ff, self.vocab
        H, KV, dh = self.n_heads, self.n_kv_heads, self.head_dim
        per_layer_attn = (
            d * (self.mla.q_lora + self.mla.kv_lora + self.mla.qk_rope)
            + self.mla.q_lora * H * (self.mla.qk_nope + self.mla.qk_rope)
            + self.mla.kv_lora * H * (self.mla.qk_nope + self.mla.v_head)
            + H * self.mla.v_head * d
            if self.attn == "mla"
            else d * H * dh + 2 * d * KV * dh + H * dh * d
        )
        dense_ffn = 3 * d * f
        n_active = 0
        n_total = 0
        for (_, is_moe) in self.layer_flags():
            n_total += per_layer_attn + 2 * d
            n_active += per_layer_attn + 2 * d
            if is_moe:
                m = self.moe
                exp = 3 * d * m.d_expert
                shared = m.n_shared * 3 * d * (m.d_shared or f)
                n_total += m.n_experts * exp + shared + d * m.n_experts
                n_active += m.top_k * exp + shared + d * m.n_experts
            else:
                n_total += dense_ffn
                n_active += dense_ffn
        emb = V * d * (1 if self.tie_embeddings else 2)
        return {
            "total": n_total + emb,
            "active": n_active + emb,
            "embed": emb,
        }
