"""Fault-tolerant checkpointing: sharded npz + manifest, atomic commit.

Layout of one checkpoint:

    <dir>/step_000123/
        manifest.json          {step, n_shards, tree, time, mesh: logical}
        shard_00000.npz        flattened path->array chunks

Properties the 1000-node posture needs:

* **Atomic commit** — writes land in ``step_k.tmp-<pid>``; the rename to
  ``step_k`` is the commit point, so a killed host never leaves a
  half-checkpoint that restore could pick up.
* **Bounded async** — ``CheckpointManager.save_async`` hands the host
  copy to a single background writer (queue depth 1): training never
  blocks on disk, but at most one checkpoint of memory is pinned
  (straggler mitigation without unbounded buffering).
* **Logical layout** — arrays are stored unsharded (gathered); restore
  re-shards onto whatever mesh the job restarts with (elastic scaling:
  checkpoints are mesh-shape independent; see elastic.py).
* **Step-keyed data** — pipelines are deterministic in (seed, step), so
  restore needs no data-loader state.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax


SHARD_BYTES = 512 * 1024 * 1024


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            # npz cannot store ml_dtypes; widen losslessly, restore casts
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Blocking save with atomic rename commit. Returns the final path."""
    flat = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = f"{final}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    shards: List[Dict[str, np.ndarray]] = [{}]
    size = 0
    for k, v in flat.items():
        if size > SHARD_BYTES:
            shards.append({})
            size = 0
        shards[-1][k] = v
        size += v.nbytes
    for i, sh in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i:05d}.npz"),
                 **{k.replace("/", "|"): v for k, v in sh.items()})
    manifest = {
        "step": step,
        "n_shards": len(shards),
        "keys": list(flat.keys()),
        "time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # commit point
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like``; returns (tree, manifest).

    Raises FileNotFoundError when no committed checkpoint exists.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for i in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{i:05d}.npz")) as z:
            for k in z.files:
                flat[k.replace("|", "/")] = z[k]
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, ref in paths:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in p
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if hasattr(ref, "dtype"):
            arr = arr.astype(ref.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Bounded-async writer: one background thread, queue depth 1."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                save_checkpoint(self.directory, step, tree, extra)
                self._gc()
            except BaseException as e:   # surfaced on next save/close
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp" not in n
            and os.path.isdir(os.path.join(self.directory, n))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None):
        if self._err is not None:
            err, self._err = self._err, None
            raise err
        host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now
        self._q.put((step, host_tree, extra))       # blocks if one pending

    def wait(self):
        self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
