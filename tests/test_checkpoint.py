"""Checkpointing + fault tolerance: roundtrip, atomicity, crash-resume."""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.distributed import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (32, 8)),
        "nested": {"b": jnp.arange(7), "c": jnp.float32(3.5)},
        "list": [jnp.ones(3), jnp.zeros((2, 2), jnp.bfloat16)],
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 5
    r, man = restore_checkpoint(str(tmp_path), t)
    assert man["step"] == 5 and man["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_latest_ignores_uncommitted(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    # a torn checkpoint: directory without manifest
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 3
    r, man = restore_checkpoint(str(tmp_path), _tree())
    assert man["step"] == 3


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.close()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path)
        if n.startswith("step_"))
    assert steps == [3, 4]
    r, _ = restore_checkpoint(str(tmp_path), _tree())
    assert np.array_equal(np.asarray(r["a"]),
                          np.asarray(_tree(4)["a"]))


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "none"), _tree())


@pytest.mark.slow
def test_crash_resume_end_to_end(tmp_path):
    """Kill training mid-run (injected crash), resume, reach the same
    final loss as an uninterrupted run — the restart-on-node-failure
    path of launch/train.py."""
    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def run(args):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch", "din",
             "--steps", "30", "--ckpt-every", "10"] + args,
            env=env, cwd=root, capture_output=True, text=True)

    d1 = str(tmp_path / "crash")
    r1 = run(["--ckpt-dir", d1, "--crash-at", "15"])
    assert r1.returncode != 0 and "injected crash" in r1.stderr
    assert latest_step(d1) == 10
    r2 = run(["--ckpt-dir", d1, "--resume"])
    assert r2.returncode == 0, r2.stderr
    assert "resumed from step 10" in r2.stdout
    # uninterrupted reference
    d2 = str(tmp_path / "clean")
    r3 = run(["--ckpt-dir", d2])
    assert r3.returncode == 0, r3.stderr

    def final_loss(out):
        lines = [l for l in out.splitlines() if "step 30 loss" in l]
        return float(lines[-1].split("loss")[1].split("(")[0])

    # deterministic step-keyed data -> identical trajectories
    assert abs(final_loss(r2.stdout) - final_loss(r3.stdout)) < 1e-5
