from .checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from .elastic import reshard, run_with_recovery
from .sharding import (
    MeshAxes,
    batch_spec,
    lm_param_spec,
    mlp_param_spec,
    named,
    opt_state_specs,
    param_specs,
    zero1_specs,
)
