"""GNN internals: SO(3) machinery, equivariance, triplets, samplers."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.gnn import equiformer_v2
from repro.models.gnn.dimenet import build_triplets
from repro.models.gnn.so3 import real_sph_harm_np, rot_to_z, wigner_d_stack


def _rand_rot(rng):
    A = rng.standard_normal((3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q


@pytest.mark.parametrize("l_max", [1, 3, 6])
def test_wigner_rotates_sph_harm(l_max):
    rng = np.random.default_rng(0)
    Q = _rand_rot(rng)
    n = rng.standard_normal((30, 3))
    n /= np.linalg.norm(n, axis=1, keepdims=True)
    D = wigner_d_stack(jnp.asarray(np.broadcast_to(Q, (30, 3, 3))), l_max)
    Y = real_sph_harm_np(l_max, n)
    Yr = real_sph_harm_np(l_max, n @ Q.T)
    for l in range(l_max + 1):
        got = np.einsum("eab,eb->ea", np.asarray(D[l]), Y[l])
        np.testing.assert_allclose(got, Yr[l], atol=1e-5)


def test_wigner_homomorphism_and_orthogonality():
    rng = np.random.default_rng(1)
    A, B = _rand_rot(rng), _rand_rot(rng)
    L = 4
    DA = wigner_d_stack(jnp.asarray(A)[None], L)
    DB = wigner_d_stack(jnp.asarray(B)[None], L)
    DAB = wigner_d_stack(jnp.asarray(A @ B)[None], L)
    for l in range(L + 1):
        np.testing.assert_allclose(
            np.asarray(DA[l][0] @ DB[l][0]), np.asarray(DAB[l][0]),
            atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(DA[l][0] @ DA[l][0].T), np.eye(2 * l + 1),
            atol=1e-5)


def test_rot_to_z():
    rng = np.random.default_rng(2)
    d = rng.standard_normal((50, 3))
    d /= np.linalg.norm(d, axis=1, keepdims=True)
    R = np.asarray(rot_to_z(jnp.asarray(d, jnp.float32)))
    np.testing.assert_allclose(
        np.einsum("eij,ej->ei", R, d), np.broadcast_to([0, 0, 1], (50, 3)),
        atol=1e-5)


def test_equiformer_invariance():
    """Scalar (energy) output is exactly invariant under global rotation."""
    rng = np.random.default_rng(3)
    N, E = 14, 40
    pos = jnp.asarray(rng.standard_normal((N, 3)), jnp.float32)
    src = rng.integers(0, N, E)
    dst = (src + 1 + rng.integers(0, N - 1, E)) % N
    base = dict(pos=pos, edge_src=jnp.asarray(src, jnp.int32),
                edge_dst=jnp.asarray(dst, jnp.int32),
                species=jnp.asarray(rng.integers(0, 5, N), jnp.int32))
    cfg = equiformer_v2.EquiformerV2Config(
        n_layers=2, d_hidden=16, l_max=3, m_max=2, n_heads=4, n_rbf=8)
    p = equiformer_v2.init_params(jax.random.PRNGKey(0), cfg)
    e0 = float(equiformer_v2.apply(p, base, cfg))
    for seed in range(3):
        Q = _rand_rot(np.random.default_rng(10 + seed))
        e1 = float(equiformer_v2.apply(
            p, dict(base, pos=pos @ jnp.asarray(Q.T, jnp.float32)), cfg))
        assert abs(e0 - e1) < 1e-3 * max(1.0, abs(e0)), (e0, e1)


def test_build_triplets_oracle():
    rng = np.random.default_rng(4)
    N, E = 8, 20
    src = rng.integers(0, N, E)
    dst = (src + 1 + rng.integers(0, N - 1, E)) % N
    kj, ji, mask = build_triplets(src, dst, N, 4096)
    got = {(int(a), int(b)) for a, b, m in zip(kj, ji, mask) if m}
    want = set()
    for e1 in range(E):           # k -> j
        for e2 in range(E):       # j -> i
            if dst[e1] == src[e2] and src[e1] != dst[e2]:
                want.add((e1, e2))
    assert got == want


def test_neighbor_sampler():
    from repro.core.graph import build_csr
    from repro.data import sample_blocks

    rng = np.random.default_rng(5)
    n = 300
    edges = rng.integers(0, n, size=(3000, 2))
    csr = build_csr(n, edges)
    blk = sample_blocks(csr, np.arange(16), (5, 3),
                        np.random.default_rng(0))
    assert blk.n_seeds == 16
    # fanout bounds per layer
    s1, d1 = blk.layers[0]
    assert len(s1) <= 16 * 5
    # every sampled edge exists in the graph
    eset = {(int(a), int(b)) for a, b in edges}
    for src_l, dst_l in blk.layers:
        for s, d in zip(src_l, dst_l):
            u = int(blk.node_ids[d])
            v = int(blk.node_ids[s])
            assert (u, v) in eset
    padded = sample_blocks(csr, np.arange(16), (5, 3),
                           np.random.default_rng(0), pad_to=512)
    assert padded.n_nodes == 512
    for src_l, dst_l in padded.layers:
        assert len(src_l) & (len(src_l) - 1) == 0  # power of two
