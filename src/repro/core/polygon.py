"""Convex-polygon query regions — the paper's footnote 2 extension.

"Without loss of generality, this paper assumes an axis-aligned rectangle
for querying. However, the proposed method can be easily extended to
handle other types of geometric objects, e.g., polygons."  This module
makes that concrete for 2DReach: the R-tree probe runs with the
polygon's bounding box (the MBR machinery is unchanged), candidate hits
are then filtered by exact point-in-convex-polygon half-plane tests —
all vectorised.

    ans = polygon_query(index, u, vertices)      # (k, 2) CCW convex hull
"""

from __future__ import annotations

from typing import Iterable

import numpy as np

from .oracle import reachable_mask
from .rtree import query_host_collect
from .two_d_reach import TwoDReachIndex


def _ccw(vertices: np.ndarray) -> np.ndarray:
    """Ensure counter-clockwise orientation."""
    v = np.asarray(vertices, dtype=np.float64).reshape(-1, 2)
    area2 = np.sum(
        v[:, 0] * np.roll(v[:, 1], -1) - np.roll(v[:, 0], -1) * v[:, 1]
    )
    return v if area2 >= 0 else v[::-1]


def points_in_convex_polygon(pts: np.ndarray, vertices: np.ndarray
                             ) -> np.ndarray:
    """(n, 2) points inside/on a convex polygon (any vertex order)."""
    v = _ccw(vertices)
    pts = np.asarray(pts, dtype=np.float64).reshape(-1, 2)
    inside = np.ones(len(pts), dtype=bool)
    for i in range(len(v)):
        a, b = v[i], v[(i + 1) % len(v)]
        cross = (b[0] - a[0]) * (pts[:, 1] - a[1]) \
            - (b[1] - a[1]) * (pts[:, 0] - a[0])
        inside &= cross >= -1e-9
    return inside


def polygon_bbox(vertices: np.ndarray) -> np.ndarray:
    v = np.asarray(vertices, dtype=np.float32).reshape(-1, 2)
    return np.array(
        [v[:, 0].min(), v[:, 1].min(), v[:, 0].max(), v[:, 1].max()],
        dtype=np.float32,
    )


def polygon_query(index: TwoDReachIndex, u: int, vertices) -> bool:
    """RangeReach with a convex polygon region (Alg. 2 + exact filter)."""
    bbox = polygon_bbox(vertices)
    if index.excluded[u]:
        return bool(points_in_convex_polygon(
            index.coords[u][None], vertices)[0])
    tid = int(index.lookup_tree(np.array([u]))[0])
    if tid < 0:
        return False
    # bbox prefilter through the R-tree, exact half-plane postfilter
    cand = query_host_collect(index.forest, tid, bbox)
    if len(cand) == 0:
        return False
    return bool(points_in_convex_polygon(
        index.coords[cand], vertices).any())


def polygon_oracle(graph, u: int, vertices) -> bool:
    seen = reachable_mask(graph, u)
    ids = np.nonzero(seen & graph.spatial_mask)[0]
    if len(ids) == 0:
        return False
    return bool(points_in_convex_polygon(
        graph.coords[ids], vertices).any())
