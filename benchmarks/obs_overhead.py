"""CI gate: disabled ``repro.obs`` instrumentation costs <2%.

The observability layer promises that, when disabled, its hot-path hooks
are a single attribute check returning a shared no-op context manager.
A naive A/B wall-clock comparison of instrumented-vs-stripped serving is
too noisy to gate on (the effect is well under run-to-run variance), so
this bench gates **analytically**:

1. measure the per-call cost of a *disabled* ``span()`` directly, by
   timing a tight loop of them (amortising the loop overhead away);
2. serve a real smoke batch stream with obs disabled and measure the
   per-batch wall time;
3. count how many ``span()``/``_obs_batch`` hook sites one batch
   actually crosses (from one *enabled* batch's event count);
4. assert  hooks_per_batch x cost_per_disabled_hook  <  2% of the
   measured per-batch time.

This bounds the disabled overhead with the measured per-hook cost while
staying deterministic enough for CI.  The enabled-path cost is reported
too (informational — enabling obs is an explicit opt-in).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro import obs
from repro.core import QueryEngine, build_2dreach
from repro.data import get_dataset, workload
from repro.obs import trace_context
from repro.obs.audit import ExactnessAuditor
from repro.resilience.faults import INJECTOR, FaultPlan, fault_point, inject

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "results", "obs_overhead.json")

GATE = 0.02          # disabled instrumentation must stay under 2%
SPAN_CALLS = 200_000


def disabled_span_cost_s() -> float:
    """Per-call seconds of a disabled ``span()`` (enter + exit)."""
    assert not obs.enabled()
    # amortise timer + loop overhead over a large call count; take the
    # best of several rounds (minimum filters scheduler noise)
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _i in range(SPAN_CALLS):
            with obs.span("overhead.probe"):
                pass
        best = min(best, (time.perf_counter() - t0) / SPAN_CALLS)
    return best


def batch_time_s(eng, us, rects, repeats=20) -> float:
    """Median per-batch seconds with obs disabled (warm shapes)."""
    eng.query_batch(us, rects)   # warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        eng.query_batch(us, rects)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def hooks_per_batch(eng, us, rects) -> int:
    """Span events one engine batch records when enabled — every one of
    them is a disabled-path hook site (the registry recordings in
    ``_obs_batch`` sit behind the same gate, counted via +1)."""
    obs.enable()
    n0 = len(obs.TRACER)
    eng.query_batch(us, rects)
    n = len(obs.TRACER) - n0
    obs.disable()
    return n + 1          # + the gated _obs_batch metrics block


def disabled_fault_point_cost_s() -> float:
    """Per-call seconds of a disabled ``fault_point()`` — the same
    single-attribute-check promise the obs spans make."""
    assert not INJECTOR.enabled
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _i in range(SPAN_CALLS):
            fault_point("overhead.probe")
        best = min(best, (time.perf_counter() - t0) / SPAN_CALLS)
    return best


def fault_hooks_per_batch(eng, us, rects) -> int:
    """Fault-point crossings one engine batch makes, counted by running
    a batch with an *empty* plan installed (every hit is a no-op but
    still counted by the injector)."""
    with inject(FaultPlan()):
        n0 = INJECTOR.hits_total
        eng.query_batch(us, rects)
        n = INJECTOR.hits_total - n0
    return n


def disabled_trace_cost_s(batch: int) -> float:
    """Per-batch seconds of the frontend's *disabled-path* causal-trace
    plumbing: one tracer-enabled check per submit returning the shared
    null context (minting and the scope push only happen enabled).
    Measured differentially — the same list build without the gate is
    subtracted — so loop machinery cancels and only the branch +
    attribute read remain."""
    assert not obs.enabled()
    tr = obs.TRACER
    null = trace_context.NULL
    rounds = max(SPAN_CALLS // max(batch, 1), 50)

    def best_of(body):
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            for _i in range(rounds):
                body()
            best = min(best, (time.perf_counter() - t0) / rounds)
        return best

    gated = best_of(lambda: [trace_context.mint(u=j) if tr.enabled
                             else null for j in range(batch)])
    base = best_of(lambda: [null for _j in range(batch)])
    return max(0.0, gated - base)


def enabled_mint_cost_s(batch: int) -> float:
    """Per-batch seconds of minting ``batch`` contexts + one scope
    push/pop — the *enabled* (opted-in) cost, reported informationally
    next to the enabled span cost."""
    rounds = max(SPAN_CALLS // max(batch, 1), 50)
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _i in range(rounds):
            ctxs = [trace_context.mint(u=j) for j in range(batch)]
            with trace_context.scope(ctxs):
                pass
        best = min(best, (time.perf_counter() - t0) / rounds)
    return best


def disabled_observe_cost_s(idx, us, rects) -> float:
    """Per-batch seconds of a *disabled* auditor ``observe`` (sampling
    off — the default), offered the whole batch."""
    aud = ExactnessAuditor(idx, sample=0.0)
    ans = np.zeros(len(us), dtype=bool)
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _i in range(2000):
            aud.observe(us, rects, ans)
        best = min(best, (time.perf_counter() - t0) / 2000)
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="same scale either way — flag kept for CI "
                         "symmetry with the perf benches")
    ap.parse_args()

    g = get_dataset("yelp", scale=0.1)
    idx = build_2dreach(g, variant="comp")
    eng = QueryEngine(idx)
    us, rects = workload(g, 256, extent_ratio=0.05, seed=11)

    obs.disable()
    per_hook = disabled_span_cost_s()
    per_batch = batch_time_s(eng, us, rects)
    hooks = hooks_per_batch(eng, us, rects)
    overhead = hooks * per_hook / per_batch
    fp_hook = disabled_fault_point_cost_s()
    fp_hooks = fault_hooks_per_batch(eng, us, rects)
    fp_overhead = fp_hooks * fp_hook / per_batch
    trace_batch = disabled_trace_cost_s(len(us))
    mint_batch = enabled_mint_cost_s(len(us))
    observe_batch = disabled_observe_cost_s(idx, us, rects)
    trace_overhead = (trace_batch + observe_batch) / per_batch

    report = {
        "disabled_span_cost_ns": per_hook * 1e9,
        "hooks_per_batch": hooks,
        "batch_time_us_disabled": per_batch * 1e6,
        "disabled_overhead_fraction": overhead,
        "disabled_fault_point_cost_ns": fp_hook * 1e9,
        "fault_hooks_per_batch": fp_hooks,
        "disabled_fault_overhead_fraction": fp_overhead,
        "disabled_trace_gate_us_per_batch": trace_batch * 1e6,
        "enabled_mint_us_per_batch": mint_batch * 1e6,
        "disabled_audit_observe_us_per_batch": observe_batch * 1e6,
        "trace_overhead_fraction": trace_overhead,
        "gate": GATE,
        "passed": bool(overhead < GATE and fp_overhead < GATE
                       and trace_overhead < GATE),
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))
    assert overhead < GATE, (
        f"disabled obs instrumentation costs {overhead * 100:.2f}% of a "
        f"batch ({hooks} hooks x {per_hook * 1e9:.0f}ns vs "
        f"{per_batch * 1e6:.0f}us) — over the {GATE * 100:.0f}% gate")
    assert fp_overhead < GATE, (
        f"disabled fault hooks cost {fp_overhead * 100:.2f}% of a batch "
        f"({fp_hooks} hooks x {fp_hook * 1e9:.0f}ns vs "
        f"{per_batch * 1e6:.0f}us) — over the {GATE * 100:.0f}% gate")
    assert trace_overhead < GATE, (
        f"disabled trace gate + disabled audit observe cost "
        f"{trace_overhead * 100:.2f}% of a batch "
        f"({(trace_batch + observe_batch) * 1e6:.1f}us vs "
        f"{per_batch * 1e6:.0f}us) — over the {GATE * 100:.0f}% gate")


if __name__ == "__main__":
    main()
