import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf variant lowering: re-compile one LM cell with config overrides
and report the roofline-term deltas vs the recorded baseline.

    PYTHONPATH=src python -m repro.launch.perf_lm --arch gemma3-12b \
        --shape train_4k --set attn_block_skip=true --set loss_chunk=256 \
        --tag blockskip

Nested overrides use dots: --set moe.balance_factor=1.0
Results: results/perf/<arch>__<shape>__<tag>.json
"""

import argparse
import dataclasses
import json
import time

import jax

from ..analysis import analyze_hlo
from ..configs import get_arch
from ..configs.base import (
    LM_SHAPES,
    _lm_decode_builder,
    _lm_prefill_builder,
    _lm_train_builder,
)
from .mesh import make_production_mesh, mesh_axes

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "results", "perf",
)


def parse_val(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def apply_overrides(cfg, overrides):
    nested = {}
    flat = {}
    for k, v in overrides.items():
        if "." in k:
            a, b = k.split(".", 1)
            nested.setdefault(a, {})[b] = v
        else:
            flat[k] = v
    for a, sub in nested.items():
        flat[a] = dataclasses.replace(getattr(cfg, a), **sub)
    return dataclasses.replace(cfg, **flat)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)

    spec = get_arch(args.arch)
    base_cfg = spec.make_config()
    cfg_fn = lambda: apply_overrides(base_cfg, overrides)  # noqa: E731
    s = LM_SHAPES[args.shape]
    if s["kind"] == "train":
        builder = _lm_train_builder(cfg_fn, s["seq"], s["batch"])
    elif s["kind"] == "prefill":
        builder = _lm_prefill_builder(cfg_fn, s["seq"], s["batch"])
    else:
        builder = _lm_decode_builder(cfg_fn, s["seq"], s["batch"])

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    axes = mesh_axes(args.multi_pod)
    t0 = time.perf_counter()
    fn, cell_args = builder(mesh, axes)
    with mesh:
        compiled = jax.jit(fn).lower(*cell_args).compile()
    stats = analyze_hlo(compiled.as_text())
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_size_in_bytes": int(ma.argument_size_in_bytes),
            "output_size_in_bytes": int(ma.output_size_in_bytes),
            "temp_size_in_bytes": int(ma.temp_size_in_bytes),
        }
    except Exception:
        mem = {}
    rec = {
        "arch": args.arch, "shape": args.shape, "tag": args.tag,
        "overrides": overrides, "hlo_stats": stats,
        "memory_analysis": mem, "n_devices": mesh.size,
        "mesh": "pod2x16x16" if args.multi_pod else "pod16x16",
        "t_total_s": round(time.perf_counter() - t0, 1),
        "ok": True,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(
        RESULTS_DIR, f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({
        "tag": args.tag,
        "flops": stats["flops"],
        "hbm_floor": stats.get("hbm_floor_bytes"),
        "coll": stats["collective_bytes"],
        "temp_GB": round(mem.get("temp_size_in_bytes", 0) / 1e9, 1),
    }))


if __name__ == "__main__":
    main()
