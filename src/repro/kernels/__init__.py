"""Pallas TPU kernels for the paper's compute hot-spots.

range_query/  — batched AABB range probe over packed R-tree leaves
                (the RangeReach online hot path).
bitset_mm/    — packed uint32 boolean OR-AND matmul (the Alg. 1 closure
                build step as a semiring matmul; + MXU variant in ops).
forest_build/ — segmented-MBR reduction (the R-tree bulk-load level
                step; also builds the query engines' tile pyramids).
segment_bag/  — fused EmbeddingBag gather+segment-sum (recsys/GNN
                substrate; JAX has no native EmbeddingBag).

Each: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper), ref.py (pure-jnp oracle). Validated vs ref in interpret mode;
see tests/test_kernels_*.py for the shape/dtype sweeps.
"""
