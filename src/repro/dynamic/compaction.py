"""Compaction policy + background compactor for `DynamicIndex`.

Query latency over a `DynamicIndex` degrades with overlay size (each
query pays one base-index probe per "entry component" the delta edges
open, plus the staging-set probe).  Compaction rebuilds the static index
over the materialised mutated graph and swaps it in, resetting the
overlay — restoring fresh-build latency at an amortised cost the policy
bounds.

``CompactionPolicy`` is a pure threshold test; ``Compactor`` runs the
rebuild either inline (``background=False``) or on a daemon thread.  The
background path snapshots the graph and an op-log cut under the index
lock, builds without the lock (queries and mutations keep flowing), and
swaps atomically: mutations that arrived during the build are replayed
into the fresh overlay, so no update is ever lost or double-applied.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional


@dataclasses.dataclass
class CompactionPolicy:
    """Size/staleness thresholds that trigger a compaction.

    Any threshold set to ``None`` is ignored.  ``updates_since_compaction``
    is the staleness guard: even a slow trickle of tiny updates eventually
    forces a rebuild so the overlay's auxiliary structures (union-find,
    reach-cache) cannot grow without bound.
    """

    max_overlay_edges: Optional[int] = 4096
    max_staged: Optional[int] = 1024
    max_updates: Optional[int] = 16384
    background: bool = False

    def should_compact(self, n_overlay_edges: int, n_staged: int,
                       updates_since_compaction: int) -> bool:
        if self.max_overlay_edges is not None \
                and n_overlay_edges >= self.max_overlay_edges:
            return True
        if self.max_staged is not None and n_staged >= self.max_staged:
            return True
        if self.max_updates is not None \
                and updates_since_compaction >= self.max_updates:
            return True
        return False


NEVER = CompactionPolicy(
    max_overlay_edges=None, max_staged=None, max_updates=None
)


class Compactor:
    """Owns the (optional) background build thread of one DynamicIndex.

    A build that raises latches ``last_error``: policy-driven triggers
    stop retrying (no rebuild storm on a deterministic failure) until an
    explicit ``compact()`` clears the latch, and ``join`` re-raises so a
    caller waiting on the swap cannot mistake the failure for success.
    """

    def __init__(self, index) -> None:
        self._index = index
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def trigger(self, background: bool) -> bool:
        """Start (or run inline) one compaction; returns False when a
        background build is already in flight."""
        idx = self._index
        # the running-check and thread assignment must be atomic with the
        # snapshot/cut capture: two racing triggers would otherwise both
        # start builds, and the loser's swap would replay a stale op-log
        # tail against the wrong base
        with idx._lock:
            if self.running:
                return False
            self.last_error = None  # explicit trigger clears the latch
            if not background:
                self._index._compact_sync()
                return True
            snapshot, cut = idx._begin_compaction()

            def _build() -> None:
                t0 = time.perf_counter()
                try:
                    built = idx._build_static(snapshot)
                    idx._finish_compaction(snapshot, built, cut,
                                           time.perf_counter() - t0)
                except BaseException as e:  # noqa: BLE001 - latched for caller
                    self.last_error = e
                    with idx._lock:
                        idx.stats["n_compaction_failures"] = (
                            idx.stats.get("n_compaction_failures", 0) + 1
                        )

            self._thread = threading.Thread(
                target=_build, name="repro-dynamic-compaction", daemon=True
            )
            self._thread.start()
        return True

    def join(self, timeout: Optional[float] = None) -> None:
        t = self._thread
        if t is not None:
            t.join(timeout)
        if self.last_error is not None:
            raise RuntimeError(
                "background compaction failed; the overlay is intact and "
                "an explicit compact() will retry"
            ) from self.last_error
