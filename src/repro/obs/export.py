"""OpenMetrics / Prometheus text exposition of the metrics registry.

``serve.py --obs`` writes ``metrics.prom`` next to the trace artifacts
so a run's final state is scrapeable by anything that speaks the
Prometheus text format (promtool, VictoriaMetrics import, Grafana agent
one-shot).  Zero dependencies: the format is lines.

Mapping (names sanitised to ``[a-zA-Z0-9_:]``, dots become
underscores, everything prefixed ``repro_``):

* :class:`~repro.obs.metrics.Counter`  -> ``counter``
  (``repro_<name>_total``);
* :class:`~repro.obs.metrics.Gauge`    -> ``gauge`` plus a sibling
  ``..._hwm`` gauge for the high-water mark;
* :class:`~repro.obs.metrics.Histogram`-> ``summary``: ``quantile``
  labelled samples from the one Histogram implementation, plus
  ``_sum`` / ``_count``.

Histograms carrying (trace id, value) exemplar reservoirs annotate each
quantile sample with one exemplar from the bucket the quantile falls in
(OpenMetrics exemplar syntax — `` # {trace_id="..."} value``), so "show
me an actual p99 request" survives the exposition round-trip: scrape
the quantile, read the trace id, resolve it in the flight bundle.

The output ends with the OpenMetrics ``# EOF`` terminator and is
parse-checked line-by-line in ``tests/test_workload.py``.
"""

from __future__ import annotations

import math
import re
from typing import Optional, Tuple

from . import metrics as _metrics

PREFIX = "repro_"
_SANITISE = re.compile(r"[^a-zA-Z0-9_:]")
QUANTILES: Tuple[float, ...] = (0.5, 0.95, 0.99)


def metric_name(name: str, prefix: str = PREFIX) -> str:
    """Sanitised exposition-format metric family name."""
    out = prefix + _SANITISE.sub("_", name)
    if out[0].isdigit():
        out = "_" + out
    return out


def _fmt(v: float) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def to_openmetrics(registry: Optional[_metrics.Registry] = None,
                   prefix: str = PREFIX) -> str:
    """The registry's current state in OpenMetrics text format."""
    reg = registry if registry is not None else _metrics.REGISTRY
    lines = []
    for name, m in reg.items():
        n = metric_name(name, prefix)
        if isinstance(m, _metrics.Counter):
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n}_total {_fmt(m.value)}")
        elif isinstance(m, _metrics.Gauge):
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {_fmt(m.value)}")
            lines.append(f"# TYPE {n}_hwm gauge")
            lines.append(f"{n}_hwm {_fmt(m.max)}")
        elif isinstance(m, _metrics.Histogram):
            lines.append(f"# TYPE {n} summary")
            if m.count:
                for q in QUANTILES:
                    v = m.percentile(q * 100.0)
                    ln = f'{n}{{quantile="{q:g}"}} {_fmt(v)}'
                    ex = m.exemplars_near(v)
                    if ex:
                        tid, ev = ex[-1]
                        ln += f' # {{trace_id="{tid}"}} {_fmt(ev)}'
                    lines.append(ln)
            lines.append(f"{n}_sum {_fmt(m.sum)}")
            lines.append(f"{n}_count {_fmt(m.count)}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def write_prom(path: str,
               registry: Optional[_metrics.Registry] = None) -> str:
    """Write :func:`to_openmetrics` to ``path``; returns the path."""
    with open(path, "w") as f:
        f.write(to_openmetrics(registry))
    return path
