"""Architecture/cell registry used by smoke tests, dry-runs and rooflines.

An ``ArchSpec`` names an architecture, its family, a config factory (full
or reduced), and its shape cells.  A ``Cell`` knows how to produce, for a
given mesh:

    fn         — the step to lower (train_step / prefill / decode_step /
                 serve / retrieval scoring)
    args       — matching ShapeDtypeStructs **with NamedShardings
                 attached** (no allocation; the dry-run contract)

Graph-shape dims are rounded up to multiples of 512 so every sharded dim
divides the (16,16)/(2,16,16) meshes — arena padding with masks, exactly
like the R-tree arenas in the core library.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..distributed.sharding import (
    MeshAxes,
    lm_param_spec,
    mlp_param_spec,
    opt_state_specs,
    param_specs,
)
from ..train.optim import AdamWConfig, adamw_init
from ..train.steps import make_train_step


def round_up(x: int, k: int = 512) -> int:
    return ((x + k - 1) // k) * k


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec)
    )


def _attach(sds_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
        ),
        sds_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    builder: Callable[[Mesh, MeshAxes], Tuple[Callable, Tuple]]

    def build(self, mesh: Mesh, axes: MeshAxes):
        return self.builder(mesh, axes)


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str                       # lm | gnn | recsys
    make_config: Callable[..., Any]   # make_config(reduced=False)
    cells: Dict[str, Cell]
    notes: str = ""


# ==========================================================================
# LM family
# ==========================================================================

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _lm_params_sds(cfg, mesh, axes):
    from ..models.lm import init_params

    sds = jax.eval_shape(partial(init_params, cfg=cfg), jax.random.PRNGKey(0))
    specs = param_specs(sds, lm_param_spec, axes, mesh)
    return _attach(sds, specs, mesh), specs


def _lm_train_builder(cfg_fn, seq, batch):
    def build(mesh: Mesh, axes: MeshAxes):
        from ..models.lm import lm_loss

        cfg = cfg_fn()
        p_sds, pspecs = _lm_params_sds(cfg, mesh, axes)
        o_sds = jax.eval_shape(adamw_init, p_sds)
        ospecs = opt_state_specs(o_sds, p_sds, pspecs, axes, mesh)
        o_sds = _attach(o_sds, ospecs, mesh)
        bspec = P(axes.data, None)
        b_sds = {
            "tokens": _sds((batch, seq), jnp.int32, mesh, bspec),
            "labels": _sds((batch, seq), jnp.int32, mesh, bspec),
        }
        act_spec = P(axes.data, None, None)
        opt_cfg = AdamWConfig()
        step = make_train_step(
            lambda p, b: lm_loss(
                p, b, cfg, mesh=mesh, act_spec=act_spec, remat=True
            ),
            opt_cfg,
        )
        return step, (p_sds, o_sds, b_sds)

    return build


def _lm_prefill_builder(cfg_fn, seq, batch):
    def build(mesh: Mesh, axes: MeshAxes):
        from ..models.lm import prefill

        cfg = cfg_fn()
        p_sds, _ = _lm_params_sds(cfg, mesh, axes)
        t_sds = _sds((batch, seq), jnp.int32, mesh, P(axes.data, None))
        act_spec = P(axes.data, None, None)

        def fn(params, tokens):
            return prefill(
                params, tokens, cfg, max_len=seq, mesh=mesh,
                act_spec=act_spec,
            )

        return fn, (p_sds, t_sds)

    return build


def _lm_decode_builder(cfg_fn, seq, batch):
    def build(mesh: Mesh, axes: MeshAxes):
        from ..models.lm import decode_step, init_cache

        cfg = cfg_fn()
        p_sds, _ = _lm_params_sds(cfg, mesh, axes)
        c_sds = jax.eval_shape(
            partial(init_cache, cfg, batch, seq)
        )
        tp = axes.model_size(mesh)
        dsize = axes.data_size(mesh)

        def cache_spec(leaf_sds):
            shp = leaf_sds.shape
            if len(shp) == 0:
                return P()
            # layouts: (R, B, L, ...) stacked or (B, L, ...) unstacked
            parts = [None] * len(shp)
            bi = len(shp) - (3 if len(shp) in (3, 4) else 4)
            # find batch dim: it equals `batch`
            for i, d in enumerate(shp):
                if d == batch and batch % dsize == 0 and batch >= dsize:
                    parts[i] = axes.data
                    bi = i
                    break
            # sequence dim: first dim after batch divisible by tp
            for i in range(len(shp)):
                if parts[i] is None and i != 0 and shp[i] % tp == 0 \
                        and shp[i] >= tp and i > bi:
                    parts[i] = axes.model
                    break
            return P(*parts)

        cspecs = jax.tree.map(
            cache_spec, c_sds,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        c_sds = _attach(c_sds, cspecs, mesh)
        tok_spec = P(axes.data) if batch % dsize == 0 and batch >= dsize \
            else P()
        t_sds = _sds((batch,), jnp.int32, mesh, tok_spec)

        def fn(params, cache, token):
            return decode_step(params, cache, token, cfg, mesh=mesh)

        return fn, (p_sds, c_sds, t_sds)

    return build


def lm_cells(name: str, cfg_fn) -> Dict[str, Cell]:
    out = {}
    for shape, s in LM_SHAPES.items():
        if s["kind"] == "train":
            b = _lm_train_builder(cfg_fn, s["seq"], s["batch"])
        elif s["kind"] == "prefill":
            b = _lm_prefill_builder(cfg_fn, s["seq"], s["batch"])
        else:
            b = _lm_decode_builder(cfg_fn, s["seq"], s["batch"])
        out[shape] = Cell(arch=name, shape=shape, kind=s["kind"], builder=b)
    return out


# ==========================================================================
# GNN family
# ==========================================================================

GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n=2708, e=10556, f=1433,
                          batched=False),
    "minibatch_lg": dict(kind="train", n=1024 + 1024 * 15 + 1024 * 15 * 10,
                         e=1024 * 15 + 1024 * 15 * 10, f=602,
                         batched=False, sampled=True),
    "ogb_products": dict(kind="train", n=2_449_029, e=61_859_140, f=100,
                         batched=False),
    "molecule": dict(kind="train", n=30, e=64, f=None, batched=True,
                     batch=128),
}


def _gnn_batch_sds(arch: str, s: Dict, mesh: Mesh, axes: MeshAxes,
                   triplet_factor: int = 2):
    dsize_spec = P(axes.data)
    if s["batched"]:
        B, n, e = s["batch"], s["n"], s["e"]
        lead = dsize_spec

        def bs(shape, dtype):
            return _sds((B,) + shape, dtype, mesh, P(axes.data))

        batch = {
            "pos": bs((n, 3), jnp.float32),
            "species": bs((n,), jnp.int32),
            "edge_src": bs((e,), jnp.int32),
            "edge_dst": bs((e,), jnp.int32),
            "edge_mask": bs((e,), jnp.bool_),
            "node_mask": bs((n,), jnp.bool_),
            "energy": bs((), jnp.float32),
        }
        if arch == "dimenet":
            T = 256
            batch["id_kj"] = bs((T,), jnp.int32)
            batch["id_ji"] = bs((T,), jnp.int32)
            batch["triplet_mask"] = bs((T,), jnp.bool_)
        if arch == "graphcast":
            f = 16
            batch["feat"] = bs((n, f), jnp.float32)
            batch["target"] = bs((n, f), jnp.float32)
            batch.pop("energy")
        return batch
    n, e, f = round_up(s["n"]), round_up(s["e"]), s["f"]
    node_spec = P(axes.data)
    edge_spec = P(axes.data)
    batch = {
        "pos": _sds((n, 3), jnp.float32, mesh, node_spec),
        "feat": _sds((n, f), jnp.float32, mesh, node_spec),
        "edge_src": _sds((e,), jnp.int32, mesh, edge_spec),
        "edge_dst": _sds((e,), jnp.int32, mesh, edge_spec),
        "edge_mask": _sds((e,), jnp.bool_, mesh, edge_spec),
        "node_mask": _sds((n,), jnp.bool_, mesh, node_spec),
    }
    if arch == "graphcast":
        batch["target"] = _sds((n, f), jnp.float32, mesh, node_spec)
    else:
        batch["energy"] = _sds((), jnp.float32, mesh, P())
    if arch == "dimenet":
        T = round_up(min(triplet_factor * e, 1 << 26))
        batch["id_kj"] = _sds((T,), jnp.int32, mesh, edge_spec)
        batch["id_ji"] = _sds((T,), jnp.int32, mesh, edge_spec)
        batch["triplet_mask"] = _sds((T,), jnp.bool_, mesh, edge_spec)
    return batch


def _gnn_loss(arch: str, module, cfg, batched: bool):
    def graph_energy_loss(params, batch):
        # geometric models on feature graphs: graph-scalar regression
        pred = module.apply(params, batch, cfg)
        return ((pred - batch["energy"]) ** 2, {})

    def graphcast_loss(params, batch):
        return (module.loss_fn(params, batch, cfg), {})

    def batched_loss(params, batch):
        if arch == "graphcast":
            losses = jax.vmap(
                lambda b: module.loss_fn(params, b, cfg))(batch)
            return (losses.mean(), {})
        # molecular losses vmap internally
        return (module.loss_fn(params, batch, cfg), {})

    if batched:
        return batched_loss
    if arch == "graphcast":
        return graphcast_loss
    return graph_energy_loss


def _gnn_builder(name: str, module, cfg_fn, shape: str):
    s = GNN_SHAPES[shape]

    def build(mesh: Mesh, axes: MeshAxes):
        # feature-graph cells need d_feat wired into the config
        cfg = cfg_fn(d_feat=None if s["batched"] else s["f"],
                     shape=shape)
        p_sds = jax.eval_shape(
            partial(module.init_params, cfg=cfg), jax.random.PRNGKey(0)
        )
        specs = param_specs(p_sds, mlp_param_spec, axes, mesh)
        p_sds = _attach(p_sds, specs, mesh)
        o_sds = jax.eval_shape(adamw_init, p_sds)
        ospecs = opt_state_specs(o_sds, p_sds, specs, axes, mesh)
        o_sds = _attach(o_sds, ospecs, mesh)
        b_sds = _gnn_batch_sds(name, s, mesh, axes)
        step = make_train_step(
            _gnn_loss(name, module, cfg, s["batched"]), AdamWConfig()
        )
        return step, (p_sds, o_sds, b_sds)

    return build


def gnn_cells(name: str, module, cfg_fn) -> Dict[str, Cell]:
    return {
        shape: Cell(arch=name, shape=shape, kind=GNN_SHAPES[shape]["kind"],
                    builder=_gnn_builder(name, module, cfg_fn, shape))
        for shape in GNN_SHAPES
    }


# ==========================================================================
# RecSys family (DIN)
# ==========================================================================

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000),
}


def _din_batch_sds(B, S, mesh, axes, with_label=True):
    bspec = P(axes.data)
    b = {
        "hist_items": _sds((B, S), jnp.int32, mesh, P(axes.data, None)),
        "hist_mask": _sds((B, S), jnp.bool_, mesh, P(axes.data, None)),
        "target_item": _sds((B,), jnp.int32, mesh, bspec),
    }
    if with_label:
        b["label"] = _sds((B,), jnp.float32, mesh, bspec)
    return b


def _din_builder(cfg_fn, shape: str):
    s = RECSYS_SHAPES[shape]

    def build(mesh: Mesh, axes: MeshAxes):
        from ..models.recsys import din

        cfg = cfg_fn()
        p_sds = jax.eval_shape(
            partial(din.init_params, cfg=cfg), jax.random.PRNGKey(0)
        )
        specs = param_specs(p_sds, mlp_param_spec, axes, mesh)
        p_sds = _attach(p_sds, specs, mesh)
        if s["kind"] == "train":
            o_sds = jax.eval_shape(adamw_init, p_sds)
            ospecs = opt_state_specs(o_sds, p_sds, specs, axes, mesh)
            o_sds = _attach(o_sds, ospecs, mesh)
            b_sds = _din_batch_sds(s["batch"], cfg.seq_len, mesh, axes)
            step = make_train_step(
                lambda p, b: (din.loss_fn(p, b, cfg), {}), AdamWConfig()
            )
            return step, (p_sds, o_sds, b_sds)
        if s["kind"] == "serve":
            b_sds = _din_batch_sds(
                s["batch"], cfg.seq_len, mesh, axes, with_label=False
            )
            return (lambda p, b: din.apply(p, b, cfg)), (p_sds, b_sds)
        # retrieval: one user, C candidates sharded over all data axes
        C = s["n_candidates"]
        b_sds = {
            "hist_items": _sds((cfg.seq_len,), jnp.int32, mesh, P()),
            "hist_mask": _sds((cfg.seq_len,), jnp.bool_, mesh, P()),
            "candidates": _sds((round_up(C, 8192),), jnp.int32, mesh,
                               P(axes.data)),
        }
        return (lambda p, b: din.score_candidates(p, b, cfg)), (p_sds, b_sds)

    return build


def recsys_cells(name: str, cfg_fn) -> Dict[str, Cell]:
    return {
        shape: Cell(arch=name, shape=shape,
                    kind=RECSYS_SHAPES[shape]["kind"],
                    builder=_din_builder(cfg_fn, shape))
        for shape in RECSYS_SHAPES
    }
