"""Unified front door for every RangeReach method.

    index = build_index(graph, method)        # offline
    ans   = batch_query(index, us, rects)     # online

``method`` is one of METHODS (the five evaluated in the paper's Section 5
plus the GeoReach baseline).  Benchmarks, examples and the serving stack
all go through this module so the methods stay interchangeable.
"""

from __future__ import annotations

from typing import Union

import numpy as np

from .georeach import GeoReachIndex, build_georeach
from .graph import GeosocialGraph
from .three_d_reach import ThreeDReachIndex, build_3dreach
from .two_d_reach import TwoDReachIndex, build_2dreach

METHODS = (
    "2dreach",
    "2dreach-comp",
    "2dreach-pointer",
    "3dreach",
    "3dreach-rev",
    "georeach",
)

AnyIndex = Union[TwoDReachIndex, ThreeDReachIndex, GeoReachIndex]


def build_index(graph: GeosocialGraph, method: str, **kw) -> AnyIndex:
    """Build the offline index for ``method`` (one of ``METHODS``).

    Keyword arguments are forwarded to the method's builder (``fanout``,
    ``dedup``, ...).  ``backend`` selects the *build* pipeline and is a
    2DReach-only option: ``backend="host"`` (default) builds in NumPy;
    ``backend="device"`` runs the reachable-set closure and the forest
    bulk-load on the accelerator and leaves the serving arrays device-
    resident, so a subsequent ``QueryEngine`` / ``ShardedEngine`` (or a
    ``DynamicIndex(engine="device"|"cluster")`` compaction swap) adopts
    them without re-uploading.  Asking for ``backend="device"`` with a
    method that has no device builder raises a ``ValueError`` naming the
    method and the supported pairings — it never falls back silently.
    """
    method = method.lower()
    if method not in METHODS:
        raise ValueError(
            f"unknown method {method!r}; expected one of {METHODS}")
    if not method.startswith("2dreach"):
        backend = kw.pop("backend", "host")   # host build == the default
        if backend != "host":
            raise ValueError(
                f"no {backend!r} build backend for method {method!r}: "
                f"backend='device' is implemented for the 2DReach "
                f"variants only (2dreach, 2dreach-comp, 2dreach-pointer);"
                f" build {method!r} with backend='host' (the default)")
    if method == "2dreach":
        return build_2dreach(graph, variant="base", **kw)
    if method == "2dreach-comp":
        return build_2dreach(graph, variant="comp", **kw)
    if method == "2dreach-pointer":
        return build_2dreach(graph, variant="pointer", **kw)
    if method == "3dreach":
        return build_3dreach(graph, variant="3d", **kw)
    if method == "3dreach-rev":
        return build_3dreach(graph, variant="3drev", **kw)
    if method == "georeach":
        return build_georeach(graph, **kw)
    # unreachable while the if-chain covers METHODS — fail loudly if a
    # new METHODS entry lands without a branch here
    raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")


def build_dynamic_index(graph: GeosocialGraph, method: str, policy=None, **kw):
    """Wrap ``method`` in a :class:`repro.dynamic.DynamicIndex`: the same
    offline build plus online ``add_edge``/``add_vertex``/``add_spatial``
    and policy-driven compaction.  Method-agnostic — every METHODS entry
    works as the static base."""
    from ..dynamic import DynamicIndex  # deferred: dynamic imports core

    return DynamicIndex(graph, method, policy=policy, **kw)


def batch_query(index, us: np.ndarray, rects: np.ndarray,
                engine: str = "host") -> np.ndarray:
    """Batched RangeReach through ``index``.

    ``engine="host"`` is the NumPy path every index supports.
    ``engine="device"`` routes 2DReach indexes through the
    compile-once :class:`~repro.core.engine.QueryEngine` (uploaded and
    memoised on first use); index types without a device engine fall
    back to the host path.
    ``engine="cluster"`` routes through the sharded multi-device
    :class:`~repro.cluster.ShardedEngine` (forest partitioned over the
    mesh, memoised on first use); cluster serving is an explicit opt-in,
    so an unsupported index type raises instead of falling back.
    """
    if engine == "device":
        from .engine import engine_for  # deferred: engine imports kernels

        eng = engine_for(index)
        if eng is not None:
            return eng.query_batch(np.asarray(us), np.asarray(rects))
    elif engine == "cluster":
        from ..cluster import sharded_engine_for  # deferred: imports core

        eng = sharded_engine_for(index)
        return eng.query_batch(np.asarray(us), np.asarray(rects))
    elif engine != "host":
        raise ValueError(
            f"unknown engine {engine!r}; expected host|device|cluster")
    return index.query_batch(np.asarray(us), np.asarray(rects))


def index_nbytes(index) -> dict:
    """Size decomposition mirroring the paper's Table 4 parentheses.

    The ``rtree`` entry is the spatial structure (GeoReach has no R-tree;
    its MBR summaries + per-component venue lists play that role) and
    ``aux`` the social/lookup side, so size comparisons across methods
    are apples-to-apples.
    """
    if isinstance(index, TwoDReachIndex):
        return {
            "rtree": index.nbytes_rtree(),
            "aux": index.nbytes_pointers(),
            "total": index.nbytes_total(),
        }
    if isinstance(index, ThreeDReachIndex):
        return {
            "rtree": index.nbytes_rtree(),
            "aux": index.nbytes_labels(),
            "total": index.nbytes_total(),
        }
    if isinstance(index, GeoReachIndex):
        return {
            "rtree": index.nbytes_spatial(),
            "aux": index.nbytes_social(),
            "total": index.nbytes_total(),
        }
    # DynamicIndex (or anything else wrapping a base index)
    if hasattr(index, "nbytes"):
        return index.nbytes()
    return {"rtree": 0, "aux": index.nbytes_total(), "total": index.nbytes_total()}
