"""Scan-aware HLO analysis: FLOPs / traffic / collective bytes.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 48 layers reports 1/48th of the real FLOPs (verified in
EXPERIMENTS.md §Dry-run).  This module parses the post-optimization HLO
text instead and walks the call graph, multiplying ``while`` bodies by
their trip counts (XLA's ``known_trip_count`` backend config, falling
back to the loop-condition bound constant):

    flops       — 2 * prod(result_dims) * contraction for every dot
    bytes       — operand + result bytes of every materializing op
                  (post-fusion: fusion internals don't touch HBM);
                  operand shapes resolved through a per-computation
                  symbol table (compact HLO omits them inline)
    collectives — result bytes of all-gather/all-reduce/reduce-scatter/
                  all-to-all/collective-permute, x trip multiplicity

Validated against known-FLOPs programs in tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([a-z][a-z0-9\-]*)\("
)
PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^()]*\))|(?:[a-z0-9]+"
                      r"\[[0-9,]*\](?:\{[^}]*\})?))")
OPERAND_RE = re.compile(r"%([\w.\-]+)")
CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n":"(\d+)"')
BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
CONST_RE = re.compile(r"constant\((-?\d+)\)")
LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id",
}
HBM_FLOOR_OPS = {
    "dot", "convolution", "dynamic-update-slice", "gather", "scatter",
    "dynamic-slice",
}
COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "reduce-scatter-start",
}


def _first_shape_elems(text: str) -> Tuple[int, List[int]]:
    m = SHAPE_RE.search(text)
    if not m:
        return 0, []
    dims = [int(x) for x in m.group(2).split(",") if x]
    n = 1
    for d in dims:
        n *= d
    return n, dims


def _shape_bytes(text: str) -> int:
    total = 0
    for m in SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _split_args(line: str, start: int = 0) -> str:
    """Args between the op's parens; ``start`` points at/after the '('."""
    i = line.find("(", start)
    if i < 0:
        return ""
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[i + 1: j]
    return line[i + 1:]


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    hbm_floor: float = 0.0
    coll: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    children: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list)
    max_const: int = 0


def _is_comp_header(line: str) -> bool:
    if line.startswith((" ", "}", "//")) or "{" not in line:
        return False
    head = line.split("{")[0]
    return "->" in head or head.lstrip().startswith(("ENTRY", "%"))


def _parse_computations(hlo: str):
    comps: Dict[str, CompStats] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    symbols: Dict[str, str] = {}

    for line in hlo.splitlines():
        if not line.strip():
            continue
        if _is_comp_header(line):
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", line)
            if not m:
                continue
            cur = m.group(2)
            comps[cur] = CompStats()
            symbols = {}
            comps[cur].symbols = symbols  # type: ignore[attr-defined]
            if m.group(1):
                entry = cur
            # parameters declared in the header: name: shape
            head = line.split("->")[0]
            for pm in PARAM_RE.finditer(head):
                symbols[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        st = comps[cur]
        for cm in CONST_RE.finditer(line):
            st.max_const = max(st.max_const, int(cm.group(1)))
        m = OP_RE.match(line)
        if not m:
            continue
        name, result_shape, opcode = m.groups()
        symbols[name] = result_shape
        args = _split_args(line, m.end() - 1)
        operand_bytes = 0
        for om in OPERAND_RE.finditer(args):
            operand_bytes += _shape_bytes(symbols.get(om.group(1), ""))
        if opcode == "dot":
            out_elems, _ = _first_shape_elems(result_shape)
            contract = 1
            cd = LHS_C_RE.search(line)
            lhs_name = OPERAND_RE.search(args)
            if cd and lhs_name:
                _, lhs_dims = _first_shape_elems(
                    symbols.get(lhs_name.group(1), ""))
                for ci in cd.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        contract *= lhs_dims[int(ci)]
            st.flops += 2.0 * out_elems * contract
        if opcode in COLLECTIVES:
            b = _shape_bytes(result_shape)
            kind = opcode.replace("-start", "")
            st.coll += b
            st.coll_by_kind[kind] = st.coll_by_kind.get(kind, 0) + b
        if opcode not in SKIP_TRAFFIC:
            st.bytes += _shape_bytes(result_shape) + operand_bytes
        if opcode in HBM_FLOOR_OPS:
            # ops whose operands/results must cross HBM<->VMEM even under
            # TPU fusion (elementwise chains fuse away; these do not)
            st.hbm_floor += _shape_bytes(result_shape) + operand_bytes
        wm = WHILE_RE.search(line)
        if opcode == "while" and wm:
            tm = TRIP_RE.search(line)
            trip = float(tm.group(1)) if tm else -1.0
            st.children.append(
                (f"__while__|{wm.group(1)}|{wm.group(2)}|{trip}", 1.0))
        else:
            for cm in CALLS_RE.finditer(line):
                st.children.append((cm.group(1), 1.0))
            for cm in TO_APPLY_RE.finditer(line):
                st.children.append((cm.group(1), 1.0))
            bm = BRANCHES_RE.search(line)
            if bm:
                for br in bm.group(1).split(","):
                    br = br.strip().lstrip("%")
                    if br:
                        st.children.append((br, 1.0))
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps, entry = _parse_computations(hlo)
    memo: Dict[str, Tuple] = {}
    visiting = set()

    def total(name: str):
        if name in memo:
            return memo[name]
        if name not in comps or name in visiting:
            return (0.0, 0.0, 0.0, 0.0, {})
        visiting.add(name)
        st = comps[name]
        f, b, h, c = st.flops, st.bytes, st.hbm_floor, st.coll
        kinds = dict(st.coll_by_kind)
        for child, mult in st.children:
            if child.startswith("__while__|"):
                _, cond, body, trip_s = child.split("|")
                trip = float(trip_s)
                if trip < 0:
                    trip = float(
                        max(comps.get(cond, CompStats()).max_const, 1))
                cf, cb, ch, cc, ck = total(body)
                df, db, dh, dc, dk = total(cond)
                f += trip * cf + (trip + 1) * df
                b += trip * cb + (trip + 1) * db
                h += trip * ch + (trip + 1) * dh
                c += trip * cc + (trip + 1) * dc
                for k, v in ck.items():
                    kinds[k] = kinds.get(k, 0) + trip * v
            else:
                cf, cb, ch, cc, ck = total(child)
                f += mult * cf
                b += mult * cb
                h += mult * ch
                c += mult * cc
                for k, v in ck.items():
                    kinds[k] = kinds.get(k, 0) + mult * v
        visiting.discard(name)
        memo[name] = (f, b, h, c, kinds)
        return memo[name]

    f, b, h, c, kinds = total(entry)
    out = {"flops": f, "bytes": b, "hbm_floor_bytes": h,
           "collective_bytes": c}
    for k, v in kinds.items():
        out[f"coll_{k}"] = v
    return out
