"""Benchmark harness: paper tables/figures + roofline readers."""
