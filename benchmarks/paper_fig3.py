"""Paper Figure 3: query-time sweeps over the three parameters x methods.

1000 queries per parameter value (paper §5.1), median of ``repeats``
runs, µs/query.  A sample of each workload is verified against the BFS
oracle before timing — a benchmark that returns wrong answers is not a
benchmark.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (
    METHODS,
    batch_query,
    build_index,
    rangereach_oracle_batch,
)
from repro.data import get_dataset, workload
from repro.data.queries import (
    DEGREE_BUCKETS,
    REGION_EXTENT_VALUES,
    SELECTIVITY_VALUES,
)

DATASETS = ("foursquare", "gowalla", "weeplaces", "yelp")
BENCH_SCALE = 0.5
N_QUERIES = 1000


def _run(indexes, g, us, rects, repeats=3, verify=32) -> Dict[str, float]:
    want = rangereach_oracle_batch(g, us[:verify], rects[:verify])
    out = {}
    for method, idx in indexes.items():
        got = batch_query(idx, us[:verify], rects[:verify])
        assert (got == want).all(), f"{method} wrong answers"
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            batch_query(idx, us, rects)
            times.append(time.perf_counter() - t0)
        out[method] = round(np.median(times) / len(us) * 1e6, 3)
    return out


def sweep(dataset: str, scale: float = BENCH_SCALE,
          n_queries: int = N_QUERIES, repeats: int = 3) -> List[Dict]:
    g = get_dataset(dataset, scale=scale)
    indexes = {m: build_index(g, m) for m in METHODS}
    rows = []
    for ratio in REGION_EXTENT_VALUES:
        us, rects = workload(g, n_queries, extent_ratio=ratio, seed=17)
        rows.append(dict(
            dataset=dataset, param="extent", value=ratio,
            **_run(indexes, g, us, rects, repeats)))
    for lo, hi in DEGREE_BUCKETS:
        us, rects = workload(g, n_queries, degree_bucket=(lo, hi), seed=18)
        rows.append(dict(
            dataset=dataset, param="degree", value=f"{lo}-{hi}",
            **_run(indexes, g, us, rects, repeats)))
    for sel in SELECTIVITY_VALUES:
        us, rects = workload(g, n_queries, selectivity=sel, seed=19)
        rows.append(dict(
            dataset=dataset, param="selectivity", value=sel,
            **_run(indexes, g, us, rects, repeats)))
    return rows


def stability(rows: List[Dict]) -> Dict[str, float]:
    """max/min query-time ratio per method across all parameter values —
    the paper's 'stable response times' claim (2DReach ~flat, 3DReach
    spikes orders of magnitude)."""
    out = {}
    for m in METHODS:
        vals = [r[m] for r in rows if m in r]
        out[m] = round(max(vals) / max(min(vals), 1e-9), 1)
    return out
