"""Time-series collector: the metrics registry sampled over a run.

One final snapshot hides everything interesting about a serve — warmup
vs steady state, a breaker opening halfway through, queue depth ramping
under load.  :class:`TimeSeriesCollector` turns the registry into
rate/percentile curves: a background sampler (or an explicit
:meth:`sample` call under a fake clock in tests) snapshots every
registered metric into a **bounded ring of timestamped deltas**:

* counters — cumulative value, per-interval delta and rate/s;
* gauges — instantaneous value and high-water mark;
* histograms — cumulative count plus a *windowed* view of the interval
  via :meth:`Histogram.since` (snapshot-delta subtraction), so the
  exported p50/p95/p99 describe the queries served in that interval,
  not the whole run smeared together.

``to_jsonl`` dumps the ring (first line: schema header) — the
``timeseries.jsonl`` artifact ``serve.py --obs`` writes; per-sample
hooks let the SLO monitor evaluate its burn-rate windows on the same
cadence without a second thread.
"""

from __future__ import annotations

import collections
import json
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from . import metrics as _metrics

SCHEMA_VERSION = 1


class TimeSeriesCollector:
    """Bounded ring of timestamped registry deltas.

    Parameters
    ----------
    registry: source of truth; defaults to the global registry.
    interval: background sampling period (s) for :meth:`start`.
    capacity: ring size; the oldest samples drop (counted) beyond it.
    clock:    wall-time source (injectable for deterministic tests).
    percentiles: exported windowed histogram percentiles.
    """

    def __init__(self, registry: Optional[_metrics.Registry] = None,
                 interval: float = 0.25, capacity: int = 4096,
                 clock: Callable[[], float] = time.time,
                 percentiles: Tuple[float, ...] = (50.0, 95.0, 99.0)):
        self.registry = registry if registry is not None \
            else _metrics.REGISTRY
        self.interval = float(interval)
        self.capacity = int(capacity)
        self.percentiles = tuple(percentiles)
        self._clock = clock
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(
            maxlen=self.capacity)
        self.dropped = 0
        self._prev_t: Optional[float] = None
        self._prev_counters: Dict[str, float] = {}
        self._prev_hist: Dict[str, _metrics.HistogramState] = {}
        self._hooks: List[Callable[[float, dict], None]] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def add_hook(self, hook: Callable[[float, dict], None]) -> None:
        """Call ``hook(t, sample)`` after every sample (the SLO monitor
        ticks through this)."""
        self._hooks.append(hook)

    # -- sampling -------------------------------------------------------

    def sample(self, t: Optional[float] = None) -> dict:
        """Take one snapshot-delta sample and append it to the ring."""
        t = self._clock() if t is None else float(t)
        with self._lock:
            dt = None if self._prev_t is None else t - self._prev_t
            sample: dict = {"t": t, "dt": dt, "counters": {},
                            "gauges": {}, "histograms": {}}
            for name, m in self.registry.items():
                if isinstance(m, _metrics.Counter):
                    v = float(m.value)
                    delta = v - self._prev_counters.get(name, 0.0)
                    self._prev_counters[name] = v
                    entry = {"value": v, "delta": delta}
                    if dt and dt > 0:
                        entry["rate"] = delta / dt
                    sample["counters"][name] = entry
                elif isinstance(m, _metrics.Gauge):
                    sample["gauges"][name] = {"value": float(m.value),
                                              "max": float(m.max)}
                elif isinstance(m, _metrics.Histogram):
                    win = m.since(self._prev_hist.get(name))
                    self._prev_hist[name] = m.state()
                    entry = {"count": int(m.count),
                             "delta": int(win.count),
                             "sum_delta": float(win.sum)}
                    if win.count > 0:
                        for p in self.percentiles:
                            key = f"p{p:g}".replace(".", "_")
                            entry[key] = win.percentile(p)
                    sample["histograms"][name] = entry
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(sample)
            self._prev_t = t
            hooks = list(self._hooks)
        for hook in hooks:
            hook(t, sample)
        return sample

    # -- background sampler ---------------------------------------------

    def start(self) -> "TimeSeriesCollector":
        """Start the background sampler thread (idempotent)."""
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="obs-timeseries", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            self.sample()

    def stop(self, final_sample: bool = True) -> None:
        """Stop the sampler; by default takes one last sample so the
        tail of the run is captured."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if final_sample:
            self.sample()

    # -- introspection / export -----------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def samples(self) -> List[dict]:
        with self._lock:
            return list(self._ring)

    def series(self, kind: str, name: str,
               field: str = "value") -> Tuple[List[float], List[float]]:
        """(timestamps, values) for one metric curve, skipping samples
        where the metric or field is absent."""
        ts: List[float] = []
        vs: List[float] = []
        for s in self.samples():
            entry = s.get(kind, {}).get(name)
            if entry is None:
                continue
            v = entry.get(field) if isinstance(entry, dict) else entry
            if v is None:
                continue
            ts.append(s["t"])
            vs.append(float(v))
        return ts, vs

    def dirty(self) -> bool:
        """True when the registry holds activity the ring has not
        sampled yet — counter movement or histogram recordings since
        the last :meth:`sample` (or any at all when none was taken)."""
        with self._lock:
            no_samples = self._prev_t is None
            prev_counters = dict(self._prev_counters)
            prev_hist = dict(self._prev_hist)
        for name, m in self.registry.items():
            if isinstance(m, _metrics.Counter):
                if float(m.value) != prev_counters.get(name, 0.0):
                    return True
            elif isinstance(m, _metrics.Histogram):
                prev = prev_hist.get(name)
                if m.count != (prev.count if prev is not None else 0):
                    return True
        return no_samples and bool(self.registry.names())

    def to_jsonl(self, path: str, final_sample: bool = True) -> str:
        """Dump the ring, one sample per line after a schema header.

        ``final_sample`` (default) first flushes the partial in-flight
        window — anything recorded since the last background sample —
        into one last sample, so a short serve that never spanned a
        full ``interval`` still exports its data instead of silently
        dropping the tail (or, with no elapsed interval at all, the
        whole run)."""
        if final_sample and self.dirty():
            self.sample()
        samples = self.samples()
        with open(path, "w") as f:
            f.write(json.dumps({
                "schema_version": SCHEMA_VERSION,
                "interval_s": self.interval,
                "capacity": self.capacity,
                "dropped": self.dropped,
                "samples": len(samples),
            }) + "\n")
            for s in samples:
                f.write(json.dumps(s) + "\n")
        return path
