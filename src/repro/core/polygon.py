"""Convex-polygon query regions — the paper's footnote 2 extension.

"Without loss of generality, this paper assumes an axis-aligned rectangle
for querying. However, the proposed method can be easily extended to
handle other types of geometric objects, e.g., polygons."  This module
makes that concrete for 2DReach with a *canonical* region predicate that
every engine evaluates identically:

* a polygon is canonicalised once into its outward-rounded float32
  bounding box plus CCW half-planes ``A*x + B*y <= C`` (coefficients
  derived in float64, stored float32);
* a point is inside the region iff it passes the bbox test *and* every
  half-plane, all comparisons and arithmetic in float32 — the same ops
  the Pallas leaf-scan kernel runs, so host, device and the NumPy
  oracle are bit-identical by construction (see ``repro.queries``).

The R-tree machinery is untouched: the probe runs with the bounding box
(prefilter), candidates are postfiltered by the half-planes — and the
batched engines push that postfilter into the leaf scan itself.

    ans = polygon_query(index, u, vertices)      # (k, 2) convex hull
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .oracle import reachable_mask
from .two_d_reach import TwoDReachIndex


def _ccw(vertices: np.ndarray) -> np.ndarray:
    """Ensure counter-clockwise orientation."""
    v = np.asarray(vertices, dtype=np.float64).reshape(-1, 2)
    area2 = np.sum(
        v[:, 0] * np.roll(v[:, 1], -1) - np.roll(v[:, 0], -1) * v[:, 1]
    )
    return v if area2 >= 0 else v[::-1]


def points_in_convex_polygon(pts: np.ndarray, vertices: np.ndarray
                             ) -> np.ndarray:
    """(n, 2) points inside/on a convex polygon (any vertex order).

    Float64 cross-product form with a small tolerance — kept for callers
    that want the geometric test; the query path uses the canonical
    float32 half-plane form below (``points_in_polygon_region``)."""
    v = _ccw(vertices)
    pts = np.asarray(pts, dtype=np.float64).reshape(-1, 2)
    inside = np.ones(len(pts), dtype=bool)
    for i in range(len(v)):
        a, b = v[i], v[(i + 1) % len(v)]
        cross = (b[0] - a[0]) * (pts[:, 1] - a[1]) \
            - (b[1] - a[1]) * (pts[:, 0] - a[0])
        inside &= cross >= -1e-9
    return inside


def round_bounds_outward(lo: np.ndarray, hi: np.ndarray):
    """Float64 lo/hi bound arrays -> float32 rounded *outward*: any
    bound the round-to-nearest downcast moved inward is nudged one ulp
    out (nextafter toward ±inf), so the f32 box always contains the f64
    box.  The shared primitive behind every conservative f32 region
    (polygon bboxes, the kNN driver's search boxes)."""
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    lo32 = lo.astype(np.float32)
    hi32 = hi.astype(np.float32)
    lo32 = np.where(lo32.astype(np.float64) > lo,
                    np.nextafter(lo32, np.float32(-np.inf)), lo32)
    hi32 = np.where(hi32.astype(np.float64) < hi,
                    np.nextafter(hi32, np.float32(np.inf)), hi32)
    return lo32, hi32


def polygon_bbox(vertices: np.ndarray) -> np.ndarray:
    """Outward-rounded float32 bounding box [xmin, ymin, xmax, ymax].

    Min/max run in float64 *before* the float32 downcast and round
    outward — a round-to-nearest cast can otherwise shrink the box past
    a venue sitting exactly on the hull edge, and the R-tree prefilter
    would drop a true hit.
    """
    v = np.asarray(vertices, dtype=np.float64).reshape(-1, 2)
    lo32, hi32 = round_bounds_outward(v.min(axis=0), v.max(axis=0))
    return np.array([lo32[0], lo32[1], hi32[0], hi32[1]], dtype=np.float32)


# --------------------------------------------------------------------------
# Canonical region form (shared by host paths, oracle and Pallas kernel)
# --------------------------------------------------------------------------

def convex_halfplanes(vertices: np.ndarray,
                      pad_to: Optional[int] = None) -> np.ndarray:
    """(3, E) float32 half-planes of a convex polygon: row 0 = A, row 1
    = B, row 2 = C with inside ⟺ ``A*x + B*y <= C``.

    Coefficients are derived in float64 from the CCW edge normals
    (A = by - ay, B = ax - bx, C = A*ax + B*ay) and stored float32 —
    the *evaluation* is float32 everywhere, which is what makes host,
    oracle and kernel answers bit-identical.  ``pad_to`` appends inert
    half-planes (A = B = 0, C = +inf: 0*x + 0*y = 0 <= inf for any
    finite point) so batches bucket to a common edge count.
    """
    v = _ccw(vertices)
    E = len(v)
    if E < 3:
        raise ValueError(f"polygon needs >= 3 vertices, got {E}")
    nxt = np.roll(v, -1, axis=0)
    A = nxt[:, 1] - v[:, 1]
    B = v[:, 0] - nxt[:, 0]
    C = A * v[:, 0] + B * v[:, 1]
    hp = np.stack([A, B, C]).astype(np.float32)
    if pad_to is not None:
        if pad_to < E:
            raise ValueError(f"pad_to={pad_to} < {E} polygon edges")
        pad = np.zeros((3, pad_to - E), dtype=np.float32)
        pad[2] = np.inf
        hp = np.concatenate([hp, pad], axis=1)
    return hp


def points_in_polygon_region(pts: np.ndarray, bbox: np.ndarray,
                             halfplanes: np.ndarray) -> np.ndarray:
    """(n,) bool — the canonical float32 region test: inside the bbox
    AND on the inner side of every half-plane.  Mirrors the Pallas
    kernel op for op (f32 multiply, f32 add, compare), so the engines
    agree bit for bit."""
    pts = np.asarray(pts, dtype=np.float32).reshape(-1, 2)
    x, y = pts[:, 0], pts[:, 1]
    ok = (
        (x >= bbox[0]) & (x <= bbox[2]) & (y >= bbox[1]) & (y <= bbox[3])
    )
    hp = np.asarray(halfplanes, dtype=np.float32)
    for e in range(hp.shape[1]):
        ok = ok & ((hp[0, e] * x + hp[1, e] * y) <= hp[2, e])
    return ok


def polygon_query(index: TwoDReachIndex, u: int, vertices) -> bool:
    """RangeReach with a convex polygon region (Alg. 2 + exact filter).

    Scalar convenience wrapper over the batched subsystem
    (:func:`repro.queries.polygon_reach_host`) — one query, host path.
    """
    from ..queries import polygon_reach_host  # deferred: queries imports core

    return bool(polygon_reach_host(index, np.array([u]), [vertices])[0])


def polygon_oracle(graph, u: int, vertices) -> bool:
    """BFS ground truth under the canonical region predicate."""
    seen = reachable_mask(graph, u)
    ids = np.nonzero(seen & graph.spatial_mask)[0]
    if len(ids) == 0:
        return False
    return bool(points_in_polygon_region(
        graph.coords[ids], polygon_bbox(vertices),
        convex_halfplanes(vertices)).any())
