"""repro.dynamic — incremental RangeReach over a mutating geosocial graph.

Public API:
    DynamicIndex(graph, method)     # wrap any static method
    .add_edge / .add_vertex / .add_spatial
    .query_batch / .query           # exact answers on the mutated graph
    .compact / .maybe_compact       # overlay -> fresh static base
"""

from .compaction import NEVER, CompactionPolicy, Compactor
from .index import DynamicIndex
from .overlay import DeltaOverlay, SpatialStaging, UnionFind

__all__ = [
    "NEVER", "CompactionPolicy", "Compactor",
    "DynamicIndex",
    "DeltaOverlay", "SpatialStaging", "UnionFind",
]
