"""Typed serving errors — the only failures a client is allowed to see.

The fault-tolerance invariant (asserted by the chaos suite) is that
every request submitted to the serving stack resolves to either the
exact answer or one of these typed errors — never a hang, never a wrong
answer, never a naked internal exception escaping the frontend.

All of them subclass :class:`ResilienceError` (itself a
``RuntimeError``, so pre-existing callers that caught the frontend's
old ``RuntimeError("Frontend is closed")`` keep working).
"""

from __future__ import annotations


class ResilienceError(RuntimeError):
    """Base class for every typed serving failure."""


class Overloaded(ResilienceError):
    """Admission control shed the request: the projected queue wait
    already exceeds the request's deadline budget, so accepting it
    would only burn capacity on an answer nobody can use."""


class QueueFull(ResilienceError):
    """The bounded submit queue stayed at capacity past the caller's
    backpressure timeout."""


class DeadlineExceeded(ResilienceError):
    """The request's deadline budget expired before it could be
    served (it waited in the queue past its budget, or every serving
    attempt within the budget failed)."""


class FrontendClosed(ResilienceError):
    """The frontend was closed: either this submit arrived after
    ``close()``, or ``close(drain=False)`` failed the still-pending
    future instead of serving it."""


class CircuitOpen(ResilienceError):
    """Internal: a circuit breaker refused the call.  Never escapes the
    resilient engine — it triggers the exact host fallback instead."""


class InjectedFault(RuntimeError):
    """Default exception raised by a ``raise``-kind fault spec.  A
    plain ``RuntimeError`` (not a :class:`ResilienceError`): injected
    faults model *untyped* infrastructure failures, which the stack
    must absorb or convert — an ``InjectedFault`` reaching a client
    future is a chaos-suite failure unless the client submitted
    directly to a faulted layer with no resilience wrapper."""

    def __init__(self, point: str = "", fire: int = 0):
        super().__init__(f"injected fault at {point!r} (fire #{fire})")
        self.point = point
        self.fire = fire


class ShardDropout(InjectedFault):
    """Injected loss of one shard of a sharded engine.  Carries the
    shard id so the resilient wrapper can open that shard's breaker
    (degrading only the queries routed to it) instead of the whole
    engine's."""

    def __init__(self, shard: int, point: str = "", fire: int = 0):
        super().__init__(point=point, fire=fire)
        self.shard = int(shard)
