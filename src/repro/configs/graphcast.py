"""graphcast [gnn]: 16L d_hidden=512 mesh_refinement=6 sum-aggregation
n_vars=227 — encoder-processor-decoder mesh GNN. [arXiv:2212.12794]"""
import dataclasses

from ..models.gnn import graphcast as module
from ..models.gnn.graphcast import GraphCastConfig
from .base import ArchSpec, gnn_cells

NAME = "graphcast"


def make_config(reduced: bool = False, d_feat=None, shape=None
                ) -> GraphCastConfig:
    if reduced:
        return GraphCastConfig(n_layers=2, d_hidden=32, n_vars=8)
    n_vars = d_feat if d_feat is not None else 16  # molecule cells: 16
    return GraphCastConfig(n_layers=16, d_hidden=512, n_vars=n_vars,
                           mesh_refinement=6)


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="gnn", make_config=make_config,
        cells=gnn_cells(NAME, module, make_config),
        notes="n_vars follows the cell's feature width (227 is the "
              "native weather config; the four assigned shapes carry "
              "their own d_feat)",
    )
