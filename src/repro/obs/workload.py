"""Workload analytics: streaming heavy hitters + shard-load skew.

The query log records per-query facts; this module aggregates them into
the signals the hot-shard economics work (ROADMAP: replication +
query-log-driven repartitioning, DAGGER-style) actually needs:

* **heavy hitters** — which vertices, rect buckets and shards dominate
  the stream.  Detection is streaming via the Space-Saving sketch
  (Metwally et al.): bounded memory (``capacity`` monitored keys),
  with the classic guarantees — every key whose true frequency exceeds
  ``n / capacity`` is monitored, estimates overcount by at most the
  tracked per-key error, and ``true <= estimate <= true + n/capacity``.
  Because the sketch consumes records as a :class:`QueryLog` sink it
  sees the *whole* stream, not just the log's retained ring window;
  :meth:`WorkloadAnalytics.verify` recounts the retained window exactly
  and cross-checks the sketch against it.
* **shard-load skew** — per-shard query share and latency share, their
  Gini coefficients, and max/mean balance: the placement report a
  repartitioner consumes (move load off shards whose share drives the
  Gini up; replicate the heavy-hitter vertices' trees).
* **healthy vs degraded split** — the schema-v2 ``status`` field lets
  the report separate device-served traffic from exact-host-degraded
  traffic, so a hot shard that is hot *because* it is degraded is
  visible as such.

Nothing here touches the serving hot path: records arrive only when the
query log records (obs enabled, or an explicit log), so the disabled
overhead stays at the existing <2% gate.
"""

from __future__ import annotations

import heapq
import threading
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np

from . import querylog as _ql


def gini(values) -> float:
    """Gini coefficient of a non-negative load vector in ``[0, 1)``:
    0 = perfectly balanced, ``(n-1)/n`` = one shard carries everything.
    Computed with the sorted-rank formula (O(n log n)), identical to
    the pairwise mean-absolute-difference definition."""
    x = np.sort(np.asarray(values, dtype=np.float64).ravel())
    n = len(x)
    s = x.sum()
    if n == 0 or s <= 0.0:
        return 0.0
    ranks = np.arange(1, n + 1, dtype=np.float64)
    return float((2.0 * np.sum(ranks * x) / (n * s)) - (n + 1.0) / n)


class SpaceSaving:
    """Space-Saving heavy-hitter sketch over a key stream.

    Maintains at most ``capacity`` monitored keys.  ``offer(key)``
    either bumps a monitored key, fills a free slot, or evicts the
    current minimum-count key and inherits its count as the newcomer's
    error bound.  Guarantees (n = total offered weight):

    * any key with true count > n / capacity is monitored;
    * for a monitored key: ``estimate - error <= true <= estimate``;
    * ``error <= n / capacity``.

    The min is tracked with a lazily-invalidated heap (stale entries
    are skipped on pop and the heap is rebuilt when it outgrows the
    monitored set), so offers stay O(log capacity) amortised.
    """

    __slots__ = ("capacity", "n", "_counts", "_errs", "_heap")

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.n = 0
        self._counts: Dict[Hashable, int] = {}
        self._errs: Dict[Hashable, int] = {}
        self._heap: List[tuple] = []    # (count, seq, key) lazy entries

    def offer(self, key: Hashable, inc: int = 1) -> None:
        self.n += inc
        c = self._counts.get(key)
        if c is not None:
            self._counts[key] = c + inc
        elif len(self._counts) < self.capacity:
            self._counts[key] = inc
            self._errs[key] = 0
        else:
            # evict the current minimum; the newcomer inherits its
            # count as the overcount bound
            while True:
                mc, _seq, mk = self._heap[0]
                if self._counts.get(mk) == mc:
                    break
                heapq.heappop(self._heap)           # stale
            heapq.heappop(self._heap)
            del self._counts[mk]
            del self._errs[mk]
            self._counts[key] = mc + inc
            self._errs[key] = mc
        heapq.heappush(self._heap, (self._counts[key], self.n, key))
        if len(self._heap) > 8 * self.capacity:     # compact lazy dups
            self._heap = [(c, 0, k) for k, c in self._counts.items()]
            heapq.heapify(self._heap)

    def count(self, key: Hashable) -> Optional[Tuple[int, int]]:
        """(estimate, error bound) for a monitored key, else None."""
        c = self._counts.get(key)
        return None if c is None else (c, self._errs[key])

    def items(self) -> List[Tuple[Hashable, int, int]]:
        """[(key, estimate, error)] sorted by estimate, descending."""
        return sorted(((k, c, self._errs[k])
                       for k, c in self._counts.items()),
                      key=lambda t: (-t[1], str(t[0])))

    def heavy_hitters(self, phi: float) -> List[Tuple[Hashable, int, int]]:
        """Keys whose estimate reaches ``phi * n``.  Complete (no false
        negatives) whenever ``phi > 1 / capacity``; reported counts obey
        the sketch error bound."""
        thr = phi * self.n
        return [t for t in self.items() if t[1] >= thr]

    def top(self, k: int) -> List[Tuple[Hashable, int, int]]:
        return self.items()[: int(k)]

    def __len__(self) -> int:
        return len(self._counts)


class WorkloadAnalytics:
    """Streaming aggregation of query-log records into a placement
    report.  Attach with ``query_log.add_sink(wa.observe)`` (or replay
    a retained window through :meth:`observe`); thread-safe — the
    frontend scheduler thread is the usual producer."""

    def __init__(self, k_vertices: int = 256, k_rects: int = 64,
                 k_shards: int = 64):
        self._lock = threading.Lock()
        self.vertices = SpaceSaving(k_vertices)
        self.rect_buckets = SpaceSaving(k_rects)
        self.shards = SpaceSaving(k_shards)
        self.total = 0
        self.latency_us_sum = 0.0
        self.by_status: Dict[str, int] = {}
        self.retries = 0
        self._shard_q: Dict[int, int] = {}
        self._shard_lat: Dict[int, float] = {}
        self._shard_degraded: Dict[int, int] = {}

    # -- ingestion ------------------------------------------------------

    def observe(self, rec: tuple) -> None:
        """Consume one query-log record (schema v2 tuple)."""
        u = rec[_ql.I_U]
        shard = rec[_ql.I_SHARD]
        lat = rec[_ql.I_LATENCY_US]
        status = rec[_ql.I_STATUS]
        with self._lock:
            self.total += 1
            self.latency_us_sum += lat
            self.by_status[status] = self.by_status.get(status, 0) + 1
            self.retries += rec[_ql.I_RETRIES]
            if u >= 0:
                self.vertices.offer(u)
            self.rect_buckets.offer(rec[_ql.I_RECT_BUCKET])
            self.shards.offer(shard)
            self._shard_q[shard] = self._shard_q.get(shard, 0) + 1
            self._shard_lat[shard] = self._shard_lat.get(shard, 0.0) + lat
            if status != "ok":
                self._shard_degraded[shard] = \
                    self._shard_degraded.get(shard, 0) + 1

    def observe_all(self, records) -> None:
        for rec in records:
            self.observe(rec)

    # -- skew -----------------------------------------------------------

    def skew(self) -> dict:
        """Per-shard load shares and their inequality metrics."""
        with self._lock:
            shard_q = dict(self._shard_q)
            shard_lat = dict(self._shard_lat)
            shard_deg = dict(self._shard_degraded)
            total = self.total
            lat_sum = self.latency_us_sum
        shards = sorted(shard_q)
        q = np.array([shard_q[s] for s in shards], dtype=np.float64)
        lat = np.array([shard_lat[s] for s in shards], dtype=np.float64)
        q_share = q / total if total else q
        lat_share = lat / lat_sum if lat_sum else lat
        per_shard = {
            str(s): {
                "queries": int(q[i]),
                "query_share": float(q_share[i]),
                "latency_us": float(lat[i]),
                "latency_share": float(lat_share[i]),
                "degraded": int(shard_deg.get(s, 0)),
            }
            for i, s in enumerate(shards)
        }
        return {
            "n_shards": len(shards),
            "per_shard": per_shard,
            "gini_queries": gini(q),
            "gini_latency": gini(lat),
            "max_query_share": float(q_share.max()) if len(q) else 0.0,
            "balance": float(q.max() / q.mean()) if len(q) else 0.0,
        }

    # -- verification ---------------------------------------------------

    def verify(self, query_log: "_ql.QueryLog",
               phi: float = 0.01) -> dict:
        """Exact recount of the log's retained window vs the sketch.

        When the window is the whole stream (nothing evicted since the
        sketch attached), the Space-Saving guarantee is checkable
        directly: every exact heavy hitter (frequency >= phi * n) must
        appear in ``heavy_hitters(phi)`` and every estimate must sit in
        ``[true, true + n/capacity]``.
        """
        records = query_log.records()
        exact: Dict[int, int] = {}
        for rec in records:
            u = rec[_ql.I_U]
            if u >= 0:
                exact[u] = exact.get(u, 0) + 1
        n = sum(exact.values())
        window_is_stream = query_log.dropped == 0 and n == self.vertices.n
        thr = phi * max(n, 1)
        exact_hh = {u for u, c in exact.items() if c >= thr}
        sketch_hh = {k for k, _c, _e in self.vertices.heavy_hitters(phi)}
        bound = self.vertices.n / self.vertices.capacity
        max_overcount = 0
        within_bound = True
        for k, c, _e in self.vertices.items():
            t = exact.get(k, 0)
            if window_is_stream:
                if not (t <= c <= t + bound):
                    within_bound = False
                max_overcount = max(max_overcount, c - t)
        return {
            "window": len(records),
            "window_is_stream": window_is_stream,
            "exact_heavy_hitters": sorted(exact_hh),
            "sketch_heavy_hitters": sorted(sketch_hh),
            "all_exact_reported": exact_hh <= sketch_hh,
            "exact_match": window_is_stream and exact_hh <= sketch_hh
            and within_bound,
            "max_overcount": int(max_overcount),
            "error_bound": float(bound),
        }

    # -- report ---------------------------------------------------------

    def placement_report(self, top_k: int = 10,
                         query_log: Optional["_ql.QueryLog"] = None,
                         phi: float = 0.01) -> dict:
        """The structured input for a repartitioner: skew + heavy
        hitters (+ an exact-recount verification block when the source
        log is supplied)."""

        def hh(sketch: SpaceSaving) -> list:
            n = max(sketch.n, 1)
            return [{"key": k if isinstance(k, str) else int(k),
                     "count": int(c), "err": int(e),
                     "share": float(c / n)}
                    for k, c, e in sketch.top(top_k)]

        with self._lock:
            total = self.total
            by_status = dict(self.by_status)
            retries = self.retries
            lat_sum = self.latency_us_sum
        report = {
            "schema_version": 1,
            "total_queries": total,
            "latency_us_sum": lat_sum,
            "by_status": by_status,
            "degraded_fraction": (
                sum(v for k, v in by_status.items() if k != "ok")
                / total if total else 0.0),
            "device_retries": retries,
            "skew": self.skew(),
            "heavy_hitters": {
                "vertices": hh(self.vertices),
                "rect_buckets": hh(self.rect_buckets),
                "shards": hh(self.shards),
            },
            "sketch": {
                "capacity": self.vertices.capacity,
                "monitored": len(self.vertices),
                "error_bound": self.vertices.n / self.vertices.capacity,
            },
        }
        if query_log is not None:
            report["verified"] = self.verify(query_log, phi=phi)
        return report

    def top_table(self, top_k: int = 10) -> str:
        """Human-readable top-k heavy-hitter table (the ``--obs`` serve
        epilogue prints this)."""
        lines = []
        n = max(self.total, 1)
        for title, sketch in (("vertex", self.vertices),
                              ("rect_bucket", self.rect_buckets),
                              ("shard", self.shards)):
            lines.append(f"  {title:>12}  {'count':>8}  {'±err':>6}  share")
            for k, c, e in sketch.top(top_k):
                lines.append(
                    f"  {str(k):>12}  {c:>8d}  {e:>6d}  {c / n:6.1%}")
        return "\n".join(lines)
