"""jit'd public wrapper: RangeReach leaf probe on a packed R-tree forest.

Bridges the host ``RTreeForest`` layout to the kernel's SoA layout:
entries are transposed once at index-load time (offline), queries are
padded to tile multiples per batch.  ``interpret=True`` on CPU; on TPU
the same call compiles to the real kernel.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ...obs import REGISTRY, span
from .kernel import TB, TP, range_query_pallas
from .ref import range_query_ref

# Number of host-side forest transpositions performed since import —
# benchmarks read this to assert the steady-state count stays flat.
# (Mirrored into the obs registry as "range_query.soa_builds"; the int
# stays because benches read module state directly across reloads.)
SOA_BUILDS = 0


def forest_to_soa(forest) -> Tuple[np.ndarray, np.ndarray]:
    """(2*dim, P_padded) SoA entry planes + (T+1,) offsets.

    Padding entries are impossible boxes (min > max) so they never hit.
    """
    global SOA_BUILDS
    SOA_BUILDS += 1
    REGISTRY.counter("range_query.soa_builds").inc()
    with span("build.soa_transpose", cat="build",
              entries=int(len(forest.entries))):
        dim = forest.dim
        P = len(forest.entries)
        Pp = max(TP, ((P + TP - 1) // TP) * TP)
        soa = np.empty((2 * dim, Pp), dtype=np.float32)
        soa[:dim, :] = 1.0
        soa[dim:, :] = 0.0
        if P:
            soa[:, :P] = forest.entries.T
    return soa, forest.entry_off.astype(np.int32)


def forest_soa(forest) -> Tuple[np.ndarray, np.ndarray]:
    """Cached ``forest_to_soa``, keyed on forest identity.

    Forests are immutable after ``build_forest``, so the transposed SoA
    is memoised on the instance itself — repeated kernel calls (and the
    device ``QueryEngine`` upload) re-transpose nothing.
    """
    cached = getattr(forest, "_soa_cache", None)
    if cached is None:
        cached = forest_to_soa(forest)
        forest._soa_cache = cached
    return cached


def rects_to_soa(rects: np.ndarray, dim: int) -> np.ndarray:
    """(B, 2*dim) -> (2*dim, B_padded); padding rects are empty boxes."""
    B = len(rects)
    Bp = max(TB, ((B + TB - 1) // TB) * TB)
    soa = np.empty((2 * dim, Bp), dtype=np.float32)
    soa[:dim, :] = 1.0
    soa[dim:, :] = 0.0
    if B:
        soa[:, :B] = np.asarray(rects, dtype=np.float32).T
    return soa


def range_query_forest(
    forest,
    tree_ids: np.ndarray,
    rects: np.ndarray,
    *,
    interpret: bool = True,
    use_ref: bool = False,
) -> np.ndarray:
    """Batched leaf-scan probe of a forest (the Pallas query engine).

    Equivalent to ``core.rtree.query_host`` — asserted in tests.
    """
    dim = forest.dim
    B = len(tree_ids)
    entries_soa, off = forest_soa(forest)
    rsoa = rects_to_soa(rects, dim)
    Bp = rsoa.shape[1]
    tid = np.asarray(tree_ids, dtype=np.int64)
    qs = np.zeros(Bp, dtype=np.int32)
    qe = np.zeros(Bp, dtype=np.int32)
    ok = tid >= 0
    qs[:B][ok] = off[tid[ok]]
    qe[:B][ok] = off[tid[ok] + 1]
    fn = range_query_ref if use_ref else range_query_pallas
    kw = {} if use_ref else {"interpret": interpret}
    out = fn(
        jnp.asarray(entries_soa),
        jnp.asarray(rsoa),
        jnp.asarray(qs),
        jnp.asarray(qe),
        dim=dim,
        **kw,
    )
    return np.asarray(out)[:B].astype(bool)
