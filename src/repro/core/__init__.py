"""Core library: the paper's contribution (2DReach) + baselines.

Public API:
    build_index(graph, method) / batch_query(index, us, rects)
"""

from .api import (
    METHODS,
    batch_query,
    build_dynamic_index,
    build_index,
    index_nbytes,
    run_queries,
)
from .condensation import Condensation, condense
from .engine import QueryEngine, engine_for
from .georeach import GeoReachIndex, build_georeach
from .graph import CSR, GeosocialGraph, build_csr, make_graph
from .interval_labels import IntervalLabels, build_interval_labels
from .oracle import (
    knn_reach_oracle,
    polygon_reach_oracle,
    range_collect_oracle,
    range_count_oracle,
    rangereach_oracle,
    rangereach_oracle_batch,
    reachable_mask,
)
from .polygon import (
    convex_halfplanes,
    points_in_convex_polygon,
    points_in_polygon_region,
    polygon_bbox,
    polygon_oracle,
    polygon_query,
)
from .reachability import (
    ClosureResult,
    closure_bitset_mm,
    closure_jax,
    closure_mbr_np,
    closure_np,
)
from .rtree import (
    DEFAULT_FANOUT,
    DeviceForest,
    RTreeForest,
    build_forest,
    build_forest_device,
    query_host,
    query_host_collect,
    query_host_collect_batch,
    query_host_count,
    query_host_knn,
    query_jax_wavefront,
)
from .scc import compact_labels, same_partition, scc_jax, scc_np
from .three_d_reach import ThreeDReachIndex, build_3dreach
from .two_d_reach import BitRank, TwoDReachIndex, build_2dreach

__all__ = [
    "METHODS", "batch_query", "build_dynamic_index", "build_index",
    "index_nbytes", "run_queries",
    "Condensation", "condense",
    "QueryEngine", "engine_for",
    "GeoReachIndex", "build_georeach",
    "CSR", "GeosocialGraph", "build_csr", "make_graph",
    "IntervalLabels", "build_interval_labels",
    "knn_reach_oracle", "polygon_reach_oracle", "range_collect_oracle",
    "range_count_oracle",
    "rangereach_oracle", "rangereach_oracle_batch", "reachable_mask",
    "convex_halfplanes", "points_in_convex_polygon",
    "points_in_polygon_region", "polygon_bbox",
    "polygon_oracle", "polygon_query",
    "ClosureResult", "closure_bitset_mm", "closure_jax", "closure_mbr_np",
    "closure_np",
    "DEFAULT_FANOUT", "DeviceForest", "RTreeForest", "build_forest",
    "build_forest_device", "query_host",
    "query_host_collect", "query_host_collect_batch", "query_host_count",
    "query_host_knn", "query_jax_wavefront",
    "compact_labels", "same_partition", "scc_jax", "scc_np",
    "ThreeDReachIndex", "build_3dreach",
    "BitRank", "TwoDReachIndex", "build_2dreach",
]
