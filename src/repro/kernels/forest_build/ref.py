"""Pure-jnp oracle for the segmented-MBR reduction kernel."""

from __future__ import annotations

import jax.numpy as jnp


def seg_mbr_ref(children: jnp.ndarray, *, dim: int, fan: int) -> jnp.ndarray:
    """Same contract as ``seg_mbr_pallas``: slot-major (fan*2*dim, N)
    child planes -> (2*dim, N) node MBRs (min over the low axes, max
    over the high axes)."""
    rows, n = children.shape
    assert rows == fan * 2 * dim
    c = children.reshape(fan, 2 * dim, n)
    return jnp.concatenate(
        [c[:, :dim].min(axis=0), c[:, dim:].max(axis=0)], axis=0
    )
