"""Geosocial graph container and CSR utilities.

A geosocial graph G = (V, E, delta) is a directed graph where a subset of
vertices carry a 2-D coordinate (the *spatial* vertices, "venues" in LBSN
terms) and the rest are purely social ("users").

Everything is stored as dense arrays so the structure is jit-able,
shardable and checkpointable:

  n_nodes        int
  edges          (m, 2) int32   directed (src, dst)
  coords         (n, 2) float32 coordinates; undefined rows for non-spatial
  spatial_mask   (n,)   bool    True where delta(v) != bottom

CSR adjacency is built host-side (NumPy) once and reused by every index
build; the arrays themselves can be moved to device.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CSR:
    """Compressed sparse row adjacency: neighbours of u are
    ``indices[indptr[u]:indptr[u+1]]``."""

    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (m,)  int32

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def m(self) -> int:
        return len(self.indices)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr)


def build_csr(n: int, edges: np.ndarray, reverse: bool = False) -> CSR:
    """Build CSR adjacency from an (m, 2) edge array.

    ``reverse=True`` builds the transpose (in-edges).
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    src = edges[:, 1] if reverse else edges[:, 0]
    dst = edges[:, 0] if reverse else edges[:, 1]
    order = np.argsort(src, kind="stable")
    src_sorted = src[order]
    indices = dst[order].astype(np.int32)
    counts = np.bincount(src_sorted, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(indptr=indptr, indices=indices)


@dataclasses.dataclass
class GeosocialGraph:
    """Dense-array geosocial graph.

    Attributes
    ----------
    n_nodes:      number of vertices.
    edges:        (m, 2) int32 directed edges (src, dst). Deduplicated,
                  no self-loops required (they are harmless).
    coords:       (n, 2) float32; rows of non-spatial vertices are 0 and
                  must not be read (mask with ``spatial_mask``).
    spatial_mask: (n,) bool.
    """

    n_nodes: int
    edges: np.ndarray
    coords: np.ndarray
    spatial_mask: np.ndarray
    _csr: Optional[CSR] = dataclasses.field(default=None, repr=False)
    _csr_rev: Optional[CSR] = dataclasses.field(default=None, repr=False)

    def __post_init__(self) -> None:
        self.edges = np.asarray(self.edges, dtype=np.int32).reshape(-1, 2)
        self.coords = np.asarray(self.coords, dtype=np.float32).reshape(-1, 2)
        self.spatial_mask = np.asarray(self.spatial_mask, dtype=bool).reshape(-1)
        assert self.coords.shape[0] == self.n_nodes, (self.coords.shape, self.n_nodes)
        assert self.spatial_mask.shape[0] == self.n_nodes
        if self.edges.size:
            assert self.edges.min() >= 0 and self.edges.max() < self.n_nodes

    # -- derived views -------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def n_spatial(self) -> int:
        return int(self.spatial_mask.sum())

    @property
    def spatial_ids(self) -> np.ndarray:
        return np.nonzero(self.spatial_mask)[0].astype(np.int32)

    @property
    def csr(self) -> CSR:
        if self._csr is None:
            self._csr = build_csr(self.n_nodes, self.edges)
        return self._csr

    @property
    def csr_rev(self) -> CSR:
        if self._csr_rev is None:
            self._csr_rev = build_csr(self.n_nodes, self.edges, reverse=True)
        return self._csr_rev

    def out_degree(self) -> np.ndarray:
        deg = np.zeros(self.n_nodes, dtype=np.int64)
        if self.edges.size:
            np.add.at(deg, self.edges[:, 0], 1)
        return deg

    def spatial_extent(self) -> np.ndarray:
        """Global MBR of all spatial vertices: [xmin, ymin, xmax, ymax]."""
        pts = self.coords[self.spatial_mask]
        if pts.size == 0:
            return np.array([0.0, 0.0, 0.0, 0.0], dtype=np.float32)
        return np.array(
            [pts[:, 0].min(), pts[:, 1].min(), pts[:, 0].max(), pts[:, 1].max()],
            dtype=np.float32,
        )

    # -- subgraphs -----------------------------------------------------
    def social_subgraph_edges(self) -> np.ndarray:
        """Edges whose endpoints are both non-spatial (the social subgraph).

        Used by the compressed variants: the SCC decomposition runs on this
        subgraph only; spatial sinks never participate in cycles in the LBSN
        data model (venues have no outgoing edges), and in the general data
        model only spatial vertices *without outgoing edges* are excluded
        (see ``spatial_sink_mask``).
        """
        keep = ~(
            self.spatial_mask[self.edges[:, 0]]
            | self.spatial_mask[self.edges[:, 1]]
        )
        return self.edges[keep]

    def spatial_sink_mask(self) -> np.ndarray:
        """Spatial vertices with no outgoing edges (safe to exclude from the
        SCC decomposition — they can never be on a cycle and their
        reachable set is exactly themselves)."""
        return self.spatial_mask & (self.out_degree() == 0)

    def validate(self) -> None:
        assert np.isfinite(self.coords[self.spatial_mask]).all()


def dedup_edges(edges: np.ndarray) -> np.ndarray:
    """Sort + dedup an (m, 2) edge array; drops exact duplicates."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.size == 0:
        return edges.astype(np.int32)
    key = edges[:, 0] << 32 | edges[:, 1]
    uniq = np.unique(key)
    out = np.stack([uniq >> 32, uniq & 0xFFFFFFFF], axis=1)
    return out.astype(np.int32)


def make_graph(
    n_nodes: int,
    edges: np.ndarray,
    coords: Optional[np.ndarray] = None,
    spatial_mask: Optional[np.ndarray] = None,
) -> GeosocialGraph:
    if coords is None:
        coords = np.zeros((n_nodes, 2), dtype=np.float32)
    if spatial_mask is None:
        spatial_mask = np.zeros(n_nodes, dtype=bool)
    return GeosocialGraph(
        n_nodes=n_nodes,
        edges=dedup_edges(edges),
        coords=coords,
        spatial_mask=spatial_mask,
    )
