"""Metrics registry: counters, gauges and bounded latency histograms.

One process-wide :data:`REGISTRY` absorbs the ad-hoc timing globals that
used to live scattered across the stack (``engine.UPLOAD_COUNTERS``,
``range_query.ops.SOA_BUILDS``, the one-time host-fallback warning, the
frontend's stats dict) plus the new per-shard and frontend gauges.
Everything is thread-safe and cheap enough to stay always-on at the
granularity it is recorded at (per batch / per flush / per build — never
per query in a kernel loop).

:class:`Histogram` is the one percentile implementation in the repo (the
hand-rolled ``np.percentile`` calls in ``launch/serve.py`` and
``benchmarks/perf_rangereach.py`` route through it): a bounded HDR-style
log-linear bucket array for streaming aggregation, plus an exact sample
window.  While the window is unsaturated — every latency distribution
the benches replay fits — percentiles are **bit-for-bit**
``np.percentile`` (linear interpolation, float64); past ``max_samples``
they degrade gracefully to bucket-interpolated values with bounded
relative error (2^(1/sub) per bucket) instead of unbounded memory.
"""

from __future__ import annotations

import json
import math
import random
import threading
from typing import Dict, Iterable, List, MutableMapping, Optional, \
    Sequence, Tuple, Union

import numpy as np

Number = Union[int, float]


class Counter:
    """Monotonic (but resettable) named counter."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: Number = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, v: Number) -> None:
        """Legacy dict-style assignment support (see CounterDict)."""
        with self._lock:
            self._value = v

    @property
    def value(self) -> Number:
        return self._value

    def reset(self) -> None:
        self.set(0)

    def snapshot(self) -> Number:
        return self._value


class Gauge:
    """Last-value-wins instantaneous measurement (queue depth, batch
    occupancy, compile count) with a high-water mark."""

    __slots__ = ("name", "_value", "_max", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._max = 0.0
        self._lock = threading.Lock()

    def set(self, v: Number) -> None:
        with self._lock:
            self._value = v
            if v > self._max:
                self._max = v

    @property
    def value(self) -> Number:
        return self._value

    @property
    def max(self) -> Number:
        return self._max

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0
            self._max = 0.0

    def snapshot(self) -> Dict[str, Number]:
        return {"value": self._value, "max": self._max}


class HistogramState:
    """Opaque snapshot of a histogram's cumulative state, taken with
    :meth:`Histogram.state` and subtracted with :meth:`Histogram.since`
    — the windowed-view primitive the time-series collector and the SLO
    burn-rate windows are built on."""

    __slots__ = ("count", "sum", "buckets", "n_samples", "min", "max")

    def __init__(self, count: int, sum_: float, buckets: np.ndarray,
                 n_samples: int, min_: float, max_: float):
        self.count = count
        self.sum = sum_
        self.buckets = buckets
        self.n_samples = n_samples
        self.min = min_
        self.max = max_


class Histogram:
    """Bounded log-linear histogram with an exact sample window.

    Parameters
    ----------
    lo, hi:      resolvable value range; values clamp into
                 ``[lo, hi)`` (underflow/overflow buckets count them).
    sub:         linear sub-buckets per octave (HDR-style); relative
                 bucket width is ``2^(1/sub) - 1`` (~4.4% at sub=16).
    max_samples: exact window size.  Below it, ``percentile`` is
                 bit-for-bit ``np.percentile``; above, bucket-
                 interpolated (``saturated`` flips to True).
    exemplar_cap: bounded (trace id, value) exemplar reservoir size per
                 bucket; recordings that pass ``exemplar=`` feed it.
    seed:        exemplar reservoir rng seed — a fixed seed over a
                 fixed stream keeps the retained exemplars
                 deterministic (golden-tested).
    """

    __slots__ = ("name", "lo", "hi", "sub", "max_samples", "_buckets",
                 "_samples", "_count", "_sum", "_min", "_max", "_lock",
                 "exemplar_cap", "seed", "_exemplars", "_ex_seen",
                 "_ex_rng")

    def __init__(self, name: str = "", lo: float = 1e-3, hi: float = 1e9,
                 sub: int = 16, max_samples: int = 65536,
                 exemplar_cap: int = 4, seed: int = 0):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got {lo}/{hi}")
        self.name = name
        self.lo = float(lo)
        self.hi = float(hi)
        self.sub = int(sub)
        self.max_samples = int(max_samples)
        self.exemplar_cap = int(exemplar_cap)
        self.seed = int(seed)
        n_octaves = int(math.ceil(math.log2(hi / lo)))
        # bucket 0: underflow; buckets 1..n: log-linear; last: overflow
        self._buckets = np.zeros(n_octaves * self.sub + 2, dtype=np.int64)
        self._samples: list = []
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        # bucket -> bounded reservoir of (trace_id, value) exemplars
        self._exemplars: Dict[int, List[Tuple[int, float]]] = {}
        self._ex_seen: Dict[int, int] = {}   # stream length per bucket
        self._ex_rng = random.Random(self.seed)
        self._lock = threading.Lock()

    @classmethod
    def from_samples(cls, values, name: str = "", **kw) -> "Histogram":
        """Histogram over a replayed sample, window sized to keep it
        exact — the unified percentile path for the benches."""
        values = np.asarray(values, dtype=np.float64).ravel()
        kw.setdefault("max_samples", max(len(values), 1))
        h = cls(name=name, **kw)
        h.record_many(values)
        return h

    # -- recording ------------------------------------------------------

    def _idx(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return len(self._buckets) - 1
        return 1 + int(math.log2(v / self.lo) * self.sub)

    def record(self, v: Number, exemplar: Optional[int] = None) -> None:
        """Record one value; ``exemplar=`` attaches a trace id to the
        value's bucket reservoir (Algorithm-R reservoir sampling with
        the histogram's seeded rng, so a fixed stream retains a fixed
        exemplar set — "show me an actual p99 request" is then a bucket
        lookup)."""
        v = float(v)
        with self._lock:
            i = self._idx(v)
            self._buckets[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if len(self._samples) < self.max_samples:
                self._samples.append(v)
            if exemplar is not None:
                seen = self._ex_seen.get(i, 0)
                self._ex_seen[i] = seen + 1
                res = self._exemplars.setdefault(i, [])
                if len(res) < self.exemplar_cap:
                    res.append((int(exemplar), v))
                else:
                    j = self._ex_rng.randrange(seen + 1)
                    if j < self.exemplar_cap:
                        res[j] = (int(exemplar), v)

    def record_many(self, values) -> None:
        for v in np.asarray(values, dtype=np.float64).ravel():
            self.record(v)

    # -- merge / windowed views -----------------------------------------

    def _compatible(self, other: "Histogram") -> None:
        if (self.lo, self.hi, self.sub) != (other.lo, other.hi, other.sub):
            raise ValueError(
                f"cannot combine histograms with different bucket "
                f"layouts: lo/hi/sub {self.lo}/{self.hi}/{self.sub} vs "
                f"{other.lo}/{other.hi}/{other.sub}")

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into this histogram (bucket layouts must
        match).  While the combined count fits this histogram's exact
        window, merged percentiles are bit-for-bit ``np.percentile`` on
        the concatenated samples; past saturation they degrade to the
        usual bucket interpolation.  Returns ``self``."""
        self._compatible(other)
        with other._lock:
            buckets = other._buckets.copy()
            count, sum_ = other._count, other._sum
            mn, mx = other._min, other._max
            samples = list(other._samples)
        with self._lock:
            self._buckets += buckets
            self._count += count
            self._sum += sum_
            if mn < self._min:
                self._min = mn
            if mx > self._max:
                self._max = mx
            room = self.max_samples - len(self._samples)
            if room > 0:
                self._samples.extend(samples[:room])
        return self

    def state(self) -> HistogramState:
        """Cumulative snapshot for later :meth:`since` subtraction."""
        with self._lock:
            return HistogramState(self._count, self._sum,
                                  self._buckets.copy(),
                                  len(self._samples), self._min, self._max)

    def since(self, prev: Optional[HistogramState]) -> "Histogram":
        """A new histogram holding only what was recorded after
        ``prev`` (``None``: everything) — snapshot-delta subtraction.

        While both snapshots were unsaturated the window's samples are
        exact (the sample list is append-only below ``max_samples``),
        so the windowed percentiles are bit-for-bit ``np.percentile``
        of the values recorded in between; otherwise they fall back to
        the bucket-diff interpolation."""
        out = Histogram(name=self.name, lo=self.lo, hi=self.hi,
                        sub=self.sub, max_samples=self.max_samples)
        with self._lock:
            buckets = self._buckets.copy()
            count, sum_ = self._count, self._sum
            mn, mx = self._min, self._max
            tail = list(self._samples[prev.n_samples:]) if prev else \
                list(self._samples)
        if prev is None:
            out._buckets[:] = buckets
            out._count, out._sum = count, sum_
        else:
            out._buckets[:] = buckets - prev.buckets
            out._count = count - prev.count
            out._sum = sum_ - prev.sum
        out._samples = tail
        if out._count == len(tail) and tail:
            out._min = min(tail)
            out._max = max(tail)
        elif out._count:
            # saturated window: exact extrema unknown — inherit the
            # cumulative bounds (they still bracket every windowed value)
            out._min, out._max = mn, mx
        return out

    def count_above(self, threshold: float) -> int:
        """Recordings ``>= threshold`` — the bad-event count for a
        latency SLO.  Exact while unsaturated; afterwards counted at
        bucket granularity (the threshold's whole bucket is included,
        so the answer errs toward alerting)."""
        v = float(threshold)
        with self._lock:
            if self._count <= len(self._samples):
                if not self._samples:
                    return 0
                return int(np.sum(
                    np.asarray(self._samples, dtype=np.float64) >= v))
            return int(self._buckets[self._idx(v):].sum())

    # -- percentiles ----------------------------------------------------

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def min(self) -> float:
        return self._min

    @property
    def max(self) -> float:
        return self._max

    @property
    def saturated(self) -> bool:
        """True once the exact window overflowed: percentiles are now
        bucket-interpolated (bounded relative error), not exact."""
        return self._count > len(self._samples)

    def _edge(self, i: int) -> float:
        """Lower value edge of log-linear bucket ``i`` (1-based)."""
        return self.lo * 2.0 ** ((i - 1) / self.sub)

    def percentile(self, p: float) -> float:
        """The ``p``-th percentile.  Unsaturated: exactly
        ``float(np.percentile(samples, p))``."""
        with self._lock:
            if self._count == 0:
                return float("nan")
            if self._count <= len(self._samples):
                return float(np.percentile(
                    np.asarray(self._samples, dtype=np.float64), p))
            buckets = self._buckets.copy()
            mn, mx = self._min, self._max
        # saturated: rank interpolation over the bucket cumulative
        cum = np.cumsum(buckets)
        rank = (cum[-1] - 1) * (p / 100.0)
        i = int(np.searchsorted(cum, rank, side="right"))
        i = min(i, len(buckets) - 1)
        if i == 0:
            return mn
        if i == len(buckets) - 1:
            return mx
        lo_e, hi_e = self._edge(i), self._edge(i + 1)
        prev = cum[i - 1]
        frac = (rank - prev + 1) / max(buckets[i], 1)
        return float(min(max(lo_e + (hi_e - lo_e) * min(frac, 1.0), mn), mx))

    def percentiles(self, ps: Sequence[float] = (50, 95, 99, 99.9)
                    ) -> Dict[str, float]:
        def key(p: float) -> str:
            return f"p{p}".replace("99.9", "999").replace(".", "_")

        return {key(p): self.percentile(p) for p in ps}

    def percentile_dict(self, ps: Sequence[float] = (50, 95, 99),
                        prefix: str = "p", suffix: str = "") -> Dict[str, float]:
        """{f"{prefix}{p}{suffix}": value} — the benches' legacy key
        shapes (``p50`` / ``lat_p50_us``) from one implementation."""
        return {f"{prefix}{int(p) if float(p).is_integer() else p}{suffix}":
                self.percentile(p) for p in ps}

    # -- exemplars ------------------------------------------------------

    def exemplars(self) -> Dict[int, List[Tuple[int, float]]]:
        """{bucket index: [(trace_id, value), ...]} — every retained
        exemplar reservoir (buckets that never saw an ``exemplar=``
        recording are absent)."""
        with self._lock:
            return {i: list(res) for i, res in self._exemplars.items()
                    if res}

    def exemplars_near(self, v: float) -> List[Tuple[int, float]]:
        """The exemplar reservoir of the bucket ``v`` falls in — e.g.
        ``h.exemplars_near(h.percentile(99))`` answers "show me actual
        p99 requests" as a lookup."""
        with self._lock:
            return list(self._exemplars.get(self._idx(float(v)), ()))

    def reset(self) -> None:
        with self._lock:
            self._buckets[:] = 0
            self._samples = []
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf
            self._exemplars = {}
            self._ex_seen = {}
            self._ex_rng = random.Random(self.seed)

    def snapshot(self) -> Dict[str, Number]:
        out: Dict[str, Number] = {
            "count": self._count,
            "sum": self._sum,
            "min": self._min if self._count else None,
            "max": self._max if self._count else None,
            "saturated": self.saturated,
        }
        if self._count:
            out.update(self.percentiles())
        if self._exemplars:
            out["exemplars"] = {
                str(i): [[tid, val] for tid, val in res]
                for i, res in sorted(self._exemplars.items()) if res}
        return out


class Registry:
    """Name -> metric, get-or-create; one global instance plus private
    ones for deterministic tests (``Frontend(metrics=Registry())``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(m).__name__}, not a "
                    f"{cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def items(self) -> list:
        """Sorted ``[(name, metric), ...]`` over the live metric
        objects — the iteration surface for the time-series collector
        and the OpenMetrics exporter."""
        with self._lock:
            return sorted(self._metrics.items())

    def snapshot(self) -> Dict[str, dict]:
        """{counters: {...}, gauges: {...}, histograms: {...}} — the
        metrics half of ``repro.obs.snapshot()``."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for name, m in sorted(items):
            if isinstance(m, Counter):
                out["counters"][name] = m.snapshot()
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.snapshot()
            else:
                out["histograms"][name] = m.snapshot()
        return out

    def reset(self) -> None:
        """Zero every registered metric (registrations stay)."""
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            m.reset()

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path


REGISTRY = Registry()


class CounterDict(MutableMapping):
    """Dict-shaped live view over registry counters.

    The legacy module globals (``engine.UPLOAD_COUNTERS``) were plain
    dicts that benchmarks read with ``dict(...)`` and code bumped with
    ``d[k] += 1``; this view keeps that surface while the values live in
    the registry, so ``repro.obs.snapshot()`` sees them too.
    """

    def __init__(self, prefix: str, keys: Iterable[str],
                 registry: Optional[Registry] = None):
        self._registry = registry or REGISTRY
        self._prefix = prefix
        self._keys = list(keys)
        for k in self._keys:
            self._registry.counter(prefix + k)

    def __getitem__(self, k: str) -> Number:
        if k not in self._keys:
            raise KeyError(k)
        return self._registry.counter(self._prefix + k).value

    def __setitem__(self, k: str, v: Number) -> None:
        if k not in self._keys:
            self._keys.append(k)
        self._registry.counter(self._prefix + k).set(v)

    def __delitem__(self, k: str) -> None:
        raise TypeError("CounterDict keys are fixed at registration")

    def __iter__(self):
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)


def latency_percentiles(lat_us, ps: Sequence[float] = (50, 95, 99),
                        prefix: str = "p", suffix: str = "") -> Dict[str, float]:
    """Percentiles of a replayed latency sample (µs) through the one
    Histogram implementation — shared by ``launch/serve.py`` and
    ``benchmarks/perf_rangereach.py`` (golden-tested bit-for-bit against
    the ``np.percentile`` math it replaced)."""
    return Histogram.from_samples(lat_us).percentile_dict(
        ps, prefix=prefix, suffix=suffix)
