"""Public EmbeddingBag op built on the segment_bag kernel.

``embedding_bag(table, indices, offsets)`` mirrors torch.nn.EmbeddingBag
(mode 'sum' / 'mean'): bag b consumes ``indices[offsets[b]:offsets[b+1]]``.
The host packs (indices, segments, weights) into tile-aligned arrays; the
device path is the Pallas kernel (interpret on CPU) or the jnp ref — both
asserted identical in tests.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax.numpy as jnp

from .kernel import TL, segment_bag_pallas
from .ref import segment_bag_ref


def pack_bags(indices: np.ndarray, offsets: np.ndarray, tl: int = TL):
    """-> (idx, seg, w) tile-aligned arrays for the kernel."""
    indices = np.asarray(indices, dtype=np.int32)
    offsets = np.asarray(offsets, dtype=np.int64)
    B = len(offsets) - 1
    L = len(indices)
    seg = np.repeat(
        np.arange(B, dtype=np.int32), np.diff(offsets).astype(np.int64)
    )
    Lp = max(tl, ((L + tl - 1) // tl) * tl)
    idx_p = np.zeros(Lp, dtype=np.int32)
    seg_p = np.full(Lp, B, dtype=np.int32)
    w_p = np.zeros(Lp, dtype=np.float32)
    idx_p[:L] = indices
    seg_p[:L] = seg
    w_p[:L] = 1.0
    return idx_p, seg_p, w_p


def embedding_bag(
    table,
    indices: np.ndarray,
    offsets: np.ndarray,
    mode: str = "sum",
    *,
    use_ref: bool = False,
    interpret: bool = True,
):
    """EmbeddingBag over a (V, D) table; returns (B, D)."""
    assert mode in ("sum", "mean")
    B = len(offsets) - 1
    idx, seg, w = pack_bags(indices, offsets)
    if use_ref:
        out = segment_bag_ref(
            jnp.asarray(table), jnp.asarray(idx), jnp.asarray(seg),
            jnp.asarray(w), n_segments=B,
        )
    else:
        out = segment_bag_pallas(
            jnp.asarray(table), jnp.asarray(idx), jnp.asarray(seg),
            jnp.asarray(w), n_segments=B, interpret=interpret,
        )
    if mode == "mean":
        cnt = np.maximum(np.diff(np.asarray(offsets)), 1).astype(np.float32)
        out = out / jnp.asarray(cnt)[:, None]
    return out
