"""Device QueryEngine vs the query_host oracle — exactness, edge cases,
bucket boundaries, and the compile-once contract."""

import numpy as np
import pytest

from repro.core import QueryEngine, batch_query, build_2dreach, engine_for
from repro.core.api import build_index
from repro.core.graph import make_graph
from repro.data import get_dataset, workload
from repro.kernels.range_query import ops as rq_ops
from repro.kernels.range_query.descent import (
    build_tile_pyramid,
    prune_tiles_pallas,
    prune_tiles_ref,
)
from repro.kernels.range_query.kernel import TB, TP


@pytest.fixture(scope="module")
def graph():
    return get_dataset("yelp", scale=0.05)


@pytest.fixture(scope="module")
def indexes(graph):
    return {v: build_2dreach(graph, variant=v)
            for v in ("base", "comp", "pointer")}


# ---------------------------------------------------------------- exactness
@pytest.mark.parametrize("variant", ["base", "comp", "pointer"])
def test_engine_matches_host_oracle(graph, indexes, variant):
    idx = indexes[variant]
    eng = QueryEngine(idx)
    for seed in range(4):
        us, rects = workload(graph, 200, extent_ratio=0.05, seed=seed)
        want = idx.query_batch(us, rects)   # host path == query_host oracle
        got = eng.query_batch(us, rects)
        assert (want == got).all()
        assert got.dtype == np.bool_ and got.shape == want.shape


@pytest.mark.parametrize("variant", ["comp", "pointer"])
def test_engine_spatial_query_vertices(graph, indexes, variant):
    """Alg. 2 special case: excluded (spatial-sink) query vertices answer
    by their own point — must fuse identically on device."""
    idx = indexes[variant]
    eng = QueryEngine(idx)
    exc = np.nonzero(idx.excluded)[0]
    assert exc.size, "fixture graph should have spatial sinks"
    rng = np.random.default_rng(7)
    us = rng.choice(exc, size=64)
    # half the rects centred on the vertex itself (hit), half far away
    pts = idx.coords[us]
    rects = np.concatenate([pts - 0.01, pts + 0.01], axis=1).astype(np.float32)
    rects[::2] += 1e3    # guaranteed miss
    want = idx.query_batch(us, rects)
    got = eng.query_batch(us, rects)
    assert (want == got).all()
    assert want[1::2].all() and not want[::2].any()


def test_engine_empty_tree_and_excluded_edge_cases():
    """Vertices with no reachable venues (tid -1), empty forests, and an
    all-excluded batch must answer False / point-test without error."""
    # graph: 0 -> 1 (venue), 2 isolated user, 3 isolated venue
    edges = np.array([[0, 1]], dtype=np.int64)
    coords = np.array([[0, 0], [1, 1], [0, 0], [5, 5]], dtype=np.float32)
    spatial = np.array([False, True, False, True])
    g = make_graph(4, edges, coords, spatial)
    for variant in ("base", "comp", "pointer"):
        idx = build_2dreach(g, variant=variant)
        eng = QueryEngine(idx)
        us = np.array([0, 2, 3, 1])
        rects = np.array([[0.5, 0.5, 1.5, 1.5]] * 4, dtype=np.float32)
        want = idx.query_batch(us, rects)
        got = eng.query_batch(us, rects)
        assert (want == got).all(), variant
        assert want[0] and not want[1]   # 0 reaches venue 1; 2 reaches none


def test_engine_rejects_non_2dreach(graph):
    idx = build_index(graph, "georeach")
    assert engine_for(idx) is None
    with pytest.raises(TypeError):
        QueryEngine(idx)


def test_engine_for_required_raises_clear_error(graph):
    """engine_for(required=True) names the unsupported index instead of
    the caller tripping an AttributeError deep inside the engine."""
    idx = build_index(graph, "georeach")
    with pytest.raises(ValueError, match="GeoReachIndex"):
        engine_for(idx, required=True)
    # DynamicIndex device/cluster serving on an unsupported method fails
    # at construction, naming the method
    from repro.core import build_dynamic_index

    for eng in ("device", "cluster"):
        with pytest.raises(ValueError, match="georeach"):
            build_dynamic_index(graph, "georeach", engine=eng)


# ---------------------------------------------------------------- buckets
@pytest.mark.parametrize("B", [1, TB, TB + 1, 2 * TB, 100])
def test_engine_bucket_boundaries(graph, indexes, B):
    idx = indexes["comp"]
    eng = QueryEngine(idx)
    us, rects = workload(graph, B, extent_ratio=0.05, seed=B)
    assert (idx.query_batch(us, rects) == eng.query_batch(us, rects)).all()


def test_engine_bucket_padding_is_inert():
    """Padded batch lanes must activate no tiles even when the data
    extent spans the padding sentinel (coords straddling [0, 1])."""
    rng = np.random.default_rng(11)
    n, nv = 40, 12
    coords = (rng.random((n, 2)) * 10 - 5).astype(np.float32)  # [-5, 5)
    spatial = np.zeros(n, dtype=bool)
    spatial[:nv] = True
    edges = np.stack([np.arange(nv, n), rng.integers(0, nv, n - nv)], axis=1)
    g = make_graph(n, edges.astype(np.int64), coords, spatial)
    idx = build_2dreach(g, variant="comp")
    eng = QueryEngine(idx)
    u = np.array([nv])                       # B=1 -> TB-1 padded lanes
    far = np.array([[50, 50, 51, 51]], np.float32)   # guaranteed miss
    assert not eng.query_batch(u, far)[0]
    assert eng.stats["tiles_scanned"] == 0, \
        "padded lanes (or a missing rect) activated leaf tiles"
    hit = np.array([[-6, -6, 6, 6]], np.float32)     # covers everything
    assert eng.query_batch(u, hit)[0] == idx.query_batch(u, hit)[0]


def test_engine_empty_batch(indexes):
    eng = QueryEngine(indexes["comp"])
    out = eng.query_batch(np.zeros(0, np.int64), np.zeros((0, 4), np.float32))
    assert out.shape == (0,) and out.dtype == np.bool_


# ---------------------------------------------------------- compile-once
def test_engine_no_steady_state_recompiles(graph, indexes):
    idx = indexes["pointer"]
    eng = QueryEngine(idx)
    # warm the buckets for B in {1..128} and the K buckets they induce
    for seed, B in [(0, 1), (1, 8), (2, 100), (3, 128)]:
        us, rects = workload(graph, B, extent_ratio=0.05, seed=seed)
        eng.query_batch(us, rects)
    warm = eng.n_compiles
    soa0 = rq_ops.SOA_BUILDS
    for seed, B in [(10, 3), (11, 100), (12, 77), (13, 128), (14, 1)]:
        us, rects = workload(graph, B, extent_ratio=0.05, seed=seed)
        assert (idx.query_batch(us, rects) == eng.query_batch(us, rects)).all()
    # jit cache-size introspection: nothing re-traced, nothing re-uploaded
    assert eng.n_compiles == warm
    assert rq_ops.SOA_BUILDS == soa0
    assert eng.stats["uploads"] == 1


def test_engine_for_memoised(indexes):
    idx = indexes["base"]
    assert engine_for(idx) is engine_for(idx)
    us = np.array([0]); rects = np.array([[0, 0, 1, 1]], np.float32)
    assert (batch_query(idx, us, rects, engine="device")
            == batch_query(idx, us, rects)).all()
    with pytest.raises(ValueError):
        batch_query(idx, us, rects, engine="warp")


def test_engine_prunes_leaf_tiles(graph, indexes):
    eng = QueryEngine(indexes["comp"])
    us, rects = workload(graph, 256, extent_ratio=0.05, seed=3)
    eng.query_batch(us, rects)
    assert 0 < eng.stats["tiles_scanned"] < eng.stats["tiles_full_scan"]


# ---------------------------------------------------------- dynamic base
def test_dynamic_device_engine_exact_across_compaction():
    """DynamicIndex(engine="device"): the device engine serves the static
    base (rebuilt on every compaction swap), the overlay stays host-side,
    and answers stay exact vs the BFS oracle through the swap."""
    from repro.core import build_dynamic_index, rangereach_oracle_batch
    from repro.data import apply_stream_op, streaming_workload
    from repro.dynamic import CompactionPolicy

    g = get_dataset("yelp", scale=0.05)
    dyn = build_dynamic_index(
        g, "2dreach-comp", engine="device",
        policy=CompactionPolicy(max_overlay_edges=60, background=False),
    )
    eng0 = dyn.base_engine
    assert eng0 is not None
    for op in streaming_workload(g, n_steps=300, seed=23, p_query=0.4,
                                 p_edge=0.4, p_vertex=0.1, p_spatial=0.1):
        apply_stream_op(dyn, op)
    assert dyn.stats["n_compactions"] >= 1
    assert dyn.base_engine is not None and dyn.base_engine is not eng0, \
        "compaction swap must rebuild the device engine over the new base"
    gm = dyn.snapshot_graph()
    vu, vr = workload(gm, 64, extent_ratio=0.05, seed=99)
    assert (dyn.query_batch(vu, vr)
            == rangereach_oracle_batch(gm, vu, vr)).all()


def test_dynamic_engine_validates_kind():
    from repro.core import build_dynamic_index

    g = get_dataset("yelp", scale=0.05)
    with pytest.raises(ValueError):
        build_dynamic_index(g, "2dreach-comp", engine="warp")


# ---------------------------------------------------------- prune kernel
@pytest.mark.parametrize("P,B", [(1, 8), (130, 16), (700, 8), (2000, 24)])
def test_prune_kernel_vs_ref(P, B):
    rng = np.random.default_rng(P + B)
    pts = (rng.random((P, 2)) * 10).astype(np.float32)
    Pp = max(TP, -(-P // TP) * TP)
    esoa = np.empty((4, Pp), np.float32)
    esoa[:2] = 1.0
    esoa[2:] = 0.0
    esoa[:, :P] = np.concatenate([pts, pts], axis=1).T
    fine, coarse, nt = build_tile_pyramid(esoa, dim=2)
    assert nt == Pp // TP
    c = (rng.random((B, 2)) * 10).astype(np.float32)
    r = (rng.random((B, 2)) * 2).astype(np.float32)
    rsoa = np.concatenate([c - r, c + r], axis=1).T.astype(np.float32)
    qs = rng.integers(0, P, size=B).astype(np.int32)
    qe = np.minimum(qs + rng.integers(0, P + 1, size=B), P).astype(np.int32)
    got = np.asarray(prune_tiles_pallas(fine, coarse, rsoa, qs, qe,
                                        interpret=True))
    want = np.asarray(prune_tiles_ref(fine, coarse, rsoa, qs, qe))
    assert (got == want).all()
    # soundness: every entry hit lies in an active tile of its query tile
    for b in range(B):
        ok = ((pts[:, 0] >= rsoa[0, b]) & (pts[:, 1] >= rsoa[1, b])
              & (pts[:, 0] <= rsoa[2, b]) & (pts[:, 1] <= rsoa[3, b]))
        ok &= (np.arange(P) >= qs[b]) & (np.arange(P) < qe[b])
        for e in np.nonzero(ok)[0]:
            assert got[b // TB, e // TP] == 1
