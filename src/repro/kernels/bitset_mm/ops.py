"""jit'd wrappers for the packed boolean closure.

* ``bitset_mm``        — one OR-AND matmul step (Pallas kernel, padded).
* ``bitset_mm_mxu``    — the MXU alternative: unpack to bf16, real matmul,
                          re-threshold, re-pack.  Trades 32x VMEM expansion
                          of the operands for systolic-array throughput;
                          wins for large d (see EXPERIMENTS.md §Perf).
* ``closure_fixpoint`` — R <- OWN | A.R iterated ``n_iters`` (>= DAG
                          depth) times: the TPU build path of paper Alg. 1.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .kernel import TI, TW, bitset_mm_pallas
from .ref import bitset_mm_ref, pack_bits_jnp, unpack_bits_jnp


def _pad_to(x: np.ndarray, rows: int, cols: int) -> np.ndarray:
    out = np.zeros((rows, cols), dtype=x.dtype)
    out[: x.shape[0], : x.shape[1]] = x
    return out


def bitset_mm(
    a_bits: np.ndarray,
    r_bits: np.ndarray,
    *,
    interpret: bool = True,
    use_ref: bool = False,
) -> np.ndarray:
    """out[i, w] = OR_j (A[i, j] & R[j, w]); handles padding."""
    d, Wd = a_bits.shape
    dj, W = r_bits.shape
    assert dj <= Wd * 32
    dp = ((d + TI - 1) // TI) * TI
    Wp = ((W + TW - 1) // TW) * TW
    a = _pad_to(np.asarray(a_bits, np.uint32), dp, Wd)
    r = _pad_to(np.asarray(r_bits, np.uint32), Wd * 32, Wp)
    if use_ref:
        out = bitset_mm_ref(jnp.asarray(a), jnp.asarray(r))
    else:
        out = bitset_mm_pallas(
            jnp.asarray(a), jnp.asarray(r), interpret=interpret
        )
    return np.asarray(out)[:d, :W]


@jax.jit
def _mxu_step(a_bits, r_bits):
    d, Wd = a_bits.shape
    dj, W = r_bits.shape
    a = unpack_bits_jnp(a_bits, dj).astype(jnp.bfloat16)
    r = unpack_bits_jnp(r_bits, W * 32).astype(jnp.bfloat16)
    prod = jax.lax.dot_general(
        a, r, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    return pack_bits_jnp(prod > 0)


def bitset_mm_mxu(a_bits: np.ndarray, r_bits: np.ndarray) -> np.ndarray:
    """MXU path: bf16 matmul of unpacked bits, repacked.  Correct whenever
    the per-output-dot true-count < 256 is NOT required: counts saturate
    bf16 accumulation into f32, and we only test > 0, so any count works."""
    return np.asarray(
        _mxu_step(jnp.asarray(a_bits, jnp.uint32), jnp.asarray(r_bits, jnp.uint32))
    )


def bitset_mm_dev(
    a_bits: jax.Array,   # (f, Wm) uint32 packed adjacency rows
    r_bits: jax.Array,   # (m, W) uint32 packed set rows, m <= Wm * 32
    *,
    interpret: bool = True,
) -> jax.Array:
    """Device-resident ``bitset_mm``: jnp padding, no host round-trip.

    The level-scheduled closure (:func:`repro.core.reachability
    .closure_bitset_mm`) calls this once per condensation level with the
    level's *frontier* — the compacted (source rows x unique-destination
    columns) block — so converged rows outside the frontier pay nothing.
    Returns the unpadded (f, W) OR-AND product, still on device.
    """
    f, Wm = a_bits.shape
    m, W = r_bits.shape
    assert m <= Wm * 32, (m, Wm)
    fp = ((f + TI - 1) // TI) * TI
    Wp = ((W + TW - 1) // TW) * TW
    a = jnp.pad(a_bits, ((0, fp - f), (0, 0)))
    r = jnp.pad(r_bits, ((0, Wm * 32 - m), (0, Wp - W)))
    out = bitset_mm_pallas(a, r, interpret=interpret)
    return out[:f, :W]


def closure_fixpoint(
    own_bits: np.ndarray,   # (d, W) uint32 — own spatial columns per comp
    a_bits: np.ndarray,     # (d, ceil(d/32)) uint32 — DAG adjacency, packed
    n_iters: int,
    *,
    interpret: bool = True,
    use_mxu: bool = False,
) -> np.ndarray:
    """R <- OWN | A.R iterated; returns the reachable-set bitset matrix.

    ``n_iters`` must be >= the condensation's level count (longest path).
    """
    r = np.asarray(own_bits, np.uint32)
    for _ in range(int(n_iters)):
        step = (
            bitset_mm_mxu(a_bits, r)
            if use_mxu
            else bitset_mm(a_bits, r, interpret=interpret)
        )
        nxt = np.bitwise_or(np.asarray(own_bits, np.uint32), step)
        if np.array_equal(nxt, r):
            break
        r = nxt
    return r
