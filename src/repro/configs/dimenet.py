"""dimenet [gnn]: 6 blocks d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6 — directional message passing, triplet gather.
[arXiv:2003.03123]"""
from ..models.gnn import dimenet as module
from ..models.gnn.dimenet import DimeNetConfig
from .base import ArchSpec, gnn_cells

NAME = "dimenet"


def make_config(reduced: bool = False, d_feat=None, shape=None
                ) -> DimeNetConfig:
    if reduced:
        return DimeNetConfig(n_blocks=2, d_hidden=32, n_bilinear=4,
                             n_spherical=4, n_radial=4)
    return DimeNetConfig(n_blocks=6, d_hidden=128, n_bilinear=8,
                         n_spherical=7, n_radial=6, d_feat=d_feat)


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="gnn", make_config=make_config,
        cells=gnn_cells(NAME, module, make_config),
        notes="triplet budget = 2*E on the large graph cells (capped "
              "2^26); feature-graph cells synthesize 3-D positions",
    )
