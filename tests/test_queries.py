"""The repro.queries analytics subsystem: count / collect / kNN-Reach /
polygon regions — oracle-checked across the three 2DReach variants,
host vs device bit-identity, edge cases, kernel units, the dynamic
overlay merges, and the compile-once contract."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import QueryEngine, build_2dreach, run_queries
from repro.core.engine import engine_for
from repro.core.graph import make_graph
from repro.core.oracle import (
    knn_reach_oracle,
    polygon_reach_oracle,
    range_collect_oracle,
    range_count_oracle,
)
from repro.core.polygon import (
    convex_halfplanes,
    points_in_polygon_region,
    polygon_bbox,
    polygon_query,
)
from repro.data import (
    get_dataset,
    knn_workload,
    polygon_workload,
    workload,
)
from repro.kernels.range_query.analytics import (
    ID_SENTINEL,
    collect_scan_ref,
    count_scan_ref,
    polygon_scan_ref,
)
from repro.kernels.range_query.kernel import TB, TP
from repro.queries import (
    QueryProgram,
    knn_reach_host,
    polygon_reach_host,
    range_collect_host,
    range_count_host,
)

VARIANTS = ("base", "comp", "pointer")


@pytest.fixture(scope="module")
def graph():
    return get_dataset("yelp", scale=0.05)


@pytest.fixture(scope="module")
def indexes(graph):
    return {v: build_2dreach(graph, variant=v) for v in VARIANTS}


@pytest.fixture(scope="module")
def engines(indexes):
    return {v: QueryEngine(idx) for v, idx in indexes.items()}


def _polygons(g, n, seed, n_edges=5):
    _, polys = polygon_workload(g, n, n_edges=n_edges, seed=seed)
    return polys


def _assert_collect_equal(a, b):
    assert (a.ids == b.ids).all()
    assert (a.counts == b.counts).all()
    assert (a.overflow == b.overflow).all()


def _assert_knn_equal(a, b):
    assert (a.ids == b.ids).all()
    assert (a.dist2 == b.dist2).all()


# ------------------------------------------------------------- exactness
@pytest.mark.parametrize("variant", VARIANTS)
def test_count_oracle_and_device(graph, indexes, engines, variant):
    idx, eng = indexes[variant], engines[variant]
    for seed in range(3):
        us, rects = workload(graph, 100, extent_ratio=0.05, seed=seed)
        host = range_count_host(idx, us, rects)
        want = np.array([range_count_oracle(graph, int(u), r)
                         for u, r in zip(us, rects)])
        assert (host == want).all()
        dev = eng.count_batch(us, rects)
        assert dev.dtype == np.int64 and (dev == host).all()


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("k", [1, 3, 16])
def test_collect_oracle_and_device(graph, indexes, engines, variant, k):
    idx, eng = indexes[variant], engines[variant]
    us, rects = workload(graph, 100, extent_ratio=0.05, seed=k)
    host = range_collect_host(idx, us, rects, k)
    dev = eng.collect_batch(us, rects, k)
    _assert_collect_equal(host, dev)
    for b in range(len(us)):
        want = range_collect_oracle(graph, int(us[b]), rects[b])
        assert host.counts[b] == len(want)
        assert (host.row(b) == want[:k]).all()   # K smallest, ascending
        assert host.overflow[b] == (len(want) > k)


@pytest.mark.parametrize("variant", VARIANTS)
def test_knn_oracle_and_device(graph, indexes, engines, variant):
    idx, eng = indexes[variant], engines[variant]
    us, points = knn_workload(graph, 64, seed=7)
    for k in (1, 5):
        host = knn_reach_host(idx, us, points, k)
        dev = eng.knn_batch(us, points, k)
        _assert_knn_equal(host, dev)
        for b in range(len(us)):
            oi, od2 = knn_reach_oracle(graph, int(us[b]), points[b], k)
            assert (host.row(b) == oi).all()
            assert (host.dist2[b, : len(od2)] == od2).all()


@pytest.mark.parametrize("variant", VARIANTS)
def test_polygon_oracle_and_device(graph, indexes, engines, variant):
    idx, eng = indexes[variant], engines[variant]
    us, _ = workload(graph, 80, extent_ratio=0.05, seed=3)
    polys = _polygons(graph, 80, seed=4)
    host = polygon_reach_host(idx, us, polys)
    want = np.array([polygon_reach_oracle(graph, int(u), p)
                     for u, p in zip(us, polys)])
    assert (host == want).all()
    dev = eng.polygon_batch(us, polys)
    assert (dev == host).all()
    assert want.any(), "workload should produce some polygon hits"


def test_polygon_mixed_edge_counts(graph, indexes, engines):
    """Batches mixing polygon sizes bucket to one edge count and stay
    exact (inert half-plane padding)."""
    idx, eng = indexes["comp"], engines["comp"]
    rng = np.random.default_rng(9)
    us, _ = workload(graph, 30, extent_ratio=0.05, seed=9)
    polys = []
    for b in range(30):
        polys.append(_polygons(graph, 1, seed=100 + b,
                               n_edges=int(rng.integers(3, 9)))[0])
    host = polygon_reach_host(idx, us, polys)
    assert (eng.polygon_batch(us, polys) == host).all()
    for b in range(len(us)):
        assert host[b] == polygon_reach_oracle(graph, int(us[b]), polys[b])


# ------------------------------------------------------------- edge cases
def _tiny_graph():
    # 0 -> 1 (venue), 2 isolated user, 3 isolated venue, 4 excluded-ish
    edges = np.array([[0, 1], [4, 1]], dtype=np.int64)
    coords = np.array([[0, 0], [1, 1], [0, 0], [5, 5], [0, 0]], np.float32)
    spatial = np.array([False, True, False, True, False])
    return make_graph(5, edges, coords, spatial)


@pytest.mark.parametrize("variant", VARIANTS)
def test_edge_cases_all_classes(variant):
    """Empty trees (tid -1), excluded spatial-sink query vertices and
    isolated venues answer correctly on every class."""
    g = _tiny_graph()
    idx = build_2dreach(g, variant=variant)
    eng = QueryEngine(idx)
    us = np.array([0, 2, 3, 1])
    rects = np.array([[0.5, 0.5, 1.5, 1.5]] * 4, np.float32)
    # count
    want_c = np.array([range_count_oracle(g, int(u), r)
                       for u, r in zip(us, rects)])
    assert (range_count_host(idx, us, rects) == want_c).all()
    assert (eng.count_batch(us, rects) == want_c).all()
    assert want_c[0] == 1 and want_c[1] == 0
    # collect
    host = range_collect_host(idx, us, rects, 2)
    _assert_collect_equal(host, eng.collect_batch(us, rects, 2))
    assert host.row(0).tolist() == [1] and host.row(1).size == 0
    # knn: vertex 3 (isolated venue) is excluded under comp/pointer and
    # reaches only itself; vertex 2 reaches nothing
    pts = np.zeros((4, 2), np.float32)
    hk = knn_reach_host(idx, us, pts, 2)
    _assert_knn_equal(hk, eng.knn_batch(us, pts, 2))
    for b in range(4):
        oi, _ = knn_reach_oracle(g, int(us[b]), pts[b], 2)
        assert (hk.row(b) == oi).all()
    assert hk.row(1).size == 0 and hk.row(2).tolist() == [3]
    # polygon
    tri = np.array([[0.5, 0.5], [1.5, 0.5], [1.0, 1.5]], np.float32)
    polys = [tri] * 4
    hp = polygon_reach_host(idx, us, polys)
    assert (eng.polygon_batch(us, polys) == hp).all()
    for b in range(4):
        assert hp[b] == polygon_reach_oracle(g, int(us[b]), polys[b])


def test_knn_duplicate_coordinate_ties():
    """Venues stacked on identical coordinates tie in distance; the
    canonical (dist², id) order resolves them identically on host,
    device and oracle."""
    n, nv = 20, 8
    coords = np.zeros((n, 2), np.float32)
    coords[:nv] = np.array([1.0, 1.0], np.float32)   # all venues stacked
    coords[2] = [1.0, 1.0]
    coords[4:nv] = [[2.0, 2.0]] * (nv - 4)
    spatial = np.zeros(n, bool)
    spatial[:nv] = True
    edges = np.stack([np.arange(nv, n),
                      np.arange(nv, n) % nv], axis=1)
    # every user reaches every venue through a chain
    chain = np.stack([np.arange(nv, n - 1), np.arange(nv + 1, n)], axis=1)
    to_all = np.stack([np.full(nv, nv), np.arange(nv)], axis=1)
    g = make_graph(n, np.concatenate([edges, chain, to_all]), coords, spatial)
    for variant in VARIANTS:
        idx = build_2dreach(g, variant=variant)
        eng = QueryEngine(idx)
        us = np.array([nv, nv + 1, n - 1])
        pts = np.array([[1.0, 1.0]] * 3, np.float32)
        for k in (2, 4, nv):
            host = knn_reach_host(idx, us, pts, k)
            _assert_knn_equal(host, eng.knn_batch(us, pts, k))
            for b in range(3):
                oi, _ = knn_reach_oracle(g, int(us[b]), pts[b], k)
                assert (host.row(b) == oi).all(), (variant, k, b)
                # ties broken by ascending id
                same = host.dist2[b] == host.dist2[b, 0]
                ids = host.ids[b][same & (host.ids[b] >= 0)]
                assert (np.diff(ids) > 0).all()


def test_collect_overflow_flags(graph, indexes, engines):
    """K-overflow: a rect holding more venues than K flags overflow and
    still returns the K smallest ids."""
    idx, eng = indexes["comp"], engines["comp"]
    ext = graph.spatial_extent()
    big = np.array([[ext[0], ext[1], ext[2], ext[3]]], np.float32)
    us, _ = workload(graph, 64, extent_ratio=0.05, seed=1)
    counts = range_count_host(idx, us, np.tile(big, (len(us), 1)))
    u = us[np.argmax(counts)]
    total = counts.max()
    assert total > 3, "need a query vertex reaching >3 venues"
    host = range_collect_host(idx, np.array([u]), big, 3)
    dev = eng.collect_batch(np.array([u]), big, 3)
    _assert_collect_equal(host, dev)
    assert host.overflow[0] and host.counts[0] == total
    want = range_collect_oracle(graph, int(u), big[0])
    assert (host.row(0) == want[:3]).all()


def test_empty_batches(indexes, engines):
    idx, eng = indexes["comp"], engines["comp"]
    z = np.zeros(0, np.int64)
    zr = np.zeros((0, 4), np.float32)
    zp = np.zeros((0, 2), np.float32)
    assert eng.count_batch(z, zr).shape == (0,)
    assert range_count_host(idx, z, zr).shape == (0,)
    assert eng.collect_batch(z, zr, 3).ids.shape == (0, 3)
    assert eng.knn_batch(z, zp, 3).ids.shape == (0, 3)
    assert eng.polygon_batch(z, []).shape == (0,)


# ------------------------------------------------------------- polygon bbox
def test_polygon_bbox_outward_rounding():
    """Regression: a venue exactly on the hull edge whose coordinate is
    not float32-representable must survive the bbox prefilter — the old
    min-after-downcast could shrink the box past it."""
    x = np.float64(0.1) + 1e-9           # between two float32 neighbours
    v = np.array([[x, 0.0], [x, 2.0], [3.0, 1.0]], np.float64)
    bbox = polygon_bbox(v)
    assert np.float64(bbox[0]) <= x and np.float64(bbox[2]) >= 3.0
    # the venue sits exactly on the hull's left edge at the f32 coord
    vx = np.float32(x)
    assert bbox[0] <= vx, "outward rounding must keep the edge venue"
    # end-to-end: the venue is the only reachable hit
    coords = np.array([[0, 0], [vx, 1.0]], np.float32)
    g = make_graph(2, np.array([[0, 1]]), coords,
                   np.array([False, True]))
    idx = build_2dreach(g, variant="comp")
    # polygon whose left edge passes through the venue
    assert polygon_query(idx, 0, v)
    assert polygon_reach_oracle(g, 0, v)
    eng = QueryEngine(idx)
    assert eng.polygon_batch(np.array([0]), [v])[0]


def test_polygon_region_predicate_consistency():
    """The canonical predicate is shared verbatim: host helper == kernel
    ref on random points/planes."""
    rng = np.random.default_rng(2)
    pts = (rng.random((200, 2)) * 4 - 2).astype(np.float32)
    poly = _polygons(make_graph(
        4, np.zeros((0, 2), np.int64),
        np.array([[-2, -2], [2, 2], [0, 0], [1, 1]], np.float32),
        np.ones(4, bool)), 1, seed=5)[0]
    bbox = polygon_bbox(poly)
    hp = convex_halfplanes(poly, pad_to=8)
    want = points_in_polygon_region(pts, bbox, hp)
    esoa = np.empty((4, 256), np.float32)
    esoa[:2] = np.inf
    esoa[2:] = -np.inf
    esoa[:, :200] = np.concatenate([pts, pts], axis=1).T
    lines = np.tile(hp.reshape(-1, 1), (1, TB)).astype(np.float32)
    rsoa = np.tile(bbox.reshape(4, 1), (1, TB)).astype(np.float32)
    got = np.asarray(polygon_scan_ref(
        jnp.asarray(esoa), jnp.asarray(rsoa), jnp.asarray(lines),
        jnp.zeros(TB, jnp.int32), jnp.full(TB, 200, jnp.int32), ne=8))
    assert bool(got[0]) == bool(want.any())


# ------------------------------------------------------------- kernels
@pytest.mark.parametrize("P,B", [(1, 8), (130, 16), (700, 8)])
def test_count_collect_kernels_vs_ref(P, B):
    from repro.core.engine import compact_candidates
    from repro.kernels.range_query.analytics import (
        collect_scan_pallas,
        count_scan_pallas,
    )
    from repro.kernels.range_query.descent import (
        build_tile_pyramid,
        prune_tiles_pallas,
    )

    rng = np.random.default_rng(P + B)
    pts = (rng.random((P, 2)) * 10).astype(np.float32)
    Pp = max(TP, -(-P // TP) * TP)
    esoa = np.empty((4, Pp), np.float32)
    esoa[:2] = np.inf
    esoa[2:] = -np.inf
    esoa[:, :P] = np.concatenate([pts, pts], axis=1).T
    ids = np.full((1, Pp), ID_SENTINEL, np.int32)
    ids[0, :P] = rng.permutation(P).astype(np.int32)
    fine, coarse, nt = build_tile_pyramid(esoa, dim=2)
    c = (rng.random((B, 2)) * 10).astype(np.float32)
    r = (rng.random((B, 2)) * 3).astype(np.float32)
    rsoa = np.concatenate([c - r, c + r], axis=1).T.astype(np.float32)
    qs = rng.integers(0, P, size=B).astype(np.int32)
    qe = np.minimum(qs + rng.integers(0, P + 1, size=B), P).astype(np.int32)
    mask = prune_tiles_pallas(fine, coarse, rsoa, qs, qe, interpret=True)
    cand, _ = compact_candidates(jnp.asarray(mask), nt)
    got_c = np.asarray(count_scan_pallas(
        cand, jnp.asarray(esoa), jnp.asarray(rsoa),
        jnp.asarray(qs), jnp.asarray(qe), interpret=True))
    want_c = np.asarray(count_scan_ref(
        jnp.asarray(esoa), jnp.asarray(rsoa),
        jnp.asarray(qs), jnp.asarray(qe)))
    assert (got_c == want_c).all()
    mat = np.asarray(collect_scan_pallas(
        cand, jnp.asarray(esoa), jnp.asarray(ids), jnp.asarray(rsoa),
        jnp.asarray(qs), jnp.asarray(qe), interpret=True))
    ref = np.asarray(collect_scan_ref(
        jnp.asarray(esoa), jnp.asarray(ids), jnp.asarray(rsoa),
        jnp.asarray(qs), jnp.asarray(qe)))
    for b in range(B):
        got_ids = np.sort(mat[b][mat[b] != ID_SENTINEL])
        want_ids = np.sort(ref[b][ref[b] != ID_SENTINEL])
        assert (got_ids == want_ids).all(), b
        assert len(got_ids) == want_c[b]   # duplicate-tile padding masked


# ------------------------------------------------------------- dispatch
def test_run_queries_dispatch(graph, indexes):
    idx = indexes["comp"]
    us, rects = workload(graph, 32, extent_ratio=0.05, seed=0)
    prog = QueryProgram.count(us, rects)
    assert (run_queries(idx, prog, engine="host")
            == run_queries(idx, prog, engine="device")).all()
    with pytest.raises(ValueError, match="host|device"):
        run_queries(idx, prog, engine="cluster")
    from repro.core.api import build_index

    geo = build_index(graph, "georeach")
    with pytest.raises(ValueError, match="GeoReachIndex"):
        run_queries(geo, prog, engine="host")
    # reach works on every method through batch_query
    reach = QueryProgram.reach(us, rects)
    assert (run_queries(geo, reach) == idx.query_batch(us, rects)).all()
    with pytest.raises(ValueError):
        QueryProgram.collect(us, rects, 0)
    with pytest.raises(ValueError):
        QueryProgram.polygon(us, [np.zeros((2, 2))] * len(us))


def test_batch_query_device_fallback_warns_or_raises(graph):
    from repro.core.api import build_index, batch_query

    geo = build_index(graph, "georeach")
    us, rects = workload(graph, 8, extent_ratio=0.05, seed=0)
    import repro.core.api as api_mod

    api_mod._FALLBACK_WARNED.discard(
        ("unsupported-index", "GeoReachIndex"))
    with pytest.warns(RuntimeWarning, match="falling back"):
        batch_query(geo, us, rects, engine="device")
    # one-time: a second call stays silent
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        batch_query(geo, us, rects, engine="device")
    with pytest.raises(ValueError, match="GeoReachIndex"):
        batch_query(geo, us, rects, engine="device", required=True)


# ------------------------------------------------------------- compile-once
def test_analytics_no_steady_state_recompiles(graph, indexes):
    idx = indexes["pointer"]
    eng = engine_for(idx)
    polys_all = _polygons(graph, 128, seed=6)
    # warm every class across the batch buckets used below
    for seed, B in [(0, 16), (1, 100), (2, 128)]:
        us, rects = workload(graph, B, extent_ratio=0.05, seed=seed)
        pts = rects[:, :2]
        eng.count_batch(us, rects)
        eng.collect_batch(us, rects, 8)
        eng.knn_batch(us, pts, 8)
        eng.polygon_batch(us, list(polys_all[:B]))
    warm = eng.n_compiles
    for seed, B in [(10, 16), (11, 77), (12, 128)]:
        us, rects = workload(graph, B, extent_ratio=0.05, seed=seed)
        pts = rects[:, :2]
        assert (eng.count_batch(us, rects)
                == range_count_host(idx, us, rects)).all()
        _assert_collect_equal(eng.collect_batch(us, rects, 8),
                              range_collect_host(idx, us, rects, 8))
        _assert_knn_equal(eng.knn_batch(us, pts, 8),
                          knn_reach_host(idx, us, pts, 8))
        assert (eng.polygon_batch(us, list(polys_all[:B]))
                == polygon_reach_host(idx, us, list(polys_all[:B]))).all()
    assert eng.n_compiles == warm, "analytics steady state retraced"


# ------------------------------------------------------------- dynamic
def test_dynamic_analytics_stream_two_swaps():
    """A mutating stream with >= 2 compaction swaps: every class stays
    exact vs the BFS oracles on the mutated graph throughout."""
    from repro.core import build_dynamic_index
    from repro.data import apply_stream_op, streaming_workload
    from repro.dynamic import CompactionPolicy

    g = get_dataset("yelp", scale=0.05)
    dyn = build_dynamic_index(
        g, "2dreach-comp", engine="device",
        policy=CompactionPolicy(max_overlay_edges=30, background=False))
    rng = np.random.default_rng(0)
    checks = 0
    for step, op in enumerate(streaming_workload(
            g, n_steps=260, seed=13, p_query=0.2, p_edge=0.4,
            p_vertex=0.2, p_spatial=0.2)):
        apply_stream_op(dyn, op)
        if step % 65 != 64:
            continue
        gm = dyn.snapshot_graph()
        vu, vr = workload(gm, 16, extent_ratio=0.05, seed=step)
        vu[:3] = rng.integers(g.n_nodes, gm.n_nodes, 3)  # post-snapshot us
        pts = vr[:, :2]
        polys = _polygons(gm, 16, seed=step)
        cnt = dyn.count_batch(vu, vr)
        col = dyn.collect_batch(vu, vr, 4)
        knn = dyn.knn_batch(vu, pts, 5)
        pol = dyn.polygon_batch(vu, polys)
        for b in range(len(vu)):
            u = int(vu[b])
            assert cnt[b] == range_count_oracle(gm, u, vr[b]), (step, b)
            want = range_collect_oracle(gm, u, vr[b])
            assert col.counts[b] == len(want)
            assert (col.row(b) == want[:4]).all()
            oi, _ = knn_reach_oracle(gm, u, pts[b], 5)
            assert (knn.row(b) == oi).all(), (step, b)
            assert pol[b] == polygon_reach_oracle(gm, u, polys[b]), (step, b)
        checks += 1
    assert checks >= 3
    assert dyn.stats["n_compactions"] >= 2, \
        "stream must cross at least two compaction swaps"


def test_dynamic_analytics_rejects_non_2dreach():
    from repro.core import build_dynamic_index

    g = get_dataset("yelp", scale=0.05)
    dyn = build_dynamic_index(g, "georeach")
    us = np.zeros(1, np.int64)
    rects = np.zeros((1, 4), np.float32)
    for call in (lambda: dyn.count_batch(us, rects),
                 lambda: dyn.collect_batch(us, rects, 2),
                 lambda: dyn.knn_batch(us, rects[:, :2], 2),
                 lambda: dyn.polygon_batch(us, [np.eye(3, 2)])):
        with pytest.raises(ValueError, match="georeach"):
            call()


def test_dynamic_analytics_range_check(graph):
    """Out-of-range query vertices raise the same clean IndexError the
    boolean path raises, on every analytics class."""
    from repro.core import build_dynamic_index

    dyn = build_dynamic_index(graph, "2dreach-comp")
    dyn.add_edge(0, 1)   # non-empty overlay
    bad = np.array([dyn.n_nodes + 5])
    rects = np.zeros((1, 4), np.float32)
    for call in (lambda: dyn.count_batch(bad, rects),
                 lambda: dyn.collect_batch(bad, rects, 2),
                 lambda: dyn.knn_batch(bad, rects[:, :2], 2),
                 lambda: dyn.polygon_batch(bad, [np.eye(3, 2)])):
        with pytest.raises(IndexError, match="out of range"):
            call()


def test_run_queries_dynamic_dispatch(graph):
    from repro.core import build_dynamic_index

    dyn = build_dynamic_index(graph, "2dreach-comp")
    dyn.add_edge(0, 1)
    us, rects = workload(graph, 16, extent_ratio=0.05, seed=2)
    got = run_queries(dyn, QueryProgram.count(us, rects))
    assert (got == dyn.count_batch(us, rects)).all()
    col = run_queries(dyn, QueryProgram.collect(us, rects, 3))
    assert col.ids.shape == (16, 3)
    # reach through a wrapper serves its own (mutated-graph) answer
    reach = QueryProgram.reach(us, rects)
    assert (run_queries(dyn, reach) == dyn.query_batch(us, rects)).all()
    # engine='device' on a host-configured wrapper must not silently
    # serve host answers
    with pytest.raises(ValueError, match="engine='host'"):
        run_queries(dyn, reach, engine="device")
    dyn_dev = build_dynamic_index(graph, "2dreach-comp", engine="device")
    assert (run_queries(dyn_dev, reach, engine="device")
            == dyn.query_batch(us, rects)).all()
    assert (run_queries(dyn_dev, QueryProgram.count(us, rects),
                        engine="device")
            == dyn_dev.count_batch(us, rects)).all()
    # a device-configured wrapper IS the device path for batch_query —
    # no fallback warning, no required=True rejection
    import warnings as _w

    from repro.core.api import batch_query

    with _w.catch_warnings():
        _w.simplefilter("error")
        got = batch_query(dyn_dev, us, rects, engine="device",
                          required=True)
    assert (got == dyn_dev.query_batch(us, rects)).all()
    # a cluster wrapper serves boolean reach but its analytics base
    # probes would silently run on host — run_queries must reject that
    dyn_cl = build_dynamic_index(graph, "2dreach-comp", engine="cluster",
                                 n_shards=1)
    assert (run_queries(dyn_cl, reach, engine="device")
            == dyn.query_batch(us, rects)).all()
    with pytest.raises(ValueError, match="cluster"):
        run_queries(dyn_cl, QueryProgram.count(us, rects),
                    engine="device")
