"""repro.obs — zero-dependency observability for the serving stack.

One subsystem replaces the three bespoke timing schemes that grew with
PRs 1–5 (ad-hoc stats dicts, module-global counters, hand-rolled
``np.percentile`` calls):

* :mod:`~repro.obs.tracer` — span tracer (context manager + decorator,
  thread-safe, ~100ns when disabled) emitting Chrome-trace JSON; wraps
  the hot paths of the engine, cluster, frontend, dynamic overlay and
  the offline build stages.
* :mod:`~repro.obs.metrics` — counters / gauges / bounded log-linear
  histograms with exact (``np.percentile``-identical) p50/p95/p99/p999
  while unsaturated; the one percentile implementation in the repo.
* :mod:`~repro.obs.profiler` — opt-in ``jax.profiler`` capture +
  per-kernel cost model (bytes touched, candidate tiles after prune).
* :mod:`~repro.obs.querylog` — bounded structured query log (vertex
  class, query class, rect bucket, shard, latency, cardinality) with
  JSONL export — the input for the future result cache/repartitioner.

Usage::

    from repro import obs
    obs.enable()                       # spans + hot-path metrics on
    ... build / serve ...
    snap = obs.snapshot()              # metrics + span summary + log
    obs.dump("results/obs")            # trace.json / metrics.json /
    obs.disable()                      # querylog.jsonl

Everything cheap stays always-on (build counters, frontend flush stats);
only per-batch span/histogram recording is gated by :func:`enable`, and
the disabled cost is gated <2% of the smoke bench by
``benchmarks/obs_overhead.py`` in CI.
"""

from __future__ import annotations

import os
import time
from typing import Optional, Sequence

from . import audit, export, flight, metrics, profiler, querylog, slo, \
    timeseries, trace_context, tracer, workload
from .audit import ExactnessAuditor
from .export import to_openmetrics, write_prom
from .flight import FLIGHT, FlightRecorder
from .metrics import (
    Counter,
    CounterDict,
    Gauge,
    Histogram,
    HistogramState,
    REGISTRY,
    Registry,
    latency_percentiles,
)
from .profiler import annotate, device_trace, engine_cost_model
from .querylog import QUERY_LOG, QueryLog, rect_bucket, vertex_class_of
from .slo import SLOMonitor, default_slos
from .timeseries import TimeSeriesCollector
from .trace_context import TraceContext
from .tracer import TRACER, span, traced
from .workload import SpaceSaving, WorkloadAnalytics, gini

__all__ = [
    "Counter", "CounterDict", "ExactnessAuditor", "FLIGHT",
    "FlightRecorder", "Gauge", "Histogram", "HistogramState",
    "QueryLog", "Registry", "REGISTRY", "SLOMonitor", "SpaceSaving",
    "TRACER", "TimeSeriesCollector", "TraceContext", "QUERY_LOG",
    "WorkloadAnalytics",
    "annotate", "coverage", "default_slos", "device_trace", "disable",
    "dump", "dump_flight", "enable", "enabled", "engine_cost_model",
    "gini", "latency_percentiles", "rect_bucket", "reset", "snapshot",
    "span", "stage_totals", "start_timeseries", "stop_timeseries",
    "to_openmetrics", "traced", "vertex_class_of", "write_prom",
]

# the default layer prefixes coverage() attributes wall time to
LAYER_PREFIXES = ("engine.", "cluster.", "frontend.", "dynamic.",
                  "build.", "serve.")


def enable() -> None:
    """Turn on span recording and gated hot-path metric recording."""
    tracer.TRACER.start()


def disable() -> None:
    tracer.TRACER.stop()


def enabled() -> bool:
    """Fast gate for optional hot-path recording — a single attribute
    check, safe to call per batch."""
    return tracer.TRACER.enabled


def reset() -> None:
    """Clear spans, zero metrics, empty the query log, forget the
    time-series sampler and the flight recorder's black box
    (registrations and enablement state stay)."""
    global _TIMESERIES
    tracer.TRACER.clear()
    metrics.REGISTRY.reset()
    querylog.QUERY_LOG.clear()
    flight.FLIGHT.reset()
    if _TIMESERIES is not None:
        _TIMESERIES.stop(final_sample=False)
        _TIMESERIES = None


# -- stage-2 singletons: the time-series sampler --------------------------

_TIMESERIES: Optional[timeseries.TimeSeriesCollector] = None


def start_timeseries(interval: float = 0.25,
                     **kw) -> timeseries.TimeSeriesCollector:
    """Start (or return) the process-wide background sampler over the
    global registry; its ring is what :func:`dump` writes to
    ``timeseries.jsonl``."""
    global _TIMESERIES
    if _TIMESERIES is None:
        _TIMESERIES = timeseries.TimeSeriesCollector(
            interval=interval, **kw)
    return _TIMESERIES.start()


def stop_timeseries() -> Optional[timeseries.TimeSeriesCollector]:
    """Stop the process-wide sampler (taking one final sample).  The
    collector and its ring stay registered so :func:`dump` still writes
    ``timeseries.jsonl``; :func:`reset` forgets it."""
    if _TIMESERIES is not None:
        _TIMESERIES.stop()
    return _TIMESERIES


def stage_totals(prefix: str = "") -> dict:
    """{span name: total µs} — per-stage attribution for the benches."""
    return tracer.TRACER.stage_totals(prefix)


def coverage(t0_s: float, t1_s: float,
             prefixes: Sequence[str] = LAYER_PREFIXES) -> float:
    """Fraction of the perf_counter interval covered by instrumented
    spans across the serving layers (the >=95% acceptance check)."""
    return tracer.TRACER.coverage(t0_s, t1_s, prefixes=prefixes)


def snapshot() -> dict:
    """One structured view of everything observed so far: metric values
    and histogram percentiles, per-span totals, query-log aggregates,
    tracer + flight-recorder state.  Schema is additive-versioned for
    the BENCH files."""
    return {
        "schema_version": 2,
        "wall_time": time.time(),
        "metrics": metrics.REGISTRY.snapshot(),
        "spans": tracer.TRACER.summary(),
        "query_log": querylog.QUERY_LOG.snapshot(),
        "tracer": {
            "enabled": tracer.TRACER.enabled,
            "events": len(tracer.TRACER),
            "dropped": tracer.TRACER.dropped,
        },
        "flight": flight.FLIGHT.snapshot(),
    }


def dump_flight(reason: str = "manual",
                dirpath: Optional[str] = None) -> Optional[str]:
    """Freeze a flight bundle right now (the ops/debugger entry point).
    Arms the recorder at ``dirpath`` first when given; bypasses the
    rate limit but not arming — returns the bundle directory, or
    ``None`` when the recorder is unarmed / over its dump budget."""
    if dirpath is not None and not flight.FLIGHT.armed:
        flight.FLIGHT.arm(dirpath)
    return flight.FLIGHT.trigger(reason, force=True)


def dump(dirpath: str, prefix: str = "") -> dict:
    """Write the trace (Chrome format), metrics snapshot (JSON and
    OpenMetrics text) and query log under ``dirpath`` — plus the
    time-series ring when the background sampler ran; returns
    {kind: path}."""
    import json

    os.makedirs(dirpath, exist_ok=True)
    paths = {
        "trace": tracer.TRACER.dump(
            os.path.join(dirpath, prefix + "trace.json")),
        "metrics": os.path.join(dirpath, prefix + "metrics.json"),
        "prom": export.write_prom(
            os.path.join(dirpath, prefix + "metrics.prom")),
        "querylog": querylog.QUERY_LOG.to_jsonl(
            os.path.join(dirpath, prefix + "querylog.jsonl")),
    }
    with open(paths["metrics"], "w") as f:
        json.dump(snapshot(), f, indent=1)
    # to_jsonl flushes the partial in-flight window itself, so even a
    # sampler that never completed an interval exports its data
    if _TIMESERIES is not None:
        paths["timeseries"] = _TIMESERIES.to_jsonl(
            os.path.join(dirpath, prefix + "timeseries.jsonl"))
    return paths
