"""BitRank (the Pointer variant's succinct lookup): word boundaries,
degenerate masks, rank monotonicity."""

import numpy as np
import pytest

from repro.core import BitRank


def _oracle_rank(mask: np.ndarray) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(mask)[:-1]]).astype(np.int64)


@pytest.mark.parametrize("d", [1, 31, 32, 33, 63, 64, 65, 96, 127, 129])
def test_word_boundary_sizes(d):
    rng = np.random.default_rng(d)
    mask = rng.random(d) < 0.5
    br = BitRank.from_mask(mask)
    ids = np.arange(d)
    member, rank = br.test_rank(ids)
    assert (member == mask).all()
    assert (rank == _oracle_rank(mask)).all()


def test_word_boundary_ids_single_bits():
    """A lone set bit at each boundary id must be found exactly there."""
    d = 128
    for hot in (0, 31, 32, 33, 63, 64, 95, 96, 127):
        mask = np.zeros(d, dtype=bool)
        mask[hot] = True
        br = BitRank.from_mask(mask)
        member, rank = br.test_rank(np.arange(d))
        assert member.sum() == 1 and member[hot]
        # rank jumps from 0 to 1 exactly after the hot id
        assert (rank[: hot + 1] == 0).all()
        assert (rank[hot + 1:] == 1).all()


@pytest.mark.parametrize("d", [1, 32, 33, 100])
def test_all_zero_mask(d):
    br = BitRank.from_mask(np.zeros(d, dtype=bool))
    member, rank = br.test_rank(np.arange(d))
    assert not member.any()
    assert (rank == 0).all()


@pytest.mark.parametrize("d", [1, 31, 32, 64, 100])
def test_all_one_mask(d):
    br = BitRank.from_mask(np.ones(d, dtype=bool))
    member, rank = br.test_rank(np.arange(d))
    assert member.all()
    assert (rank == np.arange(d)).all()


def test_rank_monotone_nondecreasing():
    rng = np.random.default_rng(99)
    for d in (50, 64, 333, 1000):
        mask = rng.random(d) < 0.3
        br = BitRank.from_mask(mask)
        _, rank = br.test_rank(np.arange(d))
        diffs = np.diff(rank)
        # monotone, steps of at most 1, and a step exactly where a bit is
        assert (diffs >= 0).all() and (diffs <= 1).all()
        assert (diffs == mask[:-1].astype(np.int64)).all()
        assert rank[-1] + int(mask[-1]) == int(mask.sum())
