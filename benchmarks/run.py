"""Benchmark harness — one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--scale 0.5]
                                            [--queries 400]

Sections:
    [table2] graph/SCC statistics per dataset vs the paper's structure
    [table3] index construction time (5 methods x 4 datasets) + claims
    [table4] index size decomposition + claims
    [fig3]   query-time sweeps (3 parameters x 6 methods x 4 datasets)
             + the stability ratio behind the paper's headline claim
    [kernels] Pallas kernel microbenches (interpret mode on CPU)
    [roofline] dry-run derived terms, if results/dryrun exists
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _section(name):
    print(f"\n===== [{name}] " + "=" * (60 - len(name)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--skip-fig3", action="store_true")
    args = ap.parse_args()
    scale = args.scale or (0.5 if args.full else 0.25)
    n_q = args.queries or (1000 if args.full else 400)

    from . import paper_fig3, paper_tables

    t_start = time.perf_counter()

    _section("table2: graph + SCC statistics (scaled synthetic vs paper)")
    for row in paper_tables.table2(scale):
        print(
            f"{row['dataset']:<11} nodes={row['nodes']:>7} "
            f"edges={row['edges']:>8} sccs={row['sccs']:>7} "
            f"user_sccs={row['user_sccs']:>7} "
            f"({row['ours_user_scc_pct']:>5.1f}% ours vs "
            f"{row['paper_user_scc_pct']:>5.1f}% paper) "
            f"distinct_rtrees={row['distinct_rtrees']}"
        )

    _section("table3: index construction time [secs]")
    t3 = paper_tables.table3(scale)
    methods = [k for k in t3[0] if k != "dataset"]
    print(f"{'dataset':<12}" + "".join(f"{m:>18}" for m in methods))
    for row in t3:
        print(f"{row['dataset']:<12}"
              + "".join(f"{row[m]:>18.3f}" for m in methods))

    _section("table4: index size [MB] (rtree/aux)")
    t4 = paper_tables.table4(scale)
    t4raw = paper_tables.table4_raw(scale)
    print(f"{'dataset':<12}" + "".join(f"{m:>22}" for m in methods))
    for row in t4:
        print(f"{row['dataset']:<12}"
              + "".join(f"{row[m]:>22}" for m in methods))

    _section("paper claims")
    for line in paper_tables.check_claims(t3, t4raw):
        print(line)

    if not args.skip_fig3:
        _section("fig3: query time sweeps [us/query]")
        all_rows = []
        for ds in paper_fig3.DATASETS:
            rows = paper_fig3.sweep(ds, scale, n_queries=n_q, repeats=2)
            all_rows.extend(rows)
            for r in rows:
                vals = "".join(
                    f"{r[m]:>12.2f}" for m in paper_fig3.METHODS)
                print(f"{ds:<11} {r['param']:<12}{str(r['value']):<10}"
                      + vals)
            stab = paper_fig3.stability(rows)
            print(f"{ds:<11} stability max/min ratio: "
                  + ", ".join(f"{m}={v}" for m, v in stab.items()))

    _section("kernel microbenches (interpret mode — correctness-scale)")
    _kernel_bench()

    _section("roofline (from results/dryrun, single-pod mesh)")
    try:
        from . import roofline

        rows = roofline.table()
        if rows:
            print(roofline.format_table(rows))
        else:
            print("no dry-run results yet "
                  "(run: python -m repro.launch.dryrun --all)")
    except Exception as e:
        print("roofline unavailable:", e)

    print(f"\n[benchmarks] total {time.perf_counter() - t_start:.1f}s")


def _kernel_bench():
    import jax.numpy as jnp

    from repro.core import build_forest, query_host
    from repro.data import get_dataset, workload
    from repro.core import build_index
    from repro.kernels.range_query.ops import range_query_forest

    g = get_dataset("gowalla", scale=0.1)
    idx = build_index(g, "2dreach-comp")
    us, rects = workload(g, 512, seed=3)
    tid = idx.lookup_tree(us)
    for name, fn in (
        ("host_wavefront", lambda: query_host(idx.forest, tid, rects)),
        ("pallas_leafscan(interp)",
         lambda: range_query_forest(idx.forest, tid, rects)),
    ):
        fn()
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        print(f"{name:<26} {dt / len(us) * 1e6:>9.2f} us/query "
              f"({len(us)} queries)")

    from repro.core.reachability import pack_rows
    from repro.kernels.bitset_mm.ops import bitset_mm_mxu

    rng = np.random.default_rng(0)
    d = 512
    A = pack_rows(rng.random((d, d)) < 0.01)
    R = pack_rows(rng.random((d, 2048)) < 0.05)
    bitset_mm_mxu(A, R)
    t0 = time.perf_counter()
    bitset_mm_mxu(A, R)
    dt = time.perf_counter() - t0
    print(f"{'bitset_mm_mxu d=512':<26} {dt * 1e3:>9.2f} ms/iter")


if __name__ == "__main__":
    main()
