"""Device-stage profiling: opt-in ``jax.profiler`` capture + a kernel
cost model so a measured latency always ships with the work it bought.

The span tracer attributes *host wall time* per stage; this module adds
the device side: :func:`device_trace` wraps a serving pass in a JAX
profiler capture (TensorBoard-loadable; per-kernel HLO timings on real
accelerators), and :func:`engine_cost_model` turns an engine's tile
counters into first-order cost terms — bytes the leaf scan touched,
candidate tiles that survived the hierarchical prune, the fraction of a
full arena scan actually paid — so a kernel-latency regression in
BENCH_*.json is explainable (did the prune get worse, or the kernel?).
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax


@contextlib.contextmanager
def device_trace(logdir: str, enabled: bool = True):
    """Opt-in ``jax.profiler`` capture around a serving pass.

    No-op when ``enabled`` is False, and degrades to a no-op (rather
    than failing the serve) when the runtime cannot start a capture —
    e.g. a second concurrent capture, or a backend without profiler
    support.
    """
    if not enabled:
        yield
        return
    try:
        jax.profiler.start_trace(logdir)
    except Exception:        # capture unavailable: never fail the serve
        yield
        return
    try:
        yield
    finally:
        try:
            jax.profiler.stop_trace()
        except Exception:
            pass


def annotate(name: str):
    """Named region visible inside a ``device_trace`` capture
    (``jax.profiler.TraceAnnotation``); falls back to a null context on
    runtimes without it."""
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


def engine_cost_model(engine) -> dict:
    """First-order per-batch cost terms from an engine's tile counters.

    Works for both :class:`~repro.core.engine.QueryEngine` and
    :class:`~repro.cluster.ShardedEngine` (their ``stats`` share the
    tile-counter schema).  All ``*_per_batch`` terms are lifetime means.

    Terms
    -----
    candidate_tiles_per_batch:  leaf tiles that survived the prune —
        the work an ideal scan does.
    grid_tiles_per_batch:       kernel grid steps incl. K-bucket
        padding (padded steps repeat a tile; their DMA is elided).
    scan_bytes_per_batch:       entry-plane bytes the scan grid touches
        (TP entries x 2*dim float32 planes per tile).
    prune_bytes_per_batch:      tile-MBR pyramid bytes the prune reads
        per query tile (fine + coarse planes).
    scan_fraction:              candidate tiles / full-arena scan — the
        prune's effectiveness; 1.0 means pruning bought nothing.
    """
    from ..kernels.range_query.descent import COARSE_GROUP
    from ..kernels.range_query.kernel import TB, TP

    stats = engine.stats
    batches = max(int(stats.get("batches", 0)), 1)
    dim = int(getattr(engine, "dim", 2))
    n_tiles = int(getattr(engine, "n_tiles", 0))
    n_shards = int(getattr(engine, "n_shards", 1))
    planes = 2 * dim
    tile_bytes = TP * planes * 4
    cand = stats.get("tiles_scanned", 0) / batches
    grid = stats.get("tiles_grid", 0) / batches
    full = stats.get("tiles_full_scan", 0) / batches
    # the prune reads every fine tile MBR + every coarse group MBR once
    # per query tile; query tiles per batch = grid steps / K columns
    pyramid_tiles = n_tiles * n_shards * (1 + 1 / max(COARSE_GROUP, 1))
    qtiles = (stats.get("queries", 0) / batches) / TB
    return {
        "batches": int(stats.get("batches", 0)),
        "queries_per_batch": stats.get("queries", 0) / batches,
        "candidate_tiles_per_batch": cand,
        "grid_tiles_per_batch": grid,
        "full_scan_tiles_per_batch": full,
        "scan_fraction": cand / full if full else None,
        "scan_bytes_per_batch": grid * tile_bytes,
        "prune_bytes_per_batch": qtiles * pyramid_tiles * planes * 4,
        "tile_shape": {"TB": TB, "TP": TP, "planes": planes},
    }
