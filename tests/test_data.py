"""Data substrate: LBSN shaping, workloads, pipelines determinism."""

import numpy as np

from repro.data import (
    SPECS,
    dataset_stats,
    din_batches,
    get_dataset,
    lm_batches,
    molecule_batches,
    workload,
)
from repro.data.pipeline import ShardInfo


def test_lbsn_shapes_match_paper_structure():
    """The knob that matters: SCC structure per dataset (paper Table 2)."""
    gow = get_dataset("gowalla", scale=0.1)
    s = dataset_stats(gow)
    assert s["user_sccs"] <= 3  # paper: 1 (one giant social SCC)
    yelp = get_dataset("yelp", scale=0.1)
    s2 = dataset_stats(yelp)
    assert s2["user_sccs"] / s2["sccs"] > 0.5  # paper: 87.9%
    assert s2["users"] / s2["nodes"] > 0.85    # paper: 93% users
    assert s["venues"] / s["nodes"] > 0.8      # paper: 87% venues
    # venues are sinks in the LBSN model
    assert gow.spatial_sink_mask().sum() == gow.n_spatial


def test_workload_parameters():
    g = get_dataset("yelp", scale=0.1)
    us, rects = workload(g, n_queries=100, extent_ratio=0.05, seed=0)
    ext = g.spatial_extent()
    area = (ext[2] - ext[0]) * (ext[3] - ext[1])
    qarea = (rects[:, 2] - rects[:, 0]) * (rects[:, 3] - rects[:, 1])
    np.testing.assert_allclose(qarea, 0.05 * area, rtol=1e-3)
    # selectivity-targeted regions contain ~k venues
    us2, rects2 = workload(g, n_queries=20, selectivity=0.001, seed=0)
    pts = g.coords[g.spatial_mask]
    k = round(0.001 * g.n_nodes)
    for r in rects2:
        inside = ((pts[:, 0] >= r[0]) & (pts[:, 0] <= r[2])
                  & (pts[:, 1] >= r[1]) & (pts[:, 1] <= r[3])).sum()
        assert inside >= k  # grown to cover at least k


def test_pipelines_deterministic_and_sharded():
    a = next(lm_batches(100, 16, 8, seed=3))
    b = next(lm_batches(100, 16, 8, seed=3))
    assert np.array_equal(a["tokens"], b["tokens"])
    # different hosts see different slices; shapes divide
    h0 = next(lm_batches(100, 16, 8, seed=3, shard=ShardInfo(0, 4)))
    h1 = next(lm_batches(100, 16, 8, seed=3, shard=ShardInfo(1, 4)))
    assert h0["tokens"].shape == (2, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # start_step resumes mid-stream identically
    it = lm_batches(100, 16, 8, seed=3)
    next(it)
    second = next(it)
    resumed = next(lm_batches(100, 16, 8, seed=3, start_step=1))
    assert np.array_equal(second["tokens"], resumed["tokens"])


def test_din_batches_have_signal():
    b = next(din_batches(1000, 20, 16, 256, seed=0))
    assert b["hist_items"].shape == (256, 16)
    assert 0.05 < b["label"].mean() < 0.95


def test_molecule_batches():
    b = next(molecule_batches(12, 32, 8, seed=0))
    assert b["pos"].shape == (8, 12, 3)
    assert b["edge_src"].shape == (8, 32)
    assert np.isfinite(b["energy"]).all()
    assert b["edge_mask"].any(axis=1).all()
