"""Brute-force RangeReach oracle — ground truth for every index method.

BFS over the raw graph; an index answer disagreeing with this is a bug.
Used by unit tests, hypothesis property tests and the benchmark sanity
pass (benchmarks verify a sample of queries against the oracle before
timing anything).
"""

from __future__ import annotations

import numpy as np

from .graph import GeosocialGraph


def reachable_mask(graph: GeosocialGraph, u: int) -> np.ndarray:
    """(n,) bool — vertices reachable from u (including u)."""
    csr = graph.csr
    seen = np.zeros(graph.n_nodes, dtype=bool)
    seen[u] = True
    frontier = np.array([u], dtype=np.int64)
    while frontier.size:
        starts = csr.indptr[frontier]
        ends = csr.indptr[frontier + 1]
        cnt = (ends - starts).astype(np.int64)
        if cnt.sum() == 0:
            break
        slot = np.repeat(starts, cnt) + _ragged_arange(cnt)
        nxt = np.unique(csr.indices[slot])
        nxt = nxt[~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    return seen


def rangereach_oracle(graph: GeosocialGraph, u: int, rect) -> bool:
    xmin, ymin, xmax, ymax = (float(v) for v in rect)
    seen = reachable_mask(graph, u)
    pts = graph.coords
    ok = (
        seen & graph.spatial_mask
        & (pts[:, 0] >= xmin) & (pts[:, 0] <= xmax)
        & (pts[:, 1] >= ymin) & (pts[:, 1] <= ymax)
    )
    return bool(ok.any())


def rangereach_oracle_batch(
    graph: GeosocialGraph, us: np.ndarray, rects: np.ndarray
) -> np.ndarray:
    return np.array(
        [rangereach_oracle(graph, int(u), r) for u, r in zip(us, rects)],
        dtype=bool,
    )


# --------------------------------------------------------------------------
# Analytics-class oracles (repro.queries): BFS + brute-force geometry
# --------------------------------------------------------------------------

def _reachable_venues_in_rect(graph: GeosocialGraph, u: int,
                              rect) -> np.ndarray:
    xmin, ymin, xmax, ymax = (np.float32(v) for v in np.asarray(rect))
    seen = reachable_mask(graph, u)
    pts = graph.coords
    ok = (
        seen & graph.spatial_mask
        & (pts[:, 0] >= xmin) & (pts[:, 0] <= xmax)
        & (pts[:, 1] >= ymin) & (pts[:, 1] <= ymax)
    )
    return np.nonzero(ok)[0].astype(np.int32)


def range_count_oracle(graph: GeosocialGraph, u: int, rect) -> int:
    """Exact number of reachable venues intersecting rect."""
    return int(len(_reachable_venues_in_rect(graph, u, rect)))


def range_collect_oracle(graph: GeosocialGraph, u: int, rect) -> np.ndarray:
    """ALL reachable venue ids in rect, ascending (callers truncate to
    K for the capped-collect comparison)."""
    return _reachable_venues_in_rect(graph, u, rect)


def knn_reach_oracle(graph: GeosocialGraph, u: int, point, k: int):
    """(ids, dist2) of the k nearest reachable venues to ``point`` by
    (dist², id) ascending — distances float64 over the float32 coords,
    the canonical order every engine reproduces."""
    seen = reachable_mask(graph, u)
    ids = np.nonzero(seen & graph.spatial_mask)[0]
    if len(ids) == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.float64)
    p = np.asarray(point, dtype=np.float32).reshape(2)
    dx = graph.coords[ids, 0].astype(np.float64) - float(p[0])
    dy = graph.coords[ids, 1].astype(np.float64) - float(p[1])
    d2 = dx * dx + dy * dy
    order = np.lexsort((ids, d2))[: int(k)]
    return ids[order].astype(np.int32), d2[order]


def polygon_reach_oracle(graph: GeosocialGraph, u: int, vertices) -> bool:
    """Any reachable venue inside the canonical (bbox + float32
    half-plane) convex-polygon region."""
    from .polygon import (  # deferred: polygon imports reachable_mask
        convex_halfplanes,
        points_in_polygon_region,
        polygon_bbox,
    )

    seen = reachable_mask(graph, u)
    ids = np.nonzero(seen & graph.spatial_mask)[0]
    if len(ids) == 0:
        return False
    return bool(points_in_polygon_region(
        graph.coords[ids], polygon_bbox(vertices),
        convex_halfplanes(vertices)).any())


def _ragged_arange(counts: np.ndarray) -> np.ndarray:
    counts = counts.astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
