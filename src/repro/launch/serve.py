"""RangeReach serving launcher — the paper's production workload.

    PYTHONPATH=src python -m repro.launch.serve --dataset yelp --scale 0.1 \
        --method 2dreach-comp --queries 2000 --engine cluster --shards 8

Builds the chosen index offline, then serves batched RANGEREACH queries
through one of five engines:

    host      — vectorised NumPy ragged wavefront (paper-equivalent)
    wavefront — jit fixed-capacity R-tree descent (device engine)
    kernel    — the range_query Pallas leaf-scan (interpret on CPU)
    device    — the compile-once QueryEngine: fused on-device pointer
                lookup + hierarchically-pruned Pallas descent
                (2DReach variants only)
    cluster   — the sharded multi-device ShardedEngine behind the
                micro-batching Frontend: forest partitioned over the
                mesh, requests flushed deadline-or-full into the
                power-of-two buckets the engine compiles for

Every engine's answers are verified against the host engine before the
timed pass.  Reported per engine: throughput *and* per-query latency
percentiles (p50/p95/p99) — batch-amortised for the batched engines,
true per-request submit→resolve latency for the cluster frontend.  The
cluster arm additionally asserts the steady-state no-recompile
contract after a warm pass.

``--query-class count|collect|knn|polygon`` serves one of the
analytics classes (:mod:`repro.queries`) instead of boolean RangeReach
— host or device engine, answers oracle-gated and (device)
bit-identical to host:

    python -m repro.launch.serve --query-class knn --engine device --k 10
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from .. import obs
from ..core import batch_query, build_index, index_nbytes
from ..data import get_dataset, workload, zipf_workload


def _percentiles(lat_s: np.ndarray) -> dict:
    """{p50, p95, p99} per-query latency in microseconds — through the
    one Histogram implementation (``repro.obs``), exact on a replayed
    sample."""
    lat_us = np.asarray(lat_s, dtype=np.float64) * 1e6
    return obs.latency_percentiles(lat_us)


def _fmt_pct(pct: dict) -> str:
    return " ".join(f"{k} {v:8.2f}us" for k, v in pct.items())


def serve_chunked(call, n: int, batch: int):
    """Serve queries [0, n) in chunks of ``batch`` via
    ``call(lo, hi) -> answers`` and measure amortised per-query latency.

    Warms the full-chunk shape *and* the ragged tail's shape first (the
    tail is its own jit shape — an unwarmed one would report compile
    time as tail latency), then times each chunk, assigning every query
    in it the chunk's wall-time / chunk size.  Returns
    ``(answers (n,) bool, per-query latencies (n,) seconds, total s)``.
    Shared by this launcher and ``benchmarks/perf_rangereach.py``.
    """
    ans = np.zeros(n, dtype=bool)
    lats = np.zeros(n, dtype=np.float64)
    call(0, min(batch, n))                   # warmup / compile
    if n % batch:
        call(n - n % batch, n)               # ... and the ragged tail
    total = 0.0
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        t0 = time.perf_counter()
        out = call(lo, hi)
        dt = time.perf_counter() - t0
        ans[lo:hi] = np.asarray(out)[: hi - lo].astype(bool)
        lats[lo:hi] = dt / (hi - lo)
        total += dt
    return ans, lats, total


def _serve_batched(fn, us, rects, batch: int):
    """``serve_chunked`` over a ``fn(us_chunk, rects_chunk)`` engine."""
    return serve_chunked(
        lambda lo, hi: fn(us[lo:hi], rects[lo:hi]), len(us), batch)


def _serve_cluster(index, us, rects, args, auditor=None):
    """ShardedEngine behind the micro-batching Frontend: per-request
    latencies (submit→resolve), steady-state no-recompile assertion."""
    from ..cluster import Frontend, ShardedEngine

    eng = ShardedEngine(index, n_shards=args.shards or None)
    part = eng.partition
    print(f"[serve] cluster: {eng.n_shards} shards on "
          f"{eng.mesh.shape['data']} device(s), "
          f"{part.n_trees} trees, per-shard entries "
          f"{part.shard_entries.tolist()} (balance {part.balance():.2f})")
    fe = Frontend(eng, max_batch=args.batch,
                  max_delay=args.flush_ms * 1e-3, auditor=auditor)
    try:
        fe.warmup(us[:args.batch], rects[:args.batch])
        fe.submit_many(us, rects)           # warm the K high-water mark
        for i in range(len(us)):            # structure-matched shakeout:
            fe.submit(int(us[i]), rects[i])
        fe.flush(timeout=120)               # same per-request submission
        # pattern as the timed pass below, so a regrouping-induced K
        # ratchet lands here; then re-pin every batch bucket at the
        # final mark so any flush grouping reuses an existing trace
        fe.warmup(us[:args.batch], rects[:args.batch])
        warm = eng.n_compiles
        n = len(us)
        lats = np.zeros(n, dtype=np.float64)
        done = np.zeros(n, dtype=bool)
        t0s = np.zeros(n, dtype=np.float64)
        n_done = [0]
        done_lock = threading.Lock()
        all_done = threading.Event()
        errs = []

        def _cb(i):
            # completion callbacks are the sync point: Future.result()
            # can return before callbacks run, so the gather below waits
            # on the callback count, not on the futures
            def cb(fut):
                try:
                    lats[i] = time.monotonic() - t0s[i]
                    done[i] = fut.result()
                except BaseException as e:   # surfaced after the wait —
                    errs.append(e)           # not swallowed by Future
                finally:
                    with done_lock:
                        n_done[0] += 1
                        if n_done[0] == n:
                            all_done.set()
            return cb

        t_all = time.perf_counter()
        for i in range(n):
            t0s[i] = time.monotonic()
            fe.submit(int(us[i]), rects[i]).add_done_callback(_cb(i))
        assert all_done.wait(timeout=120), "request stream timed out"
        if errs:
            raise errs[0]
        total = time.perf_counter() - t_all
        assert eng.n_compiles == warm, (
            f"steady-state recompile under the frontend: "
            f"{eng.n_compiles} != {warm}")
        print(f"[serve] cluster: {eng.n_compiles} compiled shapes "
              f"(flat through the steady-state pass), "
              f"frontend {int(fe.stats['n_batches'])} flushes "
              f"(full {int(fe.stats['n_flush_full'])} / deadline "
              f"{int(fe.stats['n_flush_deadline'])}), "
              f"mean batch {fe.mean_batch:.1f}")
        print(f"[serve] cluster: shard query routing "
              f"{eng.shard_queries.tolist()}, "
              f"{eng.stats['tiles_scanned']}/"
              f"{eng.stats['tiles_full_scan']} leaf tiles scanned")
        return done, lats, total
    finally:
        fe.close()


def _log_served(index, us, rects, lats_s, cards,
                query_class: str = "reach", shards=None) -> None:
    """Feed a served pass into the structured query log (and through it
    the workload-analytics sinks).  Only while obs is enabled, and only
    for the engines that don't log per batch themselves — the cluster
    frontend records its own batches."""
    if not obs.enabled():
        return
    us = np.asarray(us)
    if shards is None:
        shards = np.zeros(len(us), dtype=np.int64)
    if rects is None:
        rects = np.zeros((len(us), 4), dtype=np.float32)
    obs.QUERY_LOG.record_batch(
        query_class, obs.vertex_class_of(index, us), rects, shards,
        lats_s, np.asarray(cards).astype(np.int64), us=us)


def _serve_query_class(index, g, args):
    """Analytics query-class serving (count / collect / knn / polygon)
    through ``core.api.run_queries`` — host or device engine, answers
    gated against the BFS oracle and (device) against the host path."""
    from ..core import run_queries
    from ..core.oracle import (
        knn_reach_oracle,
        polygon_reach_oracle,
        range_collect_oracle,
        range_count_oracle,
    )
    from ..data import knn_workload, polygon_workload
    from ..queries import QueryProgram

    if args.engine not in ("host", "device"):
        raise SystemExit(
            f"--query-class {args.query_class} serves on --engine "
            f"host|device (cluster serving is boolean RangeReach only)")
    n = args.queries
    kind = args.query_class
    points = polys = rects = None
    if kind == "knn":
        us, points = knn_workload(g, n, seed=1)
    elif kind == "polygon":
        us, polys = polygon_workload(g, n, extent_ratio=args.extent, seed=1)
    else:
        us, rects = workload(g, n_queries=n, extent_ratio=args.extent,
                             seed=1)

    def prog(lo, hi):
        if kind == "knn":
            return QueryProgram.knn(us[lo:hi], points[lo:hi], args.k)
        if kind == "polygon":
            return QueryProgram.polygon(us[lo:hi], polys[lo:hi])
        if kind == "count":
            return QueryProgram.count(us[lo:hi], rects[lo:hi])
        return QueryProgram.collect(us[lo:hi], rects[lo:hi], args.k)

    host = run_queries(index, prog(0, n), engine="host")
    if args.verify:
        kv = min(args.verify, n)
        for b in range(kv):
            u = int(us[b])
            if kind == "count":
                assert host[b] == range_count_oracle(g, u, rects[b])
            elif kind == "collect":
                want = range_collect_oracle(g, u, rects[b])
                assert host.counts[b] == len(want)
                assert (host.row(b) == want[: args.k]).all()
            elif kind == "knn":
                oi, _ = knn_reach_oracle(g, u, points[b], args.k)
                assert (host.row(b) == oi).all()
            else:
                assert host[b] == polygon_reach_oracle(g, u, polys[b])
        print(f"[serve] verified {kv} {kind} queries vs BFS oracle")
    if args.engine == "device":
        dev = run_queries(index, prog(0, n), engine="device")
        if kind in ("count", "polygon"):
            ok = (dev == host).all()
        elif kind == "collect":
            ok = ((dev.ids == host.ids).all()
                  and (dev.counts == host.counts).all()
                  and (dev.overflow == host.overflow).all())
        else:
            ok = ((dev.ids == host.ids).all()
                  and (dev.dist2 == host.dist2).all())
        assert ok, f"device {kind} answers diverge from host"
        print(f"[serve] device {kind} answers bit-identical to host")

    def run(lo, hi):
        return run_queries(index, prog(lo, hi), engine=args.engine)

    run(0, min(args.batch, n))                 # warmup / compile
    if n % args.batch:
        run(n - n % args.batch, n)             # ... and the ragged tail
    lats = np.zeros(n, dtype=np.float64)
    total = 0.0
    for lo in range(0, n, args.batch):
        hi = min(lo + args.batch, n)
        t0 = time.perf_counter()
        run(lo, hi)
        dt = time.perf_counter() - t0
        lats[lo:hi] = dt / (hi - lo)
        total += dt
    _log_served(index, us, rects, lats, np.zeros(n, dtype=np.int64),
                query_class=kind)
    pct = _percentiles(lats)
    print(f"[serve] {args.engine} {kind}: {n} queries in "
          f"{total * 1e3:.1f} ms ({total / n * 1e6:.2f} us/query mean), "
          f"{_fmt_pct(pct)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="yelp")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--method", default="2dreach-comp")
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--extent", type=float, default=0.05)
    ap.add_argument("--engine", default="host",
                    choices=("host", "wavefront", "kernel", "device",
                             "cluster"))
    ap.add_argument("--query-class", default="reach", dest="query_class",
                    choices=("reach", "count", "collect", "knn", "polygon"),
                    help="query class to serve (see repro.queries); "
                         "non-reach classes run on host|device engines")
    ap.add_argument("--k", type=int, default=10,
                    help="collect cap / knn neighbour count")
    ap.add_argument("--batch", type=int, default=256,
                    help="serving batch size (keep it a power of two "
                         "to reuse the engines' compiled buckets)")
    ap.add_argument("--shards", type=int, default=0,
                    help="cluster forest partitions; 0 (default) "
                         "resolves to the local device count — on a "
                         "single device extra shards only add per-shard "
                         "kernel dispatches (see README, Cluster "
                         "serving)")
    ap.add_argument("--flush-ms", type=float, default=2.0,
                    help="cluster frontend deadline flush (ms)")
    ap.add_argument("--verify", type=int, default=64,
                    help="queries to verify against the BFS oracle")
    ap.add_argument("--zipf", type=float, default=0.0,
                    help="draw query vertices from a Zipf(s) rank "
                         "distribution over degree-ranked vertices "
                         "instead of the paper's degree-bucket sweep "
                         "(0 = off); the skewed stream is what the "
                         "--obs heavy-hitter analytics are for")
    ap.add_argument("--obs", action="store_true",
                    help="enable repro.obs span/metric recording plus "
                         "the stage-2 workload intelligence (heavy "
                         "hitters, placement report, time-series "
                         "sampler, SLO monitor) and dump trace.json / "
                         "metrics.json / metrics.prom / querylog.jsonl "
                         "/ timeseries.jsonl / placement_report.json "
                         "after serving")
    ap.add_argument("--obs-dir", default="results/obs",
                    help="directory for the --obs artifacts")
    ap.add_argument("--obs-profile", default="",
                    help="logdir for an opt-in jax.profiler device "
                         "trace of the timed pass (TensorBoard format)")
    ap.add_argument("--audit-sample", type=float, default=0.0,
                    dest="audit_sample",
                    help="fraction of served cluster queries the online "
                         "exactness auditor shadow-replays through the "
                         "bit-identical host path (0 = off)")
    ap.add_argument("--audit-oracle-sample", type=float, default=0.0,
                    dest="audit_oracle_sample",
                    help="fraction of audited queries also checked "
                         "against the BFS oracle")
    args = ap.parse_args()

    wa = mon = None
    if args.obs:
        import os as _os

        obs.enable()
        # flight recorder: SLO burns / breaker opens / audit
        # divergences freeze self-contained debug bundles here
        obs.FLIGHT.arm(_os.path.join(args.obs_dir, "flightdump"))
        # workload intelligence: sketches see every query-log record as
        # a streaming sink; the background sampler snapshots the
        # registry and ticks the SLO burn-rate monitor on its cadence
        wa = obs.WorkloadAnalytics()
        obs.QUERY_LOG.add_sink(wa.observe)
        mon = obs.default_slos(obs.SLOMonitor(clock=time.time))
        obs.start_timeseries().add_hook(lambda t, _s: mon.tick(t))
    g = get_dataset(args.dataset, scale=args.scale)
    print(f"[serve] dataset {args.dataset} x{args.scale}: "
          f"{g.n_nodes} nodes, {g.n_edges} edges, {g.n_spatial} venues")
    t0 = time.perf_counter()
    index = build_index(g, args.method)
    print(f"[serve] built {args.method} in {time.perf_counter() - t0:.2f}s; "
          f"size {index_nbytes(index)['total'] / 1e6:.1f} MB")

    if args.query_class != "reach":
        with obs.device_trace(args.obs_profile,
                              enabled=bool(args.obs_profile)):
            t_q0 = time.perf_counter()
            _serve_query_class(index, g, args)
            t_q1 = time.perf_counter()
        _obs_report(args, t_q0, t_q1, wa=wa, mon=mon)
        return

    if args.zipf > 0:
        us, rects = zipf_workload(g, n_queries=args.queries, s=args.zipf,
                                  extent_ratio=args.extent, seed=1)
        uniq = len(np.unique(us))
        print(f"[serve] zipf(s={args.zipf:g}) workload: {len(us)} "
              f"queries over {uniq} distinct vertices")
    else:
        us, rects = workload(g, n_queries=args.queries,
                             extent_ratio=args.extent, seed=1)

    # correctness gate before timing
    if args.verify:
        from ..core import rangereach_oracle_batch

        k = min(args.verify, len(us))
        want = rangereach_oracle_batch(g, us[:k], rects[:k])
        got = batch_query(index, us[:k], rects[:k])
        assert (want == got).all(), "index disagrees with oracle"
        print(f"[serve] verified {k} queries vs BFS oracle")

    host_arm = args.engine == "host" or (
        args.engine in ("wavefront", "kernel")
        and not hasattr(index, "forest")
    )
    # host reference answers, for the arms that verify against them
    host = None if host_arm else batch_query(index, us, rects)
    auditor = None
    if args.audit_sample > 0 and args.engine == "cluster":
        auditor = obs.ExactnessAuditor(
            index, graph=g, sample=args.audit_sample,
            oracle_sample=args.audit_oracle_sample).start()
    with obs.device_trace(args.obs_profile, enabled=bool(args.obs_profile)):
        t_q0 = time.perf_counter()
        with obs.span(f"serve.{args.engine}_pass", cat="serve", n=len(us)):
            if args.engine == "cluster":
                ans, lats, dt = _serve_cluster(index, us, rects, args,
                                               auditor=auditor)
            elif host_arm:
                ans, lats, dt = _serve_batched(
                    lambda ub, rb: batch_query(index, ub, rb), us, rects,
                    args.batch)
            elif args.engine == "device":
                from ..core import engine_for

                eng = engine_for(index, required=True)
                ans, lats, dt = _serve_batched(eng.query_batch, us, rects,
                                               args.batch)
                print(f"[serve] device engine: {eng.n_compiles} compiled "
                      f"shapes, {eng.stats['tiles_scanned']}/"
                      f"{eng.stats['tiles_full_scan']} leaf tiles scanned "
                      f"(vs full leaf scan)")
            else:
                if args.engine == "wavefront":
                    from ..core import query_jax_wavefront

                    def fn(ub, rb):
                        return query_jax_wavefront(
                            index.forest, index.lookup_tree(ub), rb)[0]
                else:
                    from ..kernels.range_query.ops import range_query_forest

                    def fn(ub, rb):
                        return range_query_forest(
                            index.forest, index.lookup_tree(ub), rb)
                ans, lats, dt = _serve_batched(fn, us, rects, args.batch)
                # wavefront/kernel probe trees only — mask the Alg. 2
                # spatial-sink special case the full pipeline handles
                exc = getattr(index, "excluded", None)
                m = ~exc[us] if exc is not None else np.ones(len(us), bool)
                assert (ans[m] == host[m]).all(), "engine mismatch"
                ans = host
        t_q1 = time.perf_counter()
    if args.engine in ("device", "cluster"):
        assert (ans == host).all(), f"{args.engine} engine mismatch"
    if args.engine != "cluster":        # the frontend logs its batches
        _log_served(index, us, rects, lats, ans.astype(np.int64))
    pct = _percentiles(lats)
    print(f"[serve] {args.engine}: {len(us)} queries in {dt * 1e3:.1f} ms "
          f"({dt / len(us) * 1e6:.2f} us/query mean), "
          f"{_fmt_pct(pct)}, {int(np.sum(ans))} positive")
    _obs_report(args, t_q0, t_q1, wa=wa, mon=mon, auditor=auditor)


def _obs_report(args, t_q0: float, t_q1: float,
                wa=None, mon=None, auditor=None) -> None:
    """--obs epilogue: span coverage of the timed pass, the top stage
    totals, the workload-intelligence report (heavy-hitter table +
    placement report, SLO state) and the artifact dump."""
    import json
    import os

    if not args.obs:
        return
    obs.stop_timeseries()               # final sample covers the tail
    cov = obs.coverage(t_q0, t_q1)
    totals = sorted(obs.stage_totals().items(),
                    key=lambda kv: kv[1], reverse=True)
    top = ", ".join(f"{k} {v / 1e3:.1f}ms" for k, v in totals[:6])
    print(f"[serve] obs: span coverage {cov * 100:.1f}% of the timed "
          f"pass; top stages: {top}")
    paths = obs.dump(args.obs_dir)
    if wa is not None and wa.total:
        mon.tick()                       # one last burn-rate evaluation
        report = wa.placement_report(query_log=obs.QUERY_LOG)
        report["slo"] = mon.snapshot()
        path = os.path.join(args.obs_dir, "placement_report.json")
        with open(path, "w") as f:
            json.dump(report, f, indent=1)
        paths["placement_report"] = path
        skew = report["skew"]
        ver = report["verified"]
        print(f"[serve] obs: workload heavy hitters "
              f"({wa.total} queries observed):")
        print(wa.top_table(top_k=5))
        print(f"[serve] obs: shard skew gini_q {skew['gini_queries']:.3f} "
              f"gini_lat {skew['gini_latency']:.3f} max_share "
              f"{skew['max_query_share']:.2f} over {skew['n_shards']} "
              f"shard(s); degraded {report['degraded_fraction']:.1%}; "
              f"sketch vs exact recount: "
              f"{'MATCH' if ver['exact_match'] else ver}")
        fired = sum(1 for e in mon.events if e["kind"] == "fired")
        print(f"[serve] obs: SLOs {len(mon.slos)} tracked, {fired} "
              f"fired, active now: {sorted(mon.active()) or 'none'}")
    if auditor is not None:
        auditor.stop()                   # final drain covers the tail
        rep = auditor.report()
        print(f"[serve] obs: exactness audit checked {rep['checked']} "
              f"of {rep['sampled']} sampled queries "
              f"({rep['oracle_checked']} vs BFS oracle): "
              f"{rep['divergences']} divergence(s)")
    fl = obs.FLIGHT.snapshot()
    if fl["dumps"]:
        print(f"[serve] obs: flight recorder froze {fl['dumps']} debug "
              f"bundle(s) under {fl['dir']} — replay with "
              f"python -m repro.obs.flight <bundle>")
    print(f"[serve] obs: wrote " + ", ".join(
        sorted(paths.values())))


if __name__ == "__main__":
    main()
