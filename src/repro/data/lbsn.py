"""Synthetic LBSN generator shaped to the paper's four datasets.

No dataset downloads exist in this environment, so the generator
reproduces the *structural statistics that drive the paper's results*
(Table 2), scaled down by a recorded factor:

* user/venue split          — Yelp 93/7 vs Gowalla 13/87 etc.
* edge density              — m/n between 2.8 (Weeplaces) and 10 (Yelp)
* social SCC structure      — the key variable.  ``reciprocity`` controls
  how much of the social graph collapses: Gowalla's social graph is one
  giant SCC (1 user SCC), Yelp's is nearly a DAG (87.9% of SCCs are user
  SCCs).  Reciprocal follow edges create 2-cycles that Tarjan merges.
* spatial skew              — venues drawn from a Gaussian-mixture of
  "cities" over a [0, 100]^2 world, so region queries see realistic
  selectivity variance.
* venues are sinks          — check-in edges point user -> venue and
  venues have no outgoing edges (the LBSN data model in §5.1).

Every dataset's generated Table-2-style statistics are printed by
``benchmarks.paper_tables.table2`` next to the paper's real-data numbers
so the shaping is auditable.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..core.graph import GeosocialGraph, make_graph


@dataclasses.dataclass(frozen=True)
class LBSNSpec:
    name: str
    n_nodes: int
    venue_frac: float        # fraction of nodes that are venues
    social_avg_deg: float    # mean social out-degree per user
    checkin_avg: float       # mean check-in edges per user
    reciprocity: float       # P(follow edge is reciprocated) — SCC knob
    n_cities: int = 12
    city_sigma: float = 3.0
    zipf_users: float = 1.3  # popularity skew of follow targets
    zipf_venues: float = 1.2
    seed: int = 0
    # paper Table 2 reference statistics (full-scale, for reporting)
    ref: Optional[Dict[str, float]] = None


# Scaled to ~2% of the real datasets; the *ratios* are what matters.
SPECS: Dict[str, LBSNSpec] = {
    "foursquare": LBSNSpec(
        name="foursquare", n_nodes=65_000, venue_frac=0.348,
        social_avg_deg=7.0, checkin_avg=2.5, reciprocity=0.55, seed=11,
        ref=dict(users=2_119_987, venues=1_132_617, nodes=3_252_604,
                 edges=19_685_786, sccs=1_400_154, user_sccs=267_537),
    ),
    "gowalla": LBSNSpec(
        name="gowalla", n_nodes=62_000, venue_frac=0.87,
        social_avg_deg=30.0, checkin_avg=4.5, reciprocity=0.95, seed=12,
        ref=dict(users=407_533, venues=2_723_102, nodes=3_130_635,
                 edges=23_778_362, sccs=2_723_103, user_sccs=1),
    ),
    "weeplaces": LBSNSpec(
        name="weeplaces", n_nodes=50_000, venue_frac=0.984,
        social_avg_deg=40.0, checkin_avg=2.2, reciprocity=0.95, seed=13,
        ref=dict(users=16_022, venues=971_309, nodes=987_331,
                 edges=2_758_946, sccs=971_311, user_sccs=2),
    ),
    "yelp": LBSNSpec(
        name="yelp", n_nodes=43_000, venue_frac=0.07,
        social_avg_deg=9.5, checkin_avg=1.2, reciprocity=0.04, seed=14,
        ref=dict(users=1_987_693, venues=150_310, nodes=2_138_003,
                 edges=21_357_271, sccs=1_238_535, user_sccs=1_088_225),
    ),
}


def _zipf_weights(k: int, a: float) -> np.ndarray:
    w = 1.0 / np.arange(1, k + 1, dtype=np.float64) ** a
    return w / w.sum()


def generate_lbsn(spec: LBSNSpec) -> GeosocialGraph:
    rng = np.random.default_rng(spec.seed)
    n = spec.n_nodes
    n_venues = int(round(n * spec.venue_frac))
    n_users = n - n_venues
    users = np.arange(n_users)
    venues = np.arange(n_users, n)

    # --- social follow edges (user -> user) ------------------------------
    deg = rng.poisson(spec.social_avg_deg, size=n_users).astype(np.int64)
    total = int(deg.sum())
    src = np.repeat(users, deg)
    pop = _zipf_weights(n_users, spec.zipf_users)
    dst = rng.choice(n_users, size=total, p=pop)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # reciprocity: each follow edge is returned with probability r
    rec = rng.random(len(src)) < spec.reciprocity
    social = np.concatenate(
        [
            np.stack([src, dst], axis=1),
            np.stack([dst[rec], src[rec]], axis=1),
        ]
    )

    # --- check-in edges (user -> venue); venues are sinks -----------------
    ndeg = rng.poisson(spec.checkin_avg, size=n_users).astype(np.int64)
    ctotal = int(ndeg.sum())
    csrc = np.repeat(users, ndeg)
    vpop = _zipf_weights(n_venues, spec.zipf_venues)
    cdst = venues[rng.choice(n_venues, size=ctotal, p=vpop)]
    checkins = np.stack([csrc, cdst], axis=1)

    edges = np.concatenate([social, checkins])

    # --- venue coordinates: mixture of cities ----------------------------
    centers = rng.random((spec.n_cities, 2)) * 100.0
    city = rng.integers(0, spec.n_cities, size=n_venues)
    coords = np.zeros((n, 2), dtype=np.float32)
    coords[venues] = (
        centers[city] + rng.standard_normal((n_venues, 2)) * spec.city_sigma
    ).astype(np.float32)
    np.clip(coords, 0.0, 100.0, out=coords)

    spatial_mask = np.zeros(n, dtype=bool)
    spatial_mask[venues] = True

    g = make_graph(n, edges, coords, spatial_mask)
    g.validate()
    return g


def dataset_stats(g: GeosocialGraph) -> Dict[str, float]:
    """Table-2-style statistics of a generated graph."""
    from ..core.condensation import condense
    from ..core.scc import scc_np

    labels = scc_np(g.n_nodes, g.edges)
    cond = condense(g.n_nodes, g.edges, labels)
    d = cond.n_comps
    spatial_comp = np.zeros(d, dtype=bool)
    sv = g.spatial_ids
    spatial_comp[cond.comp[sv]] = True
    return dict(
        users=g.n_nodes - g.n_spatial,
        venues=g.n_spatial,
        nodes=g.n_nodes,
        edges=g.n_edges,
        sccs=d,
        user_sccs=int((~spatial_comp).sum()),
    )
