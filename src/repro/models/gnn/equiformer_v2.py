"""EquiformerV2 (Liao et al., 2023) — equivariant graph attention via
eSCN SO(2) convolutions.

Assigned config: 12 layers, d_hidden=128 channels, l_max=6, m_max=2,
8 heads.  Node features are real-SH irrep stacks (N, (l_max+1)^2, C).
Per edge, features are rotated into the edge-aligned frame (Wigner-D from
so3.py), mixed by an SO(2) linear map that couples only equal |m| and
truncates at m_max (the O(L^6) -> O(L^3) eSCN trick), gated by invariant
attention weights (segment softmax over destinations), rotated back and
aggregated.  Node update = equivariant RMS norm + scalar-gated FFN.

Equivariance (outputs rotate with inputs) is asserted by a dedicated
test — the Wigner machinery is exact to fp32 round-off, so the model is
equivariant by construction, not approximately.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from ..nn import ACT, Params, dense, dense_init, embed_init, mlp, mlp_init
from .common import bessel_rbf, edge_vectors, seg_softmax, seg_sum
from .so3 import rot_to_z, wigner_d_stack


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 32
    cutoff: float = 10.0
    n_species: int = 100
    d_feat: int | None = None

    @property
    def n_coef(self) -> int:
        return (self.l_max + 1) ** 2


def _l_slices(l_max: int):
    """[(start, l)] offsets of each l block in the (l_max+1)^2 stack."""
    out, s = [], 0
    for l in range(l_max + 1):
        out.append((s, l))
        s += 2 * l + 1
    return out


def _m0_index(l_max: int) -> np.ndarray:
    """Coefficient indices with m == 0 (one per l)."""
    return np.array([s + l for s, l in _l_slices(l_max)], dtype=np.int32)


def _m_pairs(l_max: int, m: int) -> np.ndarray:
    """(n_l, 2) index pairs (+m, -m) over all l >= m."""
    idx = []
    for s, l in _l_slices(l_max):
        if l >= m:
            idx.append((s + l + m, s + l - m))
    return np.array(idx, dtype=np.int32)


def init_params(key, cfg: EquiformerV2Config) -> Params:
    C, L = cfg.d_hidden, cfg.l_max
    n_l = L + 1
    ks = jax.random.split(key, 6 + cfg.n_layers)
    p: Params = {}
    if cfg.d_feat is not None:
        p["enc"] = dense_init(ks[0], cfg.d_feat, C)
    else:
        p["embed"] = embed_init(ks[0], cfg.n_species, C)
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[1 + i], 8)
        lp: Params = {
            # SO(2) m=0 block: mixes (l, C) jointly
            "so2_m0": dense_init(lk[0], n_l * C, n_l * C, bias=False,
                                 scale=(1.0 / (n_l * C)) ** 0.5),
            "rad": mlp_init(lk[1], (cfg.n_rbf, C, C)),
            "attn": mlp_init(lk[2], (C + C, C, cfg.n_heads)),
            "ffn_gate": mlp_init(lk[3], (C, C, n_l * C)),
            "ffn_scalar": mlp_init(lk[4], (C, C, C)),
        }
        for m in range(1, cfg.m_max + 1):
            nl = L + 1 - m
            lp[f"so2_m{m}_r"] = dense_init(
                lk[5], nl * C, nl * C, bias=False,
                scale=(1.0 / (nl * C)) ** 0.5)
            lp[f"so2_m{m}_i"] = dense_init(
                lk[6], nl * C, nl * C, bias=False,
                scale=(1.0 / (nl * C)) ** 0.5)
        p[f"layer{i}"] = lp
    p["out"] = mlp_init(ks[-1], (C, C, 1))
    return p


def _equiv_norm(x: jnp.ndarray, l_max: int, eps: float = 1e-6):
    """RMS-normalise each l block over (m, C)."""
    outs = []
    for s, l in _l_slices(l_max):
        blk = x[:, s: s + 2 * l + 1, :]
        rms = jnp.sqrt(jnp.mean(blk * blk, axis=(1, 2), keepdims=True) + eps)
        outs.append(blk / rms)
    return jnp.concatenate(outs, axis=1)


def _rotate(x: jnp.ndarray, D: List[jnp.ndarray], l_max: int,
            transpose: bool = False) -> jnp.ndarray:
    """x (E, K, C) rotated per l-block by D[l] (E, 2l+1, 2l+1)."""
    outs = []
    for s, l in _l_slices(l_max):
        blk = x[:, s: s + 2 * l + 1, :]
        d = D[l]
        if transpose:
            outs.append(jnp.einsum("eba,ebc->eac", d, blk))
        else:
            outs.append(jnp.einsum("eab,ebc->eac", d, blk))
    return jnp.concatenate(outs, axis=1)


def _so2_conv(lp: Params, x: jnp.ndarray, cfg: EquiformerV2Config):
    """SO(2) linear in the edge-aligned frame; zero output for m > m_max."""
    E, K, C = x.shape
    L = cfg.l_max
    out = jnp.zeros_like(x)
    # m = 0
    i0 = jnp.asarray(_m0_index(L))
    x0 = x[:, i0, :].reshape(E, -1)
    y0 = dense(lp["so2_m0"], x0).reshape(E, L + 1, C)
    out = out.at[:, i0, :].set(y0)
    # m >= 1 pairs
    for m in range(1, cfg.m_max + 1):
        pairs = _m_pairs(L, m)
        ip, im = jnp.asarray(pairs[:, 0]), jnp.asarray(pairs[:, 1])
        xp = x[:, ip, :].reshape(E, -1)
        xm = x[:, im, :].reshape(E, -1)
        wr, wi = lp[f"so2_m{m}_r"], lp[f"so2_m{m}_i"]
        yp = dense(wr, xp) - dense(wi, xm)
        ym = dense(wi, xp) + dense(wr, xm)
        out = out.at[:, ip, :].set(yp.reshape(E, len(pairs), C))
        out = out.at[:, im, :].set(ym.reshape(E, len(pairs), C))
    return out


def apply(params: Params, batch: Dict, cfg: EquiformerV2Config) -> jnp.ndarray:
    pos = batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    N = pos.shape[0]
    K, C, L = cfg.n_coef, cfg.d_hidden, cfg.l_max

    if cfg.d_feat is not None:
        scal = dense(params["enc"], batch["feat"])
    else:
        scal = jnp.take(params["embed"]["emb"], batch["species"], axis=0)
    x = jnp.zeros((N, K, C), scal.dtype).at[:, 0, :].set(scal)

    vec, dist = edge_vectors(pos, src, dst)
    dirs = vec / jnp.maximum(dist[:, None], 1e-9)
    rot = rot_to_z(dirs)
    D = wigner_d_stack(rot, L)
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff)
    if emask is not None:
        rbf = rbf * emask[:, None].astype(rbf.dtype)

    for i in range(cfg.n_layers):
        lp = params[f"layer{i}"]
        xn = _equiv_norm(x, L)
        # message in edge frame
        xe = _rotate(xn[src], D, L)                       # (E, K, C)
        radial = mlp(lp["rad"], rbf, act="silu")          # (E, C)
        xe = xe * radial[:, None, :]
        me = _so2_conv(lp, xe, cfg)
        # invariant attention (m=0 scalars of message + receiver scalars)
        inv = jnp.concatenate([me[:, 0, :], xn[dst][:, 0, :]], -1)
        logits = mlp(lp["attn"], inv, act="silu")          # (E, H)
        if emask is not None:
            logits = jnp.where(emask[:, None], logits, -1e30)
        alpha = seg_softmax(logits, dst, N)                # (E, H)
        Hh = cfg.n_heads
        me = me.reshape(me.shape[0], K, Hh, C // Hh)
        me = me * alpha[:, None, :, None]
        me = me.reshape(me.shape[0], K, C)
        if emask is not None:
            me = me * emask[:, None, None].astype(me.dtype)
        me = _rotate(me, D, L, transpose=True)             # back to global
        agg = seg_sum(me, dst, N)
        x = x + agg
        # scalar-gated equivariant FFN
        xn = _equiv_norm(x, L)
        s = mlp(lp["ffn_scalar"], xn[:, 0, :], act="silu")
        gates = jax.nn.sigmoid(
            mlp(lp["ffn_gate"], xn[:, 0, :], act="silu")
        ).reshape(N, L + 1, C)
        gate_full = jnp.concatenate(
            [
                jnp.repeat(gates[:, l: l + 1, :], 2 * l + 1, axis=1)
                for l in range(L + 1)
            ],
            axis=1,
        )
        x = x + xn * gate_full
        x = x.at[:, 0, :].add(s)

    out = mlp(params["out"], x[:, 0, :], act="silu")       # (N, 1) invariant
    nmask = batch.get("node_mask")
    if nmask is not None:
        out = out * nmask[:, None].astype(out.dtype)
    return out.sum()


def loss_fn(params: Params, batch: Dict, cfg: EquiformerV2Config
            ) -> jnp.ndarray:
    pred = jax.vmap(lambda b: apply(params, b, cfg))(batch)
    return jnp.mean((pred - batch["energy"]) ** 2)
