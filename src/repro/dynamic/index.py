"""`DynamicIndex` — incremental RangeReach over any static index.

The static indexes behind ``core.api.build_index`` are built offline over
a frozen graph.  ``DynamicIndex`` wraps one and absorbs online mutations
(``add_edge`` / ``add_vertex`` / ``add_spatial``) into a
:class:`~repro.dynamic.overlay.DeltaOverlay`, answering every query over
the *mutated* graph without a rebuild.  Mutations are monotone (nothing
is ever deleted), which makes the composition exact:

A RangeReach(u, R) answer over base ∪ overlay decomposes as

1. **base probe** — the static index answers for the base graph's
   reachability and base spatial vertices (sound because base paths and
   base venues survive every mutation);
2. **overlay expansion** — a fixpoint over the delta edge buffer at
   condensation-component granularity computes which components become
   reachable *through* delta edges; every such "entry component" pays
   one extra base probe from a representative vertex (its base-graph
   reach is new to u), and reached components are collected for step 3;
3. **staging probe** — the staging R-tree yields the staged spatial
   vertices inside R; any of them whose component (or pseudo-component,
   for post-snapshot vertices) was reached answers the query.

Step 2 runs on the DynamicIndex's *own* full condensation of the base
graph (independent of the wrapped method's internals — 2DReach-Comp
excludes spatial sinks from its decomposition, the dynamic layer must
not).  DAGGER-style maintenance keeps a union-find over components:
delta edges that close a cycle collapse the endpoint components into one
group, and expansion treats a reached group as all-members-reached.
Expansion results are memoised per union-find representative; a new
delta edge (s, t) invalidates exactly the memos that cover ``s`` — the
only reachable sets the edge can grow.

Compaction (see :mod:`repro.dynamic.compaction`) materialises the
mutated graph, rebuilds the static index — inline or on a background
thread — and swaps it in atomically, replaying any mutations that
arrived mid-build into the fresh overlay.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.condensation import condense
from ..core.graph import GeosocialGraph, build_csr, make_graph
from ..core.scc import scc_np
from ..obs import span
from ..resilience.faults import fault_point
from .compaction import CompactionPolicy, Compactor
from .overlay import DeltaOverlay

_REACH_CACHE_CAP = 512

# an expansion result: (sorted reached base comps, reached new vertices,
# entry vertices — one representative per comp whose base reach is only
# available through delta edges)
_Expansion = Tuple[np.ndarray, frozenset, Tuple[int, ...]]


class DynamicIndex:
    """Updatable RangeReach index: static base + delta overlay.

    Parameters
    ----------
    graph:   initial (base) geosocial graph.
    method:  any ``core.api.METHODS`` entry; the same method is used for
             every compaction rebuild.
    policy:  compaction thresholds; ``None`` -> defaults
             (see :class:`CompactionPolicy`).
    engine:  ``"host"`` (default) answers base probes through the static
             index's NumPy path; ``"device"`` uploads the static base to
             a compile-once :class:`~repro.core.engine.QueryEngine`
             (rebuilt on every compaction swap) while the overlay —
             small, mutable, pointer-rich — stays host-side;
             ``"cluster"`` shards the static base over the mesh through
             a :class:`~repro.cluster.ShardedEngine` (repartitioned and
             re-uploaded on every compaction swap) with the same
             host-side overlay on top.
    n_shards: forest partitions for ``engine="cluster"`` (default: the
             local device count); ignored otherwise.
    build_kw: forwarded to ``build_index`` (fanout, dedup, ...).  When a
             device serving engine is selected (``"device"`` /
             ``"cluster"``) and no explicit ``backend`` is given, the
             static base — including every compaction rebuild — is
             built with ``backend="device"``, so each swap's fresh index
             is adopted by the new engine zero-copy instead of being
             re-transposed and re-uploaded from host.
    """

    def __init__(self, graph: GeosocialGraph, method: str,
                 policy: Optional[CompactionPolicy] = None,
                 engine: str = "host", n_shards: Optional[int] = None,
                 **build_kw):
        from ..core.api import build_index  # deferred: api imports us lazily

        if engine not in ("host", "device", "cluster"):
            raise ValueError(
                f"unknown engine {engine!r}; expected host|device|cluster")
        if engine != "host" and not method.lower().startswith("2dreach"):
            # fail at construction, naming the method — not deep inside
            # the first compaction's engine rebuild
            raise ValueError(
                f"engine={engine!r} serves the 2DReach variants only, "
                f"not method {method!r}")
        self.method = method.lower()
        self.engine = engine
        self.n_shards = n_shards
        self._build_kw = dict(build_kw)
        if engine != "host":
            # device serving gets the device builder by default: the
            # compaction swap then hands the freshly built arrays to the
            # new engine without a host→device re-upload
            self._build_kw.setdefault("backend", "device")
        self.policy = policy or CompactionPolicy()
        self._lock = threading.RLock()
        self._compactor = Compactor(self)
        self._oplog: List[tuple] = []
        self._replaying = False
        self.stats: Dict[str, float] = {
            "n_queries": 0, "n_updates": 0, "n_edges_added": 0,
            "n_vertices_added": 0, "n_spatial_added": 0,
            "n_compactions": 0, "t_compaction_total": 0.0,
            "t_last_compaction": 0.0, "n_scc_merges": 0,
            "cache_hits": 0, "cache_misses": 0, "n_cache_invalidations": 0,
            "updates_since_compaction": 0,
        }
        t0 = time.perf_counter()
        index = build_index(graph, self.method, **self._build_kw)
        built = self._build_reach_substrate(graph)
        self._install_base(graph, index, built)
        self.stats["t_initial_build"] = time.perf_counter() - t0

    # ------------------------------------------------------------------
    # base installation / condensation substrate
    # ------------------------------------------------------------------

    @staticmethod
    def _build_reach_substrate(graph: GeosocialGraph):
        """Full condensation of the base graph (no vertex excluded) +
        DAG CSR + one representative vertex per component."""
        n = graph.n_nodes
        labels = scc_np(n, graph.edges)
        cond = condense(n, graph.edges, labels)
        d = cond.n_comps
        csr = build_csr(d, cond.dag_edges)
        rep = np.zeros(d, dtype=np.int64)
        rep[cond.comp] = np.arange(n, dtype=np.int64)
        return cond.comp.copy(), d, csr.indptr, csr.indices, rep

    def _install_base(self, graph, index, substrate) -> None:
        comp, d, indptr, adj, rep = substrate
        self._graph = graph
        self._index = index
        self._comp = comp
        self._d = d
        self._dag_indptr = indptr
        self._dag_adj = adj
        self._comp_rep = rep
        self._overlay = DeltaOverlay(graph.n_nodes, d)
        self._stamp_arr = np.zeros(d, dtype=np.int64)
        self._stamp = 0
        self._cache: Dict[int, _Expansion] = {}
        self._base_engine = None
        if self.engine == "device":
            from ..core.engine import engine_for  # deferred: core is heavy

            # required=True: asking for device serving on a method the
            # engine cannot serve is a configuration error, not a
            # silent host fallback
            self._base_engine = engine_for(index, required=True)
        elif self.engine == "cluster":
            from ..cluster import sharded_engine_for  # deferred: heavy

            self._base_engine = sharded_engine_for(
                index, n_shards=self.n_shards)

    def _base_probe(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Static-base probe — the device engine when enabled (and the
        wrapped method has one), the host path otherwise."""
        with span("dynamic.base_probe", cat="dynamic", n=len(us)):
            if self._base_engine is not None:
                return self._base_engine.query_batch(us, rects)
            return self._index.query_batch(us, rects)

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------

    @property
    def n_nodes(self) -> int:
        return self._overlay.n_nodes

    @property
    def n_base(self) -> int:
        return self._overlay.n_base

    @property
    def base_index(self):
        return self._index

    @property
    def base_engine(self):
        """The device engine serving the static base (None on host)."""
        return self._base_engine

    @property
    def overlay_size(self) -> int:
        o = self._overlay
        return o.n_edges + o.n_staged + o.n_new_vertices

    def snapshot_graph(self) -> GeosocialGraph:
        """Materialise the current mutated graph (base + overlay)."""
        with self._lock:
            return self._materialise()

    # -- mutations ------------------------------------------------------

    def add_vertex(self, coords=None) -> int:
        """Append a vertex; with ``coords`` it is spatial from birth."""
        with self._lock:
            v = self._overlay.add_vertex()
            if coords is not None:
                x, y = (float(coords[0]), float(coords[1]))
                self._overlay.staging.add(v, x, y)
                self._oplog.append(("vertex", (x, y)))
            else:
                self._oplog.append(("vertex", None))
            self._count_update("n_vertices_added")
            return v

    def add_spatial(self, v: int, coords) -> None:
        """Check-in: an existing non-spatial vertex acquires delta(v)."""
        with self._lock:
            v = int(v)
            if not (0 <= v < self._overlay.n_nodes):
                raise IndexError(f"vertex {v} out of range")
            already = (
                v < self._overlay.n_base and bool(self._graph.spatial_mask[v])
            ) or v in self._overlay.staging
            if already:
                raise ValueError(f"vertex {v} is already spatial")
            x, y = float(coords[0]), float(coords[1])
            self._overlay.staging.add(v, x, y)
            self._oplog.append(("spatial", v, x, y))
            self._count_update("n_spatial_added")

    def add_edge(self, s: int, t: int) -> None:
        """Append a directed edge; maintains the overlay condensation
        (union-find merge when the edge closes a cycle) and invalidates
        exactly the memoised reach sets that can now grow."""
        with self._lock:
            s, t = int(s), int(t)
            n = self._overlay.n_nodes
            if not (0 <= s < n and 0 <= t < n):
                raise IndexError(f"edge ({s}, {t}) out of range [0, {n})")
            if s != t:
                # DAGGER maintenance: does t already reach s?  Then s->t
                # closes a cycle and the endpoint components collapse.
                exp = self._expand_from(t)
                if self._exp_covers(exp, s):
                    ea = self._overlay.elem_of_vertex(s, self._comp)
                    eb = self._overlay.elem_of_vertex(t, self._comp)
                    if self._overlay.uf.union(ea, eb):
                        self._overlay.n_scc_merges += 1
                        self.stats["n_scc_merges"] += 1
            self._overlay.add_edge(s, t)
            self._invalidate_covering(s)
            self._oplog.append(("edge", s, t))
            self._count_update("n_edges_added")

    # -- queries --------------------------------------------------------

    def query_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        rects = np.asarray(rects, dtype=np.float32).reshape(B, 4)
        with self._lock, span("dynamic.query_batch", cat="dynamic", n=B):
            self.stats["n_queries"] += B
            overlay = self._overlay
            self._check_query_range(us)
            ans = np.zeros(B, dtype=bool)
            base_mask = us < overlay.n_base
            if base_mask.any():
                ans[base_mask] = self._base_probe(
                    us[base_mask], rects[base_mask]
                )
            if overlay.is_empty():
                return ans
            extra_qi: List[int] = []
            extra_u: List[int] = []
            with span("dynamic.overlay", cat="dynamic", n=B):
                for i in range(B):
                    if ans[i]:
                        continue
                    reached, new_reached, entries = self._expand_from(
                        int(us[i]))
                    # staging probe: any staged venue in R whose
                    # component (or post-snapshot vertex) was reached?
                    cand = overlay.staging.candidates_in(rects[i])
                    if cand.size:
                        cb = cand[cand < overlay.n_base]
                        if cb.size and np.isin(
                                self._comp[cb], reached).any():
                            ans[i] = True
                            continue
                        if any(int(w) in new_reached
                               for w in cand[cand >= overlay.n_base]):
                            ans[i] = True
                            continue
                    # entry components: base reach opened by delta edges.
                    # comp(u)'s own probe already ran in step 1 — skip it.
                    cu = int(self._comp[us[i]]) if base_mask[i] else -1
                    for t in entries:
                        if int(self._comp[t]) == cu:
                            continue
                        extra_qi.append(i)
                        extra_u.append(t)
            if extra_u:
                got = self._base_probe(
                    np.asarray(extra_u, dtype=np.int64),
                    rects[np.asarray(extra_qi, dtype=np.int64)],
                )
                np.logical_or.at(ans, np.asarray(extra_qi), got)
            return ans

    def query(self, u: int, rect) -> bool:
        return bool(self.query_batch(np.array([u]), np.array([rect]))[0])

    # -- analytics query classes (repro.queries over base ∪ overlay) ----
    #
    # Each class decomposes like the boolean query: a base probe through
    # the static index (device engine when configured), an overlay
    # expansion yielding the extra entry components whose base reach only
    # delta edges open, and the staged-venue side.  Staged venues are
    # disjoint from base venues (staging holds only vertices that were
    # not spatial in the base snapshot), so *counts add* across the two
    # sides; multiple base probes can overlap, so whenever entry probes
    # exist the base side switches to an uncapped *collect union*
    # (exact dedup) instead of adding counts.  kNN heap-merges the base
    # candidates against the staged side.

    def _require_2dreach(self, what: str) -> None:
        if not self.method.startswith("2dreach"):
            raise ValueError(
                f"no {what!r} query class for DynamicIndex over method "
                f"{self.method!r}: the analytics classes serve the "
                f"2DReach variants only")

    def _check_query_range(self, us: np.ndarray) -> None:
        if us.size and (us.min() < 0
                        or us.max() >= self._overlay.n_nodes):
            raise IndexError("query vertex out of range")

    def _staged_arrays(self):
        st = self._overlay.staging
        return (np.asarray(st.ids, dtype=np.int64), st.coords_of())

    def _staged_reached_mask(self, sid: np.ndarray, reached, new_reached
                             ) -> np.ndarray:
        n_base = self._overlay.n_base
        keep = np.zeros(len(sid), dtype=bool)
        base = sid < n_base
        if base.any():
            keep[base] = np.isin(self._comp[sid[base]], reached)
        for j in np.nonzero(~base)[0]:
            keep[j] = int(sid[j]) in new_reached
        return keep

    def _merge_probes(self, u: int, is_base: bool):
        """(expansion, extra entry probes) for one query vertex — the
        entry list minus the component the step-1 base probe covers."""
        reached, new_reached, entries = self._expand_from(int(u))
        cu = int(self._comp[u]) if is_base else -1
        extra = [int(t) for t in entries if int(self._comp[t]) != cu]
        return reached, new_reached, extra

    def _base_analytics(self, method: str):
        """Bound base-probe callable: the device engine's batched class
        when the engine exposes it, the host descent otherwise (the
        cluster ShardedEngine serves boolean only)."""
        from ..queries import host as qhost

        eng = self._base_engine
        if eng is not None and hasattr(eng, method):
            return getattr(eng, method)
        return {
            "count_batch": lambda us, rects: qhost.range_count_host(
                self._index, us, rects),
            "collect_batch": lambda us, rects, k: qhost.range_collect_host(
                self._index, us, rects, k),
            "polygon_batch": lambda us, polys: qhost.polygon_reach_host(
                self._index, us, polys),
        }[method]

    def count_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Exact RangeCount over the mutated graph: (B,) int64."""
        self._require_2dreach("count")
        from ..queries.host import _point_in_rect, collect_csr_host

        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        rects = np.asarray(rects, dtype=np.float32).reshape(B, 4)
        with self._lock:
            self.stats["n_queries"] += B
            overlay = self._overlay
            self._check_query_range(us)
            ans = np.zeros(B, dtype=np.int64)
            base_mask = us < overlay.n_base
            if base_mask.any():
                ans[base_mask] = self._base_analytics("count_batch")(
                    us[base_mask], rects[base_mask])
            if overlay.is_empty():
                return ans
            sid, scoord = self._staged_arrays()
            for i in range(B):
                reached, new_reached, extra = self._merge_probes(
                    int(us[i]), bool(base_mask[i]))
                st = np.zeros(0, dtype=np.int64)
                if len(sid):
                    inr = _point_in_rect(scoord, rects[i][None])
                    st = sid[inr & self._staged_reached_mask(
                        sid, reached, new_reached)]
                if not extra:
                    ans[i] += len(st)     # staged ∩ base venues = ∅
                    continue
                probes = ([int(us[i])] if base_mask[i] else []) + extra
                _, ids = collect_csr_host(
                    self._index, np.asarray(probes, dtype=np.int64),
                    np.tile(rects[i], (len(probes), 1)))
                ans[i] = len(np.unique(ids)) + len(st)
            return ans

    def collect_batch(self, us: np.ndarray, rects: np.ndarray, k: int):
        """Exact RangeCollect over the mutated graph (K smallest ids,
        exact totals, overflow flags)."""
        self._require_2dreach("collect")
        from ..queries.host import _point_in_rect, collect_csr_host
        from ..queries.program import CollectResult

        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        k = int(k)
        if k < 1:
            raise ValueError(f"collect needs k >= 1, got {k}")
        rects = np.asarray(rects, dtype=np.float32).reshape(B, 4)
        with self._lock:
            self.stats["n_queries"] += B
            overlay = self._overlay
            self._check_query_range(us)
            ids = np.full((B, k), -1, dtype=np.int32)
            counts = np.zeros(B, dtype=np.int64)
            base_mask = us < overlay.n_base
            if base_mask.any():
                br = self._base_analytics("collect_batch")(
                    us[base_mask], rects[base_mask], k)
                ids[base_mask] = br.ids
                counts[base_mask] = br.counts
            if overlay.is_empty():
                return CollectResult(ids=ids, counts=counts,
                                     overflow=counts > k)
            sid, scoord = self._staged_arrays()
            for i in range(B):
                reached, new_reached, extra = self._merge_probes(
                    int(us[i]), bool(base_mask[i]))
                st = np.zeros(0, dtype=np.int64)
                if len(sid):
                    inr = _point_in_rect(scoord, rects[i][None])
                    st = sid[inr & self._staged_reached_mask(
                        sid, reached, new_reached)]
                if not extra and len(st) == 0:
                    continue
                if not extra:
                    # K smallest of (base K-smallest ∪ staged) = the
                    # union's K smallest; totals add (disjoint sides)
                    row = np.sort(np.concatenate(
                        [ids[i][ids[i] >= 0].astype(np.int64), st]))[:k]
                    counts[i] += len(st)
                else:
                    probes = ([int(us[i])] if base_mask[i] else []) + extra
                    _, base_ids = collect_csr_host(
                        self._index, np.asarray(probes, dtype=np.int64),
                        np.tile(rects[i], (len(probes), 1)))
                    merged = np.unique(np.concatenate(
                        [base_ids.astype(np.int64), st]))
                    counts[i] = len(merged)
                    row = merged[:k]
                ids[i] = -1
                ids[i, : len(row)] = row
            return CollectResult(ids=ids, counts=counts, overflow=counts > k)

    def knn_batch(self, us: np.ndarray, points: np.ndarray, k: int):
        """Exact KNNReach over the mutated graph: the k nearest
        reachable venues by (dist², id), heap-merging base-probe
        candidates with the staged-venue side."""
        self._require_2dreach("knn")
        from ..queries.knn import _pt_d2, knn_reach_host
        from ..queries.program import KNNResult

        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        k = int(k)
        if k < 1:
            raise ValueError(f"knn needs k >= 1, got {k}")
        points = np.asarray(points, dtype=np.float32).reshape(B, 2)
        with self._lock:
            self.stats["n_queries"] += B
            overlay = self._overlay
            self._check_query_range(us)
            res = KNNResult(
                ids=np.full((B, k), -1, dtype=np.int32),
                dist2=np.full((B, k), np.inf, dtype=np.float64),
            )
            base_mask = us < overlay.n_base
            eng = self._base_engine
            use_eng = eng is not None and hasattr(eng, "knn_batch")

            def base_knn(pu, pp):
                if use_eng:
                    return eng.knn_batch(pu, pp, k)
                return knn_reach_host(self._index, pu, pp, k)

            if base_mask.any() and overlay.is_empty():
                br = base_knn(us[base_mask], points[base_mask])
                res.ids[base_mask] = br.ids
                res.dist2[base_mask] = br.dist2
                return res
            sid, scoord = self._staged_arrays()
            # one batched base probe covering every (query, entry) pair
            probe_qi, probe_us = [], []
            probe_rows: List[List[int]] = [[] for _ in range(B)]
            ctxs = []
            for i in range(B):
                reached, new_reached, extra = self._merge_probes(
                    int(us[i]), bool(base_mask[i]))
                ctxs.append((reached, new_reached, extra))
                mine = ([int(us[i])] if base_mask[i] else []) + extra
                for t in mine:
                    probe_rows[i].append(len(probe_us))
                    probe_qi.append(i)
                    probe_us.append(t)
            if probe_us:
                br = base_knn(np.asarray(probe_us, dtype=np.int64),
                              points[np.asarray(probe_qi)])
            for i in range(B):
                cand_ids, cand_d2 = [], []
                for j in probe_rows[i]:
                    keep = br.ids[j] >= 0
                    cand_ids.append(br.ids[j][keep].astype(np.int64))
                    cand_d2.append(br.dist2[j][keep])
                reached, new_reached, _ = ctxs[i]
                if len(sid):
                    keep = self._staged_reached_mask(
                        sid, reached, new_reached)
                    if keep.any():
                        cand_ids.append(sid[keep])
                        cand_d2.append(_pt_d2(scoord[keep], points[i]))
                if not cand_ids:
                    continue
                ci = np.concatenate(cand_ids)
                cd = np.concatenate(cand_d2)
                ci, first = np.unique(ci, return_index=True)  # dedup probes
                cd = cd[first]
                order = np.lexsort((ci, cd))[:k]
                res.ids[i, : len(order)] = ci[order]
                res.dist2[i, : len(order)] = cd[order]
            return res

    def polygon_batch(self, us: np.ndarray, polygons) -> np.ndarray:
        """Exact convex-polygon RangeReach over the mutated graph."""
        self._require_2dreach("polygon")
        from ..core.polygon import (
            convex_halfplanes,
            points_in_polygon_region,
            polygon_bbox,
        )

        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if len(polygons) != B:
            raise ValueError(f"{len(polygons)} polygons for {B} queries")
        with self._lock:
            self.stats["n_queries"] += B
            overlay = self._overlay
            self._check_query_range(us)
            ans = np.zeros(B, dtype=bool)
            base_mask = us < overlay.n_base
            base_poly = self._base_analytics("polygon_batch")
            if base_mask.any():
                ans[base_mask] = base_poly(
                    us[base_mask], [polygons[i]
                                    for i in np.nonzero(base_mask)[0]])
            if overlay.is_empty():
                return ans
            sid, scoord = self._staged_arrays()
            # one batched base probe for every (query, entry) pair, as
            # the boolean path does with extra_qi/extra_u
            extra_qi, extra_us, extra_polys = [], [], []
            for i in range(B):
                if ans[i]:
                    continue
                reached, new_reached, extra = self._merge_probes(
                    int(us[i]), bool(base_mask[i]))
                if len(sid):
                    keep = self._staged_reached_mask(
                        sid, reached, new_reached)
                    if keep.any() and points_in_polygon_region(
                            scoord[keep], polygon_bbox(polygons[i]),
                            convex_halfplanes(polygons[i])).any():
                        ans[i] = True
                        continue
                for t in extra:
                    extra_qi.append(i)
                    extra_us.append(t)
                    extra_polys.append(polygons[i])
            if extra_us:
                got = base_poly(
                    np.asarray(extra_us, dtype=np.int64), extra_polys)
                np.logical_or.at(ans, np.asarray(extra_qi), got)
            return ans

    # -- compaction -----------------------------------------------------

    def compact(self, background: Optional[bool] = None) -> bool:
        """Force a compaction now; returns False if a background build is
        already in flight."""
        bg = self.policy.background if background is None else background
        return self._compactor.trigger(bg)

    def join_compaction(self, timeout: Optional[float] = None) -> None:
        self._compactor.join(timeout)

    @property
    def compacting(self) -> bool:
        return self._compactor.running

    @property
    def compaction_error(self):
        """Exception latched by a failed background build (None when
        healthy); an explicit ``compact()`` clears it and retries."""
        return self._compactor.last_error

    def maybe_compact(self) -> bool:
        """Apply the policy; called automatically after each mutation.
        Suppressed while a build runs or after one failed (the error
        stays latched until an explicit ``compact()`` retries)."""
        if self._compactor.running or self._compactor.last_error is not None:
            return False
        o = self._overlay
        if self.policy.should_compact(
            o.n_edges, o.n_staged,
            int(self.stats["updates_since_compaction"]),
        ):
            return self.compact()
        return False

    def nbytes(self) -> dict:
        from ..core.api import index_nbytes

        base = index_nbytes(self._index)
        ov = self._overlay.nbytes()
        return {**base, "overlay": ov,
                "total": int(base["total"]) + int(ov)}

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _count_update(self, kind: str) -> None:
        # replayed ops were already counted when first applied; they only
        # contribute to the new overlay's staleness
        self.stats["updates_since_compaction"] += 1
        if not self._replaying:
            self.stats["n_updates"] += 1
            self.stats[kind] += 1
            self.maybe_compact()

    def _covered_now(self, v: int, cur: int, new_reached: set) -> bool:
        if v < self._overlay.n_base:
            return self._stamp_arr[self._comp[v]] == cur
        return v in new_reached

    def _exp_covers(self, exp: _Expansion, v: int) -> bool:
        reached, new_reached, _ = exp
        if v < self._overlay.n_base:
            c = int(self._comp[v])
            j = int(np.searchsorted(reached, c))
            return j < len(reached) and reached[j] == c
        return v in new_reached

    def _expand_from(self, u: int) -> _Expansion:
        """Reach of u over base ∪ overlay at component granularity.

        Memoised per union-find representative of u's element; the cache
        entry stays valid until a delta edge grows a set that covers its
        source (see ``_invalidate_covering``).
        """
        overlay = self._overlay
        uf = overlay.uf
        elem = overlay.elem_of_vertex(u, self._comp)
        key = uf.find(elem)
        hit = self._cache.get(key)
        if hit is not None:
            self.stats["cache_hits"] += 1
            return hit
        self.stats["cache_misses"] += 1

        self._stamp += 1
        cur = self._stamp
        starr = self._stamp_arr
        d = self._d
        n_base = overlay.n_base
        indptr, adj = self._dag_indptr, self._dag_adj
        reached_list: List[int] = []
        new_reached: set = set()
        entries: List[int] = []
        stack: List[int] = []

        def cover(e: int, covered_primary: int = -1) -> None:
            # mark every member of e's group reached; base-comp members
            # other than ``covered_primary`` (whose base reach an already
            # issued probe covers) become entry components
            for m in uf.group(e):
                if m < d:
                    if starr[m] != cur:
                        starr[m] = cur
                        reached_list.append(m)
                        stack.append(m)
                        if m != covered_primary:
                            entries.append(int(self._comp_rep[m]))
                else:
                    new_reached.add(n_base + (m - d))

        # the start component gets an entry probe too: the memo is shared
        # across every vertex of the group, so it must be covering on its
        # own (consumers skip the probe redundant with their step-1 one)
        cover(elem)

        delta_edges = overlay.edges
        while True:
            while stack:
                c = stack.pop()
                for nb in adj[indptr[c]:indptr[c + 1]]:
                    nb = int(nb)
                    if starr[nb] != cur:
                        # base-DAG successor: reach subset of c's, which
                        # is already covered -> nb needs no entry probe,
                        # but group co-members do
                        cover(nb, covered_primary=nb)
            progressed = False
            for (s, t) in delta_edges:
                if self._covered_now(s, cur, new_reached) \
                        and not self._covered_now(t, cur, new_reached):
                    cover(overlay.elem_of_vertex(t, self._comp))
                    progressed = True
            if not progressed and not stack:
                break

        exp: _Expansion = (
            np.sort(np.asarray(reached_list, dtype=np.int64)),
            frozenset(new_reached),
            tuple(entries),
        )
        if len(self._cache) >= _REACH_CACHE_CAP:
            self._cache.pop(next(iter(self._cache)))
        self._cache[key] = exp
        return exp

    def _invalidate_covering(self, s: int) -> None:
        """Drop memoised expansions that cover s — the only ones a new
        edge out of s can grow — plus entries whose key is no longer a
        union-find representative."""
        uf = self._overlay.uf
        dead = [k for k, exp in self._cache.items()
                if self._exp_covers(exp, s) or uf.find(k) != k]
        for k in dead:
            del self._cache[k]
        self.stats["n_cache_invalidations"] += len(dead)

    # -- compaction internals ------------------------------------------

    def _materialise(self) -> GeosocialGraph:
        o = self._overlay
        g = self._graph
        n = o.n_nodes
        if o.edges:
            edges = np.concatenate(
                [g.edges, np.asarray(o.edges, dtype=np.int64).reshape(-1, 2)]
            )
        else:
            edges = g.edges
        coords = np.zeros((n, 2), dtype=np.float32)
        coords[: o.n_base] = g.coords
        sm = np.zeros(n, dtype=bool)
        sm[: o.n_base] = g.spatial_mask
        if len(o.staging):
            ids = np.asarray(o.staging.ids, dtype=np.int64)
            coords[ids] = o.staging.coords_of()
            sm[ids] = True
        return make_graph(n, edges, coords, sm)

    def _begin_compaction(self):
        with self._lock:
            return self._materialise(), len(self._oplog)

    def _build_static(self, snapshot: GeosocialGraph):
        from ..core.api import build_index

        with span("dynamic.compaction_build", cat="dynamic",
                  n=snapshot.n_nodes):
            fault_point("dynamic.compaction.build", n=snapshot.n_nodes)
            index = build_index(snapshot, self.method, **self._build_kw)
            fault_point("dynamic.compaction.mid_build")
            substrate = self._build_reach_substrate(snapshot)
        return index, substrate

    #: everything the swap rebinds — a crash anywhere inside the swap
    #: restores exactly these (plus a stats copy), so a failed
    #: compaction leaves the index serving the pre-swap state
    _SWAP_ATTRS = (
        "_graph", "_index", "_comp", "_d", "_dag_indptr", "_dag_adj",
        "_comp_rep", "_overlay", "_stamp_arr", "_stamp", "_cache",
        "_base_engine", "_oplog",
    )

    def _finish_compaction(self, snapshot, built, cut: int,
                           t_build: float) -> None:
        index, substrate = built
        with self._lock, span("dynamic.compaction_swap", cat="dynamic"):
            fault_point("dynamic.compaction.pre_swap")
            saved = {a: getattr(self, a) for a in self._SWAP_ATTRS}
            saved_stats = dict(self.stats)
            tail = self._oplog[cut:]
            try:
                self._install_base(snapshot, index, substrate)
                self._oplog = []
                self.stats["n_compactions"] += 1
                self.stats["t_compaction_total"] += t_build
                self.stats["t_last_compaction"] = t_build
                self.stats["updates_since_compaction"] = 0
                fault_point("dynamic.compaction.mid_swap")
                # replay mutations that raced the (background) build
                self._replaying = True
                try:
                    fault_point("dynamic.compaction.replay", n=len(tail))
                    for op in tail:
                        if op[0] == "edge":
                            self.add_edge(op[1], op[2])
                        elif op[0] == "vertex":
                            self.add_vertex(op[1])
                        else:  # spatial
                            self.add_spatial(op[1], (op[2], op[3]))
                finally:
                    self._replaying = False
            except BaseException:
                # atomic swap: every rebound attribute points back at
                # the untouched pre-swap objects (the old overlay still
                # holds the tail ops, the old op log still records
                # them), so queries keep answering exactly
                for a in self._SWAP_ATTRS:
                    setattr(self, a, saved[a])
                self.stats.clear()
                self.stats.update(saved_stats)
                raise

    def _compact_sync(self) -> None:
        snapshot, cut = self._begin_compaction()
        t0 = time.perf_counter()
        built = self._build_static(snapshot)
        self._finish_compaction(snapshot, built, cut,
                                time.perf_counter() - t0)

    # -- reporting ------------------------------------------------------

    def report(self) -> dict:
        """Stats + derived amortisation numbers."""
        s = dict(self.stats)
        o = self._overlay
        s.update(
            overlay_edges=o.n_edges,
            overlay_staged=o.n_staged,
            overlay_new_vertices=o.n_new_vertices,
            overlay_size=self.overlay_size,
            reach_cache_entries=len(self._cache),
        )
        if s["n_updates"]:
            s["amortized_compaction_us_per_update"] = (
                s["t_compaction_total"] / s["n_updates"] * 1e6
            )
        return s
