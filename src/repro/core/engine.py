"""Device-resident RangeReach query engine (compile-once serving).

The paper's pitch is that a 2DReach query "reduces to a single 2D R-tree
lookup" — but a lookup that round-trips through host NumPy per batch
(pointer gather on CPU, forest re-transposed to SoA per call, every leaf
scanned) forfeits the reduction.  :class:`QueryEngine` uploads a built
:class:`~repro.core.two_d_reach.TwoDReachIndex` to the accelerator
**once** and answers ``query_batch`` entirely on device:

1. **fused pointer lookup** — vertex→tree inside the jit: a plain
   gather for the base/comp variants, or the Pointer variant's
   bit-vector + rank structure evaluated with an in-jit SWAR popcount;
   spatial-sink queries (Alg. 2's special case) fuse to a point-in-rect
   test in the same trace;
2. **hierarchical prune** — the Pallas ``prune_tiles`` kernel ANDs each
   query rect against internal-level tile MBRs (coarse gate + fine
   test, see :mod:`repro.kernels.range_query.descent`) to decide which
   leaf tiles each query tile actually needs;
3. **masked descent scan** — the scalar-prefetch ``descent_scan``
   kernel visits only the compacted candidate tiles, so work scales
   with the query's R-tree footprint instead of the arena size.

Batches are padded to power-of-two **buckets** (and the candidate
capacity K likewise, with a monotone high-water mark so a smaller batch
never traces a new K shape), so the jit cache is keyed on a handful of
shapes:
steady-state serving recompiles nothing and re-transposes nothing —
asserted by tests via jit cache-size introspection.  Exactness never
rests on the pruning: the scan kernel re-masks by arena slice and exact
box test, so the engine is bit-identical to the ``query_host`` oracle
(scanning an extra tile is an idempotent OR with no new hits).

The upload path is factored into two reusable pieces so the sharded
cluster engine (:mod:`repro.cluster`) serves the same structures:

* :class:`PointerSide` — the replicated vertex→tree lookup arrays plus
  the fused in-jit routing (lookup + Alg. 2 forced answers);
* :class:`TileArena` — one SoA entry arena + tile-MBR pyramid (a shard
  holds one arena; the single-device engine holds the whole forest's).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.range_query.analytics import (
    ID_SENTINEL,
    collect_scan_pallas,
    count_scan_pallas,
    polygon_scan_pallas,
)
from ..kernels.range_query.descent import (
    build_tile_pyramid,
    descent_scan_pallas,
    prune_tiles_pallas,
)
from ..kernels.range_query.kernel import TB, TP
from ..kernels.range_query.ops import forest_soa
from ..obs import CounterDict, REGISTRY, span
from ..obs.tracer import TRACER as _TRACER
from ..resilience.faults import fault_point
from .polygon import convex_halfplanes, points_in_polygon_region, polygon_bbox
from .two_d_reach import TwoDReachIndex


def _bucket(n: int, lo: int) -> int:
    """Smallest power-of-two >= max(n, lo) (lo itself a power of two)."""
    b = lo
    while b < n:
        b <<= 1
    return b


def _collect_post(mat: jax.Array, *, kc: int):
    """Fused collect postprocess: (B, K*TP) ids-or-sentinel -> the
    ``kc`` smallest ids per row (sentinel sorts last) + exact totals."""
    srt = jnp.sort(mat, axis=1)
    cnt = jnp.sum(mat != ID_SENTINEL, axis=1)
    return srt[:, :kc], cnt


def _popcount32_jnp(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(jnp.int32)


# --------------------------------------------------------------------------
# Reusable upload pieces (single-device engine + cluster shards)
# --------------------------------------------------------------------------

# Build→serve handoff counters since import.  ``host_uploads`` counts
# arenas built from host arrays (transpose + pyramid + upload);
# ``device_adoptions`` counts arenas adopted zero-copy from a
# ``build_forest_device`` handoff.  Benchmarks and tests assert that
# serving a device-built index — including every DynamicIndex compaction
# swap — bumps only the adoption counter.  The values live in the
# ``repro.obs`` metrics registry (``engine.upload.*``); this dict-shaped
# view keeps the legacy ``UPLOAD_COUNTERS[...]`` surface working.
UPLOAD_COUNTERS = CounterDict(
    "engine.upload.", ("host_uploads", "device_adoptions"))

class PointerSide:
    """Device-resident vertex→tree lookup side of a 2DReach index.

    Holds the arrays every serving replica needs in full — coords,
    excluded mask, and the variant's pointer structure — and evaluates
    the fused lookup / Alg. 2 routing inside whatever jit traces it.
    In the cluster engine these arrays are *replicated* per device while
    the R-tree arenas shard.
    """

    def __init__(self, index: TwoDReachIndex):
        self.variant = index.variant
        self.dim = index.forest.dim
        self._coords = jnp.asarray(index.coords, jnp.float32)
        self._excluded = jnp.asarray(index.excluded)
        if self.variant == "pointer":
            self._vertex_comp = jnp.asarray(index.vertex_comp, jnp.int32)
            self._bits = jnp.asarray(index.bitrank.bits)
            self._rank = jnp.asarray(index.bitrank.rank, jnp.int32)
            self._tree_ptrs = jnp.asarray(index.tree_ptrs, jnp.int32)
            self._vertex_tree = None
        else:
            self._vertex_tree = jnp.asarray(index.vertex_tree, jnp.int32)

    def lookup(self, us: jax.Array) -> jax.Array:
        """Fused vertex -> tree id (-1: excluded / no tree), in-jit."""
        if self.variant != "pointer":
            return self._vertex_tree[us]
        c = self._vertex_comp[us]
        ok = c >= 0
        cc = jnp.maximum(c, 0)
        w = cc // 32
        b = (cc % 32).astype(jnp.uint32)
        word = self._bits[w]
        member = ((word >> b) & np.uint32(1)) > 0
        below = word & ((np.uint32(1) << b) - np.uint32(1))
        rank = self._rank[w] + _popcount32_jnp(below)
        t = self._tree_ptrs[
            jnp.minimum(rank, self._tree_ptrs.shape[0] - 1)
        ]
        return jnp.where(ok & member, t, -1)

    def route(self, us: jax.Array, rects_soa: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """(tree id, needs-tree-probe mask, Alg. 2 forced answers).

        ``forced`` is the spatial-query special case fused in-trace: an
        excluded (spatial-sink) query vertex answers by its own point
        against the rect, with the same float32 comparisons as host.
        """
        dim = self.dim
        tid = self.lookup(us)
        exc = self._excluded[us]
        valid = (tid >= 0) & ~exc
        pt = self._coords[us]
        inr = jnp.ones(us.shape[0], dtype=bool)
        for a in range(dim):
            inr = inr & (pt[:, a] >= rects_soa[a])
            inr = inr & (pt[:, a] <= rects_soa[dim + a])
        return tid, valid, exc & inr


@dataclasses.dataclass(frozen=True)
class TileArena:
    """One uploaded SoA entry arena + its tile-MBR pyramid."""

    entries: jax.Array     # (2*dim, Pp) float32 SoA planes
    fine: jax.Array        # (2*dim, NTp) float32 leaf-tile MBRs
    coarse: jax.Array      # (2*dim, NTp // COARSE_GROUP) float32
    entry_off: jax.Array   # (T+1,) int32 per-tree arena slices
    n_tiles: int           # true fine tile count (Pp // TP)

    @classmethod
    def upload(cls, esoa: np.ndarray, off: np.ndarray,
               dim: int) -> "TileArena":
        UPLOAD_COUNTERS["host_uploads"] += 1
        with span("engine.soa_upload", cat="build",
                  nbytes=int(esoa.nbytes)):
            fine, coarse, nt = build_tile_pyramid(esoa, dim)
            return cls(
                entries=jnp.asarray(esoa),
                fine=jnp.asarray(fine),
                coarse=jnp.asarray(coarse),
                entry_off=jnp.asarray(off, jnp.int32),
                n_tiles=nt,
            )

    @classmethod
    def for_forest(cls, forest, dim: int) -> "TileArena":
        """Arena for a built forest — adopted zero-copy when the forest
        carries a ``build_forest_device`` handoff (the arrays are
        already device-resident in exactly this layout), uploaded from
        the host arrays otherwise."""
        dev = getattr(forest, "device", None)
        if dev is not None:
            UPLOAD_COUNTERS["device_adoptions"] += 1
            return cls(
                entries=dev.entries,
                fine=dev.fine,
                coarse=dev.coarse,
                entry_off=dev.entry_off,
                n_tiles=dev.n_tiles,
            )
        esoa, off = forest_soa(forest)        # cached transposition
        return cls.upload(esoa, off, dim)


def compact_candidates(mask: jax.Array, nt: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Prune mask (NB, >=nt) -> compacted candidate tiles per query tile.

    Returns ``(cand (NB, nt) int32, cnt (NB,) int32)``: active tiles
    first (ascending), then the last active tile repeated so consecutive
    identical block indices elide the scan kernel's DMA.
    """
    active = mask[:, :nt] > 0
    cnt = active.sum(axis=1).astype(jnp.int32)
    j = jnp.arange(nt, dtype=jnp.int32)
    order = jnp.argsort(
        jnp.where(active, j[None, :], nt + j[None, :]), axis=1
    ).astype(jnp.int32)
    last = order[jnp.arange(order.shape[0]), jnp.maximum(cnt - 1, 0)]
    cand = jnp.where(j[None, :] < cnt[:, None], order, last[:, None])
    return cand, cnt


def pad_batch(us: np.ndarray, rects: np.ndarray, dim: int
              ) -> Tuple[int, np.ndarray, np.ndarray]:
    """Pad a host batch to its power-of-two bucket.

    Returns ``(Bb, us_p (Bb,) int32, rsoa (2*dim, Bb) float32)``.
    Padding rects must miss every box regardless of data extent:
    min=+inf / max=-inf fails both halves of the intersect test (a
    finite 1.0/0.0 sentinel would phantom-hit tiles spanning it).
    """
    B = len(us)
    rects = np.asarray(rects, dtype=np.float32).reshape(B, 2 * dim)
    Bb = _bucket(B, TB)
    us_p = np.zeros(Bb, dtype=np.int32)
    us_p[:B] = us
    rsoa = np.empty((2 * dim, Bb), dtype=np.float32)
    rsoa[:dim] = np.inf
    rsoa[dim:] = -np.inf
    rsoa[:, :B] = rects.T
    return Bb, us_p, rsoa


# --------------------------------------------------------------------------
# Single-device engine
# --------------------------------------------------------------------------

class QueryEngine:
    """Compile-once device engine over a built ``TwoDReachIndex``.

    Parameters
    ----------
    index:     any 2DReach variant (``base`` / ``comp`` / ``pointer``).
    interpret: run the Pallas kernels in interpret mode; ``None`` picks
               real kernels on TPU and interpret elsewhere.
    """

    def __init__(self, index: TwoDReachIndex,
                 interpret: Optional[bool] = None):
        if not isinstance(index, TwoDReachIndex):
            raise TypeError(
                f"QueryEngine serves TwoDReachIndex, got {type(index).__name__}"
            )
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._interpret = bool(interpret)
        self.variant = index.variant
        self.dim = index.forest.dim

        # ---- one-time upload (or zero-copy adoption) -------------------
        self._side = PointerSide(index)
        self._arena = TileArena.for_forest(index.forest, self.dim)
        self.n_tiles = self._arena.n_tiles

        # host-side routing mirrors + payload-id plane for the analytics
        # classes (count/collect/kNN/polygon, see repro.queries): the id
        # plane rides next to the entry arena (sentinel padding so misses
        # sort last), the excluded/coords mirrors resolve the Alg. 2
        # special case per class
        self._excluded_host = index.excluded
        self._coords_host = index.coords
        Pp = int(self._arena.entries.shape[1])
        ids_row = np.full((1, Pp), ID_SENTINEL, dtype=np.int32)
        ids_row[0, : len(index.forest.entry_ids)] = index.forest.entry_ids
        self._ids_row = jnp.asarray(ids_row)
        ent = index.forest.entries
        self._extent_host = (
            np.concatenate([ent[:, : self.dim].min(0),
                            ent[:, self.dim:].max(0)]).astype(np.float64)
            if len(ent) else None
        )

        self.stats: Dict[str, float] = {
            "uploads": 1, "batches": 0, "queries": 0,
            "adopted": int(getattr(index.forest, "device", None) is not None),
            "tiles_scanned": 0, "tiles_grid": 0, "tiles_full_scan": 0,
        }
        # candidate-capacity high-water mark: K only ratchets up, so a
        # smaller batch never traces a new K shape and lifetime scan
        # retraces are bounded by log2(n_tiles) per batch bucket; extra
        # K columns repeat the last candidate tile, whose DMA the
        # pipeline elides
        self._kb_hwm = 1
        self._prepare = jax.jit(self._make_prepare())
        self._scan = jax.jit(self._make_scan())
        self._count_scan = jax.jit(self._make_count_scan())
        self._collect_scan = jax.jit(self._make_collect_scan())
        self._collect_post = jax.jit(_collect_post, static_argnames=("kc",))
        self._polygon_scan = jax.jit(self._make_polygon_scan(),
                                     static_argnames=("ne",))

    # ------------------------------------------------------------------
    # jit closures (per-engine, so cache introspection is local)
    # ------------------------------------------------------------------

    def _make_prepare(self):
        nt = self.n_tiles
        interpret = self._interpret
        dim = self.dim
        side = self._side
        arena = self._arena

        def prepare(us, rects_soa):
            # us (Bb,) int32; rects_soa (2*dim, Bb) f32
            tid, valid, forced = side.route(us, rects_soa)
            t = jnp.maximum(tid, 0)
            qs = jnp.where(valid, arena.entry_off[t], 0)
            qe = jnp.where(valid, arena.entry_off[t + 1], 0)
            mask = prune_tiles_pallas(
                arena.fine, arena.coarse, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )
            cand, cnt = compact_candidates(mask, nt)
            return forced, qs, qe, cand, cnt, cnt.max()

        return prepare

    def _make_scan(self):
        dim = self.dim
        interpret = self._interpret
        arena = self._arena

        def scan(cand_k, rects_soa, qs, qe):
            return descent_scan_pallas(
                cand_k, arena.entries, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )

        return scan

    def _make_count_scan(self):
        dim = self.dim
        interpret = self._interpret
        arena = self._arena

        def scan(cand_k, rects_soa, qs, qe):
            return count_scan_pallas(
                cand_k, arena.entries, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )

        return scan

    def _make_collect_scan(self):
        dim = self.dim
        interpret = self._interpret
        arena = self._arena
        ids_row = self._ids_row

        def scan(cand_k, rects_soa, qs, qe):
            return collect_scan_pallas(
                cand_k, arena.entries, ids_row, rects_soa, qs, qe,
                dim=dim, interpret=interpret,
            )

        return scan

    def _make_polygon_scan(self):
        dim = self.dim
        interpret = self._interpret
        arena = self._arena

        def scan(cand_k, rects_soa, lines_soa, qs, qe, *, ne):
            return polygon_scan_pallas(
                cand_k, arena.entries, rects_soa, lines_soa, qs, qe,
                ne=ne, dim=dim, interpret=interpret,
            )

        return scan

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------

    @property
    def n_compiles(self) -> int:
        """Distinct (bucketed) shapes traced so far — flat in steady
        state; tests assert it via this introspection hook."""
        return int(
            self._prepare._cache_size() + self._scan._cache_size()
            + self._count_scan._cache_size()
            + self._collect_scan._cache_size()
            + self._collect_post._cache_size()
            + self._polygon_scan._cache_size()
        )

    def _route_prune(self, us: np.ndarray, rects: np.ndarray):
        """Shared phase 1 for every query class: pad to the batch
        bucket, run the fused route + hierarchical prune, ratchet the
        candidate high-water mark.  Returns ``(Bb, rsoa_dev, forced,
        qs, qe, cand_k)`` with ``cand_k`` already sliced to the K
        bucket."""
        B = len(us)
        fault_point("engine.route_prune", n=B)
        with span("engine.pad_batch", cat="engine"):
            Bb, us_p, rsoa = pad_batch(us, rects, self.dim)
            rsoa_dev = jnp.asarray(rsoa)
        with span("engine.route_prune", cat="engine", batch=B):
            forced, qs, qe, cand, cnt, mx = self._prepare(
                jnp.asarray(us_p), rsoa_dev
            )
            # int(mx) blocks on the device prune, so the span really
            # covers lookup + prune + candidate compaction
            self._kb_hwm = max(
                self._kb_hwm,
                min(_bucket(max(int(mx), 1), 1), self.n_tiles))
        kb = self._kb_hwm
        self.stats["batches"] += 1
        self.stats["queries"] += B
        # tiles_scanned: live candidate tiles (pruning effectiveness);
        # tiles_grid: kernel grid steps incl. bucket padding (actual work
        # — padded steps repeat the last tile, so their DMA is elided)
        self.stats["tiles_scanned"] += int(np.asarray(cnt).sum())
        self.stats["tiles_grid"] += (Bb // TB) * kb
        self.stats["tiles_full_scan"] += (Bb // TB) * self.n_tiles
        return Bb, rsoa_dev, forced, qs, qe, cand[:, :kb]

    def _obs_batch(self, kind: str, B: int, t0: float) -> None:
        """Gated per-batch registry recording (enabled-only: one
        histogram append + two updates per *batch*, nothing per query)."""
        if not _TRACER.enabled:
            return
        dt_us = (time.perf_counter() - t0) * 1e6
        REGISTRY.histogram("engine.batch_us").record(dt_us)
        REGISTRY.histogram(f"engine.{kind}.query_us").record(dt_us / max(B, 1))
        REGISTRY.counter(f"engine.{kind}.queries").inc(B)
        REGISTRY.gauge("engine.n_compiles").set(self.n_compiles)

    def query_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Batched RangeReach, same contract as ``TwoDReachIndex
        .query_batch`` (and bit-identical to it)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=bool)
        fault_point("engine.query_batch", n=B)
        t0 = time.perf_counter()
        with span("engine.query_batch", cat="engine", n=B):
            _, rsoa_dev, forced, qs, qe, cand_k = self._route_prune(
                us, rects)
            with span("engine.scan", cat="engine"):
                hit = self._scan(cand_k, rsoa_dev, qs, qe)
            with span("engine.sync", cat="engine"):
                out = np.asarray(hit).astype(bool) | np.asarray(forced)
        self._obs_batch("reach", B, t0)
        return out[:B]

    def query(self, u: int, rect) -> bool:
        return bool(self.query_batch(np.array([u]), np.array([rect]))[0])

    # -- analytics classes (see repro.queries) --------------------------

    def count_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Batched RangeCount: (B,) int64 exact number of reachable
        venues intersecting each rect (bit-identical to the host
        ``repro.queries.range_count_host``)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=np.int64)
        t0 = time.perf_counter()
        with span("engine.count_batch", cat="engine", n=B):
            _, rsoa_dev, forced, qs, qe, cand_k = self._route_prune(
                us, rects)
            with span("engine.scan", cat="engine"):
                counts = self._count_scan(cand_k, rsoa_dev, qs, qe)
            # forced: an excluded (spatial-sink) query vertex reaches
            # exactly itself — its tree probe counted nothing (empty
            # slice)
            with span("engine.sync", cat="engine"):
                out = (np.asarray(counts).astype(np.int64)
                       + np.asarray(forced).astype(np.int64))
        self._obs_batch("count", B, t0)
        return out[:B]

    def collect_batch(self, us: np.ndarray, rects: np.ndarray, k: int):
        """Batched RangeCollect: the K smallest reachable venue ids in
        each rect + exact totals and overflow flags — see
        ``repro.queries.CollectResult`` (bit-identical to host)."""
        from ..queries.program import CollectResult  # deferred: no cycle

        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        k = int(k)
        if k < 1:
            raise ValueError(f"collect needs k >= 1, got {k}")
        if B == 0:
            return CollectResult(
                ids=np.zeros((0, k), np.int32),
                counts=np.zeros(0, np.int64),
                overflow=np.zeros(0, bool),
            )
        t0 = time.perf_counter()
        with span("engine.collect_batch", cat="engine", n=B):
            _, rsoa_dev, forced, qs, qe, cand_k = self._route_prune(
                us, rects)
            with span("engine.scan", cat="engine"):
                mat = self._collect_scan(cand_k, rsoa_dev, qs, qe)
                top, cnt = self._collect_post(mat, kc=_bucket(k, 1))
        self._obs_batch("collect", B, t0)
        top = np.asarray(top)[:B]
        counts = np.asarray(cnt).astype(np.int64)[:B]
        ids = np.full((B, k), ID_SENTINEL, dtype=np.int32)
        take = min(k, top.shape[1])
        ids[:, :take] = top[:, :take]
        ids[ids == ID_SENTINEL] = -1
        exc = self._excluded_host[us]
        if exc.any():
            hit = np.nonzero(exc & np.asarray(forced)[:B])[0]
            ids[hit, 0] = us[hit]
            counts[hit] = 1
        return CollectResult(ids=ids, counts=counts, overflow=counts > k)

    def knn_batch(self, us: np.ndarray, points: np.ndarray, k: int):
        """Batched KNNReach via the device radius-doubling driver over
        RangeCount/RangeCollect (see ``repro.queries.knn``); results are
        the exact (dist², id)-ordered k nearest reachable venues,
        bit-identical to the host best-first descent."""
        from ..queries.knn import knn_radius_doubling  # deferred: no cycle

        with span("engine.knn_batch", cat="engine", n=len(us), k=k):
            return knn_radius_doubling(self, us, points, k)

    def polygon_batch(self, us: np.ndarray, polygons) -> np.ndarray:
        """Batched convex-polygon RangeReach: the half-plane postfilter
        runs inside the leaf-scan kernel (bbox prune + canonical f32
        region test; bit-identical to host)."""
        us = np.asarray(us, dtype=np.int64)
        B = len(us)
        if B == 0:
            return np.zeros(0, dtype=bool)
        if len(polygons) != B:
            raise ValueError(f"{len(polygons)} polygons for {B} queries")
        t0 = time.perf_counter()
        with span("engine.polygon_batch", cat="engine", n=B):
            bboxes = np.stack([polygon_bbox(p) for p in polygons])
            ne = max(len(np.asarray(p).reshape(-1, 2)) for p in polygons)
            neb = _bucket(ne, 4)
            hps = np.stack(
                [convex_halfplanes(p, pad_to=neb) for p in polygons])
            Bb, rsoa_dev, _, qs, qe, cand_k = self._route_prune(us, bboxes)
            # (B, 3, neb) -> (3*neb, Bb); padded batch lanes get inert
            # half-planes (A=B=0, C=+inf) to match their impossible rects
            lines = np.zeros((3 * neb, Bb), dtype=np.float32)
            lines[2 * neb:] = np.inf
            lines[:, :B] = hps.transpose(1, 2, 0).reshape(3 * neb, B)
            with span("engine.scan", cat="engine"):
                hit = self._polygon_scan(cand_k, rsoa_dev,
                                         jnp.asarray(lines),
                                         qs, qe, ne=neb)
            with span("engine.sync", cat="engine"):
                out = np.asarray(hit)[:B] > 0
        self._obs_batch("polygon", B, t0)
        exc = self._excluded_host[us]
        if exc.any():
            for i in np.nonzero(exc)[0]:
                out[i] = bool(points_in_polygon_region(
                    self._coords_host[us[i]][None], bboxes[i], hps[i])[0])
        return out


def _unsupported_msg(index, what: str) -> str:
    name = type(index).__name__
    method = getattr(index, "method", None) or getattr(index, "variant", None)
    via = f" (method {method!r})" if isinstance(method, str) else ""
    return (
        f"no {what} for {name}{via}: device/cluster serving supports the "
        f"2DReach variants only (2dreach, 2dreach-comp, 2dreach-pointer)"
    )


def engine_for(index, interpret: Optional[bool] = None,
               required: bool = False):
    """Memoised ``QueryEngine`` for a built 2DReach index (one upload per
    index instance).

    Supported pairings: any :class:`TwoDReachIndex` variant (``base`` /
    ``comp`` / ``pointer``), from either build backend —
    ``build_2dreach(backend="host")`` uploads its arrays here once;
    ``backend="device"`` indexes are *adopted* zero-copy (the build left
    the serving arrays on device; see ``UPLOAD_COUNTERS``).  For index
    types the device engine does not serve (3DReach, GeoReach, anything
    without a 2D forest), returns ``None`` so callers can fall back to
    the host path — or, with ``required=True``, raises a ``ValueError``
    naming the unsupported index/method (instead of the caller tripping
    an ``AttributeError`` deep inside the engine).  An explicit
    ``interpret`` that disagrees with the memoised engine's mode
    rebuilds rather than silently returning the wrong kernel mode."""
    if not isinstance(index, TwoDReachIndex):
        if required:
            raise ValueError(_unsupported_msg(index, "device QueryEngine"))
        return None
    eng = getattr(index, "_device_engine", None)
    if eng is None or (
        interpret is not None and eng._interpret != bool(interpret)
    ):
        eng = QueryEngine(index, interpret=interpret)
        index._device_engine = eng
    return eng
