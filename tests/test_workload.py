"""Workload intelligence: sketches, skew, SLO burn rates, exporters.

Covers the stage-2 observability acceptance gates:

* Space-Saving guarantees on adversarial Zipf streams — every true
  heavy hitter monitored, estimates within the ``n/capacity`` bound,
  ``heavy_hitters(phi)`` a superset of the exact heavy-hitter set.
* Gini coefficient identical to the exact pairwise NumPy definition.
* ``WorkloadAnalytics``: shard shares sum to 1, exact-recount
  verification against the query log, placement report structure.
* SLO burn-rate monitor fires and clears deterministically on a fake
  clock, with multi-window semantics (short window gates clearing).
* OpenMetrics exposition parse-checked line-by-line; summary quantiles
  bit-for-bit ``np.percentile``.
* Time-series collector: counter deltas/rates and windowed histogram
  percentiles under a fake clock; JSONL dump with schema header.
"""

from __future__ import annotations

import json
import re

import numpy as np
import pytest

from repro.obs.export import metric_name, to_openmetrics
from repro.obs.metrics import Registry
from repro.obs.querylog import QueryLog
from repro.obs.slo import SLOMonitor, default_slos, hist_count, \
    latency_above
from repro.obs.timeseries import TimeSeriesCollector
from repro.obs.workload import SpaceSaving, WorkloadAnalytics, gini


def zipf_keys(rng, n, n_keys=5000, s=1.3):
    p = np.arange(1, n_keys + 1, dtype=np.float64) ** -s
    p /= p.sum()
    return rng.choice(n_keys, size=n, p=p)


# ----------------------------------------------------------- Space-Saving

def test_space_saving_exact_below_capacity():
    ss = SpaceSaving(capacity=64)
    stream = [1, 2, 2, 3, 3, 3, 4] * 5
    for k in stream:
        ss.offer(k)
    assert ss.n == len(stream)
    assert ss.count(3) == (15, 0)            # exact, zero error
    assert ss.count(99) is None
    assert [k for k, _, _ in ss.top(2)] == [3, 2]


@pytest.mark.parametrize("s", [1.1, 1.5])
def test_space_saving_zipf_guarantees(s):
    """The classic guarantees on a skewed stream with far more distinct
    keys than sketch capacity."""
    rng = np.random.default_rng(int(s * 10))
    capacity = 64
    stream = zipf_keys(rng, 20000, n_keys=5000, s=s)
    ss = SpaceSaving(capacity)
    exact: dict = {}
    for k in stream:
        k = int(k)
        ss.offer(k)
        exact[k] = exact.get(k, 0) + 1
    n = len(stream)
    bound = n / capacity
    assert len(ss) == capacity               # memory stays bounded
    # (1) every key with true count > n/capacity is monitored
    for k, c in exact.items():
        if c > bound:
            assert ss.count(k) is not None, f"hot key {k} not monitored"
    # (2) true <= estimate <= true + n/capacity, and the per-key error
    #     bound brackets the overcount
    for k, est, err in ss.items():
        t = exact.get(k, 0)
        assert t <= est <= t + bound
        assert est - err <= t
    # (3) heavy_hitters(phi) has no false negatives for phi > 1/capacity
    phi = 2.0 / capacity
    hh = {k for k, _, _ in ss.heavy_hitters(phi)}
    exact_hh = {k for k, c in exact.items() if c >= phi * n}
    assert exact_hh <= hh


def test_space_saving_adversarial_churn():
    """Worst case for the lazy heap: a long all-distinct prefix (every
    offer evicts) followed by a returning hot key."""
    ss = SpaceSaving(capacity=8)
    for k in range(1000):
        ss.offer(k)
    for _ in range(500):
        ss.offer("hot")
    est, err = ss.count("hot")
    assert est >= 500                        # never undercounts
    assert est - err <= 500                  # error brackets the truth
    assert ss.top(1)[0][0] == "hot"
    assert len(ss) == 8


def test_space_saving_validates_capacity():
    with pytest.raises(ValueError):
        SpaceSaving(0)


# -------------------------------------------------------------------- Gini

def exact_gini(x):
    x = np.asarray(x, dtype=np.float64)
    n = len(x)
    if n == 0 or x.sum() == 0:
        return 0.0
    return float(np.abs(x[:, None] - x[None, :]).sum()
                 / (2.0 * n * n * x.mean()))


def test_gini_matches_pairwise_definition():
    rng = np.random.default_rng(4)
    for x in (rng.random(50), rng.lognormal(0, 2, 200),
              np.array([5.0]), np.array([1.0, 1.0, 1.0])):
        assert gini(x) == pytest.approx(exact_gini(x), abs=1e-12)


def test_gini_extremes():
    assert gini([]) == 0.0
    assert gini([0.0, 0.0]) == 0.0
    assert gini([3.0, 3.0, 3.0, 3.0]) == pytest.approx(0.0)
    n = 10                                   # one shard carries all
    assert gini([1.0] + [0.0] * (n - 1)) == pytest.approx((n - 1) / n)


# --------------------------------------------------- workload analytics

def _fill_log(log, rng, n=3000, n_shards=4):
    """Zipf vertices, skewed shards, a degraded slice."""
    us = zipf_keys(rng, n, n_keys=500, s=1.4)
    shards = rng.choice(n_shards, size=n, p=[0.55, 0.25, 0.15, 0.05])
    for i in range(n):
        log.record("reach", "user", int(rng.integers(-2, 3)),
                   int(shards[i]), float(rng.exponential(1e-4)),
                   1, u=int(us[i]),
                   status="degraded" if i % 10 == 0 else "ok",
                   retries=1 if i % 50 == 0 else 0)
    return us, shards


def test_workload_analytics_report_and_verify():
    rng = np.random.default_rng(17)
    log = QueryLog(capacity=10000)           # no eviction: exact window
    wa = WorkloadAnalytics()
    log.add_sink(wa.observe)
    us, shards = _fill_log(log, rng)
    n = len(us)
    assert wa.total == n

    rep = wa.placement_report(top_k=5, query_log=log)
    skew = rep["skew"]
    assert skew["n_shards"] == 4
    q_shares = [v["query_share"] for v in skew["per_shard"].values()]
    l_shares = [v["latency_share"] for v in skew["per_shard"].values()]
    assert sum(q_shares) == pytest.approx(1.0)
    assert sum(l_shares) == pytest.approx(1.0)
    assert sum(v["queries"] for v in skew["per_shard"].values()) == n
    # gini of the shares matches the exact NumPy recount of the stream
    counts = np.bincount(shards, minlength=4).astype(float)
    assert skew["gini_queries"] == pytest.approx(exact_gini(counts))
    assert skew["max_query_share"] == pytest.approx(counts.max() / n)

    # the sketch's heavy hitters match the exact recount of the log
    ver = rep["verified"]
    assert ver["window_is_stream"]
    assert ver["exact_match"]
    assert ver["all_exact_reported"]
    exact = np.bincount(us)
    top_true = int(np.argmax(exact))
    assert rep["heavy_hitters"]["vertices"][0]["key"] == top_true
    assert rep["by_status"]["degraded"] == n // 10
    assert rep["degraded_fraction"] == pytest.approx(0.1, abs=0.01)
    assert rep["device_retries"] == n // 50
    # the humans' table renders every sketch
    table = wa.top_table(top_k=3)
    assert "vertex" in table and "shard" in table and "%" in table


def test_workload_analytics_sink_outlives_ring():
    """Sketch totals cover the whole stream even when the log ring only
    retains a small window of it."""
    rng = np.random.default_rng(23)
    log = QueryLog(capacity=64)              # heavy eviction
    wa = WorkloadAnalytics()
    log.add_sink(wa.observe)
    _fill_log(log, rng, n=2000)
    assert log.dropped == 2000 - 64
    assert wa.total == 2000                  # sink saw pre-eviction
    assert wa.vertices.n == 2000
    ver = wa.verify(log)
    assert not ver["window_is_stream"]       # and says so
    assert ver["window"] == 64


# ------------------------------------------------------------ SLO monitor

def test_slo_fires_and_clears_on_fake_clock():
    reg = Registry()
    bad, tot = reg.counter("bad"), reg.counter("total")
    mon = SLOMonitor(registry=reg)
    mon.add("avail", "bad", "total", budget=0.01,
            windows=(5.0, 60.0), threshold=1.0)

    t = 0.0
    for _ in range(61):                      # healthy minute: no alerts
        tot.inc(100)
        assert mon.tick(t) == []
        t += 1.0
    assert not mon.slos[0].active

    fired_at = None
    for _ in range(10):                      # 50% bad: burn 50x short,
        tot.inc(100)                         # >1x long -> must fire
        bad.inc(50)
        for e in mon.tick(t):
            assert e["kind"] == "fired" and e["slo"] == "avail"
            fired_at = e["t"]
            assert e["burns"]["5s"] > 1.0 and e["burns"]["60s"] > 1.0
        t += 1.0
    assert fired_at is not None
    assert mon.slos[0].active
    assert reg.counter("slo.avail.fired").value == 1
    assert reg.gauge("slo.avail.active").value == 1

    cleared = []
    for _ in range(10):                      # recovery: short window
        tot.inc(100)                         # drains -> clears
        cleared += [e for e in mon.tick(t) if e["kind"] == "cleared"]
        t += 1.0
    assert len(cleared) == 1
    assert not mon.slos[0].active
    assert reg.gauge("slo.avail.active").value == 0
    snap = mon.snapshot()
    assert snap["active"] == []
    assert [e["kind"] for e in snap["events"]] == ["fired", "cleared"]


def test_slo_long_window_gates_blips():
    """A short bad blip burns the 5s window but not the 60s window:
    multi-window alerting stays quiet."""
    reg = Registry()
    bad, tot = reg.counter("b"), reg.counter("t")
    mon = SLOMonitor(registry=reg)
    mon.add("x", "b", "t", budget=0.01, windows=(5.0, 60.0))
    t = 0.0
    for i in range(120):
        tot.inc(100)
        if i == 100:                         # one bad second
            bad.inc(60)
        assert mon.tick(t) == [], f"fired on a blip at t={t}"
        t += 1.0


def test_slo_latency_sources():
    reg = Registry()
    h = reg.histogram("lat_us")
    for v in [10.0] * 90 + [9000.0] * 10:
        h.record(v)
    assert latency_above("lat_us", 1000.0)(reg) == 10
    assert hist_count("lat_us")(reg) == 100


def test_default_slos_wiring():
    reg = Registry()
    mon = default_slos(SLOMonitor(registry=reg))
    names = {s.name for s in mon.slos}
    assert names == {"availability", "degraded", "breaker", "latency"}
    # resolvable against a registry that has seen no traffic
    assert mon.tick(0.0) == []
    with pytest.raises(ValueError):
        mon.add("zero-budget", "b", "t", budget=0.0)


# ------------------------------------------------------------ OpenMetrics

# one OpenMetrics sample line: name{labels} value
_SAMPLE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z0-9_]+="[^"]*"'
    r'(,[a-zA-Z0-9_]+="[^"]*")*\})? -?[0-9].*$|'
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{.*\})? (NaN|[+-]Inf)$')
_TYPE = re.compile(r"^# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* "
                   r"(counter|gauge|summary)$")


def test_openmetrics_parses_line_by_line():
    reg = Registry()
    reg.counter("frontend.requests").inc(42)
    reg.gauge("frontend.queue_depth").set(7)
    lat = np.random.default_rng(0).lognormal(3, 1, 500)
    h = reg.histogram("engine.batch_us")
    h.record_many(lat)
    reg.counter("weird-name.с")              # sanitisation fodder

    text = to_openmetrics(reg)
    lines = text.splitlines()
    assert lines[-1] == "# EOF"
    typed = set()
    for ln in lines[:-1]:
        if ln.startswith("# TYPE"):
            assert _TYPE.match(ln), f"bad TYPE line: {ln!r}"
            typed.add(ln.split()[2])
        else:
            assert _SAMPLE.match(ln), f"unparseable sample: {ln!r}"
            fam = re.split(r"[{ ]", ln)[0]
            base = re.sub(r"(_total|_sum|_count|_hwm)$", "", fam)
            assert fam in typed or base in typed, f"untyped: {ln!r}"

    assert "repro_frontend_requests_total 42" in lines
    assert "repro_frontend_queue_depth 7" in lines
    # summary quantiles are the histogram's exact percentiles
    for q, p in ((0.5, 50), (0.99, 99)):
        want = float(np.percentile(lat, p))
        assert f'repro_engine_batch_us{{quantile="{q:g}"}} {want!r}' \
            in text or f'repro_engine_batch_us{{quantile="{q:g}"}} ' \
            f'{int(want)}' in text
    assert "repro_engine_batch_us_count 500" in lines


def test_metric_name_sanitisation():
    assert metric_name("a.b-c d") == "repro_a_b_c_d"
    assert metric_name("engine.batch_us") == "repro_engine_batch_us"
    assert metric_name("9lives", prefix="") == "_9lives"


# ------------------------------------------------------------ time series

def test_timeseries_deltas_and_windows_fake_clock():
    reg = Registry()
    c = reg.counter("served")
    h = reg.histogram("lat")
    clock_t = [100.0]
    ts = TimeSeriesCollector(registry=reg, clock=lambda: clock_t[0],
                             capacity=16)

    c.inc(10)
    first = np.array([5.0, 10.0, 20.0])
    h.record_many(first)
    s0 = ts.sample()
    assert s0["dt"] is None
    assert s0["counters"]["served"] == {"value": 10.0, "delta": 10.0}
    assert s0["histograms"]["lat"]["delta"] == 3
    assert s0["histograms"]["lat"]["p50"] == float(np.percentile(first, 50))

    clock_t[0] = 102.0
    c.inc(30)
    second = np.array([100.0, 200.0, 300.0, 400.0])
    h.record_many(second)
    s1 = ts.sample()
    assert s1["dt"] == pytest.approx(2.0)
    assert s1["counters"]["served"]["delta"] == 30.0
    assert s1["counters"]["served"]["rate"] == pytest.approx(15.0)
    win = s1["histograms"]["lat"]
    assert win["count"] == 7 and win["delta"] == 4
    # windowed percentiles describe only this interval's recordings
    assert win["p50"] == float(np.percentile(second, 50))
    assert win["sum_delta"] == pytest.approx(second.sum())

    tsx, vals = ts.series("counters", "served", "rate")
    assert tsx == [102.0] and vals == [15.0]


def test_timeseries_hooks_drive_slo(tmp_path):
    reg = Registry()
    bad, tot = reg.counter("b"), reg.counter("t")
    mon = SLOMonitor(registry=reg)
    mon.add("x", "b", "t", budget=0.01, windows=(2.0,))
    clock_t = [0.0]
    ts = TimeSeriesCollector(registry=reg, clock=lambda: clock_t[0])
    ts.add_hook(lambda t, _s: mon.tick(t))
    for i in range(8):
        tot.inc(100)
        if i >= 5:
            bad.inc(100)                     # 100% bad -> fire
        ts.sample()
        clock_t[0] += 1.0
    assert mon.slos[0].active                # ticked via the hook
    path = ts.to_jsonl(str(tmp_path / "ts.jsonl"))
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["schema_version"] == 1
    assert lines[0]["samples"] == 8 == len(lines) - 1
    assert lines[4]["counters"]["t"]["value"] == 400.0


def test_timeseries_ring_bounded():
    reg = Registry()
    reg.counter("c").inc()
    clock_t = [0.0]
    ts = TimeSeriesCollector(registry=reg, clock=lambda: clock_t[0],
                             capacity=4)
    for _ in range(10):
        ts.sample()
        clock_t[0] += 1.0
    assert len(ts) == 4
    assert ts.dropped == 6
