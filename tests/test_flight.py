"""Flight recorder + online exactness auditor.

Covers the black-box ring, arming/rate-limiting/dump-budget semantics,
every trigger source (SLO burn, breaker open, audit divergence, manual
``obs.dump_flight``), bundle self-containedness, the replay CLI, and
the auditor's clean-run / injected-wrong-answer behavior.
"""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

from conftest import random_geosocial, random_queries
from repro import obs
from repro.obs import flight as obs_flight
from repro.obs import trace_context
from repro.obs.audit import ExactnessAuditor
from repro.obs.flight import FLIGHT, FlightRecorder
from repro.obs.metrics import REGISTRY
from repro.obs.querylog import QUERY_LOG
from repro.resilience.breaker import BreakerPolicy, CircuitBreaker
from repro.resilience.faults import FaultPlan, FaultSpec, inject


@pytest.fixture(autouse=True)
def _obs_clean():
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(9)
    g = random_geosocial(rng, 300, 900)
    from repro.core import QueryEngine, build_2dreach

    idx = build_2dreach(g, variant="comp")
    eng = QueryEngine(idx)
    us, rects = random_queries(rng, g, 64)
    return g, idx, eng, us, rects


def _populate_window(eng, us, rects, n=32):
    """Serve traced traffic so a frozen bundle has spans + querylog."""
    obs.enable()
    ctxs = [trace_context.mint(u=int(u)) for u in us[:n]]
    with trace_context.scope(ctxs):
        ans = eng.query_batch(us[:n], rects[:n])
    QUERY_LOG.record_batch(
        "reach", ["member"] * n, rects[:n], [0] * n,
        np.full(n, 250e-6), np.zeros(n, dtype=np.int64),
        us=us[:n], trace_ids=[c.trace_id for c in ctxs],
        attempts=[1] * n)
    h = REGISTRY.histogram("frontend.queue_wait_us")
    for c in ctxs:
        h.record(250.0 + c.trace_id, exemplar=c.trace_id)
    return ctxs, ans


# ---------------------------------------------------------- black box


def test_note_ring_bounded_and_counted():
    fr = FlightRecorder(capacity_events=8)
    for i in range(20):
        fr.note("x", i=i)
    assert fr.events_total == 20
    evts = fr.events()
    assert len(evts) == 8                       # bounded ring
    assert [e["i"] for e in evts] == list(range(12, 20))
    assert all("t" in e and e["kind"] == "x" for e in evts)
    fr.reset()
    assert fr.events() == [] and fr.events_total == 0


def test_unarmed_trigger_is_counted_noop(tmp_path):
    assert FLIGHT.trigger("unit-test") is None
    assert REGISTRY.counter("flight.unarmed").value == 1
    assert REGISTRY.counter("flight.trigger.unit-test").value == 1
    assert not os.listdir(tmp_path)
    assert FLIGHT.snapshot()["dumps"] == 0


def test_manual_dump_bundle_contents(built, tmp_path):
    _, _, eng, us, rects = built
    ctxs, _ = _populate_window(eng, us, rects)
    bundle = obs.dump_flight(reason="manual", dirpath=str(tmp_path))
    assert bundle is not None and os.path.isdir(bundle)
    assert os.path.basename(bundle) == "000-manual"
    for fname in ("manifest.json", "trace.json", "spans.jsonl",
                  "querylog.jsonl", "events.jsonl", "metrics.json"):
        assert os.path.exists(os.path.join(bundle, fname)), fname
    with open(os.path.join(bundle, "manifest.json")) as f:
        man = json.load(f)
    assert man["schema_version"] == 1
    assert man["reason"] == "manual"
    assert man["counts"]["spans"] > 0
    assert man["counts"]["querylog"] == len(ctxs)
    assert man["worst_traces"], "no worst traces in a populated window"
    assert "frontend.queue_wait_us" in man["exemplars"]
    # spans.jsonl leads with its schema header
    with open(os.path.join(bundle, "spans.jsonl")) as f:
        head = json.loads(f.readline())
    assert head["fields"][0] == "name"


def test_rate_limit_and_force(built, tmp_path):
    _, _, eng, us, rects = built
    _populate_window(eng, us, rects, n=4)
    FLIGHT.arm(str(tmp_path), min_interval_s=3600.0)
    assert FLIGHT.trigger("first") is not None
    assert FLIGHT.trigger("second") is None          # inside the window
    assert REGISTRY.counter("flight.suppressed").value == 1
    assert FLIGHT.trigger("forced", force=True) is not None
    assert FLIGHT.snapshot()["dumps"] == 2


def test_max_dumps_budget(built, tmp_path):
    _, _, eng, us, rects = built
    _populate_window(eng, us, rects, n=4)
    FLIGHT.arm(str(tmp_path), min_interval_s=0.0, max_dumps=2)
    assert FLIGHT.trigger("a") is not None
    assert FLIGHT.trigger("b") is not None
    assert FLIGHT.trigger("c") is None               # budget spent
    assert FLIGHT.trigger("d", force=True) is None   # force can't exceed it
    assert len(os.listdir(tmp_path)) == 2


def test_slo_fired_freezes_bundle(built, tmp_path):
    """A burn-rate fire (fake clock) freezes a ``slo-<name>`` bundle."""
    _, _, eng, us, rects = built
    _populate_window(eng, us, rects, n=8)
    FLIGHT.arm(str(tmp_path), min_interval_s=0.0)
    t = [0.0]
    mon = obs.SLOMonitor(clock=lambda: t[0])
    mon.add("latency", "bad", "total", budget=0.01, windows=(1.0,))
    bad, tot = REGISTRY.counter("bad"), REGISTRY.counter("total")
    tot.inc(100)
    mon.tick()
    t[0] = 2.0
    bad.inc(50)
    tot.inc(50)
    events = mon.tick()
    assert [e["kind"] for e in events] == ["fired"]
    assert FLIGHT.snapshot()["dumps"] == 1
    (bundle,) = os.listdir(tmp_path)
    assert bundle == "000-slo-latency"
    with open(os.path.join(tmp_path, bundle, "manifest.json")) as f:
        man = json.load(f)
    assert man["detail"]["slo"] == "latency"
    assert any(e["kind"] == "slo.fired" for e in FLIGHT.events())


def test_breaker_open_freezes_bundle(built, tmp_path):
    _, _, eng, us, rects = built
    _populate_window(eng, us, rects, n=4)
    FLIGHT.arm(str(tmp_path), min_interval_s=0.0)
    br = CircuitBreaker("unit", BreakerPolicy(failure_threshold=3))
    br.record_failure()
    br.record_failure()
    assert FLIGHT.snapshot()["dumps"] == 0           # not yet open
    br.record_failure()                              # threshold: opens
    assert br.state_name == "open"
    assert FLIGHT.snapshot()["dumps"] == 1
    (bundle,) = os.listdir(tmp_path)
    assert bundle.startswith("000-breaker-open")
    assert any(e["kind"] == "breaker.opened" and e["name"] == "unit"
               for e in FLIGHT.events())


# ------------------------------------------------------------- auditor


def test_auditor_clean_run_zero_divergences(built):
    _, idx, eng, us, rects = built
    aud = ExactnessAuditor(idx, sample=1.0, seed=3)
    ans = eng.query_batch(us, rects)
    n = aud.observe(us, rects, ans, trace_ids=list(range(len(us))))
    assert n == len(us)                      # sample=1.0 takes all
    assert aud.drain() == len(us)
    rep = aud.report()
    assert rep["divergences"] == 0 and rep["kept"] == []
    assert rep["checked"] == len(us)


def test_auditor_oracle_subsample_clean(built):
    g, idx, eng, us, rects = built
    aud = ExactnessAuditor(idx, graph=g, sample=1.0, oracle_sample=0.5,
                           seed=3)
    ans = eng.query_batch(us[:32], rects[:32])
    aud.observe(us[:32], rects[:32], ans)
    aud.drain()
    rep = aud.report()
    assert rep["divergences"] == 0
    assert 0 < rep["oracle_checked"] <= 32


def test_auditor_flags_injected_wrong_answer(built, tmp_path):
    """The e2e proof: a corrupt fault flips one served answer; the
    auditor catches it within one drain and freezes an
    ``audit-divergence`` bundle naming the poisoned trace."""
    _, idx, eng, us, rects = built
    ctxs, _ = _populate_window(eng, us, rects, n=16)
    FLIGHT.arm(str(tmp_path), min_interval_s=0.0)
    aud = ExactnessAuditor(idx, sample=1.0, seed=0)
    plan = FaultPlan(FaultSpec("engine.answer", kind="corrupt",
                               max_fires=1), seed=1)
    with inject(plan):
        with trace_context.scope(ctxs):
            ans = eng.query_batch(us[:16], rects[:16])
    assert plan.fires_at("engine.answer") == 1
    aud.observe(us[:16], rects[:16], ans,
                trace_ids=[c.trace_id for c in ctxs])
    assert aud.drain() == 16                 # one drain suffices
    rep = aud.report()
    assert rep["divergences"] == 1
    (d,) = rep["kept"]
    assert d["served"] != d["expected"]
    assert d["trace_id"] == ctxs[0].trace_id     # mutator flips flat[0]
    # bundle frozen with the offender in the manifest detail
    assert FLIGHT.snapshot()["dumps"] == 1
    (bundle,) = os.listdir(tmp_path)
    assert bundle == "000-audit-divergence"
    with open(os.path.join(tmp_path, bundle, "manifest.json")) as f:
        man = json.load(f)
    assert man["detail"]["trace_id"] == ctxs[0].trace_id
    assert any(e["kind"] == "audit.divergence" for e in FLIGHT.events())
    # the injected fault sits next to the divergence in the black box
    assert any(e["kind"] == "fault.injected"
               and e["point"] == "engine.answer"
               for e in FLIGHT.events())


def test_auditor_sample_zero_is_noop(built):
    _, idx, _, us, rects = built
    aud = ExactnessAuditor(idx, sample=0.0)
    assert aud.observe(us, rects, np.zeros(len(us), bool)) == 0
    assert aud.drain() == 0 and aud.pending() == 0


def test_auditor_sampling_deterministic(built):
    _, idx, _, us, rects = built
    ans = np.zeros(len(us), dtype=bool)

    def taken(seed):
        a = ExactnessAuditor(idx, sample=0.3, seed=seed)
        a.observe(us, rects, ans, trace_ids=list(range(len(us))))
        with a._lock:
            return [it[3] for it in a._pending]

    assert taken(5) == taken(5)
    assert 0 < len(taken(5)) < len(us)


def test_auditor_background_drain_stop_final(built):
    _, idx, eng, us, rects = built
    aud = ExactnessAuditor(idx, sample=1.0, interval=30.0).start()
    ans = eng.query_batch(us[:8], rects[:8])
    aud.observe(us[:8], rects[:8], ans)
    aud.stop(final_drain=True)               # drains despite long interval
    assert aud.report()["checked"] == 8
    assert aud.pending() == 0


# ----------------------------------------------------- replay / CLI


def _frozen_bundle(built, tmp_path):
    _, _, eng, us, rects = built
    ctxs, _ = _populate_window(eng, us, rects)
    bundle = obs.dump_flight(dirpath=str(tmp_path))
    return bundle, ctxs


def test_resolve_trace_complete_story(built, tmp_path):
    bundle, ctxs = _frozen_bundle(built, tmp_path)
    data = obs_flight.load_bundle(bundle)
    story = obs_flight.resolve_trace(data, ctxs[0].trace_id)
    assert story["complete"]
    assert story["record"]["trace_id"] == ctxs[0].trace_id
    assert any(s["name"].startswith("engine.") for s in story["spans"])
    # an id never served resolves incomplete, not crashing
    missing = obs_flight.resolve_trace(data, 10**9)
    assert not missing["complete"] and missing["record"] is None


def test_replay_targets_worst_and_exemplars(built, tmp_path):
    bundle, _ = _frozen_bundle(built, tmp_path)
    rep = obs_flight.replay(bundle, top=8)
    assert rep["stories"] and rep["resolved"] == len(rep["stories"])
    assert rep["exemplar_ids"], "p99-bucket exemplars must be targets"
    assert set(rep["exemplar_ids"]) <= set(rep["targets"])


def test_cli_main_smoke(built, tmp_path, capsys):
    bundle, _ = _frozen_bundle(built, tmp_path)
    rc = obs_flight.main([bundle, "--top", "4"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "full causal chain" in out
    assert "trace " in out and "span " in out


def test_obs_snapshot_and_reset_include_flight(tmp_path):
    FLIGHT.note("x")
    FLIGHT.arm(str(tmp_path))
    snap = obs.snapshot()
    assert snap["flight"]["armed"] and snap["flight"]["events"] == 1
    obs.reset()
    fl = obs.snapshot()["flight"]
    assert not fl["armed"] and fl["events"] == 0
