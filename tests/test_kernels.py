"""Pallas kernels vs ref.py oracles — shape/dtype sweeps, interpret mode."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build_forest, query_host
from repro.core.reachability import pack_rows, unpack_rows
from repro.kernels.bitset_mm.ops import bitset_mm, bitset_mm_mxu
from repro.kernels.range_query.ops import range_query_forest
from repro.kernels.segment_bag.ops import embedding_bag


# ---------------------------------------------------------------- range_query
@pytest.mark.parametrize("dim", [2, 3])
@pytest.mark.parametrize("P,T,B", [(0, 1, 8), (7, 1, 3), (130, 4, 33),
                                   (513, 7, 64)])
def test_range_query_sweep(dim, P, T, B):
    rng = np.random.default_rng(P * 31 + T * 7 + B + dim)
    lo = rng.random((P, dim)).astype(np.float32) * 10
    hi = lo + (0 if dim == 2 else rng.random((P, dim)).astype(np.float32))
    boxes = np.concatenate([lo, hi], axis=1)
    tree_of = rng.integers(0, T, size=P)
    forest = build_forest(boxes, np.arange(P, dtype=np.int32), tree_of, T)
    tids = rng.integers(-1, T, size=B)
    c = rng.random((B, dim)).astype(np.float32) * 10
    r = rng.random((B, dim)).astype(np.float32) * 3
    rects = np.concatenate([c - r, c + r], axis=1)
    want = query_host(forest, tids, rects)
    got_k = range_query_forest(forest, tids, rects, interpret=True)
    got_r = range_query_forest(forest, tids, rects, use_ref=True)
    assert (got_k == want).all()
    assert (got_r == want).all()


# ---------------------------------------------------------------- bitset_mm
@pytest.mark.parametrize("d,dj,p", [(1, 1, 1), (8, 32, 128), (33, 40, 70),
                                    (65, 128, 257)])
def test_bitset_mm_sweep(d, dj, p):
    rng = np.random.default_rng(d * 131 + dj + p)
    A = rng.random((d, dj)) < 0.15
    R = rng.random((dj, p)) < 0.25
    want = pack_rows((A.astype(np.int64) @ R.astype(np.int64)) > 0)
    a_bits, r_bits = pack_rows(A), pack_rows(R)
    got = bitset_mm(a_bits, r_bits, interpret=True)
    ref = bitset_mm(a_bits, r_bits, use_ref=True)
    assert np.array_equal(got, want)
    assert np.array_equal(ref, want)
    # MXU path needs R padded to the word boundary of A's columns
    rpad = np.zeros((a_bits.shape[1] * 32, r_bits.shape[1]), np.uint32)
    rpad[:dj] = r_bits
    got_mxu = bitset_mm_mxu(a_bits, rpad)[:d]
    assert np.array_equal(got_mxu, want)


# ---------------------------------------------------------------- segment_bag
@pytest.mark.parametrize("V,D,B,maxlen", [(10, 8, 1, 3), (100, 32, 17, 7),
                                          (64, 128, 9, 0), (257, 16, 40, 12)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_segment_bag_sweep(V, D, B, maxlen, mode):
    rng = np.random.default_rng(V + D * 3 + B * 7 + maxlen)
    table = rng.standard_normal((V, D)).astype(np.float32)
    lens = rng.integers(0, maxlen + 1, size=B)
    offsets = np.zeros(B + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])
    idx = rng.integers(0, V, size=int(lens.sum()))
    want = np.zeros((B, D), np.float32)
    for b in range(B):
        rows = table[idx[offsets[b]:offsets[b + 1]]]
        if len(rows):
            want[b] = rows.sum(0) / (len(rows) if mode == "mean" else 1.0)
    got = np.asarray(embedding_bag(table, idx, offsets, mode=mode,
                                   interpret=True))
    ref = np.asarray(embedding_bag(table, idx, offsets, mode=mode,
                                   use_ref=True))
    np.testing.assert_allclose(got, want, atol=1e-5)
    np.testing.assert_allclose(ref, want, atol=1e-5)


def test_segment_bag_dtype_bf16():
    rng = np.random.default_rng(0)
    table = rng.standard_normal((32, 16)).astype(np.float32)
    offsets = np.array([0, 2, 5, 5, 8])
    idx = rng.integers(0, 32, size=8)
    got = embedding_bag(jnp.asarray(table, jnp.bfloat16), idx, offsets,
                        interpret=True)
    ref = embedding_bag(jnp.asarray(table, jnp.bfloat16), idx, offsets,
                        use_ref=True)
    # bf16 accumulation order differs between kernel and segment_sum ref
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        atol=5e-2, rtol=2e-2)
