"""gemma2-2b [dense]: 26L d=2304 8H (kv=4, head_dim=256) d_ff=9216
vocab=256000, alternating local/global, logit softcaps. [arXiv:2408.00118]"""
from ..models.lm import LMConfig
from .base import ArchSpec, lm_cells

NAME = "gemma2-2b"


def make_config(reduced: bool = False, dtype: str = "bfloat16") -> LMConfig:
    if reduced:
        return LMConfig(
            name=NAME + "-reduced", n_layers=4, d_model=64, n_heads=4,
            n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, window=16,
            layer_schedule="LG", attn_softcap=50.0, final_softcap=30.0,
            embed_scale=True, dtype="float32",
        )
    return LMConfig(
        name=NAME, n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        head_dim=256, d_ff=9216, vocab=256000, window=4096,
        layer_schedule="LG", attn_softcap=50.0, final_softcap=30.0,
        embed_scale=True, dtype=dtype,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="lm", make_config=make_config,
        cells=lm_cells(NAME, make_config),
        notes="global layers hold full 500k KV at bs=1 (26/2 layers * "
              "500k * 4kv * 256dh * 2 * 2B = 27 GB, 53 MB/chip at 512); "
              "8 heads < model=16 so attention projections replicate, "
              "FFN/vocab still shard",
    )
