"""2DReach — the paper's contribution (Section 4).

Three variants, exactly as evaluated in the paper's Section 5:

* ``base``     2DREACH          — SCC decomposition of the *full* graph,
  one 2-D R-tree per component over its reachable spatial set (no
  sharing), a per-vertex pointer to the component's tree.
* ``comp``     2DREACH-COMP     — spatial sinks excluded from the
  decomposition (Alg. 1 line 4 includes spatial out-neighbours instead);
  components with empty reachable sets are dropped; a parent whose
  reachable set equals one of its children's shares the child's R-tree.
  Queries special-case spatial query vertices (Alg. 2).
* ``pointer``  2DREACH-POINTER  — like ``comp`` but pointers are stored
  only per component-with-a-tree, located through a bit vector + rank
  (popcount) structure rather than a per-vertex array.  Smallest index,
  ~30% slower lookups (the paper's Figure 3 trade-off).

Beyond-paper option ``dedup="global"`` shares trees between *any* two
components with identical reachable sets (not only parent/child); the
paper's Table 2 "distinct R-trees" statistic corresponds to
``dedup="paper"``.

Build is host-side (NumPy — the index build is offline, exactly as in the
paper); the query path has a host engine and a jit/Pallas engine (see
``core.rtree`` and ``kernels.range_query``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..obs import span
from .condensation import Condensation, condense
from .graph import GeosocialGraph
from .reachability import (
    ClosureResult,
    _ragged_arange,
    closure_bitset_mm,
    closure_np,
    nonzero_cols,
    popcount32 as _popcount32,
    unpack_rows,
)
from .rtree import (
    DEFAULT_FANOUT,
    RTreeForest,
    build_forest,
    build_forest_device,
    query_host,
)
from .scc import scc_np

BUILD_BACKENDS = ("host", "device")


# --------------------------------------------------------------------------
# Bit-vector + rank (the Pointer variant's lookup structure)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BitRank:
    """Succinct membership + rank over [0, d): ``rank(i)`` = number of set
    bits strictly below i.  One uint32 word per 32 ids plus one int32
    exclusive-prefix popcount per word."""

    bits: np.ndarray   # (ceil(d/32),) uint32
    rank: np.ndarray   # (ceil(d/32),) int32 — popcount of all lower words

    @classmethod
    def from_mask(cls, mask: np.ndarray) -> "BitRank":
        d = len(mask)
        W = (d + 31) // 32
        pad = np.zeros(W * 32, dtype=bool)
        pad[:d] = mask
        by = np.packbits(pad.reshape(W, 4, 8)[..., ::-1], axis=-1)
        bits = np.ascontiguousarray(by.reshape(W, 4)).view(np.uint32).ravel()
        pc = _popcount32(bits)
        rank = np.zeros(W, dtype=np.int64)
        np.cumsum(pc[:-1], out=rank[1:])
        return cls(bits=bits, rank=rank.astype(np.int32))

    def test_rank(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(member?, rank) for each id — vectorised popcount lookup, the
        faithful reproduction of the Pointer variant's extra query work."""
        ids = np.asarray(ids, dtype=np.int64)
        w, b = ids // 32, (ids % 32).astype(np.uint32)
        word = self.bits[w]
        member = (word >> b) & np.uint32(1) > 0
        below = word & ((np.uint32(1) << b) - np.uint32(1))
        return member, self.rank[w].astype(np.int64) + _popcount32(below)

    def nbytes(self) -> int:
        return int(self.bits.nbytes + self.rank.nbytes)


# --------------------------------------------------------------------------
# Index container
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TwoDReachIndex:
    variant: str                    # base | comp | pointer
    n: int
    coords: np.ndarray              # (n, 2)
    excluded: np.ndarray            # (n,) bool — spatial sinks (comp/pointer)
    vertex_comp: np.ndarray         # (n,) int32; -1 for excluded vertices
    cond: Condensation
    forest: RTreeForest
    comp_tree: np.ndarray           # (d,) int32 tree id or -1
    vertex_tree: Optional[np.ndarray]  # (n,) int64 per-vertex pointer
    bitrank: Optional[BitRank]      # pointer variant lookup
    tree_ptrs: Optional[np.ndarray]  # compacted (n_with_tree,) int32
    stats: Dict[str, float]
    backend: str = "host"           # build backend that produced this index

    # -- sizes (Table 4 decomposition) ------------------------------------
    def nbytes_rtree(self) -> int:
        return self.forest.nbytes_total()

    def nbytes_pointers(self) -> int:
        if self.variant == "pointer":
            return int(self.bitrank.nbytes() + self.tree_ptrs.nbytes)
        return int(self.vertex_tree.nbytes)

    def nbytes_total(self) -> int:
        return self.nbytes_rtree() + self.nbytes_pointers()

    # -- queries -----------------------------------------------------------
    def lookup_tree(self, u: np.ndarray) -> np.ndarray:
        """(B,) vertex ids -> (B,) tree ids (-1: no tree / excluded)."""
        u = np.asarray(u, dtype=np.int64)
        if self.variant == "pointer":
            c = self.vertex_comp[u]
            ok = c >= 0
            out = np.full(len(u), -1, dtype=np.int64)
            if ok.any():
                member, rank = self.bitrank.test_rank(np.maximum(c[ok], 0))
                t = np.where(member, self.tree_ptrs[np.minimum(
                    rank, len(self.tree_ptrs) - 1)], -1)
                out[ok] = t
            return out
        return self.vertex_tree[u]

    def query_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        """Host batched RangeReach (Alg. 2). us (B,), rects (B, 4)."""
        us = np.asarray(us, dtype=np.int64)
        rects = np.asarray(rects, dtype=np.float32).reshape(len(us), 4)
        ans = np.zeros(len(us), dtype=bool)
        exc = self.excluded[us]
        if exc.any():
            pts = self.coords[us[exc]]
            r = rects[exc]
            ans[exc] = (
                (pts[:, 0] >= r[:, 0]) & (pts[:, 0] <= r[:, 2])
                & (pts[:, 1] >= r[:, 1]) & (pts[:, 1] <= r[:, 3])
            )
        rest = ~exc
        if rest.any():
            tid = self.lookup_tree(us[rest])
            ans[rest] = query_host(self.forest, tid, rects[rest])
        return ans

    def query(self, u: int, rect) -> bool:
        return bool(self.query_batch(np.array([u]), np.array([rect]))[0])


# --------------------------------------------------------------------------
# Build
# --------------------------------------------------------------------------

def build_2dreach(
    graph: GeosocialGraph,
    variant: str = "comp",
    fanout: int = DEFAULT_FANOUT,
    dedup: str = "paper",
    backend: str = "host",
    device_kernel: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> TwoDReachIndex:
    """Construct the 2DReach index (paper Alg. 1 + §4.1 compression).

    backend:       ``"host"`` builds everything in NumPy (the paper's
                   offline setting).  ``"device"`` runs the two
                   expensive stages on the accelerator — the
                   reachable-set closure as a level-scheduled packed
                   ``bitset_mm`` fixpoint (``closure_bitset_mm``) and
                   the forest bulk-load as a device sort + segmented-MBR
                   reduction (``build_forest_device``) — and attaches
                   the device-resident serving arrays to the forest so
                   ``QueryEngine`` / ``ShardedEngine`` adopt them
                   without re-uploading.  Both backends produce
                   identical indexes (same arrays, bit for bit).
    device_kernel: ``"pallas"`` | ``"xla"`` | ``None`` (auto: Pallas on
                   TPU, XLA elsewhere); ignored for ``backend="host"``.
    interpret:     Pallas interpret mode for ``device_kernel="pallas"``.
    """
    assert variant in ("base", "comp", "pointer")
    assert dedup in ("paper", "global", "none")
    if backend not in BUILD_BACKENDS:
        raise ValueError(
            f"unknown build backend {backend!r}; expected one of "
            f"{BUILD_BACKENDS} (backend='device' runs the closure and "
            f"forest bulk-load on the accelerator)")
    t_start = time.perf_counter()
    n = graph.n_nodes
    stats: Dict[str, float] = {}

    # ---- decomposition ---------------------------------------------------
    t0 = time.perf_counter()
    with span("build.scc", cat="build", n=n, variant=variant):
        if variant == "base":
            excluded = np.zeros(n, dtype=bool)
            dec_edges = graph.edges
            include = None
        else:
            excluded = graph.spatial_sink_mask()
            e = graph.edges
            keep = ~(excluded[e[:, 0]] | excluded[e[:, 1]])
            dec_edges = e[keep]
            include = ~excluded
        labels = scc_np(n, dec_edges)
        cond = condense(n, dec_edges, labels, include_mask=include)
    stats["t_scc"] = time.perf_counter() - t0

    # ---- reachable-set closure (Alg. 1) ----------------------------------
    t0 = time.perf_counter()
    spatial_ids = graph.spatial_ids
    extra = None
    if variant != "base":
        # Alg. 1 line 4 (modified): excluded spatial out-neighbours join
        # the component's own set
        e = graph.edges
        m = excluded[e[:, 1]] & ~excluded[e[:, 0]]
        if m.any():
            src_c = cond.comp[e[m, 0]]
            ok = src_c >= 0
            extra = (e[m, 1][ok], src_c[ok])
    with span("build.closure", cat="build", backend=backend):
        if backend == "device":
            clo = closure_bitset_mm(
                cond, n, spatial_ids, extra_vertex_comp=extra,
                kernel=device_kernel, interpret=interpret)
        else:
            clo = closure_np(cond, n, spatial_ids, extra_vertex_comp=extra)
    stats["t_closure"] = time.perf_counter() - t0

    # ---- tree assignment (+ sharing) --------------------------------------
    t0 = time.perf_counter()
    d = cond.n_comps
    with span("build.assign", cat="build", dedup=dedup):
        comp_tree, tree_indptr, cols_flat, n_shared = _assign_trees(
            cond, clo, variant=variant, dedup=dedup
        )
    n_tree = len(tree_indptr) - 1
    stats["t_assign"] = time.perf_counter() - t0

    # ---- forest bulk load --------------------------------------------------
    t0 = time.perf_counter()
    lens = np.diff(tree_indptr)
    vid = clo.spatial_vertex[cols_flat.astype(np.int64)]
    pts = graph.coords[vid]
    boxes = np.concatenate([pts, pts], axis=1)
    tree_of_entry = np.repeat(np.arange(n_tree), lens)
    ext = graph.spatial_extent()
    extent = np.array([ext[0], ext[1], ext[2], ext[3]], dtype=np.float32)
    load = build_forest_device if backend == "device" else build_forest
    load_kw = (
        {"kernel": device_kernel, "interpret": interpret}
        if backend == "device" else {}
    )
    with span("build.forest", cat="build", backend=backend,
              trees=int(n_tree), entries=int(len(vid))):
        forest = load(
            boxes, vid.astype(np.int32), tree_of_entry, n_tree,
            fanout=fanout, extent=extent, **load_kw,
        )
    stats["t_forest"] = time.perf_counter() - t0

    # ---- pointers ----------------------------------------------------------
    t0 = time.perf_counter()
    vertex_tree: Optional[np.ndarray] = None
    bitrank: Optional[BitRank] = None
    tree_ptrs: Optional[np.ndarray] = None
    with span("build.pointers", cat="build", variant=variant):
        if variant in ("base", "comp"):
            vertex_tree = np.full(n, -1, dtype=np.int64)
            inc = cond.comp >= 0
            vertex_tree[inc] = comp_tree[cond.comp[inc]]
        else:
            has = comp_tree >= 0
            bitrank = BitRank.from_mask(has)
            tree_ptrs = comp_tree[has].astype(np.int32)
            if len(tree_ptrs) == 0:
                tree_ptrs = np.zeros(1, dtype=np.int32)  # rank-lookup safety
    stats["t_pointers"] = time.perf_counter() - t0
    stats["t_total"] = time.perf_counter() - t_start

    # Table 2 statistics
    nonspatial_comp = np.ones(d, dtype=bool)
    sc = cond.comp[spatial_ids]
    nonspatial_comp[sc[sc >= 0]] = False
    stats["n_comps"] = float(d)
    stats["user_comps"] = float(nonspatial_comp.sum())
    stats["distinct_rtrees"] = float(n_tree)
    stats["shared_trees"] = float(n_shared)

    return TwoDReachIndex(
        variant=variant,
        n=n,
        coords=graph.coords,
        excluded=excluded,
        vertex_comp=cond.comp,
        cond=cond,
        forest=forest,
        comp_tree=comp_tree,
        vertex_tree=vertex_tree,
        bitrank=bitrank,
        tree_ptrs=tree_ptrs,
        stats=stats,
        backend=backend,
    )


def _comp_cols_csr(clo: ClosureResult) -> Tuple[np.ndarray, np.ndarray]:
    """CSR (indptr, cols) of reachable spatial columns for *every*
    component — the vectorised equivalent of calling ``comp_set_cols``
    per component (interior rows unpacked chunk-wise, one ``nonzero``
    per chunk instead of one per component)."""
    d = len(clo.interior_row)
    counts = np.diff(clo.own_indptr).astype(np.int64)
    n_int = clo.bits.shape[0]
    irow = icol = None
    int_cnt = None
    row_comp = None
    if n_int:
        ii = np.nonzero(clo.interior_row >= 0)[0]
        row_comp = np.empty(n_int, dtype=np.int64)
        row_comp[clo.interior_row[ii]] = ii
        chunk = max(1, (1 << 25) // max(1, clo.p))
        rows_l, cols_l = [], []
        for s in range(0, n_int, chunk):
            r, c = np.nonzero(unpack_rows(clo.bits[s:s + chunk], clo.p))
            rows_l.append(r.astype(np.int64) + s)
            cols_l.append(c.astype(np.int32))
        irow = np.concatenate(rows_l)
        icol = np.concatenate(cols_l)
        int_cnt = np.bincount(irow, minlength=n_int).astype(np.int64)
        counts[row_comp] = int_cnt
    indptr = np.zeros(d + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    cols = np.empty(int(indptr[-1]), dtype=np.int32)
    if n_int and len(irow):
        grp = np.zeros(n_int + 1, dtype=np.int64)
        np.cumsum(int_cnt, out=grp[1:])
        within = np.arange(len(irow), dtype=np.int64) - grp[irow]
        cols[indptr[row_comp[irow]] + within] = icol
    leaf = clo.interior_row < 0
    own_cnt = np.diff(clo.own_indptr)
    lcomp = np.nonzero(leaf & (own_cnt > 0))[0]
    if lcomp.size:
        cnt = own_cnt[lcomp].astype(np.int64)
        within = _ragged_arange(cnt)
        dest = np.repeat(indptr[lcomp], cnt) + within
        src = np.repeat(clo.own_indptr[lcomp], cnt) + within
        cols[dest] = clo.own_cols[src]
    return indptr, cols


def _hash_sets(indptr: np.ndarray, cols: np.ndarray) -> np.ndarray:
    """(d,) order-independent 64-bit hash of each CSR column set —
    mixed per element, combined by modular sum + xor + cardinality.
    Equal sets always hash equal; callers byte-compare on collision."""

    def mix(x: np.ndarray) -> np.ndarray:
        x = x + np.uint64(0x9E3779B97F4A7C15)
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xFF51AFD7ED558CCD)
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xC4CEB9FE1A85EC53)
        x ^= x >> np.uint64(33)
        return x

    h = mix(cols.astype(np.uint64))
    csum = np.zeros(len(h) + 1, dtype=np.uint64)
    np.cumsum(h, out=csum[1:])
    cxor = np.zeros(len(h) + 1, dtype=np.uint64)
    np.bitwise_xor.accumulate(h, out=cxor[1:])
    s = csum[indptr[1:]] - csum[indptr[:-1]]
    x = cxor[indptr[1:]] ^ cxor[indptr[:-1]]
    n = (indptr[1:] - indptr[:-1]).astype(np.uint64)
    return mix(s * np.uint64(3) ^ x ^ mix(n))


def _assign_trees(
    cond: Condensation,
    clo: ClosureResult,
    variant: str,
    dedup: str,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Map each component to a tree id; returns ``(comp_tree,
    tree_indptr, tree_cols, n_shared)`` with the per-tree column lists
    in CSR form.

    Fully vectorised: sharing candidates come from hash + cardinality
    equality (``_hash_sets``), are verified by an exact ragged
    element-wise compare (``np.logical_and.reduceat`` over the flattened
    candidate pairs), and share *chains* resolve by pointer doubling —
    no per-component Python loop anywhere.  Produces bit-identical
    output to the reference per-component walk
    (``_assign_trees_reference``, kept as the property-test oracle),
    including tree id numbering and the shared-tree count.
    """
    d = cond.n_comps
    comp_tree = np.full(d, -1, dtype=np.int32)
    nonempty = clo.comp_nonempty()
    share = (variant != "base") and (dedup != "none")

    indptr, cols_all = _comp_cols_csr(clo)
    sizes = np.diff(indptr)

    if not share:
        # one tree per nonempty comp, in comp id order
        creators = np.nonzero(nonempty)[0]
        root = np.arange(d, dtype=np.int64)
    elif dedup == "paper":
        hashes = _hash_sets(indptr, cols_all)
        child = _paper_share_children(
            cond, nonempty, indptr, cols_all, sizes, hashes)
        root = _resolve_share_roots(child)
        # tree ids are assigned in host processing order: descending
        # level, stable — children strictly before parents
        order = np.argsort(-cond.level, kind="stable")
        creators_mask = nonempty & (child < 0)
        creators = order[creators_mask[order]]
    else:  # dedup == "global": one tree per distinct set anywhere
        hashes = _hash_sets(indptr, cols_all)
        root = _global_share_reps(nonempty, indptr, cols_all, sizes, hashes)
        creators = np.nonzero(nonempty & (root == np.arange(d)))[0]

    tid = np.full(d, -1, dtype=np.int32)
    tid[creators] = np.arange(len(creators), dtype=np.int32)
    ne = np.nonzero(nonempty)[0]
    comp_tree[ne] = tid[root[ne]]
    n_shared = int(nonempty.sum()) - len(creators)

    cnt = sizes[creators].astype(np.int64)
    tree_indptr = np.zeros(len(creators) + 1, dtype=np.int64)
    np.cumsum(cnt, out=tree_indptr[1:])
    slot = np.repeat(indptr[creators], cnt) + _ragged_arange(cnt)
    tree_cols = cols_all[slot]
    return comp_tree, tree_indptr, tree_cols, n_shared


def _verify_equal_sets(
    a: np.ndarray, b: np.ndarray,
    indptr: np.ndarray, cols_all: np.ndarray, sizes: np.ndarray,
) -> np.ndarray:
    """(k,) bool — exact element-wise equality of the column sets of
    comp pairs (a[i], b[i]); the pairs must have equal sizes > 0."""
    cnt = sizes[a].astype(np.int64)
    ar = _ragged_arange(cnt)
    ia = np.repeat(indptr[a], cnt) + ar
    ib = np.repeat(indptr[b], cnt) + ar
    eq = cols_all[ia] == cols_all[ib]
    starts = np.zeros(len(a), dtype=np.int64)
    np.cumsum(cnt[:-1], out=starts[1:])
    return np.logical_and.reduceat(eq, starts)


def _paper_share_children(
    cond: Condensation, nonempty: np.ndarray,
    indptr: np.ndarray, cols_all: np.ndarray, sizes: np.ndarray,
    hashes: np.ndarray,
) -> np.ndarray:
    """(d,) chosen share child per comp (-1: own tree) — for each parent
    the first child (in DAG adjacency order) with an identical set."""
    d = cond.n_comps
    child = np.full(d, -1, dtype=np.int64)
    e = cond.dag_edges
    if e.size == 0:
        return child
    src, dst = e[:, 0].astype(np.int64), e[:, 1].astype(np.int64)
    cand = (
        nonempty[src] & nonempty[dst]
        & (hashes[src] == hashes[dst]) & (sizes[src] == sizes[dst])
    )
    src, dst = src[cand], dst[cand]
    if not len(src):
        return child
    ok = _verify_equal_sets(src, dst, indptr, cols_all, sizes)
    src, dst = src[ok], dst[ok]
    if not len(src):
        return child
    # dag_edges are (src, dst)-sorted, so the first row of each src run
    # is the first matching child the reference walk would pick
    first = np.r_[True, src[1:] != src[:-1]]
    child[src[first]] = dst[first]
    return child


def _resolve_share_roots(child: np.ndarray) -> np.ndarray:
    """Resolve share chains (parent -> equal child -> ...) to their
    terminal tree-creating comp by pointer doubling.  Chains follow DAG
    edges, so they are acyclic and converge in O(log depth) rounds."""
    f = np.where(child >= 0, child, np.arange(len(child), dtype=np.int64))
    while True:
        f2 = f[f]
        if np.array_equal(f2, f):
            return f
        f = f2


def _global_share_reps(
    nonempty: np.ndarray, indptr: np.ndarray, cols_all: np.ndarray,
    sizes: np.ndarray, hashes: np.ndarray,
) -> np.ndarray:
    """(d,) representative comp per comp (itself: creates a tree).

    Groups nonempty comps by (hash, cardinality); every group member
    byte-compares against the group's lowest comp id.  Hash collisions
    (unequal sets in one group) regroup among themselves and repeat —
    each round retires at least its representatives, so the loop
    terminates; in practice one round resolves everything."""
    d = len(sizes)
    rep = np.arange(d, dtype=np.int64)
    pending = np.nonzero(nonempty)[0]
    while len(pending) > 1:
        order = np.lexsort((pending, sizes[pending], hashes[pending]))
        ps = pending[order]
        new_grp = np.r_[
            True,
            (hashes[ps][1:] != hashes[ps][:-1])
            | (sizes[ps][1:] != sizes[ps][:-1]),
        ]
        reps = ps[new_grp]                       # lowest id per group
        my = reps[np.cumsum(new_grp) - 1]
        member = ps != my
        mm, rr = ps[member], my[member]
        if not len(mm):
            break
        ok = _verify_equal_sets(mm, rr, indptr, cols_all, sizes)
        rep[mm[ok]] = rr[ok]
        pending = mm[~ok]
    return rep


def _assign_trees_reference(
    cond: Condensation,
    clo: ClosureResult,
    variant: str,
    dedup: str,
) -> Tuple[np.ndarray, List[np.ndarray], int]:
    """Reference per-component walk (the original implementation) —
    the oracle ``_assign_trees`` is property-tested against; returns
    per-tree column *lists* rather than CSR."""
    d = cond.n_comps
    comp_tree = np.full(d, -1, dtype=np.int32)
    nonempty = clo.comp_nonempty()
    share = (variant != "base") and (dedup != "none")

    indptr, cols_all = _comp_cols_csr(clo)
    sizes = np.diff(indptr)

    def comp_cols(c: int) -> np.ndarray:
        return cols_all[indptr[c]:indptr[c + 1]]

    tree_cols: List[np.ndarray] = []
    n_shared = 0

    if not share:
        for c in range(d):
            if nonempty[c]:
                comp_tree[c] = len(tree_cols)
                tree_cols.append(comp_cols(c))
        return comp_tree, tree_cols, 0

    hashes = _hash_sets(indptr, cols_all)

    if dedup == "paper":
        # process children before parents (descending level)
        order = np.argsort(-cond.level, kind="stable")
        ch_indptr, ch = _csr(d, cond.dag_edges)
        for c in order:
            if not nonempty[c]:
                continue
            shared_t = -1
            for cc in ch[ch_indptr[c]:ch_indptr[c + 1]]:
                cc = int(cc)
                if (
                    comp_tree[cc] >= 0
                    and hashes[cc] == hashes[c]
                    and sizes[cc] == sizes[c]
                    and np.array_equal(comp_cols(cc), comp_cols(c))
                ):
                    shared_t = comp_tree[cc]
                    break
            if shared_t >= 0:
                comp_tree[c] = shared_t
                n_shared += 1
            else:
                comp_tree[c] = len(tree_cols)
                tree_cols.append(comp_cols(c))
        return comp_tree, tree_cols, n_shared

    # dedup == "global": one tree per distinct reachable set anywhere
    buckets: Dict[int, List[int]] = {}
    for c in range(d):
        if not nonempty[c]:
            continue
        cc_cols = comp_cols(c)
        bucket = buckets.setdefault(int(hashes[c]), [])
        t = -1
        for tc in bucket:
            if np.array_equal(tree_cols[tc], cc_cols):
                t = tc
                break
        if t < 0:
            t = len(tree_cols)
            tree_cols.append(cc_cols)
            bucket.append(t)
        else:
            n_shared += 1
        comp_tree[c] = t
    return comp_tree, tree_cols, n_shared


def _csr(d: int, edges: np.ndarray):
    if edges.size == 0:
        return np.zeros(d + 1, dtype=np.int64), np.zeros(0, dtype=np.int32)
    order = np.argsort(edges[:, 0], kind="stable")
    indptr = np.zeros(d + 1, dtype=np.int64)
    np.cumsum(np.bincount(edges[order, 0], minlength=d), out=indptr[1:])
    return indptr, edges[order, 1].astype(np.int32)
