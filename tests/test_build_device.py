"""backend="device" build pipeline: exactness vs the host build, the
level-scheduled device closure, the vectorised tree assignment, and the
zero-copy build→serve handoff (adoption counters)."""

import numpy as np
import pytest
from conftest import given, settings, st

from repro.core import (
    QueryEngine,
    batch_query,
    build_2dreach,
    build_dynamic_index,
    build_index,
    condense,
    scc_np,
)
from repro.core import engine as engine_mod
from repro.core.graph import make_graph
from repro.core.reachability import closure_bitset_mm, closure_np
from repro.core.two_d_reach import _assign_trees, _assign_trees_reference
from repro.data import get_dataset, workload
from repro.dynamic import CompactionPolicy
from repro.kernels.range_query import ops as rq_ops

VARIANTS = ("base", "comp", "pointer")


def _random_graph(rng, n, m, p_spatial):
    edges = rng.integers(0, n, (m, 2)).astype(np.int64)
    edges = edges[edges[:, 0] != edges[:, 1]]
    coords = (rng.random((n, 2)) * 100).astype(np.float32)
    sm = rng.random(n) < p_spatial
    return make_graph(n, edges, coords, sm)


def _assert_index_equal(a, b):
    assert a.variant == b.variant
    assert np.array_equal(a.excluded, b.excluded)
    assert np.array_equal(a.vertex_comp, b.vertex_comp)
    assert np.array_equal(a.comp_tree, b.comp_tree)
    if a.vertex_tree is not None:
        assert np.array_equal(a.vertex_tree, b.vertex_tree)
    else:
        assert np.array_equal(a.bitrank.bits, b.bitrank.bits)
        assert np.array_equal(a.bitrank.rank, b.bitrank.rank)
        assert np.array_equal(a.tree_ptrs, b.tree_ptrs)
    fa, fb = a.forest, b.forest
    assert np.array_equal(fa.entries, fb.entries)
    assert np.array_equal(fa.entry_ids, fb.entry_ids)
    assert np.array_equal(fa.entry_off, fb.entry_off)
    assert fa.depth == fb.depth
    for l in range(fa.depth):
        assert np.array_equal(fa.level_mbr[l], fb.level_mbr[l])
        assert np.array_equal(fa.tree_off[l], fb.tree_off[l])


# --------------------------------------------------------------------------
# build equivalence (the acceptance property): device == host, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
def test_device_build_identical_to_host(lbsn_graph, variant):
    g = lbsn_graph
    host = build_2dreach(g, variant=variant)
    dev = build_2dreach(g, variant=variant, backend="device")
    _assert_index_equal(host, dev)
    assert host.backend == "host" and dev.backend == "device"
    assert dev.forest.device is not None and host.forest.device is None
    for k in ("t_scc", "t_closure", "t_assign", "t_forest", "t_pointers",
              "t_total"):
        assert k in host.stats and k in dev.stats
    us, rects = workload(g, 256, extent_ratio=0.08, seed=4)
    assert np.array_equal(host.query_batch(us, rects),
                          dev.query_batch(us, rects))


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_device_build_identical_on_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 250))
    g = _random_graph(rng, n, int(rng.integers(n, 5 * n)),
                      float(rng.uniform(0.05, 0.9)))
    variant = VARIANTS[seed % 3]
    host = build_2dreach(g, variant=variant)
    dev = build_2dreach(g, variant=variant, backend="device")
    _assert_index_equal(host, dev)


def test_device_build_pallas_kernels_interpret(lbsn_graph):
    host = build_2dreach(lbsn_graph, variant="comp")
    dev = build_2dreach(lbsn_graph, variant="comp", backend="device",
                        device_kernel="pallas", interpret=True)
    _assert_index_equal(host, dev)


# --------------------------------------------------------------------------
# device closure: level-scheduled fixpoint == host sweep
# --------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_closure_bitset_mm_matches_closure_np(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 200))
    g = _random_graph(rng, n, int(rng.integers(n, 6 * n)),
                      float(rng.uniform(0.1, 0.9)))
    labels = scc_np(n, g.edges)
    cond = condense(n, g.edges, labels)
    ref = closure_np(cond, n, g.spatial_ids)
    for kw in ({"kernel": "xla"},
               {"kernel": "pallas", "interpret": True}):
        got = closure_bitset_mm(cond, n, g.spatial_ids, **kw)
        assert np.array_equal(ref.bits, got.bits)
        assert np.array_equal(ref.interior_row, got.interior_row)
        assert np.array_equal(ref.own_indptr, got.own_indptr)
        assert np.array_equal(ref.own_cols, got.own_cols)


def test_closure_np_segment_or_equals_legacy_scatter(lbsn_graph):
    g = lbsn_graph
    labels = scc_np(g.n_nodes, g.edges)
    cond = condense(g.n_nodes, g.edges, labels)
    a = closure_np(cond, g.n_nodes, g.spatial_ids, segment_or=True)
    b = closure_np(cond, g.n_nodes, g.spatial_ids, segment_or=False)
    assert np.array_equal(a.bits, b.bits)


# --------------------------------------------------------------------------
# vectorised tree assignment == reference per-component walk
# --------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_assign_trees_matches_reference(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 300))
    g = _random_graph(rng, n, int(rng.integers(n, 6 * n)),
                      float(rng.uniform(0.05, 0.9)))
    variant = VARIANTS[seed % 3]
    dedup = ("paper", "global", "none")[(seed // 3) % 3]
    if variant == "base":
        excluded = np.zeros(n, dtype=bool)
        dec_edges, include = g.edges, None
    else:
        excluded = g.spatial_sink_mask()
        e = g.edges
        keep = ~(excluded[e[:, 0]] | excluded[e[:, 1]])
        dec_edges, include = e[keep], ~excluded
    labels = scc_np(n, dec_edges)
    cond = condense(n, dec_edges, labels, include_mask=include)
    extra = None
    if variant != "base":
        e = g.edges
        m = excluded[e[:, 1]] & ~excluded[e[:, 0]]
        if m.any():
            src_c = cond.comp[e[m, 0]]
            ok = src_c >= 0
            extra = (e[m, 1][ok], src_c[ok])
    clo = closure_np(cond, n, g.spatial_ids, extra_vertex_comp=extra)
    ct, ti, tc, ns = _assign_trees(cond, clo, variant, dedup)
    ct2, tl2, ns2 = _assign_trees_reference(cond, clo, variant, dedup)
    assert ns == ns2
    assert np.array_equal(ct, ct2)
    assert len(ti) - 1 == len(tl2)
    flat = np.concatenate(tl2) if tl2 else np.zeros(0, np.int32)
    assert np.array_equal(tc, flat.astype(tc.dtype))


# --------------------------------------------------------------------------
# zero-copy handoff: engines adopt device-built arrays, no re-upload
# --------------------------------------------------------------------------

def test_query_engine_adopts_device_build(lbsn_graph):
    g = lbsn_graph
    dev = build_2dreach(g, variant="comp", backend="device")
    soa0 = rq_ops.SOA_BUILDS
    c0 = dict(engine_mod.UPLOAD_COUNTERS)
    eng = QueryEngine(dev)
    assert eng.stats["adopted"] == 1
    assert rq_ops.SOA_BUILDS == soa0              # no host transposition
    assert engine_mod.UPLOAD_COUNTERS["host_uploads"] == c0["host_uploads"]
    assert engine_mod.UPLOAD_COUNTERS["device_adoptions"] == \
        c0["device_adoptions"] + 1
    us, rects = workload(g, 200, extent_ratio=0.08, seed=6)
    assert np.array_equal(eng.query_batch(us, rects),
                          dev.query_batch(us, rects))


def test_sharded_engine_adopts_device_build(lbsn_graph):
    from repro.cluster import ShardedEngine

    g = lbsn_graph
    host = build_2dreach(g, variant="comp")
    dev = build_2dreach(g, variant="comp", backend="device")
    soa0 = rq_ops.SOA_BUILDS
    c0 = dict(engine_mod.UPLOAD_COUNTERS)
    eng = ShardedEngine(dev, n_shards=4)
    assert eng.stats["adopted"] == 1
    assert rq_ops.SOA_BUILDS == soa0
    assert engine_mod.UPLOAD_COUNTERS["host_uploads"] == c0["host_uploads"]
    assert engine_mod.UPLOAD_COUNTERS["device_adoptions"] == \
        c0["device_adoptions"] + 1
    us, rects = workload(g, 200, extent_ratio=0.08, seed=7)
    assert np.array_equal(eng.query_batch(us, rects),
                          host.query_batch(us, rects))


def test_shard_arenas_device_equals_host(lbsn_graph):
    from repro.cluster.partition import partition_forest, shard_arenas

    host = build_2dreach(lbsn_graph, variant="comp")
    dev = build_2dreach(lbsn_graph, variant="comp", backend="device")
    for s in (1, 3):
        ph, pd = partition_forest(host.forest, s), \
            partition_forest(dev.forest, s)
        assert np.array_equal(ph.tree_shard, pd.tree_shard)
        ah, ad = shard_arenas(host.forest, ph), shard_arenas(dev.forest, pd)
        for x, y, nm in zip(ah[:3], ad[:3], ("entries", "fine", "coarse")):
            assert np.array_equal(np.asarray(x), np.asarray(y)), nm
        assert ah[3] == ad[3]


def test_dynamic_device_compaction_zero_reupload(lbsn_graph):
    g = lbsn_graph
    dyn = build_dynamic_index(
        g, "2dreach-comp",
        policy=CompactionPolicy(max_overlay_edges=None, max_staged=None,
                                max_updates=None),
        engine="device",
    )
    assert dyn.base_index.backend == "device"
    assert dyn.base_engine.stats["adopted"] == 1
    soa0 = rq_ops.SOA_BUILDS
    c0 = dict(engine_mod.UPLOAD_COUNTERS)
    rng = np.random.default_rng(3)
    for _ in range(25):
        dyn.add_edge(int(rng.integers(0, g.n_nodes)),
                     int(rng.integers(0, g.n_nodes)))
    dyn.add_vertex((42.0, 17.0))
    dyn.compact(background=False)
    # the swap's fresh engine adopted the device build: no host upload,
    # no transposition, exactly one new adoption
    assert dyn.base_index.backend == "device"
    assert dyn.base_engine.stats["adopted"] == 1
    assert rq_ops.SOA_BUILDS == soa0
    assert engine_mod.UPLOAD_COUNTERS["host_uploads"] == c0["host_uploads"]
    assert engine_mod.UPLOAD_COUNTERS["device_adoptions"] == \
        c0["device_adoptions"] + 1
    snap = dyn.snapshot_graph()
    fresh = build_2dreach(snap, variant="comp")
    us, rects = workload(snap, 150, extent_ratio=0.08, seed=8)
    assert np.array_equal(dyn.query_batch(us, rects),
                          fresh.query_batch(us, rects))


# --------------------------------------------------------------------------
# error audit: unsupported backend pairings name the offender
# --------------------------------------------------------------------------

def test_build_index_rejects_device_backend_for_non_2dreach(lbsn_graph):
    for method in ("3dreach", "3dreach-rev", "georeach"):
        with pytest.raises(ValueError) as e:
            build_index(lbsn_graph, method, backend="device")
        msg = str(e.value)
        assert method in msg and "2dreach" in msg and "backend" in msg
    # explicit host backend on a host-only method is accepted
    idx = build_index(lbsn_graph, "georeach", backend="host")
    assert idx is not None


def test_build_2dreach_rejects_unknown_backend(lbsn_graph):
    with pytest.raises(ValueError) as e:
        build_2dreach(lbsn_graph, backend="gpu")
    assert "gpu" in str(e.value) and "device" in str(e.value)
    with pytest.raises(ValueError) as e:
        build_2dreach(lbsn_graph, backend="device", device_kernel="cuda")
    assert "cuda" in str(e.value)


def test_batch_query_device_engine_on_device_build(lbsn_graph):
    dev = build_2dreach(lbsn_graph, variant="pointer", backend="device")
    us, rects = workload(lbsn_graph, 128, extent_ratio=0.08, seed=5)
    assert np.array_equal(
        batch_query(dev, us, rects, engine="device"),
        batch_query(dev, us, rects, engine="host"),
    )


@pytest.fixture(scope="module")
def lbsn_graph():
    return get_dataset("yelp", scale=0.06)
