"""repro.obs: tracer, metrics registry, query log, profiler hooks.

Covers the observability acceptance gates:

* Histogram percentiles are bit-for-bit ``np.percentile`` on replayed
  latency samples (the unified path behind ``launch/serve.py`` and
  ``benchmarks/perf_rangereach.py``), degrading gracefully once the
  exact window saturates.
* The span tracer is thread-safe, bounded, emits valid Chrome-trace
  events, and its interval-union coverage attributes >=95% of a mixed
  engine+frontend serve to instrumented layers.
* ``CounterDict`` keeps the legacy dict surfaces
  (``engine.UPLOAD_COUNTERS``) live against the registry.
* The structured query log stays bounded with eviction-proof
  aggregates and exports valid JSONL.
* ``batch_query(engine="device")`` host fallback warns once *per
  (reason, index type)* and counts every fallback in the registry.
"""

from __future__ import annotations

import json
import threading
import time
import warnings

import numpy as np
import pytest

from conftest import random_geosocial, random_queries
from repro import obs
from repro.obs.metrics import CounterDict, Histogram, Registry
from repro.obs.querylog import (
    FIELDS,
    I_VERTEX_CLASS,
    QueryLog,
    SCHEMA_VERSION,
    rect_bucket,
)
from repro.obs.tracer import Tracer


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with obs disabled and empty."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(7)
    g = random_geosocial(rng, 400, 1200)
    from repro.core import QueryEngine, build_2dreach

    idx = build_2dreach(g, variant="comp")
    eng = QueryEngine(idx)
    us, rects = random_queries(rng, g, 128)
    return g, idx, eng, us, rects


# ---------------------------------------------------------------- metrics

def test_histogram_bit_for_bit_percentiles():
    rng = np.random.default_rng(3)
    for sample in (rng.lognormal(3.0, 1.0, 5000),
                   rng.random(1000) * 1e6,
                   np.array([42.0]),
                   rng.exponential(10.0, 257)):
        h = Histogram.from_samples(sample)
        assert not h.saturated
        for p in (0, 25, 50, 90, 95, 99, 99.9, 100):
            assert h.percentile(p) == float(np.percentile(sample, p)), \
                f"p{p} diverged from np.percentile"


def test_histogram_legacy_key_shapes():
    lat = np.random.default_rng(0).lognormal(2, 1, 500)
    # launch/serve.py shape
    assert set(obs.latency_percentiles(lat)) == {"p50", "p95", "p99"}
    # benchmarks/perf_rangereach.py shape
    got = obs.latency_percentiles(lat, prefix="lat_p", suffix="_us")
    assert set(got) == {"lat_p50_us", "lat_p95_us", "lat_p99_us"}
    assert got["lat_p99_us"] == float(np.percentile(lat, 99))


def test_histogram_saturated_degrades_gracefully():
    rng = np.random.default_rng(5)
    sample = rng.lognormal(3.0, 0.5, 20000)
    h = Histogram(max_samples=128, sub=16)
    h.record_many(sample)
    assert h.saturated
    for p in (50, 95, 99):
        exact = float(np.percentile(sample, p))
        # bucket-interpolated: bounded relative error, not bit-for-bit
        assert abs(h.percentile(p) - exact) / exact < 0.10
    snap = h.snapshot()
    assert snap["count"] == 20000


def test_histogram_monotone_and_stats():
    h = Histogram.from_samples([1.0, 2.0, 3.0, 10.0])
    ps = [h.percentile(p) for p in (10, 50, 90, 99)]
    assert ps == sorted(ps)
    snap = h.snapshot()
    assert snap["min"] == 1.0 and snap["max"] == 10.0
    assert snap["count"] == 4


def test_histogram_merge_golden():
    """Merged percentiles are bit-for-bit np.percentile on the
    concatenated samples while the combined window is unsaturated."""
    rng = np.random.default_rng(9)
    a = rng.lognormal(3.0, 1.0, 700)
    b = rng.exponential(50.0, 300)
    ha = Histogram.from_samples(a, max_samples=2000)
    hb = Histogram.from_samples(b)
    assert ha.merge(hb) is ha
    both = np.concatenate([a, b])
    assert ha.count == 1000 and not ha.saturated
    for p in (0, 50, 95, 99, 100):
        assert ha.percentile(p) == float(np.percentile(both, p))
    snap = ha.snapshot()
    assert snap["min"] == both.min() and snap["max"] == both.max()
    assert snap["sum"] == pytest.approx(both.sum())
    with pytest.raises(ValueError, match="bucket layouts"):
        ha.merge(Histogram(sub=8))


def test_histogram_since_windowed_view():
    """state()/since() subtraction yields exact percentiles for just
    the values recorded in between (the time-series window)."""
    rng = np.random.default_rng(13)
    h = Histogram()
    first = rng.lognormal(2.0, 0.7, 400)
    h.record_many(first)
    st = h.state()
    second = rng.lognormal(4.0, 0.3, 300)
    h.record_many(second)
    win = h.since(st)
    assert win.count == 300 and win.sum == pytest.approx(second.sum())
    for p in (50, 95, 99):
        assert win.percentile(p) == float(np.percentile(second, p))
    assert win.min == second.min() and win.max == second.max()
    whole = h.since(None)
    assert whole.count == 700
    assert whole.percentile(50) == h.percentile(50)
    empty = h.since(h.state())              # no records in between
    assert empty.count == 0 and np.isnan(empty.percentile(50))


def test_histogram_count_above():
    h = Histogram.from_samples([1.0, 5.0, 10.0, 50.0, 100.0])
    assert h.count_above(10.0) == 3          # exact while unsaturated
    assert h.count_above(1000.0) == 0
    assert h.count_above(0.5) == 5


def test_counter_gauge_registry():
    reg = Registry()
    c = reg.counter("c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counter("c") is c          # get-or-create
    g = reg.gauge("g")
    g.set(7)
    g.set(3)
    assert g.value == 3 and g.max == 7    # high-water survives
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 5
    assert snap["gauges"]["g"]["max"] == 7
    reg.reset()
    assert c.value == 0 and g.max == 0


def test_counterdict_is_live_registry_view():
    reg = Registry()
    d = CounterDict("up.", ("a", "b"), registry=reg)
    d["a"] += 2                            # legacy increment style
    d["b"] = 9                             # legacy assignment style
    assert dict(d) == {"a": 2, "b": 9}     # legacy dict() snapshot
    assert reg.counter("up.a").value == 2  # same underlying counters
    reg.counter("up.b").inc()
    assert d["b"] == 10                    # registry writes visible


def test_upload_counters_absorbed():
    """The engine's legacy UPLOAD_COUNTERS global is a registry view."""
    from repro.core import engine as engine_mod

    before = dict(engine_mod.UPLOAD_COUNTERS)
    assert set(before) == {"host_uploads", "device_adoptions"}
    assert obs.REGISTRY.counter("engine.upload.host_uploads").value == \
        before["host_uploads"]


# ----------------------------------------------------------------- tracer

def test_span_disabled_records_nothing():
    t0 = len(obs.TRACER)
    with obs.span("x.y", cat="t", detail=1):
        pass
    assert len(obs.TRACER) == t0
    # disabled spans share one no-op object (the <2% overhead design)
    assert obs.span("a") is obs.span("b")


def test_span_enabled_records_chrome_events():
    obs.enable()
    with obs.span("layer.stage", cat="test", n=3):
        time.sleep(0.002)
    obs.disable()
    trace = obs.TRACER.chrome_trace()
    ev = [e for e in trace["traceEvents"] if e["name"] == "layer.stage"]
    assert len(ev) == 1
    e = ev[0]
    assert e["ph"] == "X" and e["cat"] == "test"
    assert e["dur"] >= 2e3                # microseconds
    assert e["args"] == {"n": 3}
    assert {"ts", "pid", "tid"} <= set(e)
    json.dumps(trace)                      # serialisable as-is


def test_traced_decorator():
    calls = []

    @obs.traced("deco.fn", cat="t")
    def fn(x):
        calls.append(x)
        return x * 2

    assert fn(2) == 4                      # disabled: passthrough
    obs.enable()
    assert fn(3) == 6
    obs.disable()
    assert calls == [2, 3]
    assert obs.stage_totals("deco.")["deco.fn"] >= 0.0


def test_tracer_thread_safety_and_bound():
    tr = Tracer(max_events=5000)
    tr.start()

    def work():
        for i in range(1000):
            tr.record("t.span", "", 0, 10, None)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr) == 5000                 # bounded, never over
    assert tr.dropped == 3000              # the rest counted, not lost
    assert tr.summary()["t.span"]["count"] == 5000


def test_stage_totals_and_summary():
    obs.enable()
    for _ in range(3):
        with obs.span("eng.a"):
            pass
    with obs.span("eng.b"):
        pass
    with obs.span("other.c"):
        pass
    obs.disable()
    totals = obs.stage_totals("eng.")
    assert set(totals) == {"eng.a", "eng.b"}
    s = obs.TRACER.summary()
    assert s["eng.a"]["count"] == 3
    assert s["eng.a"]["mean_us"] == pytest.approx(
        s["eng.a"]["total_us"] / 3)


def test_coverage_interval_union():
    tr = Tracer()
    base = 1_000_000_000  # 1s in ns
    # two overlapping spans + one disjoint: union = [0.1, 0.3] + [0.5, 0.6]
    tr.record("l.a", "", int(0.1 * base), int(0.15 * base), None)
    tr.record("l.b", "", int(0.2 * base), int(0.10 * base), None)
    tr.record("l.c", "", int(0.5 * base), int(0.10 * base), None)
    tr.record("zz.d", "", int(0.7 * base), int(0.10 * base), None)
    cov = tr.coverage(0.0, 1.0, prefixes=("l.",))
    assert cov == pytest.approx(0.30, abs=1e-6)
    assert tr.coverage(0.0, 1.0) == pytest.approx(0.40, abs=1e-6)


# -------------------------------------------------------------- query log

def test_rect_bucket():
    assert rect_bucket([0, 0, 1, 1]) == 0
    assert rect_bucket([0, 0, 2, 2]) == 2          # area 4 -> log2 = 2
    assert rect_bucket([0, 0, 0, 5]) == -64        # degenerate
    assert rect_bucket([0, 0, 1e30, 1e30]) == 63   # clamped
    assert rect_bucket([0, 0, 1e-30, 1e-30]) == -63


def test_querylog_bounded_with_aggregates():
    log = QueryLog(capacity=8)
    for i in range(20):
        log.record("reach", "user", 0, i % 3, 1e-3, i)
    assert len(log) == 8
    assert log.total == 20
    assert log.dropped == 12
    snap = log.snapshot()
    assert snap["by_class"]["reach"] == 20         # eviction-proof
    assert sum(snap["by_shard"].values()) == 20
    assert snap["latency_us"]["p50"] == pytest.approx(1000.0)


def test_querylog_jsonl_roundtrip(tmp_path):
    log = QueryLog(capacity=16)
    log.record_batch(
        "reach", ["user", "sink"],
        np.array([[0, 0, 1, 1], [0, 0, 2, 2]], dtype=np.float32),
        np.array([0, 1]), [1e-3, 2e-3], [1, 0])
    path = log.to_jsonl(str(tmp_path / "q.jsonl"))
    lines = [json.loads(l) for l in open(path)]
    header, recs = lines[0], lines[1:]
    assert header == {"schema_version": SCHEMA_VERSION,
                      "fields": list(FIELDS)}
    assert len(recs) == 2
    assert all(set(r) == set(FIELDS) for r in recs)
    assert recs[0]["vertex_class"] == "user"
    assert recs[1]["rect_bucket"] == 2
    assert recs[1]["shard"] == 1
    # schema-v2 defaults when the producer reports nothing
    assert recs[0]["status"] == "ok" and recs[0]["retries"] == 0
    assert recs[0]["u"] == -1


def test_querylog_status_and_sinks():
    """v2 fields flow through record/record_batch; streaming sinks see
    every record before ring eviction."""
    log = QueryLog(capacity=4)
    seen = []
    log.add_sink(seen.append)
    log.record_batch(
        "reach", ["user"] * 3,
        np.zeros((3, 4), dtype=np.float32), np.zeros(3),
        [1e-3] * 3, [0] * 3, us=np.array([7, 7, 9]),
        statuses=["ok", "degraded", "ok"], retries=2)
    for i in range(6):                       # overflow the ring
        log.record("reach", "user", 0, 0, 1e-3, 0, u=7)
    assert len(log) == 4 and log.dropped == 5
    assert len(seen) == 9                    # sinks saw the whole stream
    snap = log.snapshot()
    assert snap["schema_version"] == SCHEMA_VERSION
    assert snap["by_status"] == {"ok": 8, "degraded": 1}


# ------------------------------------------------- engine + frontend obs

def test_engine_batch_metrics_gated(built):
    _, _, eng, us, rects = built
    eng.query_batch(us, rects)             # disabled: no recording
    h = obs.REGISTRY.histogram("engine.batch_us")
    c0 = h.snapshot()["count"]
    obs.enable()
    eng.query_batch(us, rects)
    obs.disable()
    assert h.snapshot()["count"] == c0 + 1
    assert obs.REGISTRY.counter("engine.reach.queries").value >= len(us)
    assert obs.REGISTRY.gauge("engine.n_compiles").value == eng.n_compiles
    eng.query_batch(us, rects)             # disabled again: flat
    assert h.snapshot()["count"] == c0 + 1


def test_mixed_serve_coverage_at_least_95pct(built):
    """The acceptance gate: spans across serve/engine/frontend layers
    cover >=95% of a mixed serve's wall time."""
    from repro.cluster import Frontend

    _, _, eng, us, rects = built
    obs.enable()
    t0 = time.perf_counter()
    with obs.span("serve.mixed_pass", cat="serve"):
        eng.query_batch(us, rects)                   # direct engine
        with Frontend(eng, max_batch=32, max_delay=1e-3) as fe:
            fe.submit_many(us[:64], rects[:64])      # micro-batched
    t1 = time.perf_counter()
    obs.disable()
    cov = obs.coverage(t0, t1)
    assert cov >= 0.95, f"span coverage {cov:.3f} < 0.95"
    totals = obs.stage_totals()
    layers = {name.split(".")[0] for name in totals}
    assert {"serve", "engine", "frontend"} <= layers
    snap = obs.snapshot()
    assert snap["schema_version"] == 2
    assert snap["query_log"]["total"] >= 64          # frontend logged
    assert "frontend.flush" in snap["spans"]


def test_frontend_explicit_query_log(built):
    """An explicit query_log records even with obs disabled; shard and
    vertex-class fields are populated."""
    from repro.cluster import Frontend

    _, idx, eng, us, rects = built
    qlog = QueryLog(capacity=256)
    with Frontend(eng, max_batch=16, max_delay=1e-3,
                  query_log=qlog) as fe:
        fe.submit_many(us[:48], rects[:48])
    assert qlog.total == 48
    recs = qlog.records()
    classes = {r[I_VERTEX_CLASS] for r in recs}
    assert classes <= {"user", "sink", "unknown"}
    excluded = np.asarray(idx.excluded)
    want_sink = int(excluded[us[:48].astype(np.int64)].sum())
    assert sum(1 for r in recs
               if r[I_VERTEX_CLASS] == "sink") == want_sink


def test_obs_dump_writes_artifacts(tmp_path, built):
    _, _, eng, us, rects = built
    obs.enable()
    eng.query_batch(us, rects)
    obs.disable()
    paths = obs.dump(str(tmp_path))
    trace = json.load(open(paths["trace"]))
    assert any(e["name"] == "engine.query_batch"
               for e in trace["traceEvents"])
    snap = json.load(open(paths["metrics"]))
    assert "engine.batch_us" in snap["metrics"]["histograms"]
    qlines = open(paths["querylog"]).read().splitlines()
    assert len(qlines) == 1                  # header only: nothing served
    assert json.loads(qlines[0])["schema_version"] == SCHEMA_VERSION
    prom = open(paths["prom"]).read()        # OpenMetrics always written
    assert prom.endswith("# EOF\n")
    assert "repro_engine_batch_us_count 1" in prom


def test_engine_cost_model_sanity(built):
    _, _, eng, us, rects = built
    eng.query_batch(us, rects)
    cm = obs.engine_cost_model(eng)
    assert cm["batches"] >= 1
    assert 0 < cm["candidate_tiles_per_batch"] <= \
        cm["full_scan_tiles_per_batch"]
    assert 0 < cm["scan_fraction"] <= 1.0
    assert cm["scan_bytes_per_batch"] > 0
    assert cm["prune_bytes_per_batch"] > 0
    assert cm["tile_shape"]["planes"] == 4


def test_device_trace_degrades_gracefully(tmp_path):
    # must never fail the serve, whatever the backend supports
    with obs.device_trace(str(tmp_path / "prof"), enabled=True):
        pass
    with obs.device_trace("", enabled=False):
        pass


# -------------------------------------------- host-fallback (satellite)

def test_host_fallback_warns_once_per_reason_and_counts():
    import repro.core.api as api_mod
    from repro.core.api import batch_query, build_dynamic_index, build_index

    rng = np.random.default_rng(11)
    g = random_geosocial(rng, 120, 360)
    us, rects = random_queries(rng, g, 4)
    geo = build_index(g, "georeach")                   # no device engine
    dyn = build_dynamic_index(g, "2dreach-comp")       # host-engine wrapper
    assert getattr(dyn, "engine", None) == "host"

    api_mod._FALLBACK_WARNED.discard(
        ("unsupported-index", "GeoReachIndex"))
    api_mod._FALLBACK_WARNED.discard(
        ("wrapper-host-engine", "DynamicIndex"))
    c_unsup = obs.REGISTRY.counter("api.host_fallback.unsupported-index")
    c_wrap = obs.REGISTRY.counter("api.host_fallback.wrapper-host-engine")
    n_unsup, n_wrap = c_unsup.value, c_wrap.value

    # distinct causes each get their own (single) warning
    with pytest.warns(RuntimeWarning, match="unsupported-index"):
        batch_query(geo, us, rects, engine="device")
    with pytest.warns(RuntimeWarning, match="wrapper-host-engine"):
        batch_query(dyn, us, rects, engine="device")
    # second occurrence of each: silent, but still counted
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        batch_query(geo, us, rects, engine="device")
        batch_query(dyn, us, rects, engine="device")
    assert c_unsup.value == n_unsup + 2
    assert c_wrap.value == n_wrap + 2
