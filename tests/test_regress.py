"""Bench-regression sentinel: extraction, baselines, verdicts, CLI.

The CI contract: ``regress.py`` exits 0 when a fresh bench matches the
seeded history and nonzero when a metric regresses past tolerance; the
committed ``results/bench_history.jsonl`` seed parses and covers every
tracked metric of the committed BENCH files.
"""

from __future__ import annotations

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import regress  # noqa: E402

REPO = os.path.join(os.path.dirname(__file__), "..")


def _bench(tmp_path, name="BENCH_rangereach.json", scale=1.0):
    doc = json.load(open(os.path.join(REPO, name)))
    if scale != 1.0:
        for k in doc["engines"]:
            doc["engines"][k] *= scale
    os.makedirs(str(tmp_path), exist_ok=True)
    path = str(tmp_path / name)
    json.dump(doc, open(path, "w"))
    return path


# ------------------------------------------------------------- extraction

def test_extract_committed_bench_files():
    for name in regress.BENCHES:
        doc = json.load(open(os.path.join(REPO, name)))
        metrics = regress.extract(name, doc)
        assert metrics, f"{name}: no metrics extracted"
        assert all(isinstance(v, float) and v > 0
                   for v in metrics.values())
    with pytest.raises(ValueError, match="no extractor"):
        regress.extract("BENCH_unknown.json", {})


def test_committed_seed_history_covers_benches():
    """The committed seed parses and gives every tracked metric of every
    committed BENCH file a baseline."""
    history = regress.load_history(
        os.path.join(REPO, "results", "bench_history.jsonl"))
    assert history, "seed history missing or empty"
    for run in history:
        assert run["schema_version"] == regress.SCHEMA_VERSION
        assert run["metrics"]
    for name in regress.BENCHES:
        doc = json.load(open(os.path.join(REPO, name)))
        for metric in regress.extract(name, doc):
            assert regress.baseline_for(history, name, metric,
                                        5) is not None, \
                f"{name}:{metric} has no baseline in the seed"


# ---------------------------------------------------- baseline + verdicts

def test_baseline_is_median_of_last_n():
    hist = [{"bench": "b.json", "metrics": {"m": v}}
            for v in (10.0, 10.0, 400.0, 12.0, 11.0)]
    # median of the last 3 (400, 12, 11) = 12: one outlier run cannot
    # poison the baseline
    assert regress.baseline_for(hist, "b.json", "m", 3) == 12.0
    assert regress.baseline_for(hist, "b.json", "m", 5) == 11.0
    assert regress.baseline_for(hist, "b.json", "missing", 3) is None
    assert regress.baseline_for(hist, "other.json", "m", 3) is None


def test_compare_verdicts():
    hist = [{"bench": "b.json", "metrics": {"ok": 10.0, "slow": 10.0,
                                            "fast": 10.0}}]
    rows = regress.compare(
        "b.json", {"ok": 11.0, "slow": 20.0, "fast": 2.0, "fresh": 5.0},
        hist, tol=0.25)
    verdicts = {r["metric"]: r["verdict"] for r in rows}
    assert verdicts == {"ok": regress.OK, "slow": regress.REGRESSED,
                        "fast": regress.IMPROVED, "fresh": regress.NEW}
    by = {r["metric"]: r for r in rows}
    assert by["slow"]["ratio"] == pytest.approx(2.0)
    assert by["fresh"]["baseline"] is None


def test_per_metric_tolerance_override():
    hist = [{"bench": "b.json", "metrics": {"noisy": 10.0}}]
    rows = regress.compare("b.json", {"noisy": 18.0}, hist, tol=0.25,
                           metric_tol={"noisy": 1.0})
    assert rows[0]["verdict"] == regress.OK
    rows = regress.compare("b.json", {"noisy": 18.0}, hist, tol=0.25)
    assert rows[0]["verdict"] == regress.REGRESSED


# ------------------------------------------------------------ ratio gates

def test_ratio_gates_device_vs_host():
    """The committed BENCH files satisfy every history-free ceiling —
    fused device ≤ host per class, cluster ≤ 2x device — and a
    doctored device slowdown trips exactly its gate."""
    for name in ("BENCH_queries.json", "BENCH_rangereach.json"):
        doc = json.load(open(os.path.join(REPO, name)))
        rows = regress.gate_rows(name, regress.extract(name, doc))
        assert rows, f"{name}: no ratio gates evaluated"
        assert all(r["verdict"] == regress.OK for r in rows), rows

    doc = json.load(open(os.path.join(REPO, "BENCH_queries.json")))
    m = regress.extract("BENCH_queries.json", doc)
    m["queries.reach.device_us_per_q"] = (
        m["queries.reach.host_us_per_q"] * 1.5)
    rows = regress.gate_rows("BENCH_queries.json", m)
    verdicts = {r["gate"]: r["verdict"] for r in rows}
    assert verdicts["reach.device_vs_host"] == regress.REGRESSED
    assert verdicts["count.device_vs_host"] == regress.OK
    # slack relaxes the ceiling (cross-machine CI headroom)
    rows = regress.gate_rows("BENCH_queries.json", m, slack=1.0)
    assert all(r["verdict"] == regress.OK for r in rows)


def test_cli_gate_failure_and_no_gates(tmp_path, capsys):
    hist = str(tmp_path / "hist.jsonl")
    name = "BENCH_queries.json"
    doc = json.load(open(os.path.join(REPO, name)))
    doc["classes"]["reach"]["device_us_per_q"] = (
        doc["classes"]["reach"]["host_us_per_q"] * 2.0)
    path = str(tmp_path / name)
    json.dump(doc, open(path, "w"))
    assert regress.main(["--bench", path, "--history", hist]) == 1
    assert "ratio gate" in capsys.readouterr().out
    assert regress.main(["--bench", path, "--history", hist,
                         "--no-gates"]) == 0


# ----------------------------------------------------------- CLI contract

def test_cli_seed_then_pass_then_fail(tmp_path, capsys):
    hist = str(tmp_path / "hist.jsonl")
    good = _bench(tmp_path)

    # seed (append-only, no gating)
    assert regress.main(["--bench", good, "--history", hist,
                         "--no-check", "--label", "seed"]) == 0
    runs = regress.load_history(hist)
    assert len(runs) == 1 and runs[0]["label"] == "seed"

    # identical rerun passes and appends
    assert regress.main(["--bench", good, "--history", hist]) == 0
    assert len(regress.load_history(hist)) == 2
    assert "verdict" in capsys.readouterr().out

    # doctored 3x regression fails with exit 1 ...
    bad = _bench(tmp_path / "bad", scale=3.0)
    assert regress.main(["--bench", bad, "--history", hist]) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "engines.host" in out
    # ... and the bad run is still recorded (the artifact shows it)
    assert len(regress.load_history(hist)) == 3

    # --no-append gates without recording
    assert regress.main(["--bench", good, "--history", hist,
                         "--no-append", "--baseline-n", "2"]) == 0
    assert len(regress.load_history(hist)) == 3


def test_cli_tolerance_absorbs_noise(tmp_path):
    hist = str(tmp_path / "hist.jsonl")
    regress.main(["--bench", _bench(tmp_path), "--history", hist,
                  "--no-check"])
    wobbly = _bench(tmp_path / "w", scale=1.6)
    # 1.6x fails the tight default but passes the cross-machine CI tol
    assert regress.main(["--bench", wobbly, "--history", hist,
                         "--no-append"]) == 1
    assert regress.main(["--bench", wobbly, "--history", hist,
                         "--no-append", "--tol", "1.0"]) == 0


def test_cli_metric_tol_parsing(tmp_path):
    hist = str(tmp_path / "hist.jsonl")
    regress.main(["--bench", _bench(tmp_path), "--history", hist,
                  "--no-check"])
    bad = _bench(tmp_path / "b", scale=2.0)
    args = ["--bench", bad, "--history", hist, "--no-append"]
    for m in ("engines.host", "engines.device", "engines.wavefront",
              "engines.cluster", "engines.pallas_leafscan"):
        args += ["--metric-tol", f"{m}=5.0"]
    # scaling only touched engines.*; with those overridden the
    # untouched latency metrics keep it green
    assert regress.main(args) == 0
