"""repro.cluster — sharded multi-device RangeReach serving.

The 2DReach forest partitions by tree id (each component's R-tree is an
independent lookup target); :class:`ShardedEngine` serves the partition
over a mesh with ``shard_map`` (replicated pointer lookup, per-shard
Pallas descent, OR-reduce), and :class:`Frontend` micro-batches a
request stream into the power-of-two buckets the engines compile for.

    eng  = ShardedEngine(build_index(g, "2dreach-comp"), n_shards=8)
    ans  = eng.query_batch(us, rects)         # bit-identical to host
    with Frontend(eng, max_batch=256) as fe:  # request-at-a-time surface
        fut = fe.submit(u, rect)
"""

from .frontend import Frontend
from .partition import (
    ForestPartition,
    balanced_assignment,
    partition_forest,
    shard_arenas,
)
from .sharded_engine import ShardedEngine, sharded_engine_for

__all__ = [
    "Frontend",
    "ForestPartition",
    "balanced_assignment",
    "partition_forest",
    "shard_arenas",
    "ShardedEngine",
    "sharded_engine_for",
]
