"""§Perf hillclimb: the paper-technique cell (RangeReach query engine).

Unlike the LM/GNN cells (dry-run roofline terms), the paper's own
workload runs for real on this host, so this hillclimb measures
wall-clock per query across engine variants and structural parameters:

    engine    host wavefront | jit wavefront (capacity c) | pallas leaf
    fanout    R-tree node width (VMEM tile shape analogue)
    capacity  jit wavefront frontier budget

plus the build-side closure: per-level scatter-OR vs the bitset_mm
fixpoint (VPU word loop vs MXU unpack-matmul) at growing component
counts.  Each configuration is correctness-checked against the host
engine before timing.  Output: results/perf_rangereach.json.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import build_2dreach, query_host, query_jax_wavefront
from repro.data import get_dataset, workload
from repro.kernels.range_query.ops import range_query_forest

OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "perf_rangereach.json",
)


def _t(fn, repeats=5):
    fn()  # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def engine_sweep(dataset="gowalla", scale=0.5, n_q=2000) -> List[Dict]:
    g = get_dataset(dataset, scale=scale)
    us, rects = workload(g, n_q, extent_ratio=0.05, seed=5)
    rows = []
    for fanout in (8, 16, 32, 64):
        idx = build_2dreach(g, variant="comp", fanout=fanout)
        tid = idx.lookup_tree(us)
        ref = query_host(idx.forest, tid, rects)
        # host engine
        dt = _t(lambda: query_host(idx.forest, tid, rects))
        rows.append(dict(engine="host", fanout=fanout, capacity=None,
                         us_per_q=dt / n_q * 1e6,
                         depth=idx.forest.depth))
        # jit wavefront at several capacities
        for cap in (32, 64, 128, 256):
            got, ovf = query_jax_wavefront(idx.forest, tid, rects,
                                           capacity=cap)
            valid = ~np.asarray(ovf)
            assert (np.asarray(got)[valid] == ref[valid]).all()
            ovf_frac = float(np.asarray(ovf).mean())
            dt = _t(lambda: query_jax_wavefront(
                idx.forest, tid, rects, capacity=cap))
            rows.append(dict(engine="wavefront", fanout=fanout,
                             capacity=cap, us_per_q=dt / n_q * 1e6,
                             overflow_frac=ovf_frac,
                             depth=idx.forest.depth))
        # pallas leaf scan (interpret on CPU — structural comparison)
        got = range_query_forest(idx.forest, tid, rects)
        assert (got == ref).all()
        dt = _t(lambda: range_query_forest(idx.forest, tid, rects),
                repeats=3)
        rows.append(dict(engine="pallas_leafscan", fanout=fanout,
                         capacity=None, us_per_q=dt / n_q * 1e6,
                         depth=idx.forest.depth))
    return rows


def closure_sweep() -> List[Dict]:
    """Build-side: per-level scatter-OR vs bitset-matmul fixpoint."""
    from repro.core import condense, scc_np
    from repro.core.reachability import closure_np, pack_rows
    from repro.kernels.bitset_mm.ops import closure_fixpoint

    rows = []
    for scale in (0.1, 0.25, 0.5):
        g = get_dataset("yelp", scale=scale)
        labels = scc_np(g.n_nodes, g.edges)
        cond = condense(g.n_nodes, g.edges, labels)
        t0 = time.perf_counter()
        clo = closure_np(cond, g.n_nodes, g.spatial_ids)
        t_np = time.perf_counter() - t0
        d, p = cond.n_comps, clo.p
        rows.append(dict(method="scatter_or_levels", scale=scale,
                         n_comps=d, n_spatial=p, seconds=t_np))
        if d <= 12000:
            # dense closure paths only feasible at small d
            own = np.zeros((d, p), dtype=bool)
            for c in range(d):
                own[c, clo.own_cols[
                    clo.own_indptr[c]:clo.own_indptr[c + 1]]] = True
            A = np.zeros((d, d), dtype=bool)
            if cond.dag_edges.size:
                A[cond.dag_edges[:, 0], cond.dag_edges[:, 1]] = True
            ob, ab = pack_rows(own), pack_rows(A)
            t0 = time.perf_counter()
            closure_fixpoint(ob, ab, n_iters=cond.n_levels + 1,
                             use_mxu=True)
            rows.append(dict(method="bitset_mm_mxu", scale=scale,
                             n_comps=d, n_spatial=p,
                             seconds=time.perf_counter() - t0))
    return rows


def main():
    out = {"engine_sweep": engine_sweep(), "closure": closure_sweep()}
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(out, f, indent=1)
    for r in out["engine_sweep"]:
        print(r)
    for r in out["closure"]:
        print(r)


if __name__ == "__main__":
    main()
