"""Architecture zoo: LM transformers, GNN family, recsys DIN."""
