"""Deterministic, seedable fault injection for the serving stack.

The serving layers (engine, cluster, frontend, dynamic compaction) are
dotted with named **failure points**::

    fault_point("engine.query_batch", n=B)

Disabled — the default — a fault point is a single module-attribute
check returning immediately, mirroring the obs tracer's disabled span
path (the analytic <2% overhead gate in ``benchmarks/obs_overhead.py``
covers both).  Enabled, the hit is matched against the installed
:class:`FaultPlan`'s specs and may **raise** an injected exception,
**stall** (bounded hang, releasable by the test), or **delay** (latency
spike) — all scheduled deterministically from the plan's seed, so a
chaos run replays bit-for-bit.

Usage::

    plan = FaultPlan(
        FaultSpec("engine.query_batch", kind="raise", p=0.5),
        FaultSpec("dynamic.compaction.mid_swap", max_fires=1),
        seed=7,
    )
    with inject(plan):
        ... serve ...
    plan.fires_at("engine.query_batch")   # how many actually fired

Every fire is counted in the obs registry (``faults.injected`` plus a
per-point counter), so a chaos run's obs snapshot shows exactly what
was injected next to what the stack did about it.

Failure-point registry (the names wired through the stack):

==============================    =========================================
point                             site
==============================    =========================================
engine.query_batch                device ``QueryEngine.query_batch`` entry
engine.route_prune                shared phase 1 of every analytics class
cluster.query_batch               ``ShardedEngine.query_batch`` entry
                                  (``ShardDropout`` specs model one shard)
frontend.flush                    inside the scheduler's serve latch
frontend.queue_stall              serve entry, before batch assembly (a
                                  delay/hang here stalls the scheduler)
dynamic.compaction.build          compaction build start
dynamic.compaction.mid_build      between index build and substrate build
dynamic.compaction.pre_swap       swap critical section entry (lock held)
dynamic.compaction.mid_swap       after base install, before op-log replay
dynamic.compaction.replay         before the racing-mutation replay loop
engine.answer                     value point on ``QueryEngine
                                  .query_batch`` output (``kind=
                                  "corrupt"`` flips answers — the
                                  wrong-answer fault the online
                                  exactness auditor must catch)
==============================    =========================================
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..obs import metrics as obs_metrics
from .errors import InjectedFault

KINDS = ("raise", "delay", "hang", "corrupt")


def _default_mutator(value):
    """Flip a boolean answer array's first element — the canonical
    silent wrong answer the exactness auditor exists to catch."""
    out = np.array(value, dtype=bool).copy()
    if out.size:
        out.flat[0] = ~out.flat[0]
    return out


@dataclasses.dataclass
class FaultSpec:
    """One scheduled failure at a named point.

    Parameters
    ----------
    point:     failure-point name (see the module registry table).
    kind:      ``"raise"`` (raise ``exc``), ``"delay"`` (sleep
               ``delay_s`` — a latency spike), or ``"hang"`` (block up
               to ``hang_s`` or until the plan's ``release`` event —
               a bounded stall the test can end).
    p:         per-hit firing probability, drawn from the plan's seeded
               rng (1.0 = every eligible hit fires).
    after:     skip the first ``after`` hits of this point (placing a
               crash at the N-th batch / stage boundary).
    max_fires: stop firing after this many (``None`` = unbounded).
    delay_s:   sleep duration for ``kind="delay"``.
    hang_s:    stall bound for ``kind="hang"`` (a safety net: chaos
               tests end hangs via ``plan.release``; real hangs are the
               frontend's deadline machinery's problem).
    exc:       exception *factory* ``(point, fire_no) -> BaseException``
               for ``kind="raise"``; default :class:`InjectedFault`.
    mutator:   value transform for ``kind="corrupt"`` — applied to the
               value crossing a :func:`fault_value` point (a **silent
               wrong answer**, the failure mode the online exactness
               auditor exists to catch); default flips the first
               element of a boolean answer array.  Corrupt specs only
               fire at value points; at plain :func:`fault_point` sites
               they are ignored.
    """

    point: str
    kind: str = "raise"
    p: float = 1.0
    after: int = 0
    max_fires: Optional[int] = 1
    delay_s: float = 0.0
    hang_s: float = 30.0
    exc: Optional[Callable[[str, int], BaseException]] = None
    mutator: Optional[Callable] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {KINDS}")
        if not (0.0 <= self.p <= 1.0):
            raise ValueError(f"need 0 <= p <= 1, got {self.p}")

    def make_exc(self, fire: int) -> BaseException:
        if self.exc is not None:
            return self.exc(self.point, fire)
        return InjectedFault(self.point, fire)


class FaultPlan:
    """A seeded, deterministic schedule of :class:`FaultSpec` s.

    Thread-safe: hits arrive concurrently from the caller, the frontend
    scheduler thread and background compaction builders; one lock
    serialises the rng draws and counters so a fixed seed yields a
    fixed global firing order.
    """

    def __init__(self, *specs: FaultSpec, seed: int = 0):
        self.specs: Dict[str, List[FaultSpec]] = {}
        for s in specs:
            self.specs.setdefault(s.point, []).append(s)
        self.seed = int(seed)
        self.release = threading.Event()   # opens every pending hang
        self._rng = np.random.default_rng(self.seed)
        self._lock = threading.Lock()
        self._hits: Dict[str, int] = {}
        self._fires: Dict[str, int] = {}

    # -- introspection --------------------------------------------------

    @property
    def total_fires(self) -> int:
        with self._lock:
            return sum(self._fires.values())

    def hits_at(self, point: str) -> int:
        with self._lock:
            return self._hits.get(point, 0)

    def fires_at(self, point: str) -> int:
        with self._lock:
            return self._fires.get(point, 0)

    # -- scheduling -----------------------------------------------------

    def _decide(self, point: str) -> Optional[tuple]:
        """Under the lock: should this hit fire, and with which spec?
        Returns ``(spec, fire_no)`` or None."""
        with self._lock:
            hit = self._hits.get(point, 0)
            self._hits[point] = hit + 1
            for spec in self.specs.get(point, ()):
                if hit < spec.after:
                    continue
                fired = self._fires.get(point, 0)
                if spec.max_fires is not None and fired >= spec.max_fires:
                    continue
                if spec.p < 1.0 and self._rng.random() >= spec.p:
                    continue
                self._fires[point] = fired + 1
                return spec, fired + 1
        return None


class FaultInjector:
    """Process-wide fault switchboard (one instance: :data:`INJECTOR`).

    ``enabled`` is the single-attribute hot-path gate; ``hits_total``
    counts fault-point crossings while enabled (the overhead bench uses
    it to count hook sites per batch with an *empty* plan installed).
    """

    def __init__(self):
        self.enabled = False
        self._plan: Optional[FaultPlan] = None
        self.hits_total = 0
        self._c_injected = obs_metrics.REGISTRY.counter("faults.injected")

    def install(self, plan: FaultPlan) -> None:
        self._plan = plan
        self.enabled = True

    def uninstall(self) -> None:
        self.enabled = False
        plan, self._plan = self._plan, None
        if plan is not None:
            plan.release.set()      # never strand a pending hang

    @property
    def plan(self) -> Optional[FaultPlan]:
        return self._plan

    def hit(self, point: str, ctx: Optional[dict]) -> None:
        self.hits_total += 1
        plan = self._plan
        if plan is None:
            return
        decision = plan._decide(point)
        if decision is None:
            return
        spec, fire = decision
        self._count_fire(point, spec, fire)
        if spec.kind == "raise":
            raise spec.make_exc(fire)
        if spec.kind == "delay":
            time.sleep(spec.delay_s)
            return
        if spec.kind == "corrupt":
            return        # corrupt specs only act at fault_value points
        plan.release.wait(timeout=spec.hang_s)   # "hang": bounded stall

    def hit_value(self, point: str, value, ctx: Optional[dict]):
        """A :func:`fault_value` crossing: like :meth:`hit`, but the
        point carries a value a ``kind="corrupt"`` spec may silently
        mutate; every other kind behaves as at a plain point."""
        self.hits_total += 1
        plan = self._plan
        if plan is None:
            return value
        decision = plan._decide(point)
        if decision is None:
            return value
        spec, fire = decision
        self._count_fire(point, spec, fire)
        if spec.kind == "corrupt":
            return (spec.mutator or _default_mutator)(value)
        if spec.kind == "raise":
            raise spec.make_exc(fire)
        if spec.kind == "delay":
            time.sleep(spec.delay_s)
            return value
        plan.release.wait(timeout=spec.hang_s)
        return value

    def _count_fire(self, point: str, spec: FaultSpec, fire: int) -> None:
        self._c_injected.inc()
        obs_metrics.REGISTRY.counter(f"faults.{point}").inc()
        # black-box note: injected faults land in the flight recorder's
        # always-on event ring next to what the stack did about them
        from ..obs.flight import FLIGHT  # deferred: keeps import light
        FLIGHT.note("fault.injected", point=point, fault_kind=spec.kind,
                    fire=fire)


INJECTOR = FaultInjector()


def fault_point(name: str, **ctx) -> None:
    """Named failure point.  Disabled (the default): one attribute
    check, nothing else — safe on the serve hot path.  Enabled: the
    installed plan decides whether this hit raises / stalls / delays."""
    if not INJECTOR.enabled:
        return
    INJECTOR.hit(name, ctx or None)


def fault_value(name: str, value, **ctx):
    """Named failure point **carrying a value** (an answer array about
    to be returned).  Disabled: one attribute check and the value flows
    through untouched.  Enabled: a ``kind="corrupt"`` spec may mutate
    it — the silent-wrong-answer injection the online exactness auditor
    is proven against — and every other kind acts as at a plain
    :func:`fault_point`."""
    if not INJECTOR.enabled:
        return value
    return INJECTOR.hit_value(name, value, ctx or None)


class inject:
    """Context manager installing a plan for the dynamic extent of a
    test (uninstall releases any hang still pending)::

        with inject(FaultPlan(FaultSpec("engine.query_batch"), seed=3)):
            ...
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def __enter__(self) -> FaultPlan:
        INJECTOR.install(self.plan)
        return self.plan

    def __exit__(self, *exc) -> bool:
        INJECTOR.uninstall()
        return False
