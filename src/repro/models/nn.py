"""Minimal NN substrate — parameter pytrees + pure-function layers.

No flax/optax exist in this environment, so the framework carries its own
layer toolkit: params are nested dicts of jnp arrays, layers are pure
functions, initialisers take explicit PRNG keys.  Everything is
pjit/shard_map friendly (pure pytrees, no global state).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# initialisers
# --------------------------------------------------------------------------

def dense_init(
    key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None,
    bias: bool = True,
) -> Params:
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def embed_init(key, n: int, d: int, dtype=jnp.float32, scale: float = 0.02
               ) -> Params:
    return {"emb": jax.random.normal(key, (n, d), dtype) * scale}


def norm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


# --------------------------------------------------------------------------
# layers
# --------------------------------------------------------------------------

def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["emb"], ids, axis=0)


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"]


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    y = y * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y


ACT: Dict[str, Callable] = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "ssp": lambda x: jax.nn.softplus(x) - jnp.log(2.0),  # shifted softplus
    "sigmoid": jax.nn.sigmoid,
    "identity": lambda x: x,
}


def mlp_init(key, dims: Sequence[int], dtype=jnp.float32, bias: bool = True
             ) -> Params:
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"l{i}": dense_init(keys[i], dims[i], dims[i + 1], dtype, bias=bias)
        for i in range(len(dims) - 1)
    }


def mlp(p: Params, x: jnp.ndarray, act: str = "silu",
        final_act: str = "identity") -> jnp.ndarray:
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x)
        x = ACT[act](x) if i < n - 1 else ACT[final_act](x)
    return x


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping."""
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# --------------------------------------------------------------------------
# stacked (scan-able) parameter helpers
# --------------------------------------------------------------------------

def stack_init(init_fn: Callable[[jax.Array], Params], key, n: int) -> Params:
    """Initialise ``n`` copies of a block's params, stacked on axis 0 —
    the layout ``jax.lax.scan`` consumes (keeps the HLO flat in depth)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def scan_blocks(body: Callable, params: Params, x, *, unroll: int = 1):
    """Run ``body(layer_params, x) -> x`` over stacked params via scan."""

    def step(carry, lp):
        return body(lp, carry), None

    out, _ = jax.lax.scan(step, x, params, unroll=unroll)
    return out


def count_params(params: Params) -> int:
    return sum(
        int(x.size) for x in jax.tree_util.tree_leaves(params)
        if hasattr(x, "size")
    )


def param_bytes(params: Params) -> int:
    return sum(
        int(x.size * x.dtype.itemsize)
        for x in jax.tree_util.tree_leaves(params)
        if hasattr(x, "size")
    )
