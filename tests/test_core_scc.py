"""SCC + condensation: device algorithm vs scipy oracle; DAG invariants."""

import numpy as np
import pytest
from conftest import given, st

from repro.core import (
    condense,
    same_partition,
    scc_jax,
    scc_np,
)


@given(st.integers(0, 10_000))
def test_scc_jax_matches_scipy(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 60))
    m = int(rng.integers(0, 4 * n))
    edges = rng.integers(0, n, size=(m, 2))
    assert same_partition(scc_np(n, edges), scc_jax(n, edges))


def test_scc_known_cycle():
    # a->b->c->a plus tail c->d
    edges = np.array([[0, 1], [1, 2], [2, 0], [2, 3]])
    lab = scc_np(4, edges)
    assert lab[0] == lab[1] == lab[2] != lab[3]
    labj = scc_jax(4, edges)
    assert labj[0] == labj[1] == labj[2] != labj[3]


@given(st.integers(0, 10_000))
def test_condensation_is_dag_with_levels(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 80))
    m = int(rng.integers(0, 5 * n))
    edges = rng.integers(0, n, size=(m, 2))
    cond = condense(n, edges, scc_np(n, edges))
    # every DAG edge increases the level strictly
    if cond.dag_edges.size:
        lu = cond.level[cond.dag_edges[:, 0]]
        lv = cond.level[cond.dag_edges[:, 1]]
        assert (lu < lv).all()
        # no intra-component DAG edges
        assert (cond.dag_edges[:, 0] != cond.dag_edges[:, 1]).all()
    assert cond.comp.min() >= 0 and cond.comp.max() < cond.n_comps
    assert cond.comp_sizes.sum() == n


def test_condensation_include_mask():
    edges = np.array([[0, 1], [1, 0], [1, 2]])
    include = np.array([True, True, False])
    cond = condense(3, edges[:2], scc_np(3, edges[:2]), include_mask=include)
    assert cond.comp[2] == -1
    assert cond.comp[0] == cond.comp[1] >= 0
