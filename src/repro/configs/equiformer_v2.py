"""equiformer-v2 [gnn]: 12L d_hidden=128 l_max=6 m_max=2 8 heads,
SO(2)-eSCN equivariant graph attention. [arXiv:2306.12059]"""
from ..models.gnn import equiformer_v2 as module
from ..models.gnn.equiformer_v2 import EquiformerV2Config
from .base import ArchSpec, gnn_cells

NAME = "equiformer-v2"


def make_config(reduced: bool = False, d_feat=None, shape=None
                ) -> EquiformerV2Config:
    if reduced:
        return EquiformerV2Config(n_layers=2, d_hidden=16, l_max=2,
                                  m_max=1, n_heads=2, n_rbf=8)
    return EquiformerV2Config(n_layers=12, d_hidden=128, l_max=6, m_max=2,
                              n_heads=8, d_feat=d_feat)


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="gnn", make_config=make_config,
        cells=gnn_cells(NAME, module, make_config),
        notes="exact Wigner-D (Ivanic-Ruedenberg) frame alignment; "
              "per-edge irrep state (49 coeff x 128 ch) dominates memory "
              "on ogb_products",
    )
