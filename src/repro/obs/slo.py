"""SLO burn-rate monitoring over the serving metrics.

Classic multi-window burn-rate alerting (the SRE-workbook shape) on top
of the registry's cumulative counters, the latency histograms and the
resilience layer's typed-error counters:

* an :class:`SLO` names a **bad-event** and a **total-event** source
  (cumulative, monotone — a counter value, a histogram ``count``, or a
  ``count_above`` latency threshold) and an **error budget** (the
  allowed bad fraction, e.g. 0.01 for 99% availability);
* the monitor keeps a bounded ring of timestamped (bad, total)
  snapshots per SLO and, on every :meth:`tick`, computes the burn rate
  — (bad fraction over the window) / budget — over each configured
  window (default 5s and 60s);
* the alert **fires** only when *every* window burns above the
  threshold (the short window makes detection fast, the long window
  stops a single blip from flapping) and **clears** as soon as the
  short window recovers.

Alert transitions are emitted three ways so nothing has to poll:
appended to :attr:`SLOMonitor.events` (bounded), counted/gauged in the
registry (``slo.<name>.fired`` / ``.cleared`` / ``.active`` /
``.burn``), and recorded as instantaneous events into the span tracer
so they land in the Chrome trace timeline next to the stage spans.

The monitor has no thread of its own: hook :meth:`tick` onto the
time-series collector's sampling cadence (``serve.py --obs`` does), or
drive it with a fake clock in tests.
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Dict, List, Optional, Sequence, Union

from . import metrics as _metrics
from .tracer import TRACER, _now_ns

Source = Union[str, Callable[[_metrics.Registry], float]]


def _resolve(src: Source, reg: _metrics.Registry) -> float:
    if callable(src):
        return float(src(reg))
    return float(reg.counter(src).value)


def latency_above(hist_name: str, threshold: float) -> Callable:
    """Bad-event source: recordings of ``hist_name`` at or above
    ``threshold`` (same unit the histogram records, typically µs)."""
    return lambda reg: reg.histogram(hist_name).count_above(threshold)


def hist_count(hist_name: str) -> Callable:
    """Total-event source: everything ``hist_name`` recorded."""
    return lambda reg: reg.histogram(hist_name).count


class SLO:
    """One objective: bad/total sources, budget, windows, threshold."""

    __slots__ = ("name", "bad", "total", "budget", "windows",
                 "threshold", "min_events", "ring", "active")

    def __init__(self, name: str, bad: Source, total: Source,
                 budget: float = 0.01,
                 windows: Sequence[float] = (5.0, 60.0),
                 threshold: float = 1.0, min_events: int = 1,
                 capacity: int = 4096):
        if budget <= 0:
            raise ValueError(f"budget must be > 0, got {budget}")
        self.name = name
        self.bad = bad
        self.total = total
        self.budget = float(budget)
        self.windows = tuple(float(w) for w in windows)
        self.threshold = float(threshold)
        self.min_events = int(min_events)
        self.ring: collections.deque = collections.deque(maxlen=capacity)
        self.active = False


class SLOMonitor:
    """Evaluates a set of :class:`SLO`\\ s against the registry."""

    def __init__(self, registry: Optional[_metrics.Registry] = None,
                 clock: Callable[[], float] = time.monotonic,
                 max_events: int = 1024):
        self.registry = registry if registry is not None \
            else _metrics.REGISTRY
        self._clock = clock
        self.max_events = int(max_events)
        self.slos: List[SLO] = []
        self.events: List[dict] = []

    def add(self, name: str, bad: Source, total: Source,
            budget: float = 0.01, windows: Sequence[float] = (5.0, 60.0),
            threshold: float = 1.0, min_events: int = 1) -> SLO:
        slo = SLO(name, bad, total, budget=budget, windows=windows,
                  threshold=threshold, min_events=min_events)
        self.slos.append(slo)
        return slo

    # -- evaluation -----------------------------------------------------

    def _burns(self, slo: SLO, t: float) -> Optional[Dict[float, float]]:
        """Burn rate per window, or ``None`` while no window is
        covered by history yet."""
        burns: Dict[float, float] = {}
        for w in slo.windows:
            base = None
            for s in reversed(slo.ring):
                if s[0] <= t - w:
                    base = s
                    break
            if base is None:
                # window not covered yet: fall back to the oldest
                # sample once history spans at least the window
                oldest = slo.ring[0]
                if t - oldest[0] < w:
                    return None
                base = oldest
            now = slo.ring[-1]
            dbad = now[1] - base[1]
            dtot = now[2] - base[2]
            frac = (dbad / dtot) if dtot >= max(slo.min_events, 1) else 0.0
            burns[w] = frac / slo.budget
        return burns

    def _emit(self, slo: SLO, kind: str, t: float,
              burns: Dict[float, float]) -> dict:
        event = {
            "slo": slo.name, "kind": kind, "t": t,
            "burns": {f"{w:g}s": b for w, b in burns.items()},
            "budget": slo.budget, "threshold": slo.threshold,
        }
        if len(self.events) < self.max_events:
            self.events.append(event)
        reg = self.registry
        reg.counter(f"slo.{slo.name}.{kind}").inc()
        reg.gauge(f"slo.{slo.name}.active").set(1 if kind == "fired" else 0)
        # instantaneous tracer event: alerts line up with stage spans
        TRACER.record(f"slo.{slo.name}.{kind}", "slo", _now_ns(), 0,
                      {k: round(v, 3) for k, v in event["burns"].items()})
        # flight recorder: every transition lands in the black box; a
        # burn *firing* freezes a debug bundle (rate-limited, no-op
        # unless the recorder is armed)
        from .flight import FLIGHT   # deferred: avoids an import cycle
        FLIGHT.note(f"slo.{kind}", slo=slo.name, t=t,
                    burns=event["burns"])
        if kind == "fired":
            FLIGHT.trigger(f"slo-{slo.name}", detail=event)
        return event

    def tick(self, t: Optional[float] = None) -> List[dict]:
        """Snapshot every SLO's sources and evaluate; returns the alert
        transitions (fired/cleared) this tick produced."""
        t = self._clock() if t is None else float(t)
        reg = self.registry
        out: List[dict] = []
        for slo in self.slos:
            slo.ring.append((t, _resolve(slo.bad, reg),
                             _resolve(slo.total, reg)))
            burns = self._burns(slo, t)
            if burns is None:
                continue
            reg.gauge(f"slo.{slo.name}.burn").set(max(burns.values()))
            firing = all(b > slo.threshold for b in burns.values())
            if firing and not slo.active:
                slo.active = True
                out.append(self._emit(slo, "fired", t, burns))
            elif not firing and slo.active:
                slo.active = False
                out.append(self._emit(slo, "cleared", t, burns))
        return out

    def active(self) -> Dict[str, dict]:
        """Currently-firing SLOs -> their latest fired event."""
        fired = {}
        for e in self.events:
            if e["kind"] == "fired":
                fired[e["slo"]] = e
        return {s.name: fired.get(s.name, {"slo": s.name, "kind": "fired"})
                for s in self.slos if s.active}

    def snapshot(self) -> dict:
        return {
            "slos": [{"name": s.name, "budget": s.budget,
                      "windows": list(s.windows),
                      "threshold": s.threshold, "active": s.active}
                     for s in self.slos],
            "active": sorted(self.active()),
            "events": list(self.events),
        }


def default_slos(monitor: SLOMonitor,
                 latency_slo_us: float = 50_000.0,
                 windows: Sequence[float] = (5.0, 60.0)) -> SLOMonitor:
    """The serving stack's standard objectives, wired to the counters
    the frontend and resilience layers already maintain:

    * ``availability`` — typed-error rejections (Overloaded sheds,
      DeadlineExceeded drops, QueueFull timeouts) vs accepted requests;
    * ``degraded``     — queries answered by the exact host fallback
      (breaker open / retries exhausted) vs requests;
    * ``breaker``      — circuit-breaker open transitions vs requests;
    * ``latency``      — frontend queue waits at or above
      ``latency_slo_us`` vs everything the wait histogram recorded.
    """

    def _bad_availability(reg: _metrics.Registry) -> float:
        return (reg.counter("frontend.shed").value
                + reg.counter("frontend.deadline_dropped").value
                + reg.counter("frontend.queue_full_timeouts").value)

    def _breaker_opens(reg: _metrics.Registry) -> float:
        return sum(reg.counter(n).value for n in reg.names()
                   if n.startswith("resilience.breaker.")
                   and n.endswith(".opened"))

    monitor.add("availability", _bad_availability, "frontend.requests",
                budget=0.01, windows=windows)
    monitor.add("degraded", "resilience.fallback_queries",
                "frontend.requests", budget=0.05, windows=windows)
    monitor.add("breaker", _breaker_opens, "frontend.requests",
                budget=0.001, windows=windows)
    monitor.add("latency", latency_above("frontend.queue_wait_us",
                                         latency_slo_us),
                hist_count("frontend.queue_wait_us"),
                budget=0.05, windows=windows)
    return monitor
