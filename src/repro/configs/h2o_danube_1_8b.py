"""h2o-danube-1.8b [dense]: 24L d=2560 32H (kv=8, head_dim=80) d_ff=6912
vocab=32000, llama+mistral mix, all-layer SWA window 4096.
[arXiv:2401.16818]"""
from ..models.lm import LMConfig
from .base import ArchSpec, lm_cells

NAME = "h2o-danube-1.8b"


def make_config(reduced: bool = False, dtype: str = "bfloat16") -> LMConfig:
    if reduced:
        return LMConfig(
            name=NAME + "-reduced", n_layers=4, d_model=64, n_heads=8,
            n_kv_heads=2, head_dim=8, d_ff=128, vocab=512, window=16,
            layer_schedule="L", dtype="float32",
        )
    return LMConfig(
        name=NAME, n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
        head_dim=80, d_ff=6912, vocab=32000, window=4096,
        layer_schedule="L", dtype=dtype,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        name=NAME, family="lm", make_config=make_config,
        cells=lm_cells(NAME, make_config),
        notes="pure SWA: 500k decode touches only a 4096-token ring per "
              "layer; head_dim=80 is not 128-aligned (roofline shows the "
              "MXU padding tax)",
    )
