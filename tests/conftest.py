import numpy as np
import pytest

# hypothesis is an optional test dependency (declared in pyproject's
# ``test`` extra).  When it is absent the property-based tests are
# skipped and everything else still collects and runs.
try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("repro")
except ImportError:  # pragma: no cover - exercised on minimal installs

    class _StubStrategies:
        """Accepts any strategy constructor call at decoration time."""

        def __getattr__(self, _name):
            def _strategy(*_args, **_kwargs):
                return None

            return _strategy

    st = _StubStrategies()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        """No-op decorator so ``@settings(...)`` stacks on the skip."""
        def _wrap(fn):
            return fn

        return _wrap


def random_geosocial(rng: np.random.Generator, n: int, m: int,
                     spatial_frac: float = 0.35, sink_bias: float = 0.8):
    """Random geosocial graph; most spatial vertices become sinks (the
    LBSN data model) but not all (general model paths get exercised)."""
    from repro.core import make_graph

    edges = rng.integers(0, n, size=(m, 2))
    spatial = rng.random(n) < spatial_frac
    drop = spatial[edges[:, 0]] & (rng.random(m) < sink_bias)
    coords = (rng.random((n, 2)) * 100).astype(np.float32)
    return make_graph(n, edges[~drop], coords, spatial)


def random_queries(rng, g, n_q: int):
    ext = g.spatial_extent()
    w = max(ext[2] - ext[0], 1e-3)
    h = max(ext[3] - ext[1], 1e-3)
    us = rng.integers(0, g.n_nodes, size=n_q)
    cx = rng.random(n_q) * w + ext[0]
    cy = rng.random(n_q) * h + ext[1]
    hw = rng.random(n_q) * w * 0.3
    hh = rng.random(n_q) * h * 0.3
    rects = np.stack([cx - hw, cy - hh, cx + hw, cy + hh], axis=1)
    return us, rects.astype(np.float32)
