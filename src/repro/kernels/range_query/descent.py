"""Pallas TPU kernels: hierarchically-pruned RangeReach descent.

The legacy ``range_query`` kernel scans *every* leaf tile of the entry
arena for every query tile — correct, but pointer-chasing-era wasteful
once the forest grows.  This module is the device equivalent of an
R-tree descent, split into two phases so each phase is a dense,
tile-shaped kernel:

* **Phase 1 — prune** (``prune_tiles_pallas``): the entry arena is
  covered by a *tile pyramid*: one MBR per ``TP``-entry leaf tile
  (``fine``) and one MBR per ``COARSE_GROUP`` leaf tiles (``coarse``) —
  exactly the internal levels of an R-tree with fanout ``TP`` re-based
  onto the global arena so tiles align with the scan kernel's blocks.
  The kernel ANDs each query rect against the coarse level first (a
  ``pl.when`` gate skips the fine-level test for grid steps whose
  coarse MBRs miss every query of the block), then against the fine
  level and the query's ``[qstart, qend)`` arena slice.  Output: a
  per-(query-tile, leaf-tile) activity mask.

* **Phase 2 — masked scan** (``descent_scan_pallas``): a scalar-prefetch
  grid ``(B/TB, K)`` walks a *compacted candidate list* of leaf tiles
  per query tile (active tiles first, then the last active tile
  repeated — consecutive identical block indices elide the DMA), so
  only ``K`` tiles are fetched per query tile instead of all ``P/TP``.
  Scanning a superfluous tile is harmless: the leaf test re-masks by
  arena slice and exact box intersection, and the OR-accumulate is
  idempotent — exactness never depends on the mask.

Both kernels run under ``interpret=True`` on CPU; on TPU the same calls
compile to real kernels (the coarse plane's narrow lane blocks are an
interpret-mode convenience — pad ``COARSE_GROUP`` to 1 on TPU to keep
blocks lane-aligned if the compiler objects).
"""

from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .kernel import TB, TP

TPT = 128        # fine-tile lanes per prune-kernel block
COARSE_GROUP = 8  # leaf tiles per coarse pyramid node


# --------------------------------------------------------------------------
# Tile pyramid (host, once per index upload)
# --------------------------------------------------------------------------

def build_tile_pyramid(
    entries_soa: np.ndarray, dim: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Aggregate SoA leaf entries into (fine, coarse) MBR planes.

    ``entries_soa`` is the (2*dim, Pp) plane layout of ``forest_to_soa``
    with Pp a multiple of TP; padding entries are impossible boxes
    (min > max) and only ever make tile MBRs *more* permissive along the
    axes they touch, so pruning stays conservative and phase-2 masking
    keeps it exact.

    Returns (fine_soa (2*dim, NTp), coarse_soa (2*dim, NCp), n_tiles)
    where n_tiles = Pp // TP is the true fine tile count, NTp rounds it
    up to TPT lanes and NCp rounds the coarse count up to
    TPT // COARSE_GROUP.
    """
    two_dim, Pp = entries_soa.shape
    assert two_dim == 2 * dim and Pp % TP == 0
    nt = Pp // TP
    tiled = entries_soa.reshape(two_dim, nt, TP)
    fine = np.empty((two_dim, nt), dtype=np.float32)
    fine[:dim] = tiled[:dim].min(axis=2)
    fine[dim:] = tiled[dim:].max(axis=2)

    nc = -(-nt // COARSE_GROUP)
    pad_f = nc * COARSE_GROUP
    fpad = np.empty((two_dim, pad_f), dtype=np.float32)
    fpad[:dim] = np.inf
    fpad[dim:] = -np.inf
    fpad[:, :nt] = fine
    grouped = fpad.reshape(two_dim, nc, COARSE_GROUP)
    coarse = np.empty((two_dim, nc), dtype=np.float32)
    coarse[:dim] = grouped[:dim].min(axis=2)
    coarse[dim:] = grouped[dim:].max(axis=2)

    ntp = max(TPT, -(-nt // TPT) * TPT)
    ncp = ntp // COARSE_GROUP
    # padding tiles can never intersect: min=+inf / max=-inf (extent-proof,
    # unlike a finite sentinel)
    fine_soa = np.empty((two_dim, ntp), dtype=np.float32)
    fine_soa[:dim] = np.inf
    fine_soa[dim:] = -np.inf
    fine_soa[:, :nt] = fine
    coarse_soa = np.empty((two_dim, ncp), dtype=np.float32)
    coarse_soa[:dim] = np.inf
    coarse_soa[dim:] = -np.inf
    coarse_soa[:, :nc] = coarse
    return fine_soa, coarse_soa, nt


# --------------------------------------------------------------------------
# Phase 1: hierarchical prune
# --------------------------------------------------------------------------

def _prune_kernel(f_ref, c_ref, q_ref, qs_ref, qe_ref, o_ref, *, dim: int,
                  tpt: int, tp: int, group: int):
    j = pl.program_id(1)
    q = q_ref[...]                       # (2*dim, TB)
    qs = qs_ref[...][:, None]            # (TB, 1)
    qe = qe_ref[...][:, None]

    # -- coarse level: internal MBRs gate the whole block ------------------
    c = c_ref[...]                       # (2*dim, tpt//group)
    cok = jnp.ones((q.shape[1], c.shape[1]), dtype=bool)
    for a in range(dim):
        cok = cok & (c[a][None, :] <= q[dim + a][:, None])
        cok = cok & (c[dim + a][None, :] >= q[a][:, None])

    @pl.when(jnp.any(cok))
    def _descend():
        f = f_ref[...]                   # (2*dim, tpt)
        gidx = j * tpt + jax.lax.broadcasted_iota(jnp.int32, (1, tpt), 1)
        # arena-slice overlap: fine tile g covers entries [g*tp, g*tp+tp)
        ok = (gidx * tp < qe) & (gidx * tp + tp > qs)     # (TB, tpt)
        for a in range(dim):
            ok = ok & (f[a][None, :] <= q[dim + a][:, None])
            ok = ok & (f[dim + a][None, :] >= q[a][:, None])
        ncg = tpt // group
        cexp = jnp.broadcast_to(
            cok[:, :, None], (cok.shape[0], ncg, group)
        ).reshape(cok.shape[0], tpt)
        ok = ok & cexp
        o_ref[...] = jnp.any(ok, axis=0).astype(jnp.int32)[None, :]

    @pl.when(~jnp.any(cok))
    def _pruned():
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(
    jax.jit, static_argnames=("dim", "interpret", "tb", "tpt", "tp", "group")
)
def prune_tiles_pallas(
    fine_soa: jax.Array,     # (2*dim, NTp) float32, NTp % tpt == 0
    coarse_soa: jax.Array,   # (2*dim, NTp // group) float32
    rects_soa: jax.Array,    # (2*dim, B) float32, B % tb == 0
    qstart: jax.Array,       # (B,) int32
    qend: jax.Array,         # (B,) int32
    *,
    dim: int = 2,
    interpret: bool = False,
    tb: int = TB,
    tpt: int = TPT,
    tp: int = TP,
    group: int = COARSE_GROUP,
) -> jax.Array:
    """(B // tb, NTp) int32 — 1 iff any query of tile i needs leaf tile j."""
    two_dim, ntp = fine_soa.shape
    _, B = rects_soa.shape
    assert two_dim == 2 * dim
    assert ntp % tpt == 0 and B % tb == 0, (ntp, B)
    assert coarse_soa.shape == (two_dim, ntp // group)
    nb = B // tb
    grid = (nb, ntp // tpt)
    return pl.pallas_call(
        functools.partial(
            _prune_kernel, dim=dim, tpt=tpt, tp=tp, group=group
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((two_dim, tpt), lambda i, j: (0, j)),
            pl.BlockSpec((two_dim, tpt // group), lambda i, j: (0, j)),
            pl.BlockSpec((two_dim, tb), lambda i, j: (0, i)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, tpt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, ntp), jnp.int32),
        interpret=interpret,
    )(fine_soa, coarse_soa, rects_soa, qstart, qend)


def prune_tiles_ref(fine_soa, coarse_soa, rects_soa, qstart, qend, *,
                    dim: int = 2, tb: int = TB, tp: int = TP,
                    group: int = COARSE_GROUP):
    """Dense jnp oracle for ``prune_tiles_pallas`` (same contract)."""
    ntp = fine_soa.shape[1]
    B = rects_soa.shape[1]
    gidx = jnp.arange(ntp, dtype=jnp.int32)[None, :]
    ok = (gidx * tp < qend[:, None]) & (gidx * tp + tp > qstart[:, None])
    for a in range(dim):
        ok = ok & (fine_soa[a][None, :] <= rects_soa[dim + a][:, None])
        ok = ok & (fine_soa[dim + a][None, :] >= rects_soa[a][:, None])
    cok = jnp.ones((B, ntp // group), dtype=bool)
    for a in range(dim):
        cok = cok & (coarse_soa[a][None, :] <= rects_soa[dim + a][:, None])
        cok = cok & (coarse_soa[dim + a][None, :] >= rects_soa[a][:, None])
    ok = ok & jnp.repeat(cok, group, axis=1)
    return (
        jnp.any(ok.reshape(B // tb, tb, ntp), axis=1).astype(jnp.int32)
    )


# --------------------------------------------------------------------------
# Phase 2: masked leaf scan over compacted candidate tiles
# --------------------------------------------------------------------------

def _scan_kernel(cand_ref, e_ref, q_ref, qs_ref, qe_ref, o_ref, *, dim: int,
                 tp: int):
    i, k = pl.program_id(0), pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    e = e_ref[...]                        # (2*dim, TP) — the candidate tile
    q = q_ref[...]                        # (2*dim, TB)
    tile = cand_ref[i, k]
    gidx = tile * tp + jax.lax.broadcasted_iota(jnp.int32, (1, tp), 1)
    qs = qs_ref[...][:, None]
    qe = qe_ref[...][:, None]
    ok = (gidx >= qs) & (gidx < qe)       # (TB, TP)
    for a in range(dim):
        ok = ok & (e[a][None, :] <= q[dim + a][:, None])
        ok = ok & (e[dim + a][None, :] >= q[a][:, None])
    o_ref[...] = o_ref[...] | jnp.any(ok, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("dim", "interpret", "tb", "tp"))
def descent_scan_pallas(
    cand: jax.Array,          # (B // tb, K) int32 candidate leaf tiles
    entries_soa: jax.Array,   # (2*dim, P) float32, P % tp == 0
    rects_soa: jax.Array,     # (2*dim, B) float32, B % tb == 0
    qstart: jax.Array,        # (B,) int32
    qend: jax.Array,          # (B,) int32
    *,
    dim: int = 2,
    interpret: bool = False,
    tb: int = TB,
    tp: int = TP,
) -> jax.Array:
    """(B,) int32 0/1 — OR over the K candidate tiles of each query tile.

    ``cand`` values must lie in [0, P // tp); duplicates are harmless
    (idempotent OR) and padding by repeating the last active tile keeps
    consecutive identical block indices, which the pipeline fetches only
    once.
    """
    two_dim, P = entries_soa.shape
    _, B = rects_soa.shape
    assert two_dim == 2 * dim
    assert P % tp == 0 and B % tb == 0, (P, B)
    nb = B // tb
    K = cand.shape[1]
    assert cand.shape == (nb, K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, K),
        in_specs=[
            pl.BlockSpec((two_dim, tp), lambda i, k, cand: (0, cand[i, k])),
            pl.BlockSpec((two_dim, tb), lambda i, k, cand: (0, i)),
            pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
            pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i, k, cand: (i,)),
    )
    return pl.pallas_call(
        functools.partial(_scan_kernel, dim=dim, tp=tp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(cand, entries_soa, rects_soa, qstart, qend)
