"""Circuit breaker: closed → open → half-open with a single probe.

A breaker guards one failure domain (a whole engine, or one shard of a
sharded engine).  Closed, calls flow; ``failure_threshold`` consecutive
failures open it.  Open, calls are refused outright — the resilient
engine degrades to the exact host path instead of hammering a dead
device — until ``reset_timeout_s`` elapses, when the breaker turns
half-open and admits exactly **one probe** call at a time:
``half_open_successes`` consecutive probe successes close it, any probe
failure re-opens it (restarting the timeout).

State changes are decided against an injectable monotonic clock (chaos
tests step time deterministically) and reported as a registry gauge
(``resilience.breaker.<name>.state``: 0 closed / 1 open / 2 half-open)
plus open/close transition counters.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

from ..obs import metrics as obs_metrics

CLOSED, OPEN, HALF_OPEN = 0, 1, 2
STATE_NAMES = {CLOSED: "closed", OPEN: "open", HALF_OPEN: "half_open"}


@dataclasses.dataclass(frozen=True)
class BreakerPolicy:
    failure_threshold: int = 3      # consecutive failures that open
    reset_timeout_s: float = 1.0    # open -> half-open after this
    half_open_successes: int = 1    # probe successes that close

    def __post_init__(self):
        if self.failure_threshold < 1 or self.half_open_successes < 1:
            raise ValueError("thresholds must be >= 1")
        if self.reset_timeout_s < 0:
            raise ValueError("reset_timeout_s must be >= 0")


class CircuitBreaker:
    """One failure domain's breaker; thread-safe.

    Call :meth:`allow` before attempting; when it returns True the
    caller *must* report the outcome via :meth:`record_success` /
    :meth:`record_failure` (a half-open probe slot stays taken until
    its outcome arrives, so concurrent callers during a probe are
    refused rather than stampeding the recovering domain).
    """

    def __init__(self, name: str, policy: Optional[BreakerPolicy] = None,
                 clock: Callable[[], float] = time.monotonic,
                 registry: Optional[obs_metrics.Registry] = None):
        self.name = name
        self.policy = policy or BreakerPolicy()
        self._clock = clock
        self._lock = threading.Lock()
        self._state = CLOSED
        self._failures = 0           # consecutive, while closed
        self._probe_successes = 0    # consecutive, while half-open
        self._probe_inflight = False
        self._opened_at = 0.0
        reg = registry if registry is not None else obs_metrics.REGISTRY
        self._g_state = reg.gauge(f"resilience.breaker.{name}.state")
        self._c_opened = reg.counter(f"resilience.breaker.{name}.opened")
        self._c_closed = reg.counter(f"resilience.breaker.{name}.closed")
        self._c_refused = reg.counter(f"resilience.breaker.{name}.refused")

    # -- state ----------------------------------------------------------

    @property
    def state(self) -> int:
        with self._lock:
            self._maybe_half_open()
            return self._state

    @property
    def state_name(self) -> str:
        return STATE_NAMES[self.state]

    def _set_state(self, s: int) -> None:
        self._state = s
        self._g_state.set(s)

    def _maybe_half_open(self) -> None:
        if self._state == OPEN and (
                self._clock() - self._opened_at >= self.policy.reset_timeout_s):
            self._set_state(HALF_OPEN)
            self._probe_successes = 0
            self._probe_inflight = False

    def _open(self) -> None:
        self._set_state(OPEN)
        self._opened_at = self._clock()
        self._failures = 0
        self._probe_inflight = False
        self._c_opened.inc()

    # -- protocol -------------------------------------------------------

    def allow(self) -> bool:
        """May the caller attempt the guarded call right now?"""
        with self._lock:
            self._maybe_half_open()
            if self._state == CLOSED:
                return True
            if self._state == HALF_OPEN and not self._probe_inflight:
                self._probe_inflight = True   # this caller is the probe
                return True
            self._c_refused.inc()
            return False

    def record_success(self) -> None:
        with self._lock:
            if self._state == CLOSED:
                self._failures = 0
                return
            if self._state == HALF_OPEN:
                self._probe_inflight = False
                self._probe_successes += 1
                if self._probe_successes >= self.policy.half_open_successes:
                    self._set_state(CLOSED)
                    self._failures = 0
                    self._c_closed.inc()

    def record_failure(self) -> None:
        opened = False
        with self._lock:
            if self._state == HALF_OPEN:
                self._open()                  # failed probe: back to open
                opened = True
            elif self._state == CLOSED:
                self._failures += 1
                if self._failures >= self.policy.failure_threshold:
                    self._open()
                    opened = True
        if opened:
            self._notify_opened()

    def release(self) -> None:
        """An ``allow()`` grant went unused (no call was made): free the
        half-open probe slot without counting an outcome."""
        with self._lock:
            if self._state == HALF_OPEN:
                self._probe_inflight = False

    def trip(self) -> None:
        """Force the breaker open (ops switch / degraded-bench arm)."""
        with self._lock:
            self._open()
        self._notify_opened()

    def _notify_opened(self) -> None:
        """Black-box + bundle trigger for an open transition — called
        *after* the state lock is released so bundle writing never
        happens under a lock the serve path contends on."""
        from ..obs.flight import FLIGHT   # deferred: keeps import light
        FLIGHT.note("breaker.opened", name=self.name,
                    opens=int(self._c_opened.value))
        FLIGHT.trigger("breaker-open", detail={"breaker": self.name})
