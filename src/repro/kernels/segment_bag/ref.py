"""Pure-jnp oracle for segment_bag — also the sharded production path."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_bag_ref(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    segments: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    n_segments: int,
) -> jnp.ndarray:
    rows = jnp.take(table, indices, axis=0) * weights[:, None]
    out = jax.ops.segment_sum(rows, segments, num_segments=n_segments + 1)
    return out[:n_segments]
