"""All assigned architectures, importable by --arch id."""
from . import (
    deepseek_v3_671b,
    dimenet,
    din,
    equiformer_v2,
    gemma2_2b,
    gemma3_12b,
    graphcast,
    h2o_danube_1_8b,
    llama4_maverick_400b_a17b,
    schnet,
)

_MODULES = (
    llama4_maverick_400b_a17b,
    deepseek_v3_671b,
    gemma3_12b,
    h2o_danube_1_8b,
    gemma2_2b,
    graphcast,
    dimenet,
    equiformer_v2,
    schnet,
    din,
)

ARCHS = {m.NAME: m for m in _MODULES}


def arch_names():
    return tuple(ARCHS)


def get_arch(name: str):
    return ARCHS[name].spec()


def all_cells():
    """[(arch, shape, Cell)] — the 40 dry-run cells."""
    out = []
    for name in ARCHS:
        spec = get_arch(name)
        for shape, cell in spec.cells.items():
            out.append((name, shape, cell))
    return out
