"""SchNet (Schütt et al., 2017) — continuous-filter convolutions.

n_interactions=3, d_hidden=64, rbf=300 Gaussians, cutoff=10 (the assigned
config).  cfconv: filter W(r_ij) from an RBF-MLP, message h_j * W(r_ij),
segment-sum aggregation, atom-wise dense layers with shifted softplus.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..nn import ACT, Params, dense, dense_init, embed_init, mlp, mlp_init
from .common import edge_vectors, gaussian_rbf, masked_graph_readout, seg_sum


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    d_feat: Optional[int] = None   # set for feature-input graphs (no species)


def init_params(key, cfg: SchNetConfig) -> Params:
    ks = jax.random.split(key, 3 + cfg.n_interactions)
    d = cfg.d_hidden
    p: Params = {}
    if cfg.d_feat is not None:
        p["enc"] = dense_init(ks[0], cfg.d_feat, d)
    else:
        p["embed"] = embed_init(ks[0], cfg.n_species, d)
    for i in range(cfg.n_interactions):
        k1, k2, k3, k4 = jax.random.split(ks[1 + i], 4)
        p[f"int{i}"] = {
            "filter": mlp_init(k1, (cfg.n_rbf, d, d)),
            "in2f": dense_init(k2, d, d, bias=False),
            "f2out": mlp_init(k3, (d, d, d)),
        }
    p["out"] = mlp_init(ks[-1], (d, d // 2, 1))
    return p


def apply(params: Params, batch: Dict, cfg: SchNetConfig) -> jnp.ndarray:
    """Returns per-graph scalar (energy)."""
    pos = batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    N = pos.shape[0]
    if cfg.d_feat is not None:
        h = dense(params["enc"], batch["feat"])
    else:
        h = jnp.take(params["embed"]["emb"], batch["species"], axis=0)
    _, dist = edge_vectors(pos, src, dst)
    rbf = gaussian_rbf(dist, cfg.n_rbf, cfg.cutoff)
    if emask is not None:
        rbf = rbf * emask[:, None].astype(rbf.dtype)
    for i in range(cfg.n_interactions):
        ip = params[f"int{i}"]
        w = mlp(ip["filter"], rbf, act="ssp", final_act="ssp")   # (E, d)
        m = dense(ip["in2f"], h)[src] * w
        agg = seg_sum(m, dst, N)
        h = h + mlp(ip["f2out"], agg, act="ssp")
    out = mlp(params["out"], h, act="ssp")                        # (N, 1)
    return masked_graph_readout(out, batch.get("node_mask"))[0]


def loss_fn(params: Params, batch: Dict, cfg: SchNetConfig) -> jnp.ndarray:
    """Batched-molecule MSE (vmap over leading batch dim)."""
    pred = jax.vmap(lambda b: apply(params, b, cfg))(batch)
    return jnp.mean((pred - batch["energy"]) ** 2)
