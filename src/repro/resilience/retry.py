"""Deadline budgets and bounded retry with decorrelated-jitter backoff.

Two small, deterministic pieces the resilient engine composes:

* :class:`Deadline` — a per-request time budget on an injectable
  monotonic clock.  Everything downstream (retry sleeps, fallback
  decisions, queue-wait projections) asks the same object "how much
  budget is left", so a request can never sleep past its own deadline.
* :class:`RetryPolicy` — attempt count plus exponential backoff with
  **decorrelated jitter** (`sleep = min(cap, uniform(base, 3·prev))`,
  the AWS-architecture variant): retries from many callers de-correlate
  instead of thundering back in lockstep, while the cap bounds the
  worst case.  The rng is injectable, so tests replay exact schedules.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional

import numpy as np

from .errors import DeadlineExceeded


class Deadline:
    """Absolute time budget on a monotonic clock.

    ``Deadline(None)`` is the unlimited budget (``remaining() == inf``,
    never expires) so call sites need no None-handling.
    """

    __slots__ = ("_t_end", "_clock")

    def __init__(self, budget_s: Optional[float],
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._t_end = (math.inf if budget_s is None
                       else clock() + float(budget_s))

    @classmethod
    def none(cls) -> "Deadline":
        return cls(None)

    def remaining(self) -> float:
        return self._t_end - self._clock()

    def expired(self) -> bool:
        return self._clock() >= self._t_end

    def check(self, what: str = "request") -> None:
        if self.expired():
            raise DeadlineExceeded(f"{what} deadline budget exhausted")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry schedule for transient serving failures.

    ``max_attempts`` counts the first try too (1 = no retry).  Backoff
    is decorrelated jitter: the next sleep is drawn uniformly from
    ``[base_s, 3 * previous_sleep]`` and clipped to ``cap_s``.
    """

    max_attempts: int = 3
    base_s: float = 1e-3
    cap_s: float = 50e-3

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"need max_attempts >= 1, got {self.max_attempts}")
        if not (0 < self.base_s <= self.cap_s):
            raise ValueError(
                f"need 0 < base_s <= cap_s, got {self.base_s}/{self.cap_s}")

    def next_backoff(self, prev_s: float,
                     rng: np.random.Generator) -> float:
        """Sleep before the next attempt, given the previous sleep
        (pass 0.0 before the first retry)."""
        hi = max(self.base_s, 3.0 * prev_s)
        return float(min(self.cap_s, rng.uniform(self.base_s, hi)))

    def schedule(self, rng: np.random.Generator) -> list:
        """The full (deterministic, given ``rng``) backoff schedule —
        ``max_attempts - 1`` sleeps; used by tests and docs."""
        out, prev = [], 0.0
        for _ in range(self.max_attempts - 1):
            prev = self.next_backoff(prev, rng)
            out.append(prev)
        return out
