"""GeoReach baseline (Sun & Sarwat 2016) — SPA-graph pruned traversal.

The first dedicated RangeReach method: every vertex carries precomputed
spatial-reachability summaries and the query *traverses the graph*,
pruning branches whose summary cannot intersect the region.  We implement
the B (reachability bit) and R (reachability MBR) tiers of the SPA-graph,
computed per SCC component (all members share a summary) via the same
reverse-topological closure substrate as 2DReach — only tracking 4-float
MBRs instead of bitsets.

The traversal runs on the condensation (equivalent to the vertex-level
SPA-graph walk but strictly less work) and exhibits exactly the failure
mode the paper describes: when the answer is negative or the graph has
many components, large portions of the DAG must be explored.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict

import numpy as np

from .condensation import Condensation, condense
from .graph import GeosocialGraph
from .reachability import closure_mbr_np
from .scc import scc_np


@dataclasses.dataclass
class GeoReachIndex:
    n: int
    cond: Condensation
    comp_mbr: np.ndarray        # (d, 4) reachability MBR per component
    dag_indptr: np.ndarray      # DAG out-edge CSR
    dag_adj: np.ndarray
    own_indptr: np.ndarray      # per-comp own spatial vertex CSR
    own_pts: np.ndarray         # (k, 2) coordinates aligned with own CSR
    stats: Dict[str, float]
    _visit_stamp: np.ndarray = dataclasses.field(default=None, repr=False)
    _stamp: int = 0

    def nbytes_spatial(self) -> int:
        """Spatial-structure bytes: the R-MBR summaries plus the
        per-component venue point lists (GeoReach's stand-in for the
        R-tree column of the paper's Table 4)."""
        return int(
            self.comp_mbr.nbytes + self.own_indptr.nbytes
            + self.own_pts.nbytes
        )

    def nbytes_social(self) -> int:
        """Social-side bytes: the condensation DAG the query traverses."""
        return int(self.dag_indptr.nbytes + self.dag_adj.nbytes)

    def nbytes_total(self) -> int:
        return self.nbytes_spatial() + self.nbytes_social()

    def query(self, u: int, rect) -> bool:
        """DFS over the condensation with R-MBR pruning."""
        xmin, ymin, xmax, ymax = (float(v) for v in rect)
        c0 = int(self.cond.comp[u])
        if c0 < 0:
            return False
        if self._visit_stamp is None or len(self._visit_stamp) != self.cond.n_comps:
            self._visit_stamp = np.zeros(self.cond.n_comps, dtype=np.int64)
            self._stamp = 0
        self._stamp += 1
        stamp = self._stamp
        vis = self._visit_stamp
        mbr = self.comp_mbr
        indptr, adj = self.dag_indptr, self.dag_adj
        oi, op = self.own_indptr, self.own_pts
        stack = [c0]
        vis[c0] = stamp
        explored = 0
        while stack:
            c = stack.pop()
            explored += 1
            # R tier prune: reachability MBR disjoint from region
            if (
                mbr[c, 0] > xmax or mbr[c, 2] < xmin
                or mbr[c, 1] > ymax or mbr[c, 3] < ymin
            ):
                continue
            # own spatial members inside the region?
            s, e = oi[c], oi[c + 1]
            if s < e:
                pts = op[s:e]
                if (
                    (pts[:, 0] >= xmin) & (pts[:, 0] <= xmax)
                    & (pts[:, 1] >= ymin) & (pts[:, 1] <= ymax)
                ).any():
                    self.stats["last_explored"] = float(explored)
                    return True
            for ch in adj[indptr[c]:indptr[c + 1]]:
                if vis[ch] != stamp:
                    vis[ch] = stamp
                    stack.append(ch)
        self.stats["last_explored"] = float(explored)
        return False

    def query_batch(self, us: np.ndarray, rects: np.ndarray) -> np.ndarray:
        us = np.asarray(us, dtype=np.int64)
        rects = np.asarray(rects, dtype=np.float32).reshape(len(us), 4)
        return np.array(
            [self.query(int(u), r) for u, r in zip(us, rects)], dtype=bool
        )


def build_georeach(graph: GeosocialGraph) -> GeoReachIndex:
    t_start = time.perf_counter()
    stats: Dict[str, float] = {}
    n = graph.n_nodes

    t0 = time.perf_counter()
    labels = scc_np(n, graph.edges)
    cond = condense(n, graph.edges, labels)
    stats["t_scc"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    comp_mbr = closure_mbr_np(cond, graph.coords, graph.spatial_mask)
    stats["t_mbr_closure"] = time.perf_counter() - t0

    d = cond.n_comps
    # DAG CSR
    de = cond.dag_edges
    if de.size:
        order = np.argsort(de[:, 0], kind="stable")
        dag_indptr = np.zeros(d + 1, dtype=np.int64)
        np.cumsum(np.bincount(de[order, 0], minlength=d), out=dag_indptr[1:])
        dag_adj = de[order, 1].astype(np.int32)
    else:
        dag_indptr = np.zeros(d + 1, dtype=np.int64)
        dag_adj = np.zeros(0, dtype=np.int32)

    # own spatial members CSR
    sv = graph.spatial_ids
    c = cond.comp[sv]
    ok = c >= 0
    c, sv2 = c[ok], sv[ok]
    order = np.argsort(c, kind="stable")
    own_indptr = np.zeros(d + 1, dtype=np.int64)
    np.cumsum(np.bincount(c[order], minlength=d), out=own_indptr[1:])
    own_pts = graph.coords[sv2[order]]

    stats["t_total"] = time.perf_counter() - t_start
    return GeoReachIndex(
        n=n, cond=cond, comp_mbr=comp_mbr,
        dag_indptr=dag_indptr, dag_adj=dag_adj,
        own_indptr=own_indptr, own_pts=own_pts, stats=stats,
    )
