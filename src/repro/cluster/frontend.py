"""Micro-batching frontend: request queue -> engine-sized batches.

A serving node receives single RangeReach requests; the engines want
batches (the jit cache is keyed on power-of-two buckets, and per-query
overhead amortises across a tile).  :class:`Frontend` sits between:

* ``submit(u, rect)`` enqueues a request onto a **bounded** queue
  (backpressure: submit blocks while ``max_queue`` requests are
  pending) and returns a future;
* a scheduler thread flushes the queue into the engine on
  **deadline-or-full**: as soon as ``max_batch`` requests are pending,
  or when the oldest pending request has waited ``max_delay`` seconds —
  whichever comes first.  Flushed batches are at most ``max_batch``
  (keep it a power of two so steady state re-uses the engine's compiled
  buckets), and the engine's own bucket padding absorbs ragged tails.

The frontend is engine-agnostic: anything with a
``query_batch(us, rects) -> bool array`` works — the single-device
``QueryEngine``, the cluster ``ShardedEngine``, or a host index.
``warmup`` pre-traces every batch bucket the flush policy can produce,
so a steady-state stream recompiles nothing (asserted in tests via the
engine's ``n_compiles`` introspection).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..kernels.range_query.kernel import TB
from ..obs import metrics as obs_metrics
from ..obs import querylog as obs_querylog
from ..obs import span
from ..obs.tracer import TRACER as _TRACER


class Frontend:
    """Deadline-or-full micro-batch scheduler in front of a query engine.

    Parameters
    ----------
    engine:    anything with ``query_batch(us, rects)``.
    max_batch: flush as soon as this many requests are pending (keep it
               a power of two to reuse the engine's compiled buckets).
    max_delay: flush when the oldest pending request is this old (s).
    max_queue: bounded-queue capacity; ``submit`` blocks above it.
    metrics:   a :class:`repro.obs.Registry` for the frontend's gauges
               (queue depth, batch occupancy), counters (flushes by
               reason, deadline misses, backpressure blocks) and wait /
               lateness histograms; defaults to the global registry.
    query_log: a :class:`repro.obs.QueryLog` receiving one structured
               record per served request; ``None`` uses the global log
               when ``repro.obs`` is enabled (and skips logging when it
               is not, keeping the disabled fast path flat).
    clock:     monotonic time source (seconds) — injectable so load
               tests drive deadlines deterministically with a fake
               clock instead of sleeping.
    deadline_grace: lateness tolerance (s) before a flush that starts
               after ``enqueue + max_delay`` counts as a deadline miss;
               defaults to ``max_delay / 4`` (absorbs timer wakeup
               jitter without hiding real scheduler stalls).
    """

    def __init__(self, engine, max_batch: int = 256,
                 max_delay: float = 2e-3, max_queue: int = 8192,
                 metrics: Optional["obs_metrics.Registry"] = None,
                 query_log: Optional["obs_querylog.QueryLog"] = None,
                 clock: Optional[Callable[[], float]] = None,
                 deadline_grace: Optional[float] = None):
        if max_batch < 1 or max_queue < max_batch:
            raise ValueError(
                f"need 1 <= max_batch <= max_queue, got "
                f"{max_batch}/{max_queue}")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.max_queue = int(max_queue)
        self.metrics = metrics if metrics is not None else obs_metrics.REGISTRY
        self._query_log = query_log
        self._clock = clock if clock is not None else time.monotonic
        self.deadline_grace = (float(deadline_grace)
                               if deadline_grace is not None
                               else self.max_delay / 4.0)
        self._cond = threading.Condition()
        self._rect_len = None                 # fixed by the first submit
        self._pending: List[tuple] = []       # (u, rect, future, t_enq)
        self._inflight = False
        self._closed = False
        self._force = False
        self.stats: Dict[str, float] = {
            "n_requests": 0, "n_batches": 0, "n_flush_full": 0,
            "n_flush_deadline": 0, "n_flush_forced": 0,
            "batched_queries": 0, "max_pending_seen": 0,
            "n_deadline_misses": 0, "n_submit_blocked": 0,
        }
        m = self.metrics
        self._g_depth = m.gauge("frontend.queue_depth")
        self._g_occupancy = m.gauge("frontend.batch_occupancy")
        self._g_inflight = m.gauge("frontend.inflight")
        self._c_requests = m.counter("frontend.requests")
        self._c_misses = m.counter("frontend.deadline_misses")
        self._c_blocked = m.counter("frontend.submit_blocked")
        self._h_wait = m.histogram("frontend.queue_wait_us")
        self._h_lateness = m.histogram("frontend.flush_lateness_us")
        self._h_batch = m.histogram("frontend.batch_size")
        self._flush_counters = {
            r: m.counter(f"frontend.{r}")
            for r in ("n_flush_full", "n_flush_deadline", "n_flush_forced")
        }
        self._thread = threading.Thread(
            target=self._run, name="rangereach-frontend", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    # client surface
    # ------------------------------------------------------------------

    def submit(self, u: int, rect) -> "Future[bool]":
        """Enqueue one request; returns a future resolving to the answer.
        Blocks while the queue is at capacity (backpressure)."""
        fut: Future = Future()
        rect = np.asarray(rect, dtype=np.float32).ravel()
        with self._cond:
            # reject shape mismatches in the caller's thread — a ragged
            # rect must never reach batch assembly on the scheduler
            if self._rect_len is None:
                self._rect_len = len(rect)
            elif len(rect) != self._rect_len:
                raise ValueError(
                    f"rect has {len(rect)} coords, expected "
                    f"{self._rect_len}")
            if len(self._pending) >= self.max_queue and not self._closed:
                self.stats["n_submit_blocked"] += 1
                self._c_blocked.inc()
                while (len(self._pending) >= self.max_queue
                       and not self._closed):
                    self._cond.wait()
            if self._closed:
                raise RuntimeError("Frontend is closed")
            self._pending.append((int(u), rect, fut, self._clock()))
            self.stats["n_requests"] += 1
            self._c_requests.inc()
            depth = len(self._pending)
            self._g_depth.set(depth)
            self.stats["max_pending_seen"] = max(
                self.stats["max_pending_seen"], depth)
            self._cond.notify_all()
        return fut

    def submit_many(self, us: Sequence[int], rects,
                    timeout: Optional[float] = None) -> np.ndarray:
        """Submit a request stream one by one and gather the answers —
        the convenience used by benchmarks and examples."""
        rects = np.asarray(rects, dtype=np.float32)
        futs = [self.submit(u, r) for u, r in zip(us, rects)]
        return np.array([f.result(timeout=timeout) for f in futs],
                        dtype=bool)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Force-dispatch everything pending and wait until served."""
        with self._cond:
            self._force = True
            self._cond.notify_all()
            self._cond.wait_for(
                lambda: not self._pending and not self._inflight,
                timeout=timeout)
            # don't leak the flag onto requests submitted after the
            # flush completes (they should wait for deadline-or-full)
            self._force = False

    def warmup(self, us: np.ndarray, rects: np.ndarray) -> None:
        """Pre-trace every batch bucket the flush policy can produce,
        using a representative workload (tiled up to ``max_batch``)."""
        us = np.asarray(us, dtype=np.int64)
        rects = np.asarray(rects, dtype=np.float32).reshape(len(us), -1)
        reps = -(-self.max_batch // max(len(us), 1))
        us = np.tile(us, reps)
        rects = np.tile(rects, (reps, 1))
        b = TB
        while True:
            k = min(b, self.max_batch)
            self.engine.query_batch(us[:k], rects[:k])
            if b >= self.max_batch:
                break
            b <<= 1

    def close(self, timeout: Optional[float] = None) -> None:
        """Serve everything pending, then stop the scheduler thread."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "Frontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def mean_batch(self) -> float:
        b = self.stats["n_batches"]
        return self.stats["batched_queries"] / b if b else 0.0

    # ------------------------------------------------------------------
    # scheduler thread
    # ------------------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cond:
                while True:
                    if self._pending:
                        n = len(self._pending)
                        deadline = self._pending[0][3] + self.max_delay
                        now = self._clock()
                        if n >= self.max_batch:
                            reason = "n_flush_full"
                            break
                        if self._force or self._closed:
                            reason = "n_flush_forced"
                            break
                        if now >= deadline:
                            reason = "n_flush_deadline"
                            break
                        self._cond.wait(timeout=deadline - now)
                    elif self._closed:
                        return
                    else:
                        self._force = False
                        self._cond.wait()
                batch = self._pending[: self.max_batch]
                del self._pending[: self.max_batch]
                # flush lateness: how far past the oldest request's
                # deadline this batch starts serving; beyond the grace
                # it is a deadline miss (the scheduler could not keep
                # the latency SLO — usually an inflight batch ahead)
                lateness = max(0.0, self._clock() - deadline)
                self._g_depth.set(len(self._pending))
                if not self._pending:
                    self._force = False
                self._inflight = True
                self._g_inflight.set(1)
                self._cond.notify_all()       # queue space freed
            self._h_lateness.record(lateness * 1e6)
            if lateness > self.deadline_grace:
                self.stats["n_deadline_misses"] += 1
                self._c_misses.inc()
            self._serve(batch, reason)
            with self._cond:
                self._inflight = False
                self._g_inflight.set(0)
                self._cond.notify_all()

    def _serve(self, batch: List[tuple], reason: str) -> None:
        try:
            # assembly inside the latch too: no input may ever kill the
            # scheduler thread and strand the batch's futures
            with span("frontend.flush", cat="frontend", n=len(batch),
                      reason=reason):
                us = np.array([b[0] for b in batch], dtype=np.int64)
                rects = np.stack([b[1] for b in batch])
                ans = self.engine.query_batch(us, rects)
        except BaseException as e:  # latch the error onto every future
            for _, _, fut, _ in batch:
                try:
                    fut.set_exception(e)
                except InvalidStateError:   # client cancelled meanwhile
                    pass
            return
        self.stats["n_batches"] += 1
        self.stats[reason] += 1
        self.stats["batched_queries"] += len(batch)
        self._flush_counters[reason].inc()
        self._h_batch.record(len(batch))
        self._g_occupancy.set(len(batch) / self.max_batch)
        now = self._clock()
        for (_, _, fut, t_enq), a in zip(batch, ans):
            self._h_wait.record((now - t_enq) * 1e6)
            try:
                fut.set_result(bool(a))
            except InvalidStateError:       # client cancelled meanwhile
                pass
        self._log_batch(us, rects, ans, batch, now)

    def _log_batch(self, us, rects, ans, batch, now) -> None:
        """Structured query-log records for a served batch — explicit
        ``query_log`` always logs; otherwise the global log, only while
        ``repro.obs`` is enabled."""
        qlog = self._query_log
        if qlog is None:
            if not _TRACER.enabled:
                return
            qlog = obs_querylog.QUERY_LOG
        shard_of = getattr(self.engine, "shard_of", None)
        shards = (shard_of(us) if shard_of is not None
                  else np.zeros(len(us), dtype=np.int64))
        vclass = obs_querylog.vertex_class_of(self.engine, us)
        lats = [now - b[3] for b in batch]
        qlog.record_batch("reach", vclass, rects, shards, lats,
                          np.asarray(ans).astype(np.int64))
