"""Train SchNet on batched synthetic molecules; verify EquiformerV2's
exact rotation invariance on the same data.

    PYTHONPATH=src python examples/gnn_molecules.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import molecule_batches
from repro.models.gnn import equiformer_v2, schnet
from repro.train import AdamWConfig, adamw_init, make_train_step

cfg = schnet.SchNetConfig(n_interactions=2, d_hidden=32, n_rbf=30)
params = schnet.init_params(jax.random.PRNGKey(0), cfg)
step_fn = jax.jit(make_train_step(
    lambda p, b: (schnet.loss_fn(p, b, cfg), {}),
    AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=80)))
opt = adamw_init(params)

data = molecule_batches(n_nodes=12, n_edges=40, batch=16, seed=0)
losses = []
t0 = time.perf_counter()
for step in range(80):
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    params, opt, m = step_fn(params, opt, batch)
    losses.append(float(m["loss"]))
    if (step + 1) % 20 == 0:
        print(f"schnet step {step + 1:3d} mse {losses[-1]:.4f}")
print(f"schnet: {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f} "
      f"({time.perf_counter() - t0:.1f}s)")
assert np.mean(losses[-5:]) < np.mean(losses[:5])

# ---- EquiformerV2: energies are exactly rotation-invariant ---------------
ecfg = equiformer_v2.EquiformerV2Config(
    n_layers=2, d_hidden=16, l_max=3, m_max=2, n_heads=4, n_rbf=8)
ep = equiformer_v2.init_params(jax.random.PRNGKey(1), ecfg)
b = {k: jnp.asarray(v[0]) for k, v in next(data).items()}
e0 = float(equiformer_v2.apply(ep, b, ecfg))
rng = np.random.default_rng(0)
A = rng.standard_normal((3, 3))
Q, _ = np.linalg.qr(A)
if np.linalg.det(Q) < 0:
    Q[:, 0] *= -1
e1 = float(equiformer_v2.apply(
    ep, dict(b, pos=b["pos"] @ jnp.asarray(Q.T, jnp.float32)), ecfg))
print(f"equiformer-v2 energy {e0:.5f} vs rotated {e1:.5f} "
      f"(delta {abs(e0 - e1):.2e})")
assert abs(e0 - e1) < 1e-3
print("equivariance: OK")
