"""End-to-end training launcher with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
        --reduced --steps 100 --ckpt-dir /tmp/ckpt [--resume]

Any assigned LM/GNN/recsys arch runs; --reduced selects the smoke-scale
config (the full configs are exercised via the dry-run, not host CPU).
The loop demonstrates the production posture end-to-end: deterministic
step-keyed data, bounded-async checkpoints, restore-on-restart, and
crash-injection testing via --crash-at (used by tests/test_checkpoint).
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..data import ShardInfo, din_batches, lm_batches, molecule_batches
from ..distributed import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
)
from ..train import AdamWConfig, adamw_init, make_train_step


def build_reduced(arch_name: str):
    spec = get_arch(arch_name)
    cfg = spec.make_config(reduced=True)
    if spec.family == "lm":
        from ..models.lm import init_params, lm_loss

        params = init_params(jax.random.PRNGKey(0), cfg)
        loss = lambda p, b: lm_loss(p, b, cfg)
        data = lm_batches(cfg.vocab, 32, 8)
        to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
        return params, loss, data, to_dev
    if spec.family == "recsys":
        from ..models.recsys import din

        params = din.init_params(jax.random.PRNGKey(0), cfg)
        loss = lambda p, b: (din.loss_fn(p, b, cfg), {})
        data = din_batches(cfg.n_items, cfg.n_cates, cfg.seq_len, 64)
        to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
        return params, loss, data, to_dev
    # gnn: batched molecules
    import importlib

    mod = importlib.import_module(
        f"repro.models.gnn.{arch_name.replace('-', '_')}"
    )
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    n_nodes, n_edges = 12, 32
    data = molecule_batches(n_nodes, n_edges, 8)

    if arch_name == "dimenet":
        from ..models.gnn.dimenet import build_triplets

        def to_dev(b):
            B = b["pos"].shape[0]
            kj = np.zeros((B, 128), np.int32)
            ji = np.zeros((B, 128), np.int32)
            tm = np.zeros((B, 128), bool)
            for i in range(B):
                kj[i], ji[i], tm[i] = build_triplets(
                    b["edge_src"][i], b["edge_dst"][i], n_nodes, 128
                )
            b = dict(b, id_kj=kj, id_ji=ji, triplet_mask=tm)
            return {k: jnp.asarray(v) for k, v in b.items()}
    else:
        def to_dev(b):
            return {k: jnp.asarray(v) for k, v in b.items()}

    if arch_name == "graphcast":
        def loss(p, b):
            B = b["pos"].shape[0]
            f = cfg.n_vars
            bb = dict(b)
            key = jax.random.PRNGKey(1)
            bb["feat"] = jax.random.normal(
                key, (B, b["pos"].shape[1], f))
            bb["target"] = bb["feat"] * 0.9
            bb.pop("energy")
            return (jax.vmap(
                lambda x: mod.loss_fn(p, x, cfg))(bb).mean(), {})
    else:
        def loss(p, b):
            return (mod.loss_fn(p, b, cfg), {})
    return params, loss, data, to_dev


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="inject a crash after this step (testing)")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    params, loss_fn, data, to_dev = build_reduced(args.arch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(loss_fn, opt_cfg))

    start = 0
    if args.resume:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            (state, man) = restore_checkpoint(
                args.ckpt_dir, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = last
            print(f"[train] resumed from step {start}")

    mgr = CheckpointManager(args.ckpt_dir)
    # skip the pipeline forward to the resume point (step-keyed data)
    for _ in range(start):
        next(data)
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = to_dev(next(data))
        params, opt, metrics = step_fn(params, opt, batch)
        if (step + 1) % 10 == 0 or step == start:
            print(f"[train] step {step + 1} loss {float(metrics['loss']):.4f} "
                  f"({(time.perf_counter() - t0):.1f}s)")
        if (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt})
        if args.crash_at == step + 1:
            mgr.wait()
            raise RuntimeError(f"injected crash at step {step + 1}")
    mgr.save_async(args.steps, {"params": params, "opt": opt})
    mgr.close()
    print(f"[train] done: {args.steps} steps in "
          f"{time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
