"""LM internals: attention equivalences, decode==train consistency, MoE."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.lm import (
    LMConfig, MLASpec, MoESpec, decode_step, forward, init_params, prefill,
)
from repro.models.lm.attention import banded_attention, flash_attention
from repro.models.lm.moe import _expert_ffn_local, _routing, moe_ffn


def naive_attn(q, k, v, window=None, softcap=None):
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G, Hg = KV, H // KV
    qr = q.reshape(B, S, G, Hg, dh)
    s = jnp.einsum("bsghd,btgd->bghst", qr, k) * dh ** -0.5
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = i >= j
    if window is not None:
        mask = mask & (i - j < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bghst,btgd->bsghd", p, v).reshape(B, S, H, dh)


@pytest.mark.parametrize("blk", [16, 32])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_flash_matches_naive(blk, softcap):
    key = jax.random.PRNGKey(0)
    B, S, H, KV, dh = 2, 64, 4, 2, 16
    q, k, v = (
        jax.random.normal(jax.random.PRNGKey(i), s)
        for i, s in enumerate(
            [(B, S, H, dh), (B, S, KV, dh), (B, S, KV, dh)])
    )
    got = flash_attention(q, k, v, causal=True, blk_q=blk, blk_k=blk,
                          softcap=softcap)
    want = naive_attn(q, k, v, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("window", [4, 12, 64, 200])
def test_banded_matches_naive(window):
    B, S, H, KV, dh = 2, 64, 4, 2, 16
    q, k, v = (
        jax.random.normal(jax.random.PRNGKey(i + 5), s)
        for i, s in enumerate(
            [(B, S, H, dh), (B, S, KV, dh), (B, S, KV, dh)])
    )
    got = banded_attention(q, k, v, window=window, blk=16)
    want = naive_attn(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("flavor", ["gqa", "mla", "swa", "softcap"])
def test_prefill_decode_matches_forward(flavor):
    """Serving path: prefill(S) + decode == forward(S+1) last logits."""
    kw = dict(name="t", n_layers=3, d_model=48, n_heads=4, n_kv_heads=2,
              head_dim=12, d_ff=96, vocab=128)
    if flavor == "mla":
        kw.update(attn="mla", n_kv_heads=4,
                  mla=MLASpec(q_lora=24, kv_lora=16, qk_nope=12, qk_rope=8,
                              v_head=12))
    if flavor == "swa":
        kw.update(window=8, layer_schedule="L")
    if flavor == "softcap":
        kw.update(attn_softcap=30.0, final_softcap=20.0, window=8,
                  layer_schedule="LG")
    cfg = LMConfig(**kw)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 17
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab)
    # reference: full forward over S+1 tokens
    hidden, _ = forward(params, toks, cfg)
    from repro.models.lm.model import _head_weight, _softcap

    ref_logits = _softcap(
        (hidden[:, -1] @ _head_weight(params, cfg)).astype(jnp.float32),
        cfg.final_softcap)
    # serve: prefill S tokens, decode token S
    _, cache = prefill(params, toks[:, :S], cfg, max_len=S + 4)
    got_logits, _ = decode_step(params, cache, toks[:, S], cfg)
    np.testing.assert_allclose(
        np.asarray(got_logits), np.asarray(ref_logits), atol=2e-3,
        rtol=2e-3)


def test_moe_capacity_matches_dense_dispatch():
    """With generous capacity the packed path equals explicit per-expert
    computation."""
    E, k, d, f, T = 4, 2, 16, 32, 24
    cfg = MoESpec(n_experts=E, top_k=k, d_expert=f, balance_factor=8.0)
    key = jax.random.PRNGKey(0)
    from repro.models.lm.moe import moe_init

    p = moe_init(key, d, f, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
    w, e, _ = _routing(x, p["router"], cfg)
    got = _expert_ffn_local(x, w, e, p["w_gu"], p["w_d"], cfg, 0, E,
                            cap=T * k, act="silu")
    # dense reference
    want = jnp.zeros_like(x)
    for t in range(T):
        for j in range(k):
            ei = int(e[t, j])
            gu = x[t] @ p["w_gu"][ei]
            h = jax.nn.silu(gu[:f]) * gu[f:]
            want = want.at[t].add(w[t, j] * (h @ p["w_d"][ei]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


def test_moe_shard_map_matches_local():
    """EP shard_map path (1-device mesh) == direct local path."""
    E, k, d, f, T = 8, 2, 16, 24, 32
    cfg = MoESpec(n_experts=E, top_k=k, d_expert=f, n_shared=1, d_shared=32,
                  balance_factor=8.0)
    from repro.models.lm.moe import moe_init

    p = moe_init(jax.random.PRNGKey(0), d, 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
    out_local, aux_local = moe_ffn(p, x, cfg, mesh=None)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        out_sm, aux_sm = jax.jit(
            lambda p, x: moe_ffn(p, x, cfg, mesh=mesh))(p, x)
    np.testing.assert_allclose(
        np.asarray(out_local), np.asarray(out_sm), atol=1e-5)
    np.testing.assert_allclose(float(aux_local), float(aux_sm), atol=1e-5)


def test_scan_segments_cover_all_layers():
    for nl, sched, moe in [
        (48, "G", MoESpec(n_experts=4, top_k=1, d_expert=8, interleave=2)),
        (61, "G", MoESpec(n_experts=4, top_k=1, d_expert=8, first_dense=3)),
        (48, "LLLLLG", None),
        (26, "LG", None),
        (24, "L", None),
    ]:
        cfg = LMConfig(name="x", n_layers=nl, d_model=8, n_heads=2,
                       n_kv_heads=2, head_dim=4, d_ff=16, vocab=32,
                       layer_schedule=sched, moe=moe)
        segs = cfg.scan_segments()
        total = sum(len(unit) * n for unit, n in segs)
        assert total == nl, (sched, segs)


def test_param_counts_sane():
    from repro.configs import get_arch

    # deepseek-v3 ~671B total / ~37B active
    cfg = get_arch("deepseek-v3-671b").make_config()
    c = cfg.param_counts()
    assert 6.0e11 < c["total"] < 7.5e11, c
    assert 3.0e10 < c["active"] < 4.5e10, c
    # llama4 maverick ~400B total / ~17B active
    cfg = get_arch("llama4-maverick-400b-a17b").make_config()
    c = cfg.param_counts()
    assert 3.0e11 < c["total"] < 4.8e11, c
    assert 1.2e10 < c["active"] < 2.4e10, c


def test_flash_block_skip_exact():
    B, S, H, KV, dh = 2, 128, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(7), (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(8), (B, S, KV, dh))
    v = jax.random.normal(jax.random.PRNGKey(9), (B, S, KV, dh))
    a = flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32)
    b = flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32,
                        block_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
