"""RangeReach serving launcher — the paper's production workload.

    PYTHONPATH=src python -m repro.launch.serve --dataset yelp --scale 0.1 \
        --method 2dreach-comp --queries 2000 --engine kernel

Builds the chosen index offline, then serves batched RANGEREACH queries
through one of four engines:

    host      — vectorised NumPy ragged wavefront (paper-equivalent)
    wavefront — jit fixed-capacity R-tree descent (device engine)
    kernel    — the range_query Pallas leaf-scan (interpret on CPU)
    device    — the compile-once QueryEngine: fused on-device pointer
                lookup + hierarchically-pruned Pallas descent
                (2DReach variants only)

Every engine's answers are verified against the host engine before
timing; throughput and per-query latency are reported.  On a mesh the
query batch shards over the data axes (engine fns are pure jit).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import batch_query, build_index, index_nbytes
from ..data import get_dataset, workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="yelp")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--method", default="2dreach-comp")
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--extent", type=float, default=0.05)
    ap.add_argument("--engine", default="host",
                    choices=("host", "wavefront", "kernel", "device"))
    ap.add_argument("--verify", type=int, default=64,
                    help="queries to verify against the BFS oracle")
    args = ap.parse_args()

    g = get_dataset(args.dataset, scale=args.scale)
    print(f"[serve] dataset {args.dataset} x{args.scale}: "
          f"{g.n_nodes} nodes, {g.n_edges} edges, {g.n_spatial} venues")
    t0 = time.perf_counter()
    index = build_index(g, args.method)
    print(f"[serve] built {args.method} in {time.perf_counter() - t0:.2f}s; "
          f"size {index_nbytes(index)['total'] / 1e6:.1f} MB")

    us, rects = workload(g, n_queries=args.queries,
                         extent_ratio=args.extent, seed=1)

    # correctness gate before timing
    if args.verify:
        from ..core import rangereach_oracle_batch

        k = min(args.verify, len(us))
        want = rangereach_oracle_batch(g, us[:k], rects[:k])
        got = batch_query(index, us[:k], rects[:k])
        assert (want == got).all(), "index disagrees with oracle"
        print(f"[serve] verified {k} queries vs BFS oracle")

    if args.engine == "host" or not hasattr(index, "forest"):
        t0 = time.perf_counter()
        ans = batch_query(index, us, rects)
        dt = time.perf_counter() - t0
    elif args.engine == "device":
        from ..core import engine_for

        eng = engine_for(index)
        if eng is None:
            raise SystemExit(
                f"--engine device serves the 2DReach variants only, "
                f"not {args.method}")
        eng.query_batch(us, rects)  # warm up / compile + upload
        t0 = time.perf_counter()
        sub = eng.query_batch(us, rects)
        dt = time.perf_counter() - t0
        ans = batch_query(index, us, rects)
        assert (sub == ans).all(), "device engine mismatch"
        print(f"[serve] device engine: {eng.n_compiles} compiled shapes, "
              f"{eng.stats['tiles_scanned']}/"
              f"{eng.stats['tiles_full_scan']} leaf tiles scanned "
              f"(vs full leaf scan)")
    else:
        tid = index.lookup_tree(us)
        if args.engine == "wavefront":
            from ..core import query_jax_wavefront

            fn = lambda: query_jax_wavefront(index.forest, tid, rects)[0]
        else:
            from ..kernels.range_query.ops import range_query_forest

            fn = lambda: range_query_forest(index.forest, tid, rects)
        sub = fn()   # warm up / compile
        t0 = time.perf_counter()
        sub = fn()
        dt = time.perf_counter() - t0
        host = batch_query(index, us, rects)
        exc = getattr(index, "excluded", None)
        if exc is not None:
            m = ~exc[us]
            assert (sub[m] == host[m]).all(), "engine mismatch"
        ans = host
    print(f"[serve] {args.engine}: {len(us)} queries in {dt * 1e3:.1f} ms "
          f"({dt / len(us) * 1e6:.2f} us/query), "
          f"{int(np.sum(ans))} positive")


if __name__ == "__main__":
    main()
