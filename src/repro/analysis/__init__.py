from .hlo_stats import analyze_hlo
