"""The scan-aware HLO analyzer vs known-FLOPs programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import analyze_hlo


def _stats(fn, *args):
    return analyze_hlo(jax.jit(fn).lower(*args).compile().as_text())


def test_plain_matmul():
    n = 256
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    st = _stats(lambda a, b: a @ b, x, x)
    assert st["flops"] == 2 * n ** 3


def test_scan_multiplies_trip_count():
    n, L = 128, 8
    w = jax.ShapeDtypeStruct((L, n, n), jnp.float32)
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def scanned(w, x):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    st = _stats(scanned, w, x)
    assert st["flops"] == 2 * L * n ** 3


def test_nested_scan():
    n, L1, L2 = 64, 3, 5
    w = jax.ShapeDtypeStruct((L1, L2, n, n), jnp.float32)
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def inner(c, ws):
        return jax.lax.scan(lambda c2, wi: (c2 @ wi, None), c, ws)[0]

    def nested(w, x):
        return jax.lax.scan(lambda c, ws: (inner(c, ws), None), x, w)[0]

    st = _stats(nested, w, x)
    assert st["flops"] == 2 * L1 * L2 * n ** 3


def test_grad_counts_backward():
    n = 128
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def loss(a, b):
        return jnp.sum((a @ b) ** 2)

    st = _stats(jax.grad(loss, argnums=(0, 1)), x, x)
    # fwd dot + two bwd dots
    assert st["flops"] == pytest.approx(3 * 2 * n ** 3, rel=0.01)


def test_flash_attention_flops():
    from repro.models.lm.attention import banded_attention, flash_attention

    B, S, H, dh = 1, 512, 4, 64
    q = jax.ShapeDtypeStruct((B, S, H, dh), jnp.float32)
    st = _stats(
        lambda q: flash_attention(q, q, q, causal=True, blk_q=128,
                                  blk_k=128), q)
    assert st["flops"] == 2 * 2 * B * H * S * S * dh
    # banded attention touches only ceil(w/blk)+1 kv blocks per q block
    w = 128
    st2 = _stats(
        lambda q: banded_attention(q, q, q, window=w, blk=128), q)
    assert st2["flops"] == 2 * 2 * B * H * S * (2 * 128) * dh


def test_collectives_counted():
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    def f(a):
        return jax.lax.psum(a, "x")

    fn = shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P())
    x = jax.ShapeDtypeStruct((64,), jnp.float32)
    st = analyze_hlo(jax.jit(fn).lower(x).compile().as_text())
    # all-reduce result bytes counted (64 * 4 on the 1-dev mesh)
    assert st["collective_bytes"] >= 0  # present and parseable


def test_model_flops_formulas():
    from benchmarks.roofline import model_flops

    for arch, shape in (
        ("h2o-danube-1.8b", "train_4k"),
        ("deepseek-v3-671b", "decode_32k"),
        ("din", "retrieval_cand"),
        ("graphcast", "ogb_products"),
    ):
        mf = model_flops(arch, shape)
        assert mf and mf > 0, (arch, shape)
