"""§Perf hillclimb: the analytics query classes (repro.queries).

Per query class — boolean reach (baseline), RangeCount, RangeCollect,
KNNReach, convex-polygon reach — this bench measures wall-clock per
query on the host NumPy descents vs the compile-once device engine
(Pallas analytics leaf scans; interpret mode on CPU, real kernels on
TPU), after verifying the two paths answer bit-identically.

Outputs: results/perf_queries.json (full rows) and a root-level
BENCH_queries.json summary with per-class host/device latency and the
steady-state compile counts, gated to zero: after the warm pass no
class may trace a new shape.  ``--smoke`` runs a seconds-scale subset
for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro import obs
from repro.core import build_2dreach
from repro.core.engine import engine_for
from repro.data import get_dataset, knn_workload, polygon_workload, workload
from repro.queries import (
    knn_reach_host,
    polygon_reach_host,
    range_collect_host,
    range_count_host,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "results", "perf_queries.json")
BENCH_OUT = os.path.join(ROOT, "BENCH_queries.json")


def _t(fn, repeats=5):
    fn()  # warmup
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _same(kind, a, b) -> bool:
    if kind in ("reach", "count", "polygon"):
        return bool((a == b).all())
    if kind == "collect":
        return bool((a.ids == b.ids).all() and (a.counts == b.counts).all()
                    and (a.overflow == b.overflow).all())
    return bool((a.ids == b.ids).all() and (a.dist2 == b.dist2).all())


def class_sweep(dataset="gowalla", scale=0.5, n_q=2000, k=10,
                repeats=5, variant="comp") -> List[Dict]:
    g = get_dataset(dataset, scale=scale)
    idx = build_2dreach(g, variant=variant)
    eng = engine_for(idx)
    us, rects = workload(g, n_q, extent_ratio=0.05, seed=5)
    kus, pts = knn_workload(g, n_q, seed=6)
    pus, polys = polygon_workload(g, n_q, extent_ratio=0.05, seed=7)
    polys = list(polys)

    cases = {
        "reach": (
            lambda: idx.query_batch(us, rects),
            lambda: eng.query_batch(us, rects),
        ),
        "count": (
            lambda: range_count_host(idx, us, rects),
            lambda: eng.count_batch(us, rects),
        ),
        "collect": (
            lambda: range_collect_host(idx, us, rects, k),
            lambda: eng.collect_batch(us, rects, k),
        ),
        "knn": (
            lambda: knn_reach_host(idx, kus, pts, k),
            lambda: eng.knn_batch(kus, pts, k),
        ),
        "polygon": (
            lambda: polygon_reach_host(idx, pus, polys),
            lambda: eng.polygon_batch(pus, polys),
        ),
    }

    # the classes with a retained two-launch path: timed alongside the
    # fused trace so the artifact carries the fusion win per class
    two_phase = {
        "reach": lambda: eng.query_batch_two_phase(us, rects),
        "count": lambda: eng.count_batch_two_phase(us, rects),
        "collect": lambda: eng.collect_batch_two_phase(us, rects, k),
    }

    # warm every class (shared prepare trace + per-class scans + the
    # candidate/collect-cap high-water marks), then gate on flat compiles
    for kind, (host_fn, dev_fn) in cases.items():
        host_ans = host_fn()
        assert _same(kind, host_ans, dev_fn()), \
            f"{kind}: device answers diverge from host"
        if kind in two_phase:
            assert _same(kind, host_ans, two_phase[kind]()), \
                f"{kind}: two-phase answers diverge from host"
    warm = eng.n_compiles

    def _stage_pass(fn):
        """One instrumented pass after the timed one: per-stage span
        attribution without skewing the us_per_q numbers."""
        was = obs.enabled()
        obs.enable()
        sub0 = obs.stage_totals("engine.")
        fn()
        sub1 = obs.stage_totals("engine.")
        if not was:
            obs.disable()
        return {k2: round(sub1.get(k2, 0.0) - sub0.get(k2, 0.0), 3)
                for k2 in sub1
                if sub1.get(k2, 0.0) > sub0.get(k2, 0.0)}

    rows = []
    for kind, (host_fn, dev_fn) in cases.items():
        compiles0 = eng.n_compiles
        t_host = _t(host_fn, repeats=repeats)
        t_dev = _t(dev_fn, repeats=repeats)
        stage_us = _stage_pass(dev_fn)
        row = dict(
            query_class=kind, variant=variant, n_queries=n_q, k=k,
            host_us_per_q=t_host / n_q * 1e6,
            device_us_per_q=t_dev / n_q * 1e6,
            device_stage_us=stage_us,
        )
        if kind in two_phase:
            t_tp = _t(two_phase[kind], repeats=repeats)
            row["two_phase_us_per_q"] = t_tp / n_q * 1e6
            row["two_phase_stage_us"] = _stage_pass(two_phase[kind])
        row["steady_state_recompiles"] = eng.n_compiles - compiles0
        rows.append(row)
    rows.append(dict(query_class="_all", variant=variant, n_queries=n_q,
                     k=k, host_us_per_q=None, device_us_per_q=None,
                     steady_state_recompiles=eng.n_compiles - warm))
    return rows


def bench_summary(rows: List[Dict]) -> Dict:
    classes = {}
    for r in rows:
        if r["query_class"] == "_all":
            continue
        cls = {
            "host_us_per_q": r["host_us_per_q"],
            "device_us_per_q": r["device_us_per_q"],
            "device_stage_us": r.get("device_stage_us"),
        }
        if r.get("two_phase_us_per_q") is not None:
            cls["two_phase_us_per_q"] = r["two_phase_us_per_q"]
            cls["two_phase_stage_us"] = r.get("two_phase_stage_us")
            cls["fusion_speedup_x"] = (
                r["two_phase_us_per_q"] / r["device_us_per_q"]
                if r["device_us_per_q"] else None)
        classes[r["query_class"]] = cls
    total_rec = int(sum(r["steady_state_recompiles"] for r in rows
                        if r["query_class"] != "_all"))
    return {
        "schema_version": 2,
        "unit": "us_per_query",
        "classes": classes,
        "device_bit_identical_to_host": True,   # asserted before timing
        "steady_state_recompiles": total_rec,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI")
    args = ap.parse_args()

    if args.smoke:
        rows = class_sweep(dataset="yelp", scale=0.1, n_q=256, k=8,
                           repeats=2)
    else:
        rows = class_sweep()
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({"class_sweep": rows}, f, indent=1)
    summary = bench_summary(rows)
    with open(BENCH_OUT, "w") as f:
        json.dump(summary, f, indent=1)
    for r in rows:
        print(r)
    print(json.dumps(summary, indent=1))
    assert summary["steady_state_recompiles"] == 0, \
        "analytics steady-state recompile"
    assert set(summary["classes"]) == {
        "reach", "count", "collect", "knn", "polygon"}, \
        "missing query class in the bench summary"


if __name__ == "__main__":
    main()
