"""§Perf: incremental RangeReach — query latency vs overlay size and
compaction amortisation, for all three 2DReach variants.

A `DynamicIndex` absorbs a stream of updates; each query over the
mutated graph pays the base probe plus overlay work that grows with the
delta buffer.  This benchmark measures

* **latency vs overlay size** — the same 1000-query workload timed at
  growing overlay sizes (updates drawn from ``streaming_workload``);
* **compaction restoration** — post-swap latency vs a *fresh* static
  build over the identical mutated graph (the acceptance bar: within
  10%);
* **amortised compaction cost** — rebuild seconds spread over the
  updates absorbed since the previous swap.

Output: results/perf_dynamic.json.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import batch_query, build_index, rangereach_oracle_batch
from repro.data import (
    apply_stream_op,
    get_dataset,
    streaming_workload,
    workload,
)
from repro.dynamic import NEVER, DynamicIndex

OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "perf_dynamic.json",
)

VARIANTS = ("2dreach", "2dreach-comp", "2dreach-pointer")
OVERLAY_CHECKPOINTS = (0, 64, 256, 1024)


def _t(fn, repeats: int = 5) -> float:
    fn()  # warmup
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def dynamic_sweep(dataset: str = "gowalla", scale: float = 0.1,
                  n_q: int = 1000, seed: int = 7,
                  verify_sample: int = 32) -> Dict:
    g = get_dataset(dataset, scale=scale)
    us, rects = workload(g, n_q, extent_ratio=0.05, seed=seed)

    # update-only stream (queries come from the fixed workload so latency
    # numbers are comparable across overlay sizes)
    ops = [op for op in streaming_workload(
        g, n_steps=3 * max(OVERLAY_CHECKPOINTS), seed=seed,
        p_query=0.0, p_edge=0.6, p_vertex=0.2, p_spatial=0.2,
    )]

    out: Dict[str, List[dict]] = {v: [] for v in VARIANTS}
    for variant in VARIANTS:
        dyn = DynamicIndex(g, variant, policy=NEVER)
        it = iter(ops)
        for target in OVERLAY_CHECKPOINTS:
            while dyn.overlay_size < target:
                apply_stream_op(dyn, next(it))
            dt = _t(lambda: dyn.query_batch(us, rects))
            out[variant].append(dict(
                phase="overlay", overlay_size=dyn.overlay_size,
                us_per_q=dt / n_q * 1e6,
            ))
            print(f"[{variant}] overlay={dyn.overlay_size:5d}  "
                  f"{dt / n_q * 1e6:8.2f} us/q")

        # correctness spot-check on the mutated graph before timing swaps
        gm = dyn.snapshot_graph()
        want = rangereach_oracle_batch(
            gm, us[:verify_sample], rects[:verify_sample]
        )
        got = dyn.query_batch(us[:verify_sample], rects[:verify_sample])
        assert (got == want).all(), f"{variant}: overlay answers wrong"

        # compaction swap
        t0 = time.perf_counter()
        dyn.compact(background=False)
        t_compact = time.perf_counter() - t0
        dt_post = _t(lambda: dyn.query_batch(us, rects), repeats=15)

        # fresh static build over the identical mutated graph
        t0 = time.perf_counter()
        fresh = build_index(gm, variant)
        t_fresh_build = time.perf_counter() - t0
        dt_fresh = _t(lambda: batch_query(fresh, us, rects), repeats=15)
        assert (dyn.query_batch(us[:verify_sample], rects[:verify_sample])
                == want).all(), f"{variant}: post-swap answers wrong"

        rep = dyn.report()
        n_upd = max(1, int(rep["n_updates"]))
        out[variant].append(dict(
            phase="post_compaction",
            overlay_size=dyn.overlay_size,
            us_per_q=dt_post / n_q * 1e6,
            fresh_us_per_q=dt_fresh / n_q * 1e6,
            post_over_fresh=dt_post / dt_fresh,
            t_compaction_s=t_compact,
            t_fresh_build_s=t_fresh_build,
            amortized_compaction_us_per_update=t_compact / n_upd * 1e6,
            n_updates_absorbed=n_upd,
            n_scc_merges=int(rep["n_scc_merges"]),
        ))
        print(f"[{variant}] post-swap {dt_post / n_q * 1e6:8.2f} us/q   "
              f"fresh {dt_fresh / n_q * 1e6:8.2f} us/q   "
              f"ratio {dt_post / dt_fresh:5.2f}   "
              f"compaction {t_compact:6.2f}s over {n_upd} updates "
              f"({t_compact / n_upd * 1e6:7.1f} us/update amortized)")
    return out


def main():
    results = {"dynamic_sweep": dynamic_sweep()}
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[perf_dynamic] wrote {OUT}")


if __name__ == "__main__":
    main()
